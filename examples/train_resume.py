"""Fault-tolerance demo: train, die, resume — bit-exact continuation.

Trains a reduced assigned-architecture model, simulates a node failure at
step 40, restarts from the last committed checkpoint, and verifies the
final parameters equal an uninterrupted run's.

  PYTHONPATH=src python examples/train_resume.py
"""

import shutil
import tempfile

import jax
import numpy as np

from repro.configs import reduced_config
from repro.data.pipeline import make_batch
from repro.optim.adamw import AdamWConfig
from repro.runtime import TrainLoop, TrainLoopConfig

STEPS, DIE_AT = 60, 40
CFG = reduced_config("qwen3_14b")
OPT = AdamWConfig(lr=1e-3, total_steps=STEPS, warmup_steps=3)


def batch_fn(step):
    return jax.tree.map(jax.numpy.asarray,
                        make_batch(CFG, "train", 32, 2, step=step))


def main():
    root = tempfile.mkdtemp(prefix="repro_ft_")
    try:
        print("== uninterrupted run ==")
        ref = TrainLoop(CFG, OPT, TrainLoopConfig(
            ckpt_dir=f"{root}/ref", ckpt_every=20, log_every=20), batch_fn)
        ref_state, m = ref.run(STEPS)
        print(f"   final loss {float(m['loss']):.4f}")

        print(f"== run that dies at step {DIE_AT} ==")
        victim_dir = f"{root}/victim"
        victim = TrainLoop(CFG, OPT, TrainLoopConfig(
            ckpt_dir=victim_dir, ckpt_every=20, log_every=20), batch_fn)
        try:
            victim.run(STEPS, die_at_step=DIE_AT)
        except RuntimeError as e:
            print(f"   {e}")

        print("== restarted process resumes ==")
        resumed = TrainLoop(CFG, OPT, TrainLoopConfig(
            ckpt_dir=victim_dir, ckpt_every=20, log_every=20), batch_fn)
        print(f"   resumed at step {resumed.step}")
        res_state, m = resumed.run(STEPS)

        for a, b in zip(jax.tree.leaves(ref_state["params"]),
                        jax.tree.leaves(res_state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("bit-exact match with the uninterrupted run — OK")
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
