"""End-to-end driver: the paper's target cloud application — image search
over a partitioned graph database, served with batched requests.

The "image encoder" is a stub (fixed random projection of synthetic image
patches -> 128-dim descriptors), standing in for the SIFT/CNN feature
extraction the paper assumes happens upstream. Everything downstream —
partitioned build, HBM-resident serving, stage-2 merge, latency/QPS
accounting — is the real system.

  PYTHONPATH=src python examples/image_search_serving.py
"""

import time

import numpy as np

from repro.api import IndexSpec, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.launch.serve import serve_loop


def stub_image_encoder(images: np.ndarray, dim: int = 128) -> np.ndarray:
    """images [N, 16, 16] -> L2-normalized descriptors [N, dim]."""
    rng = np.random.default_rng(42)
    proj = rng.normal(size=(16 * 16, dim)).astype(np.float32) / 16.0
    feats = np.maximum(images.reshape(len(images), -1) @ proj, 0.0)
    return 100.0 * feats / (np.linalg.norm(feats, axis=1, keepdims=True) + 1e-6)


def main():
    rng = np.random.default_rng(0)
    # synthetic "image library": 6000 images from 24 texture classes
    classes = rng.normal(size=(24, 16, 16)).astype(np.float32)
    labels = rng.integers(0, 24, 6000)
    library = classes[labels] + 0.3 * rng.normal(size=(6000, 16, 16)).astype(np.float32)
    db_vectors = stub_image_encoder(library)

    print("building 4-partition graph database ...")
    t0 = time.time()
    # descriptors are L2-normalized upstream, so cosine is the natural
    # metric — the registry re-normalizes and the kernels minimize 1 - cos.
    engine = SearchService.build(
        db_vectors,
        IndexSpec(metric="cosine", backend="partitioned", num_partitions=4,
                  hnsw=HNSWConfig(M=16, ef_construction=100)))
    print(f"  built in {time.time()-t0:.1f}s")

    # query stream: noisy views of library images
    q_idx = rng.integers(0, 6000, 256)
    q_images = library[q_idx] + 0.3 * rng.normal(size=(256, 16, 16)).astype(np.float32)
    queries = stub_image_encoder(q_images)

    ids, stats = serve_loop(engine, queries, batch=32, k=10, ef=40)

    # task metric: does the top-10 contain same-class images?
    hit = np.mean([
        np.mean(labels[ids[i][ids[i] >= 0]] == labels[q_idx[i]])
        for i in range(len(q_idx))])
    print(f"same-class hit-rate in top-10: {hit:.3f}")
    assert hit > 0.5
    print("OK")


if __name__ == "__main__":
    main()
