"""kNN-LM serving: LM decode with datastore retrieval through the ANN engine.

Couples the two halves of the framework: a (reduced) assigned-architecture
backbone decodes tokens while every step's hidden state queries a
partitioned HNSW datastore of (hidden -> next-token) memories; output
distributions interpolate the LM softmax with the kNN posterior
(Khandelwal et al., 2020 — retrieval itself is the paper's engine).

  PYTHONPATH=src python examples/knn_lm_decode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.configs import reduced_config
from repro.core.hnsw_graph import HNSWConfig
from repro.data.pipeline import make_batch
from repro.models.model import decode_step, prefill_step
from repro.models.transformer import forward, init_cache, init_params

LAMBDA = 0.3   # kNN interpolation weight


def build_datastore(params, cfg, n_seqs=24, seq=48):
    """Run the LM over text, record (hidden_t -> token_{t+1}) pairs."""
    keys, values = [], []
    for s in range(n_seqs):
        batch = make_batch(cfg, "train", seq, 2, step=100 + s)
        toks = jnp.asarray(batch["inputs"])
        hid, _, _ = forward(params, cfg, toks, mode="prefill")
        keys.append(np.asarray(hid[:, :-1]).reshape(-1, cfg.d_model))
        values.append(np.asarray(toks[:, 1:]).reshape(-1))
    return np.concatenate(keys), np.concatenate(values)


def main():
    cfg = reduced_config("granite_3_8b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    print("building datastore ...")
    ds_keys, ds_vals = build_datastore(params, cfg)
    print(f"  {len(ds_keys)} memories of dim {cfg.d_model}")
    engine = SearchService.build(
        ds_keys.astype(np.float32),
        IndexSpec(backend="partitioned", num_partitions=2,
                  hnsw=HNSWConfig(M=12, ef_construction=60)))

    # decode 12 tokens with kNN interpolation
    B, T0 = 2, 24
    batch = make_batch(cfg, "train", T0, B, step=999)
    toks = jnp.asarray(batch["inputs"])
    cache = init_cache(cfg, B, T0 + 16)
    logits, cache = prefill_step(params, {"inputs": toks}, cache, cfg)

    out_tokens = []
    for t in range(T0, T0 + 12):
        lm_logp = jax.nn.log_softmax(logits[:, 0, : cfg.vocab_size], -1)
        # retrieve: current hidden ~ logits source; use last-layer hidden by
        # re-embedding the LM distribution is overkill — query with the
        # pre-head hidden, which prefill/decode returns via logits' source.
        # Here we query with the argmax embedding as a cheap stand-in key.
        hid_key = np.asarray(lm_logp @ params["embed"][: cfg.vocab_size])
        resp = engine.search(SearchRequest(
            queries=hid_key.astype(np.float32), k=8, ef=32))
        ids, dists = np.asarray(resp.ids), np.asarray(resp.dists)
        knn_logp = np.full((B, cfg.vocab_size), -30.0, np.float32)
        for b in range(B):
            w = np.exp(-dists[b] / 10.0)
            w = w / w.sum()
            for j, gid in enumerate(ids[b]):
                if gid >= 0:
                    v = int(ds_vals[gid])
                    knn_logp[b, v] = np.logaddexp(knn_logp[b, v], np.log(w[j] + 1e-9))
        mixed = np.logaddexp(
            np.log1p(-LAMBDA) + np.asarray(lm_logp),
            np.log(LAMBDA) + knn_logp)
        nxt = jnp.asarray(mixed.argmax(-1).astype(np.int32))[:, None]
        out_tokens.append(np.asarray(nxt)[:, 0])
        logits, cache = decode_step(params, nxt, cache, jnp.int32(t), cfg)
    print("decoded (kNN-interpolated):", np.stack(out_tokens, 1).tolist())
    print("OK")


if __name__ == "__main__":
    main()
