"""Quickstart for the unified `repro.api` search service.

The whole public surface is three objects:

  IndexSpec     — what to build: metric (l2 / ip / cosine), backend
                  (exact / hnsw / partitioned / distributed / csd),
                  partition count, HNSW knobs, vector dtype
                  (float32 / uint8 / int8)
  SearchRequest — one batched call: k, ef, rerank, with_stats
  SearchService — build/load once, search many times, versioned save()

This script builds the paper's two-stage partitioned engine (§4.1) at its
SIFT1B operating point (K=10, ef=40), verifies recall against the exact
backend, repeats the exercise under the cosine metric to show the metric
registry end to end, and finally rebuilds the index quantized to uint8 —
the precision the paper's billion-scale result actually runs at.

  PYTHONPATH=src python examples/quickstart.py [--n 5000 --dim 128]

(--n/--dim shrink the dataset; CI runs the README's tiny-data command.)
"""

import argparse

import numpy as np

from repro.api import IndexSpec, SearchRequest, SearchService, exact_topk_np
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset


def recall_at_k(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    return float(np.mean(
        [len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--partitions", type=int, default=4)
    args = ap.parse_args()

    # 1) a SIFT-like dataset (clustered features)
    ds = VectorDataset(n=args.n, dim=args.dim, n_clusters=32, seed=0)
    vectors = ds.vectors()
    queries = ds.queries(32)

    # 2) build the two-stage partitioned engine (paper §4.1): P sub-graphs,
    #    each independently searchable / independently placeable in HBM.
    spec = IndexSpec(backend="partitioned", num_partitions=args.partitions,
                     hnsw=HNSWConfig(M=16, ef_construction=100),
                     keep_vectors=True)
    svc = SearchService.build(vectors, spec)

    # 3) search (stage 1 per-partition + stage 2 merge) at the paper's
    #    SIFT1B operating point: K=10, ef=40. rerank=True folds the paper's
    #    host-side stage-2 brute force into one batched device call.
    resp = svc.search(SearchRequest(queries=queries, k=10, ef=40,
                                    rerank=True, with_stats=True))
    ids = np.asarray(resp.ids)

    # 4) verify against the exact backend (paper Fig. 9 baseline).
    gt = exact_topk_np("l2", vectors, queries, 10)
    r = recall_at_k(ids, gt, 10)
    reads = float(np.mean(np.asarray(resp.stats.dist_calcs)))
    print(f"l2     recall@10 (ef=40, {args.partitions} partitions): {r:.3f}  "
          f"(~{reads:.0f} vector reads/query of {len(vectors)})")
    assert r >= 0.9

    # 5) same engine, cosine metric: the registry normalizes the data and
    #    the queries at the edge; the graph kernels minimize 1 - cos.
    svc_cos = SearchService.build(
        vectors, IndexSpec(metric="cosine", backend="partitioned",
                           num_partitions=args.partitions,
                           hnsw=HNSWConfig(M=16, ef_construction=100)))
    ids_cos = np.asarray(svc_cos.search(
        SearchRequest(queries=queries, k=10, ef=40)).ids)
    gt_cos = exact_topk_np("cosine", vectors, queries, 10)
    r_cos = recall_at_k(ids_cos, gt_cos, 10)
    print(f"cosine recall@10 (ef=40, {args.partitions} partitions): "
          f"{r_cos:.3f}")
    assert r_cos >= 0.9

    # 6) the paper's actual SIFT1B precision: uint8 vectors. The service
    #    fits a symmetric scalar quantizer (scale/zero-point land in the
    #    index manifest), stores 1-byte codes everywhere, traverses in
    #    integer code space, and keeps stage-2 rerank in float32 over
    #    dequantized rows.
    svc_u8 = SearchService.build(
        vectors, IndexSpec(backend="partitioned", dtype="uint8",
                           num_partitions=args.partitions,
                           hnsw=HNSWConfig(M=16, ef_construction=100),
                           keep_vectors=True))
    ids_u8 = np.asarray(svc_u8.search(
        SearchRequest(queries=queries, k=10, ef=40, rerank=True)).ids)
    r_u8 = recall_at_k(ids_u8, gt, 10)
    print(f"uint8  recall@10 (ef=40, {args.partitions} partitions): "
          f"{r_u8:.3f}  (scale={svc_u8.spec.qscale:.4g}, "
          f"zero_point={svc_u8.spec.qzero}, 1 byte/dim)")
    assert r_u8 >= 0.85

    print(f"first query -> ids {ids[0][:5]} "
          f"dists {np.asarray(resp.dists)[0][:5].round(1)}")
    print("OK")


if __name__ == "__main__":
    main()
