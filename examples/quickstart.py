"""Quickstart: build a partitioned HNSW engine, search, verify vs exact.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.engine import ANNEngine
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset


def main():
    # 1) a SIFT-like dataset (clustered 128-dim features)
    ds = VectorDataset(n=5000, dim=128, n_clusters=32, seed=0)
    vectors = ds.vectors()
    queries = ds.queries(32)

    # 2) build the two-stage partitioned engine (paper §4.1): 4 sub-graphs,
    #    each independently searchable / independently placeable in HBM.
    engine = ANNEngine.build(vectors, num_partitions=4,
                             cfg=HNSWConfig(M=16, ef_construction=100))

    # 3) search (stage 1 per-partition + stage 2 merge) at the paper's
    #    SIFT1B operating point: K=10, ef=40.
    ids, dists = engine.search(queries, k=10, ef=40)
    ids = np.asarray(ids)

    # 4) verify against the exact brute-force baseline (paper Fig. 9).
    gt_ids, _ = engine.bruteforce(queries, k=10)
    gt_ids = np.asarray(gt_ids)
    recall = np.mean([len(set(ids[b]) & set(gt_ids[b])) / 10
                      for b in range(len(queries))])
    print(f"recall@10 (ef=40, 4 partitions): {recall:.3f}")
    print(f"first query -> ids {ids[0][:5]} dists {np.asarray(dists)[0][:5].round(1)}")
    assert recall >= 0.9
    print("OK")


if __name__ == "__main__":
    main()
