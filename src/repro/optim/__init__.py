from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.compression import (
    CompressionConfig,
    PQQuantizer,
    VectorQuantizer,
    build_pq_lut,
    compress_grads,
    decompress_grads,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
    "CompressionConfig", "compress_grads", "decompress_grads",
    "VectorQuantizer", "PQQuantizer", "build_pq_lut",
]
