"""Quantization utilities: the ANN vector quantizer + gradient compression.

Two users share this module:

1. `VectorQuantizer` — the symmetric scalar quantizer behind the search
   service's uint8/int8 vector path (`IndexSpec.dtype`). The paper's
   headline SIFT1B result runs on **uint8 vectors** (1 byte/dim is what
   makes a billion points fit the SmartSSD, and the accelerator's distance
   units consume integer data); this is the software analogue. One scale
   and one zero-point cover the whole dataset (stored in the index
   manifest via `IndexSpec.qscale`/`qzero`), codes are
   `clip(round(x/scale) + zero_point)`, and squared-L2 in *code space*
   equals `scale**2 *` real-space squared-L2 up to rounding — the
   zero-point cancels in differences, so ranking is preserved and a
   single `scale**2` multiply converts code distances back to real units.

2. int8 error-feedback gradient compression for cross-pod all-reduce:
   at 1000+ nodes the `pod` axis rides DCI links an order of magnitude
   slower than ICI; compressing the pod-axis all-reduce 4x (f32 -> int8 +
   per-tensor scale) trades negligible accuracy (error feedback keeps the
   quantization residual and re-injects it next step) for 4x less
   cross-pod traffic.

       g_q, scales, err = compress_grads(grads, err)
       g_q = lax.psum(g_q_as_int32, 'pod')   # cheap collective
       grads = decompress_grads(g_q, scales, n_pods)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CompressionConfig", "compress_grads", "decompress_grads",
           "VectorQuantizer", "PQQuantizer", "CODE_DTYPES", "code_dtype",
           "PQ_K", "build_pq_lut"]


# ---------------------------------------------------------------------------
# Vector quantization (the ANN uint8/int8 path)
# ---------------------------------------------------------------------------

# dtype name -> (lowest code, highest code, numpy dtype)
CODE_DTYPES: dict[str, tuple[int, int, np.dtype]] = {
    "uint8": (0, 255, np.dtype(np.uint8)),
    "int8": (-127, 127, np.dtype(np.int8)),
}


def code_dtype(name: str) -> np.dtype:
    """Numpy dtype of the stored codes for a quantized IndexSpec.dtype."""
    if name == "pq":
        return np.dtype(np.uint8)
    try:
        return CODE_DTYPES[name][2]
    except KeyError:
        raise ValueError(
            f"unknown quantized dtype {name!r}; "
            f"available: {sorted(CODE_DTYPES) + ['pq']}") from None


@dataclasses.dataclass(frozen=True)
class VectorQuantizer:
    """Symmetric scalar quantizer: x ≈ (code - zero_point) * scale.

    `fit` picks the scale so the observed range maps onto the full code
    range; the zero-point is *fixed by the dtype and the data's sign*
    (0 for int8 and for non-negative uint8 data — SIFT-style byte vectors
    with integer values and max 255 then round-trip exactly — 128 for
    signed data stored as uint8). It is never tuned per value, which is
    what makes the quantizer symmetric: real-space differences map to
    code-space differences by a pure `1/scale` scaling, so squared-L2
    ranking is preserved and `dist_scale == scale**2` converts code-space
    distances back to real units.

    Round-trip bound (values inside the representable range):
        |x - decode(encode(x))| <= scale / 2        (per component)

    `encode` is plain numpy (round-half-even, then clip) — every backend
    funnels through this one function, which is what makes the quantized
    `partitioned` and `csd` engines bit-identical.
    """

    dtype: str            # "uint8" | "int8"
    scale: float
    zero_point: int

    @classmethod
    def fit(cls, vectors: np.ndarray, dtype: str) -> "VectorQuantizer":
        lo, hi, _ = CODE_DTYPES[dtype]  # validates dtype
        x = np.asarray(vectors, np.float32)
        if dtype == "uint8" and float(x.min(initial=0.0)) >= 0.0:
            zero_point = 0
            scale = float(x.max(initial=0.0)) / hi
        else:
            # symmetric around 0; uint8 parks 0 at code 128
            zero_point = 128 if dtype == "uint8" else 0
            span = min(hi - zero_point, zero_point - lo) or hi
            scale = float(np.abs(x).max(initial=0.0)) / span
        return cls(dtype=dtype, scale=max(scale, 1e-12),
                   zero_point=zero_point)

    @property
    def dist_scale(self) -> float:
        """Multiply a code-space squared-L2 distance by this to get the
        (approximate) real-space squared-L2 distance."""
        return self.scale * self.scale

    def encode(self, x: np.ndarray) -> np.ndarray:
        """float32 -> codes (np.uint8 / np.int8)."""
        lo, hi, np_dt = CODE_DTYPES[self.dtype]
        q = np.round(np.asarray(x, np.float32) / self.scale) + self.zero_point
        return np.clip(q, lo, hi).astype(np_dt)

    def encode_f32(self, x: np.ndarray) -> np.ndarray:
        """Codes as float32 (the query-side representation: the search
        kernels consume code-valued f32 arrays)."""
        return self.encode(x).astype(np.float32)

    def decode(self, codes) -> np.ndarray:
        """Codes (any int/float array, numpy or jax) -> float32 values.
        (c - zp) * scale in f32 — one rounding, identical wherever run."""
        if isinstance(codes, np.ndarray):
            return ((codes.astype(np.float32) - np.float32(self.zero_point))
                    * np.float32(self.scale))
        return ((codes.astype(jnp.float32) - jnp.float32(self.zero_point))
                * jnp.float32(self.scale))

    def to_json(self) -> dict:
        return {"dtype": self.dtype, "scale": self.scale,
                "zero_point": self.zero_point}

    @classmethod
    def from_json(cls, d: dict) -> "VectorQuantizer":
        return cls(dtype=d["dtype"], scale=float(d["scale"]),
                   zero_point=int(d["zero_point"]))


# ---------------------------------------------------------------------------
# Product quantization (the ANN dtype="pq" path)
# ---------------------------------------------------------------------------

PQ_K = 256  # centroids per subspace; one uint8 code per subspace


@jax.jit
def build_pq_lut(queries: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Per-query ADC lookup tables: [B, d] x [m, 256, dsub] -> [B, m, 256].

    lut[b, m, c] = ||q_b[sub m] - codebook[m, c]||^2 in f32. This is THE
    canonical LUT build: every backend (partitioned, distributed, csd,
    exact) funnels through this one jitted function, which — together with
    the fixed gather + `jnp.sum(..., axis=-1)` accumulation in
    `core.search` — is what makes PQ distances bit-identical everywhere.
    Do not re-derive the LUT with a different expansion (e.g.
    `q@q - 2 q@c + c@c`): a different reduction order gives last-ulp
    differences and breaks the csd==partitioned==cluster contract.
    """
    b = queries.shape[0]
    m, k, dsub = codebooks.shape
    qs = queries.astype(jnp.float32).reshape(b, m, 1, dsub)
    diff = qs - codebooks.astype(jnp.float32)[None]
    return jnp.sum(diff * diff, axis=-1)


@dataclasses.dataclass(frozen=True, eq=False)
class PQQuantizer:
    """Product quantizer: d dims -> m uint8 codes (one per subspace).

    Each vector is split into `m` contiguous subspaces of `dsub = d/m`
    dims; each subspace is snapped to the nearest of 256 k-means
    centroids. A row shrinks from `4*d` bytes (or `d` bytes at uint8) to
    `m` bytes — 16x vs uint8 at m=8, d=128 — which is what fits
    SIFT1B-class databases in HBM or a small PageCache footprint.

    Distances are *asymmetric* (ADC): the query stays float32, and
    `adc(q, codes) == ||q - decode(codes)||^2` exactly — computed as a
    per-query [m, 256] lookup table (`build_pq_lut`) followed by a
    table-gather + sum over subspaces. Codebooks ride the index manifest
    (format_version 3) as nested JSON lists; float32 -> repr -> float32
    round-trips exactly, so a reloaded index reproduces bit-identical
    distances.

    `fit` is deterministic under a pinned seed: centroid init is an
    `np.random.default_rng(seed)` row sample and Lloyd updates use
    `np.add.at`/`bincount` (sequential, order-stable) — the same data and
    seed always yield the same codebooks.
    """

    m: int
    dsub: int
    codebooks: np.ndarray  # [m, 256, dsub] float32

    @classmethod
    def fit(cls, vectors: np.ndarray, m: int, *, iters: int = 10,
            seed: int = 0) -> "PQQuantizer":
        x = np.asarray(vectors, np.float32)
        if x.ndim != 2:
            raise ValueError(f"fit expects [n, d] vectors, got {x.shape}")
        n, d = x.shape
        if m <= 0 or d % m != 0:
            raise ValueError(
                f"pq_m={m} must be a positive divisor of dim={d}")
        dsub = d // m
        rng = np.random.default_rng(seed)
        codebooks = np.empty((m, PQ_K, dsub), np.float32)
        for mi in range(m):
            sub = np.ascontiguousarray(x[:, mi * dsub:(mi + 1) * dsub])
            idx = rng.choice(n, size=PQ_K, replace=n < PQ_K)
            cb = sub[idx].astype(np.float32)
            sub_sq = np.einsum("nd,nd->n", sub, sub)
            for _ in range(iters):
                # n x 256 assignment via the expanded form (argmin is
                # invariant to the q^2 term, kept for numeric sanity)
                d2 = (sub_sq[:, None] - 2.0 * (sub @ cb.T)
                      + np.einsum("kd,kd->k", cb, cb)[None])
                assign = d2.argmin(axis=1)
                counts = np.bincount(assign, minlength=PQ_K)
                sums = np.zeros((PQ_K, dsub), np.float64)
                np.add.at(sums, assign, sub)
                live = counts > 0
                cb[live] = (sums[live] / counts[live, None]).astype(
                    np.float32)
            codebooks[mi] = cb
        return cls(m=m, dsub=dsub, codebooks=codebooks)

    @property
    def dim(self) -> int:
        return self.m * self.dsub

    @property
    def dist_scale(self) -> float:
        """ADC distances are already real-space squared-L2 (to the
        reconstruction) — no code-space rescale."""
        return 1.0

    def encode(self, x: np.ndarray) -> np.ndarray:
        """float32 [n, d] -> codes [n, m] uint8 (nearest centroid per
        subspace; numpy argmin takes the first minimum, so encoding is
        deterministic)."""
        x = np.asarray(x, np.float32)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[None]
        if x.shape[-1] != self.dim:
            raise ValueError(
                f"expected dim {self.dim}, got {x.shape[-1]}")
        codes = np.empty((x.shape[0], self.m), np.uint8)
        for mi in range(self.m):
            sub = x[:, mi * self.dsub:(mi + 1) * self.dsub]
            cb = self.codebooks[mi]
            d2 = (np.einsum("nd,nd->n", sub, sub)[:, None]
                  - 2.0 * (sub @ cb.T)
                  + np.einsum("kd,kd->k", cb, cb)[None])
            codes[:, mi] = d2.argmin(axis=1).astype(np.uint8)
        return codes[0] if squeeze else codes

    def decode(self, codes) -> np.ndarray:
        """Codes [..., m] (numpy or jax) -> float32 [..., d]
        reconstructions (centroid concatenation)."""
        if isinstance(codes, np.ndarray):
            parts = [self.codebooks[mi][codes[..., mi].astype(np.int64)]
                     for mi in range(self.m)]
            return np.concatenate(parts, axis=-1).astype(np.float32)
        cbs = jnp.asarray(self.codebooks)
        parts = [cbs[mi][codes[..., mi].astype(jnp.int32)]
                 for mi in range(self.m)]
        return jnp.concatenate(parts, axis=-1).astype(jnp.float32)

    def lut_np(self, q: np.ndarray) -> np.ndarray:
        """Numpy twin of `build_pq_lut` for ONE query: [d] -> [m, 256].

        Prediction-only (the csd shadow planner): last-ulp drift vs the
        jitted build is tolerated there because mispredicted supersteps
        roll back. Never feed this into a distance the engine reports.
        """
        q = np.asarray(q, np.float32).reshape(self.m, 1, self.dsub)
        diff = q - self.codebooks
        return np.sum(diff * diff, axis=-1, dtype=np.float32)

    def to_json(self) -> dict:
        return {"m": self.m, "dsub": self.dsub,
                "codebooks": self.codebooks.astype(np.float32).tolist()}

    @classmethod
    def from_json(cls, d: dict) -> "PQQuantizer":
        cb = np.asarray(d["codebooks"], np.float32)
        return cls(m=int(d["m"]), dsub=int(d["dsub"]), codebooks=cb)


# ---------------------------------------------------------------------------
# Gradient compression (training substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8


def _q(x, err):
    x = x.astype(jnp.float32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compress_grads(grads, err_state=None):
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state) if err_state is not None else [None] * len(leaves)
    qs, scales, new_errs = zip(*[_q(g, e) for g, e in zip(leaves, errs)])
    return (
        jax.tree.unflatten(tdef, qs),
        jax.tree.unflatten(tdef, scales),
        jax.tree.unflatten(tdef, new_errs),
    )


def decompress_grads(q_grads, scales, denom: float = 1.0):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s / denom, q_grads, scales)
