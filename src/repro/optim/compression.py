"""Quantization utilities: the ANN vector quantizer + gradient compression.

Two users share this module:

1. `VectorQuantizer` — the symmetric scalar quantizer behind the search
   service's uint8/int8 vector path (`IndexSpec.dtype`). The paper's
   headline SIFT1B result runs on **uint8 vectors** (1 byte/dim is what
   makes a billion points fit the SmartSSD, and the accelerator's distance
   units consume integer data); this is the software analogue. One scale
   and one zero-point cover the whole dataset (stored in the index
   manifest via `IndexSpec.qscale`/`qzero`), codes are
   `clip(round(x/scale) + zero_point)`, and squared-L2 in *code space*
   equals `scale**2 *` real-space squared-L2 up to rounding — the
   zero-point cancels in differences, so ranking is preserved and a
   single `scale**2` multiply converts code distances back to real units.

2. int8 error-feedback gradient compression for cross-pod all-reduce:
   at 1000+ nodes the `pod` axis rides DCI links an order of magnitude
   slower than ICI; compressing the pod-axis all-reduce 4x (f32 -> int8 +
   per-tensor scale) trades negligible accuracy (error feedback keeps the
   quantization residual and re-injects it next step) for 4x less
   cross-pod traffic.

       g_q, scales, err = compress_grads(grads, err)
       g_q = lax.psum(g_q_as_int32, 'pod')   # cheap collective
       grads = decompress_grads(g_q, scales, n_pods)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CompressionConfig", "compress_grads", "decompress_grads",
           "VectorQuantizer", "CODE_DTYPES", "code_dtype"]


# ---------------------------------------------------------------------------
# Vector quantization (the ANN uint8/int8 path)
# ---------------------------------------------------------------------------

# dtype name -> (lowest code, highest code, numpy dtype)
CODE_DTYPES: dict[str, tuple[int, int, np.dtype]] = {
    "uint8": (0, 255, np.dtype(np.uint8)),
    "int8": (-127, 127, np.dtype(np.int8)),
}


def code_dtype(name: str) -> np.dtype:
    """Numpy dtype of the stored codes for a quantized IndexSpec.dtype."""
    try:
        return CODE_DTYPES[name][2]
    except KeyError:
        raise ValueError(
            f"unknown quantized dtype {name!r}; "
            f"available: {sorted(CODE_DTYPES)}") from None


@dataclasses.dataclass(frozen=True)
class VectorQuantizer:
    """Symmetric scalar quantizer: x ≈ (code - zero_point) * scale.

    `fit` picks the scale so the observed range maps onto the full code
    range; the zero-point is *fixed by the dtype and the data's sign*
    (0 for int8 and for non-negative uint8 data — SIFT-style byte vectors
    with integer values and max 255 then round-trip exactly — 128 for
    signed data stored as uint8). It is never tuned per value, which is
    what makes the quantizer symmetric: real-space differences map to
    code-space differences by a pure `1/scale` scaling, so squared-L2
    ranking is preserved and `dist_scale == scale**2` converts code-space
    distances back to real units.

    Round-trip bound (values inside the representable range):
        |x - decode(encode(x))| <= scale / 2        (per component)

    `encode` is plain numpy (round-half-even, then clip) — every backend
    funnels through this one function, which is what makes the quantized
    `partitioned` and `csd` engines bit-identical.
    """

    dtype: str            # "uint8" | "int8"
    scale: float
    zero_point: int

    @classmethod
    def fit(cls, vectors: np.ndarray, dtype: str) -> "VectorQuantizer":
        lo, hi, _ = CODE_DTYPES[dtype]  # validates dtype
        x = np.asarray(vectors, np.float32)
        if dtype == "uint8" and float(x.min(initial=0.0)) >= 0.0:
            zero_point = 0
            scale = float(x.max(initial=0.0)) / hi
        else:
            # symmetric around 0; uint8 parks 0 at code 128
            zero_point = 128 if dtype == "uint8" else 0
            span = min(hi - zero_point, zero_point - lo) or hi
            scale = float(np.abs(x).max(initial=0.0)) / span
        return cls(dtype=dtype, scale=max(scale, 1e-12),
                   zero_point=zero_point)

    @property
    def dist_scale(self) -> float:
        """Multiply a code-space squared-L2 distance by this to get the
        (approximate) real-space squared-L2 distance."""
        return self.scale * self.scale

    def encode(self, x: np.ndarray) -> np.ndarray:
        """float32 -> codes (np.uint8 / np.int8)."""
        lo, hi, np_dt = CODE_DTYPES[self.dtype]
        q = np.round(np.asarray(x, np.float32) / self.scale) + self.zero_point
        return np.clip(q, lo, hi).astype(np_dt)

    def encode_f32(self, x: np.ndarray) -> np.ndarray:
        """Codes as float32 (the query-side representation: the search
        kernels consume code-valued f32 arrays)."""
        return self.encode(x).astype(np.float32)

    def decode(self, codes) -> np.ndarray:
        """Codes (any int/float array, numpy or jax) -> float32 values.
        (c - zp) * scale in f32 — one rounding, identical wherever run."""
        if isinstance(codes, np.ndarray):
            return ((codes.astype(np.float32) - np.float32(self.zero_point))
                    * np.float32(self.scale))
        return ((codes.astype(jnp.float32) - jnp.float32(self.zero_point))
                * jnp.float32(self.scale))

    def to_json(self) -> dict:
        return {"dtype": self.dtype, "scale": self.scale,
                "zero_point": self.zero_point}

    @classmethod
    def from_json(cls, d: dict) -> "VectorQuantizer":
        return cls(dtype=d["dtype"], scale=float(d["scale"]),
                   zero_point=int(d["zero_point"]))


# ---------------------------------------------------------------------------
# Gradient compression (training substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8


def _q(x, err):
    x = x.astype(jnp.float32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compress_grads(grads, err_state=None):
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state) if err_state is not None else [None] * len(leaves)
    qs, scales, new_errs = zip(*[_q(g, e) for g, e in zip(leaves, errs)])
    return (
        jax.tree.unflatten(tdef, qs),
        jax.tree.unflatten(tdef, scales),
        jax.tree.unflatten(tdef, new_errs),
    )


def decompress_grads(q_grads, scales, denom: float = 1.0):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s / denom, q_grads, scales)
