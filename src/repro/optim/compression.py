"""int8 error-feedback gradient compression for cross-pod all-reduce.

At 1000+ nodes the `pod` axis rides DCI links an order of magnitude slower
than ICI; compressing the pod-axis all-reduce 4x (f32 -> int8 + per-tensor
scale) trades negligible accuracy (error feedback keeps the quantization
residual and re-injects it next step) for 4x less cross-pod traffic.

Usage in the train step:
    g_q, scales, err = compress_grads(grads, err)
    g_q = lax.psum(g_q_as_int32, 'pod')   # cheap collective
    grads = decompress_grads(g_q, scales, n_pods)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "compress_grads", "decompress_grads"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8


def _q(x, err):
    x = x.astype(jnp.float32) + (err if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compress_grads(grads, err_state=None):
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state) if err_state is not None else [None] * len(leaves)
    qs, scales, new_errs = zip(*[_q(g, e) for g, e in zip(leaves, errs)])
    return (
        jax.tree.unflatten(tdef, qs),
        jax.tree.unflatten(tdef, scales),
        jax.tree.unflatten(tdef, new_errs),
    )


def decompress_grads(q_grads, scales, denom: float = 1.0):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s / denom, q_grads, scales)
