"""Cost-model calibration: fit HW parameters from measured telemetry.

`launch/costmodel.py` prices queries from hand-entered `HW` constants
(§6.5's SSD-bandwidth-bound regime: a guessed `ssd_bw`, a guessed cache
hit rate, zero dispatch overhead). After this module, the constants come
from the system itself: point `calibrate()` at a REGISTRY snapshot (the
JSON the `PeriodicExporter` / `write_snapshot` emit) and get back the
parameters the workload actually exhibited —

    cache_hit_rate        store_cache hits / (hits + misses)
    effective_ssd_bw      flash bytes actually read / seconds spent in
                          store-read spans (the continuous profiler's
                          `profile_stage_ms{stage="store-read"}` sum)
    blocks_per_query      demand block accesses per csd query
    dispatch_overhead_s   per-superstep host time NOT inside the hop
                          kernel: (superstep span time - hop-kernel span
                          time) / supersteps — the host<->device sync tax
                          the fused-hop work amortizes
    hops/supersteps/bytes per query, from the csd_* counters

`compare_terms()` then prices the measured workload through the analytic
model twice — once with the HW priors, once with the fitted parameters —
and reports per-term modeled-vs-measured relative error (storage,
fanout, dispatch). `ann_dryrun --calibrated <metrics.json>` surfaces
exactly this table, so capacity planning runs on observed numbers
(ROADMAP item 5).

Requires a snapshot taken while the continuous profiler was on (the
default) and csd traffic flowed; missing inputs yield None fields rather
than errors, and `compare_terms` marks those terms unavailable.
"""

from __future__ import annotations

import dataclasses
import json

__all__ = ["Calibration", "calibrate", "load_calibration", "compare_terms"]


# -- snapshot accessors ------------------------------------------------------

def _counter_sum(snap: dict, name: str) -> float | None:
    """Sum of a counter over all label sets; None when absent entirely."""
    vals = [s["value"] for s in snap.get("counters", ())
            if s["name"] == name]
    return float(sum(vals)) if vals else None


def _gauge_max(snap: dict, name: str) -> float | None:
    vals = [s["value"] for s in snap.get("gauges", ())
            if s["name"] == name]
    return float(max(vals)) if vals else None


def _hist_totals(snap: dict, name: str, **labels) -> tuple[float, int]:
    """(sum, count) over histograms matching `name` + label subset."""
    tot, n = 0.0, 0
    for h in snap.get("histograms", ()):
        if h["name"] != name:
            continue
        if any(h["labels"].get(k) != v for k, v in labels.items()):
            continue
        tot += h["sum"]
        n += h["count"]
    return tot, n


# -- the fit -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    """Fitted workload/hardware parameters (None = not in the snapshot)."""

    queries: int | None
    cache_hit_rate: float | None
    effective_ssd_bw: float | None       # bytes/s through store-read spans
    blocks_per_query: float | None       # demand block accesses / query
    bytes_per_query: float | None        # flash bytes / query
    hops_per_query: float | None
    supersteps_per_query: float | None
    dispatch_overhead_s: float | None    # host s per superstep, ex-kernel
    store_read_s: float | None           # total wall s inside store reads
    graph_degree: int | None             # csd m0_pad (padded out-degree)
    vector_row_bytes: int | None
    block_size: int | None
    source: dict = dataclasses.field(default_factory=dict)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


def calibrate(snapshot: dict) -> Calibration:
    """Fit a `Calibration` from one REGISTRY snapshot (see module doc)."""
    hits = _counter_sum(snapshot, "store_cache_hits_total")
    misses = _counter_sum(snapshot, "store_cache_misses_total")
    flash_bytes = _counter_sum(snapshot, "store_bytes_read_total")
    queries = _counter_sum(snapshot, "csd_queries_total")
    hops = _counter_sum(snapshot, "csd_hops_total")
    steps = _counter_sum(snapshot, "csd_supersteps_total")

    store_ms, store_n = _hist_totals(snapshot, "profile_stage_ms",
                                     stage="store-read")
    # superstep wall time: "hop_superstep" on the fused path, "hop" on the
    # unfused path (there each hop IS one superstep / host sync)
    sup_ms, sup_n = _hist_totals(snapshot, "profile_stage_ms",
                                 stage="hop_superstep")
    hop_ms, hop_n = _hist_totals(snapshot, "profile_stage_ms", stage="hop")
    kern_ms, _ = _hist_totals(snapshot, "profile_stage_ms",
                              stage="hop-kernel")
    sup_ms += hop_ms
    sup_n += hop_n

    demand = (hits + misses) if hits is not None and misses is not None \
        else None
    hit_rate = (hits / demand) if demand else None
    store_read_s = store_ms / 1e3 if store_n else None
    eff_bw = (flash_bytes / store_read_s
              if flash_bytes and store_read_s else None)
    q = int(queries) if queries else None
    dispatch = (max(0.0, sup_ms - kern_ms) / 1e3 / sup_n) if sup_n else None

    return Calibration(
        queries=q,
        cache_hit_rate=hit_rate,
        effective_ssd_bw=eff_bw,
        blocks_per_query=(demand / q) if demand is not None and q else None,
        bytes_per_query=(flash_bytes / q)
        if flash_bytes is not None and q else None,
        hops_per_query=(hops / q) if hops is not None and q else None,
        supersteps_per_query=(steps / q)
        if steps is not None and q else None,
        dispatch_overhead_s=dispatch,
        store_read_s=store_read_s,
        graph_degree=(int(g) if (g := _gauge_max(snapshot,
                                                 "csd_graph_degree")) else None),
        vector_row_bytes=(int(g) if (g := _gauge_max(
            snapshot, "csd_vector_row_bytes")) else None),
        block_size=(int(g) if (g := _gauge_max(snapshot,
                                               "csd_block_size")) else None),
        source={"store_read_spans": store_n, "superstep_spans": sup_n},
    )


def load_calibration(path: str) -> Calibration:
    """Calibrate from a metrics snapshot JSON on disk (the exporter's
    `.json` output)."""
    with open(path) as f:
        return calibrate(json.load(f))


# -- modeled vs measured -----------------------------------------------------

def _term(modeled, measured, calibrated=None) -> dict:
    rel = ((modeled - measured) / measured) if measured else None
    out = {"modeled": modeled, "measured": measured,
           "rel_error": round(rel, 4) if rel is not None else None}
    if calibrated is not None:
        crel = ((calibrated - measured) / measured) if measured else None
        out["calibrated"] = calibrated
        out["calibrated_rel_error"] = (round(crel, 4)
                                       if crel is not None else None)
    return out


def compare_terms(cal: Calibration, hw=None) -> dict:
    """Per-term modeled-vs-measured error on the measured workload.

    storage  : seconds/query in flash reads — HW-prior model vs the
               profiler's store-read time, plus the calibrated model
               (measured hit rate + effective bandwidth).
    fanout   : demand block accesses/query — the analytic
               hops x degree x row/block estimate vs the cache's count.
    dispatch : host seconds/superstep — the model's prior is 0 (it only
               prices flash); measured is the fitted per-superstep
               overhead, which `dispatch_cost` can now price.
    """
    from repro.launch.costmodel import dispatch_cost, storage_cost
    from repro.launch.roofline import HW
    hw = hw or HW()
    terms: dict[str, dict] = {}

    q = cal.queries or 0
    if q and cal.blocks_per_query and cal.block_size and cal.store_read_s:
        measured_s = cal.store_read_s / q
        prior = storage_cost(cal.blocks_per_query, cal.block_size,
                             cache_hit_rate=0.0, ssd_bw=hw.ssd_bw)
        fitted = storage_cost(cal.blocks_per_query, cal.block_size,
                              cache_hit_rate=cal.cache_hit_rate or 0.0,
                              ssd_bw=cal.effective_ssd_bw or hw.ssd_bw)
        terms["storage"] = _term(prior.storage_s, measured_s,
                                 fitted.storage_s)
        terms["storage"]["unit"] = "s/query"
    else:
        terms["storage"] = {"unavailable": True}

    if (cal.hops_per_query and cal.graph_degree and cal.vector_row_bytes
            and cal.block_size and cal.blocks_per_query):
        modeled_bpq = (cal.hops_per_query * cal.graph_degree
                       * cal.vector_row_bytes / cal.block_size)
        terms["fanout"] = _term(round(modeled_bpq, 3),
                                round(cal.blocks_per_query, 3),
                                round(cal.blocks_per_query, 3))
        terms["fanout"]["unit"] = "blocks/query"
    else:
        terms["fanout"] = {"unavailable": True}

    if cal.dispatch_overhead_s is not None:
        dc = dispatch_cost(cal.supersteps_per_query or 0.0,
                           cal.dispatch_overhead_s)
        # the prior model prices dispatch at zero — the whole point of
        # this term is to surface how much that omission costs
        terms["dispatch"] = _term(0.0, cal.dispatch_overhead_s,
                                  cal.dispatch_overhead_s)
        terms["dispatch"]["unit"] = "s/superstep"
        terms["dispatch"]["dispatch_s_per_query"] = round(dc.dispatch_s, 9)
    else:
        terms["dispatch"] = {"unavailable": True}

    return terms
