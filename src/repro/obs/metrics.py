"""Process-wide metrics registry: counters, gauges, bounded histograms.

One `MetricsRegistry` (`REGISTRY`) absorbs every ad-hoc stats surface the
stack grew — the PageCache counters, the serve rollup, the cluster
rollup, the ingest residency bounds — behind a single `snapshot()` that
the exporters (`obs.export`) turn into Prometheus text or JSON.

Two ways in:

  * direct instruments — `REGISTRY.counter("serve_requests_total")` /
    `gauge` / `histogram`; get-or-create by (name, labels), each with its
    own lock so N threads incrementing never lose a count (pinned by the
    concurrency test);
  * collectors — `REGISTRY.register_collector(obj, fn)` holds a WEAK
    reference to `obj` and calls `fn(obj)` at snapshot time. Objects that
    already keep counters under their own locks (PageCache, ClusterRouter,
    MutableSearchService) publish through this with zero hot-path cost;
    a garbage-collected owner silently drops out of the snapshot.

Collector sample form: `(kind, name, labels_dict, value)` where kind is
"counter" or "gauge". Histograms are direct-only (they need `observe`).

Metric naming follows Prometheus conventions (`*_total` for counters,
`*_bytes`/`*_ms` units in the name); docs/observability.md carries the
full name table with the paper-figure mapping.
"""

from __future__ import annotations

import bisect
import itertools
import threading
import weakref

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "DEFAULT_MS_BUCKETS", "next_uid"]

# Latency buckets (ms): two-decade log-ish spread around the regimes the
# repo actually serves (sub-ms kernels to multi-second cold builds).
DEFAULT_MS_BUCKETS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)

_uid = itertools.count()


def next_uid() -> str:
    """Small unique label value for per-object metric streams (one per
    PageCache / service / router instance)."""
    return str(next(_uid))


class Counter:
    """Monotonic counter; `inc` is exact under concurrency (own lock)."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value; settable and incrementable."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Histogram:
    """Bounded-bucket histogram (cumulative counts, Prometheus-style).

    `buckets` are inclusive upper bounds; one implicit +Inf bucket tops
    them off, so memory is fixed no matter how many observations land."""

    __slots__ = ("name", "labels", "buckets", "_lock", "_counts", "_sum",
                 "_count")

    def __init__(self, name: str, labels: dict, buckets=DEFAULT_MS_BUCKETS):
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.name = name
        self.labels = labels
        self.buckets = b
        self._lock = threading.Lock()
        self._counts = [0] * (len(b) + 1)        # last slot == +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, v) -> None:
        v = float(v)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum, out = 0, []
        for le, c in zip(self.buckets + (float("inf"),), counts):
            cum += c
            out.append((le, cum))
        return {"buckets": out, "sum": s, "count": total}


def _key(kind: str, name: str, labels: dict) -> tuple:
    return (kind, name, tuple(sorted(labels.items())))


class MetricsRegistry:
    """Get-or-create instrument store + weakref collector hub."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[tuple, object] = {}
        self._collectors: list[tuple[weakref.ref, object]] = []

    # -- direct instruments --------------------------------------------------

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, buckets=DEFAULT_MS_BUCKETS,
                  **labels) -> Histogram:
        k = _key("histogram", name, labels)
        with self._lock:
            m = self._metrics.get(k)
            if m is None:
                m = self._metrics[k] = Histogram(name, labels, buckets)
            return m

    def _get(self, kind, cls, name, labels):
        k = _key(kind, name, labels)
        with self._lock:
            m = self._metrics.get(k)
            if m is None:
                m = self._metrics[k] = cls(name, labels)
            return m

    # -- collectors ----------------------------------------------------------

    def register_collector(self, obj, fn) -> None:
        """At snapshot time call `fn(obj)` -> iterable of
        (kind, name, labels, value). Weakly referenced: when `obj` dies its
        series vanish from the snapshot (no unregister bookkeeping)."""
        with self._lock:
            self._collectors.append((weakref.ref(obj), fn))

    # -- snapshot ------------------------------------------------------------

    def snapshot(self) -> dict:
        """One structured view of everything: the registry's instruments
        plus every live collector's samples."""
        with self._lock:
            metrics = list(self._metrics.items())
            collectors = list(self._collectors)
        out = {"counters": [], "gauges": [], "histograms": []}
        for (kind, name, _), m in metrics:
            if kind == "histogram":
                out["histograms"].append(
                    {"name": name, "labels": dict(m.labels),
                     **m.snapshot()})
            else:
                out[kind + "s"].append({"name": name,
                                        "labels": dict(m.labels),
                                        "value": m.value})
        dead = False
        for ref, fn in collectors:
            obj = ref()
            if obj is None:
                dead = True
                continue
            try:
                samples = fn(obj)
            except Exception:            # a dying owner must not take the
                continue                 # whole snapshot with it
            for kind, name, labels, value in samples:
                out[kind + "s"].append({"name": name, "labels": dict(labels),
                                        "value": value})
        if dead:
            with self._lock:
                self._collectors = [(r, f) for r, f in self._collectors
                                    if r() is not None]
        out["counters"].sort(key=lambda s: (s["name"], sorted(
            s["labels"].items())))
        out["gauges"].sort(key=lambda s: (s["name"], sorted(
            s["labels"].items())))
        out["histograms"].sort(key=lambda s: (s["name"], sorted(
            s["labels"].items())))
        return out


# The process-wide registry every layer publishes into.
REGISTRY = MetricsRegistry()
