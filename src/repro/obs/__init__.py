"""repro.obs — the telemetry spine: traces, metrics, exporters.

One coherent observability layer replacing the three ad-hoc stats
surfaces the stack grew (serve's `_pct` rollup, cluster's inline
percentiles, the PageCache counter dicts):

  * `TRACER`   — hierarchical trace spans over the whole request path
                 (request -> queue -> batch -> dispatch -> shard ->
                 segment -> traversal -> store-read -> hop), exported as
                 Chrome/Perfetto trace-event JSON. Near-zero cost when
                 disabled (the default), sampled when enabled.
  * `REGISTRY` — process-wide metrics (counters / gauges / bounded
                 histograms) every layer publishes into, snapshot behind
                 one call, exported as Prometheus text or JSON.
  * `latency_summary` — the one percentile helper (p50/p99/p999/mean).

See docs/observability.md for the span hierarchy and the metric-name
table (with the paper-figure mapping, e.g. store_block_reads_total <->
Fig. 9).
"""

from repro.obs.export import (PeriodicExporter, to_json, to_prometheus,
                              write_snapshot)
from repro.obs.metrics import (DEFAULT_MS_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, REGISTRY)
from repro.obs.stats import latency_summary
from repro.obs.trace import TRACER, SpanCtx, Tracer

__all__ = [
    "TRACER", "Tracer", "SpanCtx",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_MS_BUCKETS",
    "latency_summary",
    "to_prometheus", "to_json", "write_snapshot", "PeriodicExporter",
]
