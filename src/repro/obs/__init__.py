"""repro.obs — the telemetry spine: traces, metrics, exporters, and the
phase-2 consumers (profiler, SLOs, flight recorder, calibration).

One coherent observability layer replacing the three ad-hoc stats
surfaces the stack grew (serve's `_pct` rollup, cluster's inline
percentiles, the PageCache counter dicts):

  * `TRACER`   — hierarchical trace spans over the whole request path
                 (request -> queue -> batch -> dispatch -> shard ->
                 segment -> traversal -> store-read -> hop), exported as
                 Chrome/Perfetto trace-event JSON. Near-zero cost when
                 disabled (the default), sampled when enabled.
  * `REGISTRY` — process-wide metrics (counters / gauges / bounded
                 histograms) every layer publishes into, snapshot behind
                 one call, exported as Prometheus text or JSON.
  * `PROFILER` — continuous per-stage profiling fed at span close,
                 always on (tracing on OR off); `profile_report()` is
                 the fig_obs latency attribution, live.
  * `SLOTracker` / `default_slos` — declarative latency / error-rate /
                 recall objectives with multi-window burn-rate breaches.
  * `FlightRecorder` — bounded capture of the N slowest + errored
                 requests, dumpable as Perfetto JSON.
  * `calibrate` / `compare_terms` — fit cost-model HW parameters from a
                 metrics snapshot; `ann_dryrun --calibrated` consumes it.
  * `latency_summary` — the one percentile helper (p50/p99/p999/mean).

See docs/observability.md for the span hierarchy and the metric-name
table (with the paper-figure mapping, e.g. store_block_reads_total <->
Fig. 9).
"""

from repro.obs.calibrate import (Calibration, calibrate, compare_terms,
                                 load_calibration)
from repro.obs.export import (PeriodicExporter, to_json, to_prometheus,
                              write_snapshot)
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (DEFAULT_MS_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, REGISTRY)
from repro.obs.profile import PROFILER, Profiler, profile_report
from repro.obs.slo import SLO, SLOTracker, default_slos
from repro.obs.stats import latency_summary
from repro.obs.trace import TRACER, SpanCtx, Tracer

# Close the loop: the global tracer feeds the global profiler at span
# close, so per-stage timings keep flowing with tracing disabled (the
# production default). Private Tracer()/Profiler() instances stay
# unlinked — tests rely on the disabled tracer's shared no-op span.
TRACER.profiler = PROFILER

__all__ = [
    "TRACER", "Tracer", "SpanCtx",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_MS_BUCKETS",
    "PROFILER", "Profiler", "profile_report",
    "SLO", "SLOTracker", "default_slos",
    "FlightRecorder",
    "Calibration", "calibrate", "load_calibration", "compare_terms",
    "latency_summary",
    "to_prometheus", "to_json", "write_snapshot", "PeriodicExporter",
]
