"""Shared latency/percentile summaries.

This is the one home for the percentile math that used to be duplicated
(differently) in `serve/server.py` (`_pct`) and `cluster/shard.py`
(inline `np.percentile` with its own empty-guard). Both now call
`latency_summary`; the empty-input edge case — `np.percentile` raising on
a zero-length array — is fixed exactly once, here, by returning zeros.

The p50/p99/mean values are bit-identical to the old call sites'
formulas (pinned in tests/test_obs.py); p999 and count are additions the
paper-style load reports (p50/p99/p999 under load, ROADMAP item 5) need.
"""

from __future__ import annotations

import numpy as np

__all__ = ["latency_summary"]


def latency_summary(xs) -> dict:
    """Summary of a latency sample: {"p50", "p99", "p999", "mean", "count"}.

    Accepts any array-like (list, deque, ndarray); an empty sample returns
    all-zero fields instead of raising (the once-duplicated edge case)."""
    a = np.asarray(tuple(xs) if not isinstance(xs, np.ndarray) else xs,
                   np.float64).ravel()
    if a.size == 0:
        return {"p50": 0.0, "p99": 0.0, "p999": 0.0, "mean": 0.0, "count": 0}
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "p999": float(np.percentile(a, 99.9)),
            "mean": float(a.mean()),
            "count": int(a.size)}
