"""Exporters: Prometheus text exposition, JSON snapshots, periodic files.

    from repro.obs import REGISTRY, export
    print(export.to_prometheus(REGISTRY.snapshot()))   # scrape format
    export.write_snapshot("metrics.json")              # one-shot file
    with export.PeriodicExporter("metrics.prom", interval_s=5.0):
        serve_forever()                                # file refreshes

The periodic emitter is the scrape story for a process with no HTTP
server: it rewrites the target file atomically (tmp + rename) every
interval, so node-exporter-style textfile collectors (or a `watch cat`)
always see a complete exposition. Format follows the extension: `.json`
emits the structured snapshot, anything else Prometheus text. When a
tracer is attached (`trace_path`), the Chrome/Perfetto trace JSON is
re-emitted on the same cadence.
"""

from __future__ import annotations

import json
import os
import threading
import time

from repro.obs.metrics import REGISTRY, MetricsRegistry

__all__ = ["to_prometheus", "to_json", "write_snapshot", "PeriodicExporter"]


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _labels(labels: dict, extra: dict | None = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"'
                     for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def _num(v) -> str:
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus(snapshot: dict) -> str:
    """Registry snapshot -> Prometheus text exposition format v0.0.4."""
    lines: list[str] = []
    typed: set[str] = set()

    def _type(name: str, kind: str) -> None:
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for s in snapshot.get("counters", []):
        _type(s["name"], "counter")
        lines.append(f"{s['name']}{_labels(s['labels'])} {_num(s['value'])}")
    for s in snapshot.get("gauges", []):
        _type(s["name"], "gauge")
        lines.append(f"{s['name']}{_labels(s['labels'])} {_num(s['value'])}")
    for s in snapshot.get("histograms", []):
        _type(s["name"], "histogram")
        for le, cum in s["buckets"]:
            lab = _labels(s["labels"], {"le": _num(le)})
            lines.append(f"{s['name']}_bucket{lab} {cum}")
        lab = _labels(s["labels"])
        lines.append(f"{s['name']}_sum{lab} {_num(s['sum'])}")
        lines.append(f"{s['name']}_count{lab} {s['count']}")
    return "\n".join(lines) + "\n"


def to_json(snapshot: dict) -> str:
    """Registry snapshot -> stable JSON text (timestamped)."""
    return json.dumps({"ts_unix": time.time(), **snapshot}, indent=1,
                      sort_keys=True)


def _render(path: str, registry: MetricsRegistry) -> str:
    snap = registry.snapshot()
    return (to_json(snap) if path.endswith(".json")
            else to_prometheus(snap))


def _atomic_write(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


def write_snapshot(path: str, registry: MetricsRegistry = REGISTRY) -> str:
    """One-shot snapshot file (format by extension, atomic)."""
    _atomic_write(path, _render(path, registry))
    return path


class PeriodicExporter:
    """Background thread re-emitting the snapshot file every interval."""

    def __init__(self, path: str, interval_s: float = 5.0, *,
                 registry: MetricsRegistry = REGISTRY, tracer=None,
                 trace_path: str | None = None):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.path = path
        self.interval_s = float(interval_s)
        self.registry = registry
        self.tracer = tracer
        self.trace_path = trace_path
        self.emits = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._final_emitted = False

    def emit(self) -> None:
        _atomic_write(self.path, _render(self.path, self.registry))
        if self.tracer is not None and self.trace_path is not None:
            _atomic_write(self.trace_path,
                          json.dumps(self.tracer.export()))
        self.emits += 1

    def start(self) -> "PeriodicExporter":
        if self._thread is None:
            self._stop.clear()              # restartable after stop()
            self._final_emitted = False
            self.emit()                     # a scrape target exists at once
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="obs-exporter")
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.emit()
            except Exception:               # a bad disk must not kill the
                pass                        # serving process

    def stop(self) -> None:
        """Idempotent shutdown with EXACTLY ONE final emission.

        The final emit happens after the thread has joined, so metrics
        recorded between the last periodic tick and stop() always land in
        the file; a second stop() (or stop() without start()) must not
        emit again — callers treat the file as complete at first return.
        """
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if not self._final_emitted:
            self._final_emitted = True
            self.emit()                     # final, complete snapshot

    def __enter__(self) -> "PeriodicExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
