"""Declarative SLOs with multi-window burn-rate breach detection.

The paper's operating point is a latency/recall contract (75.59 QPS at
recall 0.94, §6.2/§6.5); this module makes such contracts first-class:
declare objectives, feed the tracker from the serve path, and breaches
surface as `slo_*` REGISTRY series plus bounded in-process events.

Objective kinds
---------------
  latency    : `objective` fraction of requests must finish within
               `target` ms ("p99 e2e <= 50ms" is objective=0.99,
               target=50). Error budget = 1 - objective.
  error_rate : the failed-request fraction must stay below `target`
               (budget = target; successes arrive via record_latency,
               failures via record_error).
  recall     : `objective` fraction of recall probes (the recall-
               regression fixtures replayed against live traffic) must
               score >= `target`. Budget = 1 - objective.

Breach semantics (the SRE multi-window burn-rate rule)
------------------------------------------------------
Each sample is good/bad; over a sliding window the burn rate is
bad_fraction / error_budget (1.0 = consuming budget exactly as fast as
the objective allows). A breach fires only when BOTH the long window
(`window_s`) and the short window (`window_s * short_frac`) burn at
>= `burn_threshold`, with at least `min_samples` long-window samples:
the long window gives significance, the short window makes the alert
reset quickly once the condition clears (no alerting on stale pain).

Breach EVENTS are edge-triggered (not-breaching -> breaching), appended
to a bounded list and counted in `slo_breaches_total`; the current burn
rates and breach state are gauges, re-set on every `evaluate()`.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

from repro.obs.metrics import REGISTRY, MetricsRegistry

__all__ = ["SLO", "SLOTracker", "default_slos"]

_KINDS = ("latency", "error_rate", "recall")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective (see module docstring for kinds)."""

    name: str
    kind: str
    target: float
    objective: float = 0.99
    window_s: float = 60.0
    short_frac: float = 1.0 / 12.0     # SRE convention: short = long/12
    burn_threshold: float = 2.0
    min_samples: int = 20

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r} "
                             f"(expected one of {_KINDS})")
        if self.budget() <= 0.0:
            raise ValueError(
                f"SLO {self.name!r} has no error budget: "
                f"objective/target leave nothing to burn")

    def budget(self) -> float:
        """Allowed bad-sample fraction (what burn rate 1.0 consumes)."""
        if self.kind == "error_rate":
            return self.target
        return 1.0 - self.objective

    @property
    def short_window_s(self) -> float:
        return self.window_s * self.short_frac


class _State:
    """Per-SLO sliding window: (monotonic_t, bad) samples + edge state."""

    __slots__ = ("samples", "breaching")

    def __init__(self, max_samples: int):
        self.samples: deque = deque(maxlen=max_samples)
        self.breaching = False


def _collect_slo(tr: "SLOTracker"):
    with tr._lock:
        return [("counter", "slo_samples_total", {"slo": s.name, **tr.labels},
                 tr._seen[s.name]) for s in tr.slos]


class SLOTracker:
    """Feeds samples from the serve path, evaluates burn rates on demand.

    Hot-path cost per request: one lock + one deque append per matching
    SLO. Windows are bounded (`max_samples`) so a tracker that is fed but
    never evaluated cannot grow without bound."""

    def __init__(self, slos, *, clock=time.monotonic, labels=None,
                 registry: MetricsRegistry = REGISTRY,
                 max_samples: int = 65536, max_events: int = 256):
        self.slos: tuple[SLO, ...] = tuple(slos)
        if not self.slos:
            raise ValueError("SLOTracker needs at least one SLO")
        self.labels = dict(labels or {})
        self.clock = clock
        self.registry = registry
        self._lock = threading.Lock()
        self._state = {s.name: _State(max_samples) for s in self.slos}
        # lifetime sample count (stays monotone when the window wraps)
        self._seen = {s.name: 0 for s in self.slos}
        self._events: deque = deque(maxlen=max_events)
        self._m_breaches = {
            s.name: registry.counter("slo_breaches_total",
                                     slo=s.name, **self.labels)
            for s in self.slos}
        registry.register_collector(self, _collect_slo)

    # -- feeding -------------------------------------------------------------

    def _push(self, slo: SLO, bad: bool) -> None:
        self._seen[slo.name] += 1
        self._state[slo.name].samples.append((self.clock(), bad))

    def record_latency(self, e2e_ms: float) -> None:
        """One completed request: a latency sample AND an error-rate
        success sample."""
        with self._lock:
            for s in self.slos:
                if s.kind == "latency":
                    self._push(s, e2e_ms > s.target)
                elif s.kind == "error_rate":
                    self._push(s, False)

    def record_error(self, n: int = 1) -> None:
        """`n` failed requests (dispatch exceptions, shard failures)."""
        with self._lock:
            for s in self.slos:
                if s.kind == "error_rate":
                    for _ in range(int(n)):
                        self._push(s, True)

    def record_recall(self, recall: float) -> None:
        """One recall probe (recall-regression fixture replayed live)."""
        with self._lock:
            for s in self.slos:
                if s.kind == "recall":
                    self._push(s, recall < s.target)

    # -- evaluation ----------------------------------------------------------

    @staticmethod
    def _window(samples, now: float, horizon_s: float):
        n = bad = 0
        cutoff = now - horizon_s
        for (t, b) in samples:
            if t >= cutoff:
                n += 1
                bad += b
        return n, bad

    def evaluate(self, now: float | None = None) -> list[dict]:
        """Prune, compute both windows' burn rates, fire edge-triggered
        breach events, refresh the `slo_*` gauges. Returns one status
        dict per SLO."""
        if now is None:
            now = self.clock()
        out = []
        with self._lock:
            for s in self.slos:
                st = self._state[s.name]
                cutoff = now - s.window_s
                while st.samples and st.samples[0][0] < cutoff:
                    st.samples.popleft()
                n_long, bad_long = self._window(st.samples, now, s.window_s)
                n_short, bad_short = self._window(st.samples, now,
                                                  s.short_window_s)
                budget = s.budget()
                frac_long = bad_long / n_long if n_long else 0.0
                frac_short = bad_short / n_short if n_short else 0.0
                burn_long = frac_long / budget
                burn_short = frac_short / budget
                breaching = (n_long >= s.min_samples
                             and burn_long >= s.burn_threshold
                             and burn_short >= s.burn_threshold)
                if breaching and not st.breaching:
                    self._events.append({
                        "slo": s.name, "kind": s.kind, "at": now,
                        "burn_long": round(burn_long, 3),
                        "burn_short": round(burn_short, 3),
                        "samples": n_long, "bad": bad_long,
                        "labels": dict(self.labels)})
                    self._m_breaches[s.name].inc()
                st.breaching = breaching
                out.append({
                    "slo": s.name, "kind": s.kind, "target": s.target,
                    "objective": s.objective, "window_s": s.window_s,
                    "samples": n_long, "bad": bad_long,
                    "bad_frac": round(frac_long, 6),
                    "burn_long": round(burn_long, 3),
                    "burn_short": round(burn_short, 3),
                    "burn_threshold": s.burn_threshold,
                    "breaching": breaching})
        reg = self.registry
        for row in out:
            lab = {"slo": row["slo"], **self.labels}
            reg.gauge("slo_burn_rate", window="long", **lab).set(
                row["burn_long"])
            reg.gauge("slo_burn_rate", window="short", **lab).set(
                row["burn_short"])
            reg.gauge("slo_breaching", **lab).set(
                1.0 if row["breaching"] else 0.0)
        return out

    def breaches(self) -> list[dict]:
        """Edge-triggered breach events so far (bounded, oldest first)."""
        with self._lock:
            return list(self._events)

    def summary(self, now: float | None = None) -> str:
        """Human-readable drain-time summary (launch/serve.py --slo)."""
        lines = []
        for row in self.evaluate(now):
            state = "BREACH" if row["breaching"] else "ok"
            lines.append(
                f"slo {row['slo']:<14} [{state:>6}] kind={row['kind']} "
                f"target={row['target']} burn={row['burn_long']:.2f}x"
                f"/{row['burn_short']:.2f}x (long/short) "
                f"bad={row['bad']}/{row['samples']}")
        n = len(self.breaches())
        lines.append(f"slo breach events: {n}")
        return "\n".join(lines)


def default_slos(p99_ms: float = 50.0, error_rate: float = 0.01,
                 recall_floor: float | None = None,
                 window_s: float = 60.0) -> list[SLO]:
    """The serve CLI's stock objectives: p99 e2e latency, error rate,
    and (optional) a recall floor matching the recall-regression tests."""
    slos = [
        SLO(name="latency_p99", kind="latency", target=p99_ms,
            objective=0.99, window_s=window_s),
        SLO(name="error_rate", kind="error_rate", target=error_rate,
            window_s=window_s),
    ]
    if recall_floor is not None:
        slos.append(SLO(name="recall_floor", kind="recall",
                        target=recall_floor, objective=0.95,
                        window_s=window_s))
    return slos
