"""Slow-query flight recorder: keep the evidence for the tail.

A p999 outlier is gone by the time anyone looks for it — the trace
buffer has rotated, the histogram only says "something was slow". The
flight recorder keeps a bounded record of exactly the requests worth
replaying:

  * the N SLOWEST completed requests (a min-heap keyed on e2e latency:
    a new request only displaces the fastest of the current captures),
    each with its latency split, parameters, per-query engine stats
    (`QueryStats`, JSON-safe), and — when the request was traced — its
    trace id;
  * every ERRORED request (a separate ring, newest-kept), because a
    failure is always worth more than a slow success.

`export(tracer)` turns the captures into one Perfetto/Chrome trace
document: the tracer's span trees filtered to just the captured trace
ids (`Tracer.export(trace_ids=...)`), with the capture records embedded
under `otherData.flight`. `SearchServer.debug_dump()` and the serve
CLI's `--flight-out` flag write exactly this document.

The hot-path cost is one lock + one float compare per completed request
(plus a heap push only when the request makes the cut).
"""

from __future__ import annotations

import dataclasses
import heapq
import json
import threading
from collections import deque

import numpy as np

from repro.obs.metrics import REGISTRY, MetricsRegistry

__all__ = ["FlightRecorder"]


def _jsonable(v):
    """JSON-safe view of capture payloads (QueryStats carries numpy)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {f.name: _jsonable(getattr(v, f.name))
                for f in dataclasses.fields(v)}
    a = np.asarray(v)
    if a.ndim == 0:
        return a.item()
    return a.tolist()


def _collect_flight(fr: "FlightRecorder"):
    with fr._lock:
        return [
            ("counter", "flight_captured_total", {}, fr._captured),
            ("counter", "flight_errors_total", {}, fr._errored),
            ("gauge", "flight_slowest_ms", {},
             fr._heap[0][0] if len(fr._heap) == fr.capacity else 0.0),
        ]


class FlightRecorder:
    """Bounded capture of the slowest + errored requests (see module
    docstring). Thread-safe; one instance per SearchServer."""

    def __init__(self, capacity: int = 16,
                 registry: MetricsRegistry = REGISTRY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._heap: list = []          # (e2e_ms, uniq, record) min-heap
        self._errors: deque = deque(maxlen=self.capacity)
        self._uniq = 0                 # heap tie-break, monotone
        self._captured = 0             # lifetime records admitted
        self._errored = 0
        registry.register_collector(self, _collect_flight)

    # -- recording -----------------------------------------------------------

    def record(self, *, seq: int, e2e_ms: float, queue_ms: float = 0.0,
               exec_ms: float = 0.0, k: int | None = None,
               ef: int | None = None, trace=None, stats=None) -> bool:
        """Offer one completed request; returns True if it was kept.
        `trace` is the request's SpanCtx (trace id kept only when the
        request was actually sampled); `stats` its QueryStats, if any."""
        e2e_ms = float(e2e_ms)
        with self._lock:
            if len(self._heap) == self.capacity and e2e_ms <= self._heap[0][0]:
                return False           # faster than every current capture
            rec = {
                "seq": int(seq),
                "e2e_ms": round(e2e_ms, 3),
                "queue_ms": round(float(queue_ms), 3),
                "exec_ms": round(float(exec_ms), 3),
                "k": k, "ef": ef,
                "trace_id": (trace.trace_id
                             if trace is not None and trace.sampled else None),
                "stats": _jsonable(stats),
            }
            self._uniq += 1
            item = (e2e_ms, self._uniq, rec)
            if len(self._heap) < self.capacity:
                heapq.heappush(self._heap, item)
            else:
                heapq.heapreplace(self._heap, item)
            self._captured += 1
            return True

    def record_error(self, *, seq: int, error: str,
                     k: int | None = None, trace=None) -> None:
        """An errored request is always kept (newest `capacity` of them)."""
        with self._lock:
            self._errored += 1
            self._errors.append({
                "seq": int(seq), "error": str(error), "k": k,
                "trace_id": (trace.trace_id
                             if trace is not None and trace.sampled
                             else None),
            })

    # -- inspection / export -------------------------------------------------

    def snapshot(self) -> dict:
        """Current captures: slowest first, plus the errored ring."""
        with self._lock:
            slowest = [rec for (_, _, rec) in
                       sorted(self._heap, key=lambda it: -it[0])]
            return {"capacity": self.capacity,
                    "captured_total": self._captured,
                    "errors_total": self._errored,
                    "slowest": slowest,
                    "errored": list(self._errors)}

    def trace_ids(self) -> set:
        with self._lock:
            ids = {rec["trace_id"] for (_, _, rec) in self._heap}
            ids |= {r["trace_id"] for r in self._errors}
        ids.discard(None)
        return ids

    def export(self, tracer=None) -> dict:
        """One Perfetto/Chrome trace document: the captured requests'
        span trees (when `tracer` recorded them) + the capture records
        under otherData.flight. Valid trace JSON even with no tracer."""
        ids = self.trace_ids()
        if tracer is not None and ids:
            doc = tracer.export(trace_ids=ids)
        else:
            doc = {"traceEvents": [], "displayTimeUnit": "ms",
                   "otherData": {}}
        doc.setdefault("otherData", {})["flight"] = self.snapshot()
        return doc

    def write(self, path: str, tracer=None) -> str:
        with open(path, "w") as f:
            json.dump(self.export(tracer), f)
        return path
