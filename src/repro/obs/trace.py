"""Hierarchical trace spans over the whole serving stack (Fig. 9-12 fuel).

The paper's evaluation is a per-stage attribution exercise — how much of a
query's latency is queueing, traversal, flash reads (P2P-DMA), rerank —
and every ROADMAP perf item needs the same breakdown to be validated.
`Tracer` provides it as one global object threaded through the hot path:

    from repro.obs.trace import TRACER
    TRACER.configure(enabled=True, sample_rate=1.0)
    with TRACER.span("search", backend="csd"):
        with TRACER.child_span("traversal", partition=0):
            ...
    TRACER.write("trace.json")        # Chrome/Perfetto trace-event JSON

Design points (all load-bearing for the <5%-enabled / unmeasurable-
disabled overhead budget):

  * disabled        : `span()` is one attribute check returning a shared
                      no-op context manager — no allocation, no clock read,
                      no lock. This is the default state.
  * sampling        : the decision is made ONCE per trace, at the root
                      span (`sample_rate`); descendants inherit it through
                      a thread-local span stack, so an unsampled request
                      costs only a stack push/pop per span.
  * nesting         : implicit via the thread-local stack on one thread;
                      explicit via `parent=ctx` across threads (the
                      batcher -> replica handoff) and across the wire
                      (`SpanCtx.wire()` rides the shard message header).
  * retroactive     : stages whose timestamps already exist (queue wait,
                      batch windows) are recorded after the fact with
                      `record_span(t0, t1, ...)` — zero hot-path cost.
  * bounded         : at most `max_events` spans are kept; later spans are
                      counted in `dropped` instead of growing memory.

Span identity is exported into each trace event's `args` (`span_id`,
`parent_id`, `trace_id`) so tests and the per-stage benchmark can rebuild
the tree; Chrome/Perfetto nest visually by (tid, time containment).
"""

from __future__ import annotations

import json
import random
import threading
import time

__all__ = ["SpanCtx", "Tracer", "TRACER"]


class SpanCtx:
    """Lightweight handle to a span: enough to parent children anywhere
    (another thread, another process via `wire()`)."""

    __slots__ = ("trace_id", "span_id", "parent_id", "sampled")

    def __init__(self, trace_id: int, span_id: int, parent_id: int,
                 sampled: bool):
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.sampled = sampled

    def wire(self) -> list:
        """Wire-encodable form (rides the cluster message JSON header)."""
        return [self.trace_id, self.span_id, 1 if self.sampled else 0]

    @classmethod
    def from_wire(cls, w) -> "SpanCtx":
        return cls(int(w[0]), int(w[1]), 0, bool(w[2]))


class _NoopSpan:
    """Returned when tracing is disabled: does nothing, allocates nothing."""

    __slots__ = ()
    sampled = False
    ctx = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NOOP = _NoopSpan()


class _UnsampledSpan:
    """Keeps the thread-local nesting bookkeeping for a sampled-out trace
    (so descendants see `sampled=False`) without recording anything."""

    __slots__ = ("_stack",)
    sampled = False
    ctx = None

    def __init__(self, stack: list):
        self._stack = stack

    def __enter__(self):
        self._stack.append(self)
        return self

    def __exit__(self, *exc):
        self._stack.pop()
        return False

    def set(self, **attrs):
        pass


class Span:
    """One live sampled span; records itself on exit."""

    __slots__ = ("_tracer", "_stack", "name", "attrs", "trace_id",
                 "span_id", "parent_id", "t0", "t1")
    sampled = True

    def __init__(self, tracer: "Tracer", stack: list, name: str,
                 trace_id: int, span_id: int, parent_id: int, attrs: dict):
        self._tracer = tracer
        self._stack = stack
        self.name = name
        self.attrs = attrs
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = 0.0
        self.t1 = 0.0

    @property
    def ctx(self) -> SpanCtx:
        return SpanCtx(self.trace_id, self.span_id, self.parent_id, True)

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self._stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.t1 = time.perf_counter()
        self._stack.pop()
        self._tracer._record(self.name, self.t0, self.t1, self.trace_id,
                             self.span_id, self.parent_id, None, self.attrs)
        return False


_AMBIENT = object()          # sentinel: "parent = current thread-local span"


class Tracer:
    """Process-wide span recorder. One instance (`TRACER`) serves the whole
    stack; tests may build private instances."""

    def __init__(self, enabled: bool = False, sample_rate: float = 1.0,
                 max_events: int = 1_000_000):
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self.max_events = int(max_events)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._events: list[dict] = []
        self._next_id = 1
        self._epoch = time.perf_counter()
        self._rng = random.Random()
        self.dropped = 0
        # Optional continuous profiler fed at span close (obs phase 2).
        # Left None on private tracers; repro.obs.__init__ attaches the
        # global PROFILER to the global TRACER so stage timings keep
        # flowing even with tracing disabled (span() hands out a
        # lightweight profiler span instead of the shared no-op).
        self.profiler = None

    # -- configuration -------------------------------------------------------

    def configure(self, enabled: bool | None = None,
                  sample_rate: float | None = None,
                  max_events: int | None = None) -> "Tracer":
        if sample_rate is not None:
            if not 0.0 <= sample_rate <= 1.0:
                raise ValueError(
                    f"sample_rate must be in [0, 1], got {sample_rate}")
            self.sample_rate = float(sample_rate)
        if max_events is not None:
            self.max_events = int(max_events)
        if enabled is not None:
            self.enabled = bool(enabled)
        return self

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self.dropped = 0
            self._epoch = time.perf_counter()

    # -- internals -----------------------------------------------------------

    def _stack(self) -> list:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _ids(self, n: int = 1) -> int:
        with self._lock:
            i = self._next_id
            self._next_id += n
        return i

    def _sample(self) -> bool:
        r = self.sample_rate
        return r >= 1.0 or (r > 0.0 and self._rng.random() < r)

    def _record(self, name, t0, t1, trace_id, span_id, parent_id, tid,
                attrs) -> None:
        p = self.profiler
        if p is not None and p.enabled:
            # before the max_events bound: profiling aggregates are O(1)
            # per stage name, so they never drop with the event buffer
            p.observe(name, (t1 - t0) * 1e3)
        ev = {"name": name, "t0": t0, "t1": t1, "trace": trace_id,
              "id": span_id, "parent": parent_id,
              "tid": tid if tid is not None else threading.current_thread().name,
              "attrs": attrs or {}}
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    # -- span creation -------------------------------------------------------

    def span(self, name: str, parent=_AMBIENT, **attrs):
        """Context manager for one span.

        parent omitted : nest under the current thread-local span; start a
                         new (sampling-decided) trace if there is none.
        parent=ctx     : explicit cross-thread/cross-wire parent.
        parent=None    : force a new root trace.
        """
        if not self.enabled:
            p = self.profiler
            if p is not None and p.enabled:
                return p.span(name)
            return _NOOP
        stack = self._stack()
        if parent is _AMBIENT:
            top = stack[-1] if stack else None
            if top is None:
                if not self._sample():
                    return _UnsampledSpan(stack)
                tid = self._ids(2)
                return Span(self, stack, name, tid, tid + 1, 0, attrs)
            if not top.sampled:
                return _UnsampledSpan(stack)
            return Span(self, stack, name, top.trace_id, self._ids(),
                        top.span_id, attrs)
        if parent is None:
            if not self._sample():
                return _UnsampledSpan(stack)
            tid = self._ids(2)
            return Span(self, stack, name, tid, tid + 1, 0, attrs)
        if not parent.sampled:
            return _UnsampledSpan(stack)
        return Span(self, stack, name, parent.trace_id, self._ids(),
                    parent.span_id, attrs)

    def child_span(self, name: str, **attrs):
        """A span ONLY if a sampled span is already open on this thread —
        never starts a new trace. The inner layers (store reads, hops,
        segments) use this so background work (prefetch threads, health
        probes) cannot spawn stray root traces."""
        if not self.enabled:
            p = self.profiler
            if p is not None and p.enabled:
                return p.span(name)
            return _NOOP
        stack = self._stack()
        top = stack[-1] if stack else None
        if top is None or not top.sampled:
            return _NOOP
        return Span(self, stack, name, top.trace_id, self._ids(),
                    top.span_id, attrs)

    def current_ctx(self) -> SpanCtx | None:
        """Ctx of the innermost span on this thread (None when untraced)."""
        if not self.enabled:
            return None
        stack = self._stack()
        top = stack[-1] if stack else None
        return top.ctx if top is not None and top.sampled else None

    # -- out-of-band recording (retroactive / pre-allocated spans) -----------

    def sample_request(self) -> SpanCtx | None:
        """Reserve a root ctx for a request whose span will be recorded
        retroactively (the serve queue records `request`/`queue` spans at
        scatter time, when the timestamps are known). Returns None when
        tracing is disabled; an unsampled ctx when sampled out."""
        if not self.enabled:
            return None
        if not self._sample():
            return SpanCtx(0, 0, 0, False)
        tid = self._ids(2)
        return SpanCtx(tid, tid + 1, 0, True)

    def child_ctx(self, parent: SpanCtx | None) -> SpanCtx | None:
        """Pre-allocate a ctx under `parent` (recorded later via
        `record_span(ctx=...)`); None if the parent is absent/unsampled."""
        if parent is None or not parent.sampled or not self.enabled:
            return None
        return SpanCtx(parent.trace_id, self._ids(), parent.span_id, True)

    def record_span(self, name: str, t0: float, t1: float, *,
                    ctx: SpanCtx | None = None, parent: SpanCtx | None = None,
                    tid: str | None = None, **attrs) -> SpanCtx | None:
        """Record a span from already-measured perf_counter timestamps.

        `ctx` uses a pre-allocated identity (sample_request / child_ctx);
        otherwise a fresh span id is minted under `parent`. Returns the
        recorded span's ctx (None if unsampled/disabled)."""
        if not self.enabled:
            return None
        if ctx is not None:
            if not ctx.sampled:
                return None
            trace_id, span_id, parent_id = (ctx.trace_id, ctx.span_id,
                                            ctx.parent_id)
            if parent is not None and parent.sampled:
                parent_id = parent.span_id
        elif parent is not None:
            if not parent.sampled:
                return None
            trace_id, span_id, parent_id = (parent.trace_id, self._ids(),
                                            parent.span_id)
        else:
            trace_id = self._ids(2)
            span_id, parent_id = trace_id + 1, 0
        self._record(name, t0, t1, trace_id, span_id, parent_id, tid, attrs)
        return SpanCtx(trace_id, span_id, parent_id, True)

    # -- export --------------------------------------------------------------

    def spans(self) -> list[dict]:
        """Raw recorded spans (internal schema) — tests and the per-stage
        benchmark aggregate over this."""
        with self._lock:
            return list(self._events)

    def export(self, trace_ids=None) -> dict:
        """Chrome trace-event JSON object (loads in chrome://tracing and
        https://ui.perfetto.dev): complete ('X') events, ts/dur in us
        relative to the tracer epoch.

        `trace_ids` (an iterable of trace ids) restricts the export to
        those traces — the flight recorder uses this to dump only the
        span trees of the requests it captured."""
        with self._lock:
            events = list(self._events)
            epoch = self._epoch
            dropped = self.dropped
        if trace_ids is not None:
            keep = set(trace_ids)
            events = [ev for ev in events if ev["trace"] in keep]
        tids: dict[str, int] = {}
        out = []
        for ev in events:
            tid = tids.setdefault(str(ev["tid"]), len(tids) + 1)
            args = {"trace_id": ev["trace"], "span_id": ev["id"],
                    "parent_id": ev["parent"]}
            args.update(ev["attrs"])
            out.append({"name": ev["name"], "ph": "X", "pid": 1, "tid": tid,
                        "ts": round((ev["t0"] - epoch) * 1e6, 3),
                        "dur": round((ev["t1"] - ev["t0"]) * 1e6, 3),
                        "cat": "repro", "args": args})
        meta = [{"name": "process_name", "ph": "M", "pid": 1,
                 "args": {"name": "repro"}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": 1, "tid": n,
                  "args": {"name": t}} for t, n in sorted(
                      tids.items(), key=lambda kv: kv[1])]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": dropped}}

    def write(self, path: str, trace_ids=None) -> str:
        with open(path, "w") as f:
            json.dump(self.export(trace_ids), f)
        return path


# The process-wide tracer every layer records into. Disabled by default;
# launch/serve.py --trace, scripts, and tests flip it on.
TRACER = Tracer(enabled=False)
