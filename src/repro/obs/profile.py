"""Continuous per-stage profiler: the fig_obs breakdown, live (obs phase 2).

fig_obs answers "where does a request's time go?" by replaying recorded
trace spans offline. This module answers it continuously, in-process,
with the same zero-cost-when-disabled discipline as `Tracer`:

  * every span close feeds `PROFILER.observe(name, ms)` — either through
    `Tracer._record` (tracing enabled) or through the lightweight
    `_ProfSpan` the tracer hands out on its disabled path (tracing
    disabled, the default), so stage timings flow whether or not trace
    events are being retained;
  * durations aggregate into REGISTRY histograms
    (`profile_stage_ms{stage=...}`) — bounded memory, Prometheus-ready —
    plus internal resettable sums that `profile_report()` turns into the
    batch-size-weighted attribution fig_obs computes from spans:
    queue / traversal / store_read / rerank / dispatch_other, summing to
    the measured e2e latency exactly (queue+exec == e2e by construction;
    the exec residue is `dispatch_other`, never dropped);
  * batch-size weighting is explicit: `Replica._search` wraps the search
    call in `PROFILER.weighted(n_queries)` (a thread-local), so a stage
    shared by a batch of B co-riders counts B times — every rider
    experiences the whole stage — exactly fig_obs's `size/n_req` weight;
  * request-level latencies arrive via `PROFILER.request(queue, exec,
    e2e)` from the serve collector, NOT from spans: the batcher's
    retroactive request/queue/exec spans exist only for sampled traces,
    and the profiler must see every request.

Attribution caveat: with tracing enabled at sample_rate < 1.0, stage
spans are only observed for sampled traces while `request()` sees every
request — the breakdown then under-attributes stages. It is exact when
tracing is off (the production default) or fully sampled.

Overhead budget: the always-on profiler must cost <= 2% QPS on the csd
lane harness (asserted by benchmarks/fig_obs.py before BENCH_obs.json is
written). Disabled, it is one attribute check on the tracer's disabled
path.
"""

from __future__ import annotations

import threading
import time

from repro.obs.metrics import REGISTRY, MetricsRegistry

__all__ = ["Profiler", "PROFILER", "profile_report"]


class _NoopSpan:
    __slots__ = ()
    sampled = False
    ctx = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NOOP = _NoopSpan()


class _ProfSpan:
    """Times one stage and feeds the profiler on exit. Handed out by the
    tracer's disabled path; mimics the span surface (`sampled`/`ctx`/
    `set`) so call sites need no branching."""

    __slots__ = ("_prof", "_name", "_t0")
    sampled = False
    ctx = None

    def __init__(self, prof: "Profiler", name: str):
        self._prof = prof
        self._name = name
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._prof.observe(self._name, (time.perf_counter() - self._t0) * 1e3)
        return False

    def set(self, **attrs):
        pass


class _Weighted:
    """Context manager setting the thread-local batch-size weight."""

    __slots__ = ("_local", "_n", "_prev")

    def __init__(self, local, n):
        self._local = local
        self._n = n
        self._prev = None

    def __enter__(self):
        self._prev = getattr(self._local, "weight", None)
        self._local.weight = self._n
        return self

    def __exit__(self, *exc):
        self._local.weight = self._prev
        return False


def _collect_profiler(prof: "Profiler"):
    """Snapshot-time samples: totals the report is built from, published so
    an external scraper can compute the same attribution."""
    with prof._lock:
        n = prof._req_n
        out = [("counter", "profile_requests_total", {}, n)]
        for name, w in sorted(prof._wsum.items()):
            out.append(("counter", "profile_stage_weighted_ms_total",
                        {"stage": name}, w))
    return out


class Profiler:
    """Process-wide per-stage duration aggregator (one instance: PROFILER).

    Enabled by default — "always-on" is the point; `configure(
    enabled=False)` reduces it to one attribute check per span."""

    def __init__(self, enabled: bool = True,
                 registry: MetricsRegistry = REGISTRY):
        self.enabled = bool(enabled)
        self.registry = registry
        self._lock = threading.Lock()
        self._local = threading.local()
        self._hists: dict[str, object] = {}
        # resettable aggregates behind profile_report(); the REGISTRY
        # histograms stay cumulative (Prometheus counters never reset)
        self._sum: dict[str, float] = {}
        self._count: dict[str, int] = {}
        self._wsum: dict[str, float] = {}
        self._req_n = 0
        self._req_queue = 0.0
        self._req_exec = 0.0
        self._req_e2e = 0.0
        registry.register_collector(self, _collect_profiler)

    # -- configuration -------------------------------------------------------

    def configure(self, enabled: bool | None = None) -> "Profiler":
        if enabled is not None:
            self.enabled = bool(enabled)
        return self

    def reset(self) -> None:
        """Zero the report window (REGISTRY histograms are cumulative and
        stay)."""
        with self._lock:
            self._sum = {}
            self._count = {}
            self._wsum = {}
            self._req_n = 0
            self._req_queue = 0.0
            self._req_exec = 0.0
            self._req_e2e = 0.0

    # -- recording -----------------------------------------------------------

    def span(self, name: str):
        """A timing context for `name` (the tracer's disabled path calls
        this; direct use is fine too)."""
        if not self.enabled:
            return _NOOP
        return _ProfSpan(self, name)

    def weighted(self, n: int) -> _Weighted:
        """Stage observations inside this context count `n` times in the
        weighted attribution (n = the batch's pre-padding request count)."""
        return _Weighted(self._local, int(n))

    def observe(self, name: str, ms: float) -> None:
        """One closed stage span of `ms` milliseconds."""
        h = self._hists.get(name)
        if h is None:
            h = self._hists.setdefault(
                name, self.registry.histogram("profile_stage_ms", stage=name))
        h.observe(ms)
        w = getattr(self._local, "weight", None)
        with self._lock:
            self._sum[name] = self._sum.get(name, 0.0) + ms
            self._count[name] = self._count.get(name, 0) + 1
            if w:
                self._wsum[name] = self._wsum.get(name, 0.0) + ms * w

    def request(self, queue_ms: float, exec_ms: float, e2e_ms: float) -> None:
        """One completed request's latency split (from serve._Collector —
        the batcher's retroactive spans exist only for sampled traces)."""
        with self._lock:
            self._req_n += 1
            self._req_queue += queue_ms
            self._req_exec += exec_ms
            self._req_e2e += e2e_ms

    # -- reporting -----------------------------------------------------------

    def report(self) -> dict:
        """The live per-request stage attribution (fig_obs's breakdown).

        stage_ms sums to e2e_ms exactly: queue + exec == e2e by
        construction, traversal is reported net of its nested store
        reads, and the exec residue (replica wait, batch pack/pad,
        scatter) is `dispatch_other`."""
        with self._lock:
            n = self._req_n
            queue_s, exec_s, e2e_s = (self._req_queue, self._req_exec,
                                      self._req_e2e)
            wsum = dict(self._wsum)
            spans = {name: {"count": self._count[name],
                            "total_ms": round(self._sum[name], 3)}
                     for name in sorted(self._sum)}
        if n == 0:
            return {"requests": 0, "spans": spans}
        queue = queue_s / n
        execm = exec_s / n
        e2e = e2e_s / n
        trav = wsum.get("traversal", 0.0) / n
        store = wsum.get("store-read", 0.0) / n
        rerank = wsum.get("rerank", 0.0) / n
        breakdown = {
            "queue": queue,
            "traversal": trav - store,
            "store_read": store,
            "rerank": rerank,
            "dispatch_other": execm - trav - rerank,
        }
        total = sum(breakdown.values())
        return {
            "requests": n,
            "e2e_ms": round(e2e, 3),
            "stage_ms": {k: round(v, 3) for k, v in breakdown.items()},
            "stage_sum_ms": round(total, 3),
            "sum_matches_e2e": bool(
                abs(total - e2e) < 1e-6 * max(1.0, e2e)),
            "spans": spans,
        }


# The process-wide profiler (attached to TRACER by repro.obs.__init__).
# Enabled by default: continuous profiling is the always-on telemetry tier.
PROFILER = Profiler(enabled=True)


def profile_report(reset: bool = False) -> dict:
    """The global profiler's attribution; `reset=True` starts a fresh
    window afterwards."""
    rep = PROFILER.report()
    if reset:
        PROFILER.reset()
    return rep
