"""Topology-agnostic sharded checkpoints with async save + elastic restore.

Layout:   <dir>/step_<N>/
            manifest.json          {step, leaf paths, shapes, dtypes}
            <leaf-hash>.npy        one file per pytree leaf
            _COMMITTED             written last — a crash mid-save never
                                   yields a checkpoint that restore will read

Elasticity: leaves are stored UNSHARDED (gathered to host), so a checkpoint
written on a 256-chip mesh restores onto 512 chips, 8 chips, or 1 CPU — the
restore path reshards via device_put with the *target* sharding. At real
fleet scale you'd write per-shard files; the manifest/commit protocol is the
same, and `save_sharded=True` exercises that path too (one file per data
shard of each leaf).

Fault model covered: crash during save (commit marker), crash between saves
(resume from latest committed), topology change on restart (reshard).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "step_dir", "list_steps", "AsyncCheckpointer"]


def step_dir(ckpt_dir: str, step: int) -> str:
    """The canonical on-disk directory of one checkpoint step."""
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def list_steps(ckpt_dir: str, committed_only: bool = True) -> list[int]:
    """Ascending step numbers found under `ckpt_dir`.

    This is the single implementation of step discovery — the checkpoint
    store, its GC, and the api index loader all go through it, so the
    commit-marker contract cannot drift between them.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if committed_only and not os.path.exists(
                os.path.join(ckpt_dir, name, "_COMMITTED")):
            continue
        steps.append(int(name.split("_")[1]))
    return sorted(steps)


def _leaf_name(path: str) -> str:
    return hashlib.sha1(path.encode()).hexdigest()[:16]


def _paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
            for kp, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, save_sharded: bool = False):
    """Blocking save. Returns the checkpoint path."""
    d = step_dir(ckpt_dir, step)
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    keys, leaves, _ = _paths(tree)
    manifest = {"step": step, "leaves": []}
    for key, leaf in zip(keys, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_name(key)
        manifest["leaves"].append(
            {"path": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
        if arr.dtype.kind not in "fiub":      # ml_dtypes (bf16/fp8): widen —
            arr = arr.astype(np.float32)      # exact, and .npy-portable
        np.save(os.path.join(tmp, fname + ".npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(d):
        shutil.rmtree(d)
    os.replace(tmp, d)
    return d


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; optionally reshard onto a
    (possibly different) mesh via a matching tree of NamedShardings."""
    d = step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    keys, leaves, treedef = _paths(like_tree)
    out = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for key, like, sh in zip(keys, leaves, shard_leaves):
        e = by_path[key]
        arr = np.load(os.path.join(d, e["file"] + ".npy"))
        assert tuple(arr.shape) == tuple(like.shape), (key, arr.shape, like.shape)
        arr = arr.astype(like.dtype)                 # narrow back (exact)
        if sh is not None:
            out.append(jax.device_put(arr, sh))      # elastic reshard
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlap checkpoint writes with training: device->host copy happens on
    the caller thread (cheap, ordered), serialization on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._err: Exception | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _gc(self):
        steps = list_steps(self.ckpt_dir, committed_only=False)
        for s in steps[: -self.keep]:
            shutil.rmtree(step_dir(self.ckpt_dir, s), ignore_errors=True)
