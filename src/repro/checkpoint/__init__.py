from repro.checkpoint.store import (
    save_checkpoint, restore_checkpoint, latest_step, step_dir, list_steps,
    AsyncCheckpointer,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "step_dir", "list_steps", "AsyncCheckpointer"]
