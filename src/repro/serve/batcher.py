"""Dynamic batcher: flush on max_batch or max_wait_ms, scatter per-request.

The batcher thread drains the `RequestQueue` in arrival order, packs each
key-compatible batch into ONE `SearchRequest`, hands it to a dispatch
callable (typically `ReplicaPool.submit`, which returns a future so the
batcher keeps flushing while replicas work), and scatters the response back
onto the per-request futures:

  * variable k packs at k_max — the traversal only depends on `ef`
    (`SearchParams.resolve`), so each request's own top-k is the first k
    rows of the packed result, bit-identical to a direct search;
  * the query batch is padded with zero rows to the next power-of-two
    bucket (capped at max_batch), so a jit-compiled backend sees a few
    fixed shapes instead of one compilation per arrival pattern — padded
    rows are dropped before scatter and never touch a future.

Any dispatch/scatter failure lands as `set_exception` on every future of
the batch — a request is never silently lost.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.api.types import QueryStats, SearchRequest
from repro.obs.trace import TRACER
from repro.serve.queue import PendingQuery, QueryResult, RequestQueue

__all__ = ["DynamicBatcher", "bucket_size", "slice_stats"]


def bucket_size(n: int, max_batch: int) -> int:
    """Next power-of-two >= n, capped at max_batch (compile-shape bucket)."""
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch) if max_batch >= n else n


def slice_stats(stats: QueryStats, i: int) -> QueryStats:
    """Row `i` of the per-query stats arrays; per-request scalars (the csd
    storage counters — shared PageCache, per-query attribution undefined)
    and the per-segment dict list (mutable indexes) pass through
    unchanged."""
    vals = {}
    for f in dataclasses.fields(stats):
        v = getattr(stats, f.name)
        if v is None:
            vals[f.name] = None
            continue
        if f.name == "segments":       # per-request structure, not per-query
            vals[f.name] = v
            continue
        a = np.asarray(v)
        vals[f.name] = a[i] if a.ndim >= 1 else v
    return QueryStats(**vals)


class DynamicBatcher:
    """One daemon thread turning queued single queries into packed batches.

    dispatch : called as dispatch(request, n_queries=<real batch size>) ->
        SearchResponse | Future. `n_queries` is the pre-padding request
        count, so per-replica accounting never counts bucket-padding rows.
        A future return (the replica pool) lets the batcher flush the next
        batch while this one executes; a plain response (direct service)
        makes the batcher synchronous.
    collector : optional stats sink with record_batch(size) /
        record_done(result, t_done) / record_error(n)
        (see server._Collector).
    flight : optional FlightRecorder capturing the slowest + errored
        requests at scatter time.
    """

    def __init__(self, queue: RequestQueue, dispatch, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, pad_to_bucket: bool = True,
                 collector=None, flight=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.queue = queue
        self.dispatch = dispatch
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.pad_to_bucket = pad_to_bucket
        self.collector = collector
        self.flight = flight
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="serve-batcher")

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        self._thread.start()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    # -- the flush loop ------------------------------------------------------

    def _run(self) -> None:
        while True:
            batch = self.queue.collect(self.max_batch,
                                       self.max_wait_ms / 1e3)
            if batch is None:
                return
            try:
                self._flush(batch)
            except Exception as e:          # a failed batch fails loudly,
                self._fail(batch, e)        # on its own futures only

    def _flush(self, batch: list[PendingQuery]) -> None:
        t = time.perf_counter()
        for p in batch:
            p.t_dispatch = t
        head = batch[0]
        q = np.stack([p.query for p in batch])
        if self.pad_to_bucket:
            b = bucket_size(len(batch), self.max_batch)
            if b > len(batch):
                q = np.concatenate(
                    [q, np.zeros((b - len(batch), q.shape[1]), q.dtype)])
        # the batch span parents on the first sampled request's root; its
        # ctx rides the SearchRequest so the replica-thread dispatch/search
        # spans nest under this batch, not under some other thread's state
        head_ctx = next((p.trace for p in batch
                         if p.trace is not None and p.trace.sampled), None)
        batch_ctx = TRACER.child_ctx(head_ctx)
        req = SearchRequest(queries=q, k=max(p.k for p in batch),
                            ef=head.ef, rerank=head.rerank,
                            with_stats=head.with_stats, trace=batch_ctx)
        if self.collector is not None:
            self.collector.record_batch(len(batch))
        out = self.dispatch(req, n_queries=len(batch))
        if isinstance(out, Future):
            out.add_done_callback(
                lambda f, b=batch, c=batch_ctx: self._completed(b, f, c))
        else:
            self._scatter(batch, out, batch_ctx)

    def _completed(self, batch: list[PendingQuery], fut: Future,
                   batch_ctx=None) -> None:
        try:
            resp = fut.result()
        except Exception as e:
            self._fail(batch, e)
            return
        try:
            self._scatter(batch, resp, batch_ctx)
        except Exception as e:
            self._fail(batch, e)

    def _scatter(self, batch: list[PendingQuery], resp,
                 batch_ctx=None) -> None:
        ids = np.asarray(resp.ids)
        dists = np.asarray(resp.dists)
        t_done = time.perf_counter()
        head = batch[0]
        if batch_ctx is not None:
            # retroactive: the batch window (flush -> results back), one
            # span per batch on a virtual "batch" lane
            TRACER.record_span("batch", head.t_dispatch, t_done,
                               ctx=batch_ctx, tid="batch",
                               size=len(batch), ef=head.ef)
        for i, p in enumerate(batch):
            stats = None
            if p.with_stats and resp.stats is not None:
                stats = slice_stats(resp.stats, i)
            res = QueryResult(ids=ids[i, :p.k], dists=dists[i, :p.k],
                              stats=stats,
                              queue_ms=(p.t_dispatch - p.t_enqueue) * 1e3,
                              exec_ms=(t_done - p.t_dispatch) * 1e3,
                              e2e_ms=(t_done - p.t_enqueue) * 1e3)
            if p.trace is not None and p.trace.sampled:
                # retroactive per-request spans, on a virtual per-request
                # lane so Perfetto nests request > queue/exec by containment
                lane = f"req-{p.seq % 16}"
                TRACER.record_span("request", p.t_enqueue, t_done,
                                   ctx=p.trace, tid=lane, seq=p.seq, k=p.k)
                TRACER.record_span("queue", p.t_enqueue, p.t_dispatch,
                                   parent=p.trace, tid=lane)
                TRACER.record_span("exec", p.t_dispatch, t_done,
                                   parent=p.trace, tid=lane)
            if self.collector is not None:
                self.collector.record_done(res, t_done)
            if self.flight is not None:
                self.flight.record(seq=p.seq, e2e_ms=res.e2e_ms,
                                   queue_ms=res.queue_ms,
                                   exec_ms=res.exec_ms, k=p.k, ef=head.ef,
                                   trace=p.trace, stats=stats)
            p.future.set_result(res)

    def _fail(self, batch: list[PendingQuery], exc: Exception) -> None:
        n = 0
        for p in batch:
            if not p.future.done():
                p.future.set_exception(exc)
                n += 1
                if self.flight is not None:
                    self.flight.record_error(
                        seq=p.seq, error=f"{type(exc).__name__}: {exc}",
                        k=p.k, trace=p.trace)
        if n and self.collector is not None:
            self.collector.record_error(n)
