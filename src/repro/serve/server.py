"""Serving front end: queue + dynamic batcher + replica pool, one object.

    svc = SearchService.build(vectors, spec)
    with SearchServer(svc, replicas=4, max_batch=64, max_wait_ms=2.0) as srv:
        fut = srv.submit(query, k=10, ef=40)        # returns immediately
        res = fut.result()                          # QueryResult
        srv.drain()                                 # wait for in-flight work
        print(srv.stats().summary())

Latency semantics (see serve/README.md for the full table):

    queue_ms : enqueue -> the batcher flushed the batch containing this
               request (time spent waiting for co-riders / a flush slot)
    exec_ms  : flush -> this request's results materialized on the host
               (replica queueing + device compute + transfer)
    e2e_ms   : enqueue -> materialized == queue_ms + exec_ms

`ServeStats` is the rollup the paper's §6.4 deployment table needs: QPS
over the measurement window, p50/p99 of each latency, the batch-size
histogram (how well dynamic batching packs), and per-replica counters
(including each csd replica's own block_reads / cache_hit_rate).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import Counter
from concurrent.futures import Future

import numpy as np

from repro.obs import export as _export
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import REGISTRY
from repro.obs.profile import PROFILER
from repro.obs.slo import SLOTracker
from repro.obs.stats import latency_summary
from repro.obs.trace import TRACER
from repro.serve.batcher import DynamicBatcher
from repro.serve.dispatch import ReplicaPool
from repro.serve.queue import QueryResult, RequestQueue, ServeClosed

__all__ = ["SearchServer", "ServeStats"]

# batch sizes are small powers of two (bucket padding) — histogram bounds
# to match, not the latency default
_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """One rollup of a serving window."""

    completed: int                  # requests resolved
    wall_s: float                   # first enqueue -> last completion
    qps: float
    queue_ms: dict                  # latency_summary dict:
    exec_ms: dict                   # {"p50","p99","p999","mean","count"}
    e2e_ms: dict
    batch_sizes: dict               # {real batch size: count} (pre-padding)
    mean_batch: float
    replicas: list                  # per-replica dicts (dispatch.Replica.stats)

    def summary(self) -> str:
        per_rep = " ".join(
            f"r{r['replica']}:{r['queries']}q" for r in self.replicas)
        return (f"{self.completed} queries  {self.qps:.1f} QPS  "
                f"queue p50 {self.queue_ms['p50']:.2f}ms  "
                f"exec p50 {self.exec_ms['p50']:.2f}ms  "
                f"e2e p99 {self.e2e_ms['p99']:.2f}ms  "
                f"mean batch {self.mean_batch:.1f}  [{per_rep}]")


class _Collector:
    """Thread-safe sink the batcher reports into."""

    def __init__(self, slo: SLOTracker | None = None) -> None:
        self._slo = slo
        self._lock = threading.Lock()
        self.queue_ms: list[float] = []
        self.exec_ms: list[float] = []
        self.e2e_ms: list[float] = []
        self.batch_sizes: Counter = Counter()
        self.t_first: float | None = None   # first enqueue (set by server)
        self.t_last: float | None = None    # last completion
        # registry instruments (process-wide series — servers aggregate)
        self._m_requests = REGISTRY.counter("serve_requests_total")
        self._m_batches = REGISTRY.counter("serve_batches_total")
        self._m_queue = REGISTRY.histogram("serve_queue_ms")
        self._m_exec = REGISTRY.histogram("serve_exec_ms")
        self._m_e2e = REGISTRY.histogram("serve_e2e_ms")
        self._m_bsz = REGISTRY.histogram("serve_batch_size",
                                         buckets=_BATCH_BUCKETS)
        self._m_errors = REGISTRY.counter("serve_errors_total")

    def mark_enqueue(self, t: float) -> None:
        with self._lock:
            if self.t_first is None:
                self.t_first = t

    def record_batch(self, size: int) -> None:
        with self._lock:
            self.batch_sizes[size] += 1
        self._m_batches.inc()
        self._m_bsz.observe(size)

    def record_done(self, res: QueryResult, t_done: float) -> None:
        with self._lock:
            self.queue_ms.append(res.queue_ms)
            self.exec_ms.append(res.exec_ms)
            self.e2e_ms.append(res.e2e_ms)
            self.t_last = (t_done if self.t_last is None
                           else max(self.t_last, t_done))
        self._m_requests.inc()
        self._m_queue.observe(res.queue_ms)
        self._m_exec.observe(res.exec_ms)
        self._m_e2e.observe(res.e2e_ms)
        # the continuous profiler sees EVERY request here (the batcher's
        # retroactive request/queue/exec spans exist only when sampled)
        if PROFILER.enabled:
            PROFILER.request(res.queue_ms, res.exec_ms, res.e2e_ms)
        if self._slo is not None:
            self._slo.record_latency(res.e2e_ms)

    def record_error(self, n: int = 1) -> None:
        """Requests failed by a dispatch exception (batcher _fail path)."""
        self._m_errors.inc(n)
        if self._slo is not None:
            self._slo.record_error(n)

    def rollup(self, replica_stats: list[dict]) -> ServeStats:
        with self._lock:
            completed = len(self.e2e_ms)
            wall = ((self.t_last - self.t_first)
                    if self.t_first is not None and self.t_last is not None
                    else 0.0)
            sizes = dict(sorted(self.batch_sizes.items()))
            n_batches = sum(sizes.values())
            return ServeStats(
                completed=completed,
                wall_s=wall,
                qps=completed / wall if wall > 0 else 0.0,
                queue_ms=latency_summary(self.queue_ms),
                exec_ms=latency_summary(self.exec_ms),
                e2e_ms=latency_summary(self.e2e_ms),
                batch_sizes=sizes,
                mean_batch=(completed / n_batches) if n_batches else 0.0,
                replicas=replica_stats,
            )


class SearchServer:
    """Async serving over one SearchService (or a prebuilt ReplicaPool)."""

    def __init__(self, service, *, replicas: int = 1, max_batch: int = 32,
                 max_wait_ms: float = 2.0, pad_to_bucket: bool = True,
                 slo=None, flight: int | FlightRecorder | None = 16):
        """`slo` is an SLOTracker (or an iterable of SLO objects, wrapped
        into one); `flight` sizes the slow-query flight recorder
        (int capacity, a prebuilt FlightRecorder, or None/0 to disable)."""
        self.pool = (service if isinstance(service, ReplicaPool)
                     else ReplicaPool.replicate(service, replicas))
        self.queue = RequestQueue()
        if slo is not None and not isinstance(slo, SLOTracker):
            slo = SLOTracker(slo)
        self.slo = slo
        if isinstance(flight, int):
            flight = FlightRecorder(capacity=flight) if flight > 0 else None
        self.flight = flight
        self._collector = _Collector(slo=slo)
        self.batcher = DynamicBatcher(
            self.queue, self.pool.submit, max_batch=max_batch,
            max_wait_ms=max_wait_ms, pad_to_bucket=pad_to_bucket,
            collector=self._collector, flight=self.flight)
        self._outstanding = 0
        self._drain_cond = threading.Condition()
        self._shutdown = False
        self.batcher.start()

    # -- submission ----------------------------------------------------------

    def submit(self, query, *, k: int = 10, ef: int = 40,
               rerank: bool = False, with_stats: bool = False) -> Future:
        """Enqueue one query vector [D]; the future resolves to QueryResult."""
        p = self.queue.put(query, k=k, ef=ef, rerank=rerank,
                           with_stats=with_stats)
        self._collector.mark_enqueue(p.t_enqueue)
        with self._drain_cond:
            self._outstanding += 1
        p.future.add_done_callback(self._one_done)
        return p.future

    def submit_many(self, queries, **kw) -> list[Future]:
        """One future per row of `queries` [B, D] (arrival order = row order)."""
        return [self.submit(q, **kw) for q in np.asarray(queries)]

    # -- mutations (mutable segmented indexes only) --------------------------
    # Writes interleave with batched reads under snapshot consistency: the
    # mutable service applies each mutation atomically under its own lock,
    # and every dispatched batch snapshots (segments, tombstones, memtable)
    # under that same lock — a batch sees the whole write or none of it.
    # Replicas share the one mutable service (dispatch._clone_service), so
    # a mutation is visible to every replica the moment it returns.

    def _mutable(self):
        svc = self.pool.replicas[0].service
        if not (hasattr(svc, "insert") and hasattr(svc, "compact")):
            raise TypeError(
                f"the served index (backend="
                f"{getattr(svc.spec, 'backend', '?')!r}) is immutable — "
                f"serve a repro.api.MutableSearchService to accept writes")
        if self._shutdown:
            raise ServeClosed("server is shut down; no new mutations")
        return svc

    def insert(self, vectors) -> np.ndarray:
        """Insert rows into the served mutable index; returns global ids.
        Synchronous: on return, every later-dispatched batch sees them."""
        return self._mutable().insert(vectors)

    def delete(self, ids) -> int:
        """Tombstone global ids; batches dispatched after the call can
        never return them. Returns the newly-deleted count."""
        return self._mutable().delete(ids)

    def flush_index(self) -> None:
        """Seal the served index's memtable into a segment."""
        self._mutable().flush()

    def compact_index(self) -> dict:
        """Compact the served index; in-flight batches keep serving from
        their pre-compaction snapshot while the rebuild runs."""
        return self._mutable().compact()

    def _one_done(self, _fut: Future) -> None:
        with self._drain_cond:
            self._outstanding -= 1
            if self._outstanding == 0:
                self._drain_cond.notify_all()

    # -- lifecycle -----------------------------------------------------------

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every submitted request has resolved (or timeout);
        returns True when fully drained."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._drain_cond:
            while self._outstanding > 0:
                left = (None if deadline is None
                        else deadline - time.perf_counter())
                if left is not None and left <= 0:
                    return False
                self._drain_cond.wait(timeout=left)
            return True

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Graceful stop: optionally drain, then close the queue (new
        submits raise ServeClosed), stop the batcher, close the pool.
        Without drain, already-queued requests are still flushed — a
        request is never dropped, only refused at the door."""
        if self._shutdown:
            return
        if drain:
            self.drain(timeout)
        self._shutdown = True
        self.queue.close()
        self.batcher.join(timeout=30)
        self.drain(timeout=30)             # flushed-at-close stragglers
        self.pool.close()

    def stats(self) -> ServeStats:
        return self._collector.rollup(self.pool.stats())

    def slo_status(self) -> list[dict] | None:
        """Evaluate the attached SLOs now (None when none attached)."""
        return None if self.slo is None else self.slo.evaluate()

    def debug_dump(self, path: str | None = None):
        """The flight recorder's Perfetto document: span trees of the
        slowest/errored captured requests + their records under
        otherData.flight. Writes to `path` when given (returns the path),
        else returns the document dict."""
        if self.flight is None:
            raise RuntimeError("flight recorder disabled (flight=None)")
        if path is not None:
            return self.flight.write(path, tracer=TRACER)
        return self.flight.export(tracer=TRACER)

    def metrics(self, fmt: str = "prometheus") -> str:
        """Process-wide metrics snapshot (this server's series included),
        rendered for scraping: fmt='prometheus' (text exposition) or
        'json'."""
        snap = REGISTRY.snapshot()
        if fmt == "prometheus":
            return _export.to_prometheus(snap)
        if fmt == "json":
            return _export.to_json(snap)
        raise ValueError(f"unknown metrics format {fmt!r}; "
                         f"use 'prometheus' or 'json'")

    def __enter__(self) -> "SearchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # convenience re-export so callers can `except srv.Closed`
    Closed = ServeClosed
