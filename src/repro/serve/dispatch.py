"""Replica pool: dispatch packed batches across N SearchService replicas.

This models the paper's 4-SmartSSD scale-up (Fig. 10/11): one host-side
dispatcher, N independent engines, each holding the whole database (graph
parallelism's stage-1 unit here is a whole replica). Replication is
backend-aware:

  in-memory backends  : replicas place their device arrays round-robin over
                        `jax.devices()`; on a single-device host they share
                        the (immutable, functionally-searched) arrays, so
                        replication costs nothing and still buys overlap of
                        host-side work with device compute;
  distributed backend : already spans the mesh — replicas share the service
                        (the mesh IS the scale-up);
  csd backend         : each replica opens its OWN StoreReader — an
                        independent PageCache + Prefetcher over the one
                        shared block store, exactly the paper's four
                        SmartSSD DRAMs in front of one logical database.

Selection is least-in-flight-depth with a round-robin tiebreak; each
replica runs a single worker thread, so batches on one replica serialize
(one engine == one accelerator queue) while distinct replicas overlap.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import numpy as np

from repro.obs.metrics import REGISTRY, next_uid
from repro.obs.profile import PROFILER
from repro.obs.trace import TRACER

__all__ = ["Replica", "ReplicaPool"]


class Replica:
    """One SearchService plus its serial executor and counters."""

    def __init__(self, service, rid: int, *, owns_backend: bool = False):
        self.service = service
        self.rid = rid
        self.owns_backend = owns_backend   # pool closes what it opened
        self.inflight = 0                  # guarded by the pool lock
        self.batches = 0
        self.queries = 0
        self.busy_s = 0.0
        self._ex = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"serve-replica-{rid}")

    def _search(self, request, n_queries: int):
        # this runs on the replica's own thread: parent explicitly on the
        # batch ctx the batcher stamped (cross-thread handoff); no ctx ->
        # child_span, which is a no-op unless this thread is already traced
        ctx = getattr(request, "trace", None)
        if ctx is not None:
            sp = TRACER.span("dispatch", parent=ctx, replica=self.rid,
                             n=n_queries)
        else:
            sp = TRACER.child_span("dispatch", replica=self.rid)
        t0 = time.perf_counter()
        # every stage span closed on this thread (traversal, store-read,
        # rerank, hops) weights by the batch's real request count in the
        # continuous profiler: a stage shared by B co-riders is B requests'
        # worth of that stage (fig_obs's size/n_req weighting, live)
        with PROFILER.weighted(n_queries):
            with sp:
                resp = self.service.search(request)
                jax.block_until_ready((resp.ids, resp.dists))
        self.busy_s += time.perf_counter() - t0
        self.batches += 1
        self.queries += n_queries
        return resp

    def stats(self) -> dict:
        d = {"replica": self.rid, "backend": self.service.spec.backend,
             "batches": self.batches, "queries": self.queries,
             "busy_s": self.busy_s, "inflight": self.inflight}
        reader = getattr(self.service.backend, "reader", None)
        if reader is not None:             # csd: this replica's own cache
            snap = reader.cache.snapshot()
            demand = snap["hits"] + snap["misses"]
            d.update(block_reads=snap["block_reads"],
                     bytes_read=snap["bytes_read"],
                     cache_hits=snap["hits"],
                     cache_misses=snap["misses"],
                     cache_hit_rate=(snap["hits"] / demand if demand
                                     else 0.0))
        return d

    def close(self) -> None:
        self._ex.shutdown(wait=True)
        if self.owns_backend:
            reader = getattr(self.service.backend, "reader", None)
            if reader is not None:
                reader.close()


def _collect_pool(pool: "ReplicaPool"):
    """Snapshot-time metric samples for every replica of this pool."""
    out = []
    for r in pool.replicas:
        labels = {"pool": pool.uid, "replica": str(r.rid)}
        out.append(("counter", "serve_replica_batches_total", labels,
                    r.batches))
        out.append(("counter", "serve_replica_queries_total", labels,
                    r.queries))
        out.append(("counter", "serve_replica_busy_seconds_total", labels,
                    r.busy_s))
        out.append(("gauge", "serve_replica_inflight", labels, r.inflight))
    return out


class ReplicaPool:
    """N replicas behind one `submit(request) -> Future[SearchResponse]`."""

    def __init__(self, replicas: list[Replica]):
        if not replicas:
            raise ValueError("ReplicaPool needs at least one replica")
        self.replicas = replicas
        self._lock = threading.Lock()
        self._rr = 0                       # round-robin cursor for ties
        self.uid = next_uid()
        REGISTRY.register_collector(self, _collect_pool)

    # -- construction --------------------------------------------------------

    @classmethod
    def replicate(cls, service, n: int) -> "ReplicaPool":
        """Replica 0 is the given service; 1..n-1 are backend-aware clones."""
        reps = [Replica(service, 0)]
        for i in range(1, max(int(n), 1)):
            svc, owns = _clone_service(service, i)
            reps.append(Replica(svc, i, owns_backend=owns))
        return cls(reps)

    # -- dispatch ------------------------------------------------------------

    def submit(self, request, *, n_queries: int | None = None) -> Future:
        """Least-loaded replica (in-flight depth), round-robin on ties.

        `n_queries` is the real (pre-padding) request count for the
        replica's counters; defaults to the batch's row count."""
        if n_queries is None:
            n_queries = int(np.asarray(request.queries).shape[0])
        with self._lock:
            n = len(self.replicas)
            rep = min(self.replicas,
                      key=lambda r: (r.inflight, (r.rid - self._rr) % n))
            self._rr = (rep.rid + 1) % n
            rep.inflight += 1
        fut = rep._ex.submit(rep._search, request, n_queries)
        fut.add_done_callback(lambda _f, r=rep: self._done(r))
        return fut

    def _done(self, rep: Replica) -> None:
        with self._lock:
            rep.inflight -= 1

    # -- stats / lifecycle ---------------------------------------------------

    def stats(self) -> list[dict]:
        with self._lock:
            return [r.stats() for r in self.replicas]

    def close(self) -> None:
        for r in self.replicas:
            r.close()

    def __len__(self) -> int:
        return len(self.replicas)


# ---------------------------------------------------------------------------
# Backend-aware replication
# ---------------------------------------------------------------------------


def _clone_service(service, i: int):
    """Returns (service, owns_backend) for replica i of the given service.

    Sharing is always safe — `search` is functional over immutable state —
    so every branch that cannot (or need not) clone falls back to it."""
    from repro.api.service import SearchService

    if hasattr(service, "shards"):
        # cluster router (repro.cluster): replication already happens one
        # layer down (per-shard replica sets with failover), so server
        # lanes share the one router — it is thread-safe by construction.
        return service, False

    if hasattr(service, "insert") and hasattr(service, "compact"):
        # mutable segmented index (repro.ingest): every replica MUST share
        # the one service — independent clones would diverge on writes.
        # Its search() snapshots under the service lock, so shared serving
        # stays snapshot-consistent per batch.
        return service, False

    spec = service.spec
    if spec.backend == "csd":
        # independent PageCache/Prefetcher over the one shared block store
        from repro.store.csd import CSDBackend
        from repro.store.layout import open_store
        reader = open_store(spec.storage_path, spec.cache_bytes,
                            prefetch=spec.prefetch)
        return SearchService(spec, CSDBackend(spec, reader)), True

    devices = jax.devices()
    if len(devices) > 1 and spec.backend in ("exact", "hnsw", "partitioned"):
        dev = devices[i % len(devices)]
        clone = _place_on_device(service, dev)
        if clone is not None:
            return clone, False
    # distributed (spans the mesh already) and single-device hosts: share
    return service, False


def _place_on_device(service, dev):
    """In-memory backend copy with its arrays on `dev`; None if the backend
    shape is unrecognized (caller falls back to sharing)."""
    from repro.api.service import SearchService

    backend = service.backend
    put = lambda t: jax.tree.map(lambda a: jax.device_put(a, dev), t)
    if hasattr(backend, "pdb"):            # partitioned / hnsw
        from repro.core.partitioned import PartitionedDB
        pdb = PartitionedDB(db=put(backend.pdb.db),
                            num_partitions=backend.pdb.num_partitions,
                            dim=backend.pdb.dim)
        clone = type(backend)(service.spec, pdb, raw=backend.raw)
        if clone.dev_vectors is not None:   # rerank tables follow the graph
            clone.dev_vectors = put(clone.dev_vectors)
            clone.dev_sqnorms = put(clone.dev_sqnorms)
        return SearchService(service.spec, clone)
    if hasattr(backend, "vectors") and hasattr(backend, "sqnorms"):  # exact
        clone = type(backend)(service.spec, backend.raw)
        clone.vectors = put(clone.vectors)
        clone.sqnorms = put(clone.sqnorms)
        return SearchService(service.spec, clone)
    return None
