"""repro.serve — async dynamic-batching query scheduler + replica dispatch.

The deployment layer of the reproduction (paper Fig. 10-11): clients submit
single queries and get futures; a dynamic batcher packs them into
accelerator-sized `SearchRequest`s; a replica pool spreads batches over N
`SearchService` replicas (independent PageCaches over one block store for
the `csd` backend — the paper's 4-SmartSSD scale-up). See serve/README.md.
"""

from repro.serve.batcher import DynamicBatcher, bucket_size, slice_stats
from repro.serve.dispatch import Replica, ReplicaPool
from repro.serve.queue import (
    PendingQuery,
    QueryResult,
    RequestQueue,
    ServeClosed,
)
from repro.serve.server import SearchServer, ServeStats

__all__ = [
    "DynamicBatcher",
    "bucket_size",
    "slice_stats",
    "Replica",
    "ReplicaPool",
    "PendingQuery",
    "QueryResult",
    "RequestQueue",
    "ServeClosed",
    "SearchServer",
    "ServeStats",
]
