"""Thread-safe request queue: one future per enqueued query.

This is the host-side front of the paper's deployment pipeline (Fig. 10):
clients hand over *single* queries and immediately get a
`concurrent.futures.Future`; the dynamic batcher drains the queue and packs
compatible requests into one `SearchRequest` for the accelerators. Each
`PendingQuery` carries everything the batcher needs to pack it (query row,
k/ef/rerank/stats knobs) and everything the stats rollup needs to attribute
latency (enqueue/dispatch timestamps, arrival sequence number).

Only requests that would traverse the graph identically may share a batch:
`batch_key` is (ef, rerank, with_stats). `k` is deliberately NOT part of
the key — the traversal shape is a function of `ef` alone
(`SearchParams.resolve`), so variable-k requests pack at k_max and slice
their own prefix back out, bit-identically.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any

import numpy as np

from repro.obs.trace import TRACER

__all__ = ["ServeClosed", "PendingQuery", "QueryResult", "RequestQueue"]


class ServeClosed(RuntimeError):
    """Raised when submitting to a queue/server that has been shut down."""


@dataclasses.dataclass(eq=False)
class PendingQuery:
    """One enqueued query and the future its result will land in."""

    query: np.ndarray          # [D]
    k: int
    ef: int
    rerank: bool
    with_stats: bool
    future: Future
    seq: int                   # arrival order (global, monotonically rising)
    t_enqueue: float
    t_dispatch: float = 0.0    # stamped by the batcher at flush time
    trace: Any = None          # root SpanCtx (sampling decided at enqueue);
                               # the request/queue spans are recorded
                               # retroactively at scatter time

    @property
    def batch_key(self) -> tuple:
        """Requests may share a batch iff their traversal is identical;
        `k` is excluded on purpose (packed at max, sliced back)."""
        return (self.ef, self.rerank, self.with_stats)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """What a resolved future carries: this request's own top-k slice plus
    the latency split (queueing vs execution vs end-to-end)."""

    ids: np.ndarray            # [k] global ids (-1 pads)
    dists: np.ndarray          # [k] distances (+inf pads)
    stats: Any = None          # per-query QueryStats row, if requested
    queue_ms: float = 0.0      # enqueue -> batch flush
    exec_ms: float = 0.0       # batch flush -> result materialized
    e2e_ms: float = 0.0        # enqueue -> result materialized


class RequestQueue:
    """FIFO of `PendingQuery` guarded by one condition variable.

    `collect` implements the dynamic-batching wait: it blocks until the
    head-of-line request either has `max_batch - 1` key-compatible followers
    or has waited `max_wait_s`, then atomically removes and returns that
    batch (arrival order preserved). Close flushes whatever is left
    immediately and makes further `put` calls raise `ServeClosed`.
    """

    def __init__(self) -> None:
        self._items: deque[PendingQuery] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self._seq = 0

    def put(self, query, *, k: int = 10, ef: int = 40, rerank: bool = False,
            with_stats: bool = False) -> PendingQuery:
        q = np.asarray(query, np.float32)
        if q.ndim != 1:
            raise ValueError(
                f"RequestQueue.put takes one query vector [D], got shape "
                f"{q.shape}; use SearchServer.submit_many for a batch")
        with self._cond:
            if self._closed:
                raise ServeClosed("queue is shut down; no new requests")
            p = PendingQuery(query=q, k=k, ef=ef, rerank=rerank,
                             with_stats=with_stats, future=Future(),
                             seq=self._seq, t_enqueue=time.perf_counter(),
                             trace=TRACER.sample_request())
            self._seq += 1
            self._items.append(p)
            self._cond.notify_all()
        return p

    def collect(self, max_batch: int, max_wait_s: float
                ) -> list[PendingQuery] | None:
        """Block until a flushable batch exists; None == closed and empty.

        The batch is the first `max_batch` requests (in arrival order) that
        share the head-of-line request's `batch_key`; requests with other
        keys stay queued and form the next batches."""
        with self._cond:
            while True:
                if self._items:
                    head = self._items[0]
                    key = head.batch_key
                    matched = [p for p in self._items if p.batch_key == key]
                    wait_left = (head.t_enqueue + max_wait_s
                                 - time.perf_counter())
                    if (len(matched) >= max_batch or wait_left <= 0
                            or self._closed):
                        batch = matched[:max_batch]
                        taken = set(map(id, batch))
                        self._items = deque(
                            p for p in self._items if id(p) not in taken)
                        return batch
                    self._cond.wait(timeout=wait_left)
                elif self._closed:
                    return None
                else:
                    self._cond.wait()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)
