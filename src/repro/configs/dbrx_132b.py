"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) d_ff(expert)=10752 vocab=100352.

16 experts top-4, fine-grained [hf:databricks/dbrx-base; unverified].
Largest assigned model: 2D (model x data) param sharding is mandatory for
both train and serve cells (see launch/sharding.py).
"""

import dataclasses

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    pattern=(LayerSpec("attn", "moe"),), num_periods=40,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff=10752),
    rope_theta=5e5, family="moe", param_dtype=jnp.bfloat16, grad_accum=8)

REDUCED = dataclasses.replace(
    CONFIG, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128,
    vocab_size=512, num_periods=2,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64, capacity_factor=8.0),
    param_dtype=jnp.float32, loss_chunk=16, block_q=16, block_k=32)
