"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) d_ff=12800 vocab=49155.

GQA [hf:ibm-granite/granite-3.0-2b-base; hf]. Standard SiLU-GLU llama-style
stack. Pure full attention -> long_500k skipped.
"""

from repro.configs.common import dense_lm, reduce_dense

CONFIG = dense_lm(
    "granite-3-8b", layers=40, d_model=4096, n_heads=32, n_kv=8,
    d_ff=12800, vocab=49155, head_dim=128, tie=True)

REDUCED = reduce_dense(CONFIG)
