"""xlstm-350m [ssm]: 24 blocks d1024 4H vocab=50304, mLSTM:sLSTM = 7:1.

sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]. Blocks carry their own
projections (d_ff=0 in the assignment): LayerSpec.ffn="none". Recurrent
state is O(1) in sequence length -> long_500k runs (state: C[B,H,dh,dh]).
"""

import dataclasses

import jax.numpy as jnp

from repro.models.ssm import XLSTMConfig
from repro.models.transformer import LayerSpec, ModelConfig

_PERIOD = tuple([LayerSpec("mlstm", "none")] * 7 + [LayerSpec("slstm", "none")])

CONFIG = ModelConfig(
    name="xlstm-350m", d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    pattern=_PERIOD, num_periods=3,
    xlstm=XLSTMConfig(n_heads=4, m_proj_factor=2.0, d_conv=4, chunk=64),
    family="ssm", sub_quadratic=True, param_dtype=jnp.bfloat16,
    tie_embeddings=True, grad_accum=2)

REDUCED = dataclasses.replace(
    CONFIG, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32, vocab_size=512,
    num_periods=1,
    xlstm=XLSTMConfig(n_heads=2, m_proj_factor=2.0, d_conv=4, chunk=8),
    param_dtype=jnp.float32, loss_chunk=16, block_q=16, block_k=32)
