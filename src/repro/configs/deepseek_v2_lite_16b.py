"""deepseek-v2-lite-16b [moe]: 27L d2048 16H d_ff(expert)=1408 vocab=102400.

MLA with kv_lora=512 (+64 rope dims), 2 shared + 64 routed experts top-6
[arXiv:2405.04434; hf]. Layer 0 is a dense GLU layer (d_ff 10944), layers
1..26 are MoE — expressed as a prefix layer + 26 periods. The MLA compressed
cache (576 floats/token) is the decode-cell differentiator.
"""

import dataclasses

import jax.numpy as jnp

from repro.models.layers import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", d_model=2048, n_heads=16, n_kv_heads=16,
    head_dim=192, d_ff=10944, vocab_size=102400,
    prefix_pattern=(LayerSpec("mla", "glu"),),
    pattern=(LayerSpec("mla", "moe"),), num_periods=26,
    mla=MLAConfig(kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, n_shared=2,
                  shared_d_ff=2816),
    rope_theta=1e4, family="moe", param_dtype=jnp.bfloat16)

REDUCED = dataclasses.replace(
    CONFIG, d_model=128, n_heads=4, head_dim=48, d_ff=256, vocab_size=512,
    num_periods=2,
    mla=MLAConfig(kv_lora=32, qk_nope=32, qk_rope=16, v_dim=32),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=64, n_shared=1, shared_d_ff=64,
                  capacity_factor=8.0),
    param_dtype=jnp.float32, loss_chunk=16, block_q=16, block_k=32)
