"""jamba-v0.1-52b [hybrid]: 32L d4096 32H (GQA kv=8) vocab=65536, MoE 16e top-2.

Mamba+attention 1:7 interleave with MoE every other layer
[arXiv:2403.19887; hf]. Period of 8: attention at index 4, mamba elsewhere;
odd indices are MoE (16 experts top-2, d_ff 14336), even are dense GLU.
Only 4/32 layers hold a KV cache and mamba state is O(1) -> long_500k runs.
"""

import dataclasses

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.ssm import MambaConfig
from repro.models.transformer import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "glu")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=65536,
    pattern=_PERIOD, num_periods=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=64),
    family="hybrid", sub_quadratic=True, param_dtype=jnp.bfloat16,
    grad_accum=16)

REDUCED = dataclasses.replace(
    CONFIG, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
    vocab_size=512, num_periods=1,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64, capacity_factor=8.0),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk=8),
    param_dtype=jnp.float32, loss_chunk=16, block_q=16, block_k=32)
