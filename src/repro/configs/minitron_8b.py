"""minitron-8b [dense]: 32L d4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned Nemotron [arXiv:2407.14679; hf]: non-gated squared-ReLU MLP.
Pure full attention -> long_500k skipped. The 256k vocab stresses the
chunked-vocab loss path.
"""

from repro.configs.common import dense_lm, reduce_dense

CONFIG = dense_lm(
    "minitron-8b", layers=32, d_model=4096, n_heads=32, n_kv=8,
    d_ff=16384, vocab=256000, head_dim=128, ffn="dense", act="relu2")

REDUCED = reduce_dense(CONFIG)
