"""h2o-danube-3-4b [dense]: 24L d3840 32H (GQA kv=8) d_ff=10240 vocab=32000.

llama+mistral mix with sliding-window attention [arXiv:2401.16818;
unverified]. All layers use SWA (mistral-style, window 4096), which bounds
the decode KV cache to the window — this is what makes `long_500k`
legitimately sub-quadratic for this arch (ring-buffer cache, DESIGN.md).
head_dim = 3840/32 = 120 (not a 128 multiple: the MXU pads the contraction;
noted in the roofline commentary).
"""

from repro.configs.common import dense_lm, reduce_dense

CONFIG = dense_lm(
    "h2o-danube3-4b", layers=24, d_model=3840, n_heads=32, n_kv=8,
    d_ff=10240, vocab=32000, head_dim=120, window=4096,
    rope_theta=5e5, sub_quadratic=True)

REDUCED = reduce_dense(CONFIG, window=8)
