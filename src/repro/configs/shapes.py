"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

train_4k    : train_step,   seq 4096,    global_batch 256
prefill_32k : prefill_step, seq 32768,   global_batch 32
decode_32k  : decode_step,  KV 32768,    global_batch 128
long_500k   : decode_step,  KV 524288,   global_batch 1   (sub-quadratic only)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache

__all__ = ["SHAPES", "ShapeCfg", "input_specs", "cache_spec", "shape_runnable"]


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}


def shape_runnable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (SWA / SSM / hybrid)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention architecture — "
                       "unbounded KV at 512k context (see DESIGN.md)")
    return True, ""


def _tok(b, t):
    return jax.ShapeDtypeStruct((b, t), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeCfg, act_dtype=jnp.bfloat16):
    """Inputs for the step function of this cell (no allocation)."""
    B, T = shape.batch, shape.seq
    if shape.kind == "train":
        if cfg.embed_inputs:
            inputs = _tok(B, T)
        else:  # modality frontend stub: precomputed frame/patch embeddings
            inputs = jax.ShapeDtypeStruct((B, T, cfg.d_model), act_dtype)
        labels = (
            _tok(B, T) if cfg.num_output_heads == 1
            else jax.ShapeDtypeStruct((B, T, cfg.num_output_heads), jnp.int32))
        batch = {"inputs": inputs, "labels": labels}
        if cfg.prefix_lm:
            batch["prefix_len"] = jax.ShapeDtypeStruct((), jnp.int32)
        return batch
    if shape.kind == "prefill":
        if cfg.embed_inputs:
            inputs = _tok(B, T)
        else:
            inputs = jax.ShapeDtypeStruct((B, T, cfg.d_model), act_dtype)
        batch = {"inputs": inputs}
        if cfg.prefix_lm:
            batch["prefix_len"] = jax.ShapeDtypeStruct((), jnp.int32)
        return batch
    # decode: one new token against a seq-length cache
    if cfg.embed_inputs:
        tokens = _tok(B, 1)
    else:
        tokens = jax.ShapeDtypeStruct((B, 1, cfg.d_model), act_dtype)
    return {"tokens": tokens, "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_spec(cfg: ModelConfig, shape: ShapeCfg, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the KV/recurrent cache for this cell."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.batch, shape.seq, dtype=dtype))
