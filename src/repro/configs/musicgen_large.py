"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens [arXiv:2306.05284; hf]. The EnCodec
frontend is a STUB: input_specs() provides precomputed frame embeddings
(sum of the 4 codebook embeddings after the delay pattern) [B, T, d]; the
output is 4 codebook heads of vocab 2048 each (num_output_heads=4).
Non-gated GELU MLP. Full attention -> long_500k skipped.
"""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", d_model=2048, n_heads=32, n_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=2048,
    pattern=(LayerSpec("attn", "dense"),), num_periods=48,
    act="gelu", embed_inputs=False, num_output_heads=4,
    family="audio", param_dtype=jnp.bfloat16, kv_quant=True)

REDUCED = dataclasses.replace(
    CONFIG, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
    vocab_size=512, num_periods=2,
    param_dtype=jnp.float32, loss_chunk=16, block_q=16, block_k=32,
    kv_quant=False)
