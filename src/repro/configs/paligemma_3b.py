"""paligemma-3b [vlm]: 18L d2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

SigLIP + gemma [arXiv:2407.07726; hf]. The SigLIP frontend is a STUB per
the assignment: input_specs() provides precomputed patch+text embeddings
[B, T, d]; the first `prefix_len` positions (image patches) attend
bidirectionally (prefix-LM). head_dim=256 (gemma-2b). Full prefix attention
-> long_500k skipped.
"""

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    pattern=(LayerSpec("attn", "glu"),), num_periods=18,
    act="gelu", embed_inputs=False, prefix_lm=True,
    family="vlm", param_dtype=jnp.bfloat16)

REDUCED = dataclasses.replace(
    CONFIG, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32, d_ff=256,
    vocab_size=512, num_periods=2,
    param_dtype=jnp.float32, loss_chunk=16, block_q=16, block_k=32)
