"""Shared helpers for architecture config modules."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.transformer import LayerSpec, ModelConfig

__all__ = ["dense_lm", "reduce_dense", "LayerSpec", "ModelConfig"]


def dense_lm(name, *, layers, d_model, n_heads, n_kv, d_ff, vocab,
             head_dim=None, ffn="glu", act="silu", qk_norm=False, window=0,
             rope_theta=1e4, tie=False, family="dense", sub_quadratic=False,
             dtype=jnp.bfloat16, **kw):
    head_dim = head_dim or d_model // n_heads
    return ModelConfig(
        name=name, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=d_ff, vocab_size=vocab,
        pattern=(LayerSpec("attn", ffn, window),), num_periods=layers,
        qk_norm=qk_norm, act=act, rope_theta=rope_theta, tie_embeddings=tie,
        family=family, sub_quadratic=sub_quadratic, param_dtype=dtype, **kw)


def reduce_dense(full: ModelConfig, *, layers=4, d_model=128, n_heads=4,
                 n_kv=2, head_dim=32, d_ff=256, vocab=512, window=0, **kw):
    """Structure-preserving shrink for CPU smoke tests."""
    pat = tuple(
        dataclasses.replace(s, window=(window or (8 if s.window else 0)))
        for s in full.pattern)
    return dataclasses.replace(
        full, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=d_ff, vocab_size=vocab, pattern=pat,
        num_periods=layers, param_dtype=jnp.float32, loss_chunk=16,
        block_q=16, block_k=32, **kw)
