"""qwen3-14b [dense]: 40L d5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk_norm + GQA [hf:Qwen/Qwen3-8B; hf]. head_dim fixed at 128 (Qwen3 style).
Pure full attention -> long_500k skipped.
"""

from repro.configs.common import dense_lm, reduce_dense

CONFIG = dense_lm(
    "qwen3-14b", layers=40, d_model=5120, n_heads=40, n_kv=8,
    d_ff=17408, vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6)

REDUCED = reduce_dense(CONFIG)
