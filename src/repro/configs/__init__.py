"""Architecture registry: get_config / reduced_config / input_specs.

Every assigned architecture is a selectable config (``--arch <id>``); each
module defines CONFIG (full, dry-run-only) and REDUCED (CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCHS = [
    "h2o_danube3_4b",
    "qwen3_14b",
    "minitron_8b",
    "granite_3_8b",
    "deepseek_v2_lite_16b",
    "dbrx_132b",
    "xlstm_350m",
    "paligemma_3b",
    "musicgen_large",
    "jamba_v01_52b",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _module(name: str):
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def reduced_config(name: str):
    return _module(name).REDUCED


def list_archs():
    return list(ARCHS)


from repro.configs.shapes import SHAPES, input_specs, cache_spec  # noqa: E402

__all__ = ["ARCHS", "get_config", "reduced_config", "list_archs",
           "SHAPES", "input_specs", "cache_spec"]
