"""Fault-tolerant training loop: resume, async checkpoints, straggler watch.

Restart discipline: data is a pure function of step (data/pipeline.py),
checkpoints carry the full {params, opt} state, and RNG never leaks across
steps — so kill -9 at any point resumes bit-exactly from the last committed
checkpoint (tests/test_runtime.py proves equality against an uninterrupted
run).

Straggler mitigation: per-step wall time is tracked with an EMA; steps
slower than `straggler_factor` x EMA are logged with their step index. On a
real fleet this feeds the coordinator's slow-host eviction; on one host it
is the observability hook (the policy layer is pluggable via `on_straggler`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.models.model import make_train_state, train_step
from repro.optim.adamw import AdamWConfig

__all__ = ["TrainLoop", "TrainLoopConfig"]


@dataclasses.dataclass
class TrainLoopConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class TrainLoop:
    def __init__(self, cfg, opt_cfg: AdamWConfig, loop_cfg: TrainLoopConfig,
                 batch_fn: Callable[[int], dict], seed: int = 0,
                 state_shardings=None, on_straggler=None, log=print):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.loop = loop_cfg
        self.batch_fn = batch_fn
        self.ckpt = AsyncCheckpointer(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
        self.on_straggler = on_straggler or (lambda step, dt, ema: None)
        self.log = log
        key = jax.random.PRNGKey(seed)
        self.state = make_train_state(key, cfg, opt_cfg)
        self.step = 0
        last = latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            self.state = restore_checkpoint(
                loop_cfg.ckpt_dir, last, self.state, shardings=state_shardings)
            self.step = last
            self.log(f"[resume] restored step {last} from {loop_cfg.ckpt_dir}")

    def run(self, num_steps: int, die_at_step: int | None = None):
        """Run until self.step == num_steps. `die_at_step` simulates a node
        failure (raises) — used by the fault-tolerance tests/example."""
        ema = None
        metrics = {}
        while self.step < num_steps:
            batch = self.batch_fn(self.step)
            t0 = time.perf_counter()
            self.state, metrics = train_step(
                self.state, batch, self.cfg, self.opt_cfg)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > self.loop.straggler_factor * ema and self.step > 3:
                self.log(f"[straggler] step {self.step}: {dt:.3f}s "
                         f"(ema {ema:.3f}s)")
                self.on_straggler(self.step, dt, ema)
            self.step += 1
            if self.step % self.loop.log_every == 0:
                self.log(f"[train] step {self.step} "
                         f"loss {float(metrics['loss']):.4f} {dt*1e3:.0f}ms")
            if self.step % self.loop.ckpt_every == 0 or self.step == num_steps:
                self.ckpt.save(self.step, self.state)
            if die_at_step is not None and self.step == die_at_step:
                self.ckpt.wait()
                raise RuntimeError(f"simulated node failure at step {self.step}")
        self.ckpt.wait()
        return self.state, metrics
