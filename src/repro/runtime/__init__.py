from repro.runtime.trainloop import TrainLoop, TrainLoopConfig

__all__ = ["TrainLoop", "TrainLoopConfig"]
