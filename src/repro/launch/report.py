"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from sweep JSONL.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun_all.jsonl
"""

from __future__ import annotations

import json
import sys


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}GB"


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load(path):
    recs = {}
    for line in open(path):
        line = line.strip()
        if not line or line in ("DONE", "ALLDONE"):
            continue
        r = json.loads(line)
        arch = r["arch"].replace("-", "_").replace(".", "")
        r["arch"] = arch
        key = (arch, r["shape"], r["mesh"], r.get("variant", ""))
        recs[key] = r  # last write wins
    return recs


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | status | compile | resident/dev | fits | collectives present |",
            "|---|---|---|---|---|---|---|---|"]
    for (a, s, m, var), r in sorted(recs.items()):
        if var: continue
        mem = r.get("mem") or {}
        coll = r.get("collectives_hlo_raw") or {}
        kinds = ",".join(sorted(k for k in coll if k != "total" and coll[k] > 0))
        status = r["status"] if r["status"] != "skipped" else "skip"
        rows.append(
            f"| {a} | {s} | {m} | {status} | {r.get('compile_s','-')}s "
            f"| {_fmt_bytes(mem.get('resident_bytes'))} "
            f"| {mem.get('fits_hbm','-')} | {kinds or '-'} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "MODEL_FLOPs/HLO | note |",
            "|---|---|---|---|---|---|---|---|"]
    for (a, s, m, var), r in sorted(recs.items()):
        if var: continue
        if m != mesh or r["status"] != "ok":
            continue
        note = _bottleneck_note(r)
        rows.append(
            f"| {a} | {s} | {_fmt_s(r.get('compute_s'))} "
            f"| {_fmt_s(r.get('memory_s'))} | {_fmt_s(r.get('collective_s'))} "
            f"| **{r.get('dominant','-').replace('_s','')}** "
            f"| {r.get('useful_flops_ratio', 0):.2f} | {note} |")
    return "\n".join(rows)


def _bottleneck_note(r) -> str:
    dom = r.get("dominant")
    if dom == "compute_s":
        ratio = r.get("useful_flops_ratio", 0)
        if ratio < 0.55:
            return "masked attn blocks / remat waste: skip fully-masked KV blocks"
        return "near peak: fuse or quantize to move further"
    if dom == "memory_s":
        return "weight/KV streaming bound: quantize KV or batch more queries"
    return "shard or overlap collectives; compress cross-pod grads"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_all.jsonl"
    recs = load(path)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    n_err = sum(1 for r in recs.values() if r["status"] == "error")
    fits = sum(1 for r in recs.values()
               if r.get("mem", {}).get("fits_hbm") is True)
    print(f"## Dry-run summary: {n_ok} ok / {n_skip} skipped / {n_err} error; "
          f"{fits}/{n_ok} fit 16GB HBM\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, per device, per step)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
