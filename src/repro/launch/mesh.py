"""Production mesh definitions.

Single pod : (16, 16)    = 256 chips, axes (data, model)
Multi-pod  : (2, 16, 16) = 512 chips, axes (pod, data, model)

The `model` axis carries TP/EP (and graph parallelism for the ANN engine —
the paper's linear-scaling strategy, §6.3); `data`/`pod` carry DP/FSDP and
query parallelism. Functions, not module constants: importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "mesh_shape",
           "enter_mesh"]


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types where the jax version has them."""
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):    # absent on older jax
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def enter_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh on new jax, the
    legacy `with mesh:` global-mesh context on older releases."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes usable for batch/data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_shape(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
