"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

`cost_analysis()` on the partitioned module is per-device (verified against
a hand-counted matmul). Collective bytes are parsed from the compiled HLO
text: we sum the result-shape bytes of every collective op, scaled by the
ring-traffic factor (all-reduce moves ~2x its payload over the slowest
link; the others ~1x).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e chip constants (per assignment) + the storage tier."""

    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link
    hbm_bytes: float = 16e9
    # Storage tier (the paper's SmartSSD): sequential-read / P2P-DMA
    # bandwidth from flash to the accelerator — §6.5 measures ~3 GB/s and
    # shows the whole platform is bound by this term at SIFT1B scale.
    ssd_bw: float = 3.0e9           # B/s per device


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_FACTOR = {
    # ring all-reduce = reduce-scatter + all-gather: ~2x payload on a link.
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes by collective kind (result-shape * ring factor)."""
    out: dict[str, float] = {}
    for type_str, kind in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(type_str) * _FACTOR[kind]
        out[kind] = out.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def roofline_terms(flops_per_dev, bytes_per_dev, coll_bytes_per_dev,
                   hw: HW = HW()):
    t_c = flops_per_dev / hw.peak_flops
    t_m = bytes_per_dev / hw.hbm_bw
    t_n = coll_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n}
    dom = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_n)
    frac = t_c / bound if bound > 0 else 0.0
    return {**terms, "dominant": dom, "compute_fraction": frac}


def model_flops(n_params_active: float, tokens: float, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for train, 2*N*D for inference forward."""
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_params_active * tokens
