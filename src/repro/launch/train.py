"""End-to-end training launcher.

Runs on whatever devices exist (1 CPU for the examples; the production mesh
shardings engage automatically when the device count allows). Demonstrates
the full substrate: deterministic data, AdamW, remat, async checkpoints,
resume-after-failure.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 200 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import TokenDataset
from repro.data.pipeline import make_batch
from repro.optim.adamw import AdamWConfig
from repro.runtime import TrainLoop, TrainLoopConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--die-at-step", type=int, default=None,
                    help="simulate a node failure (for FT demos)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    loop_cfg = TrainLoopConfig(ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every)

    def batch_fn(step: int):
        b = make_batch(cfg, "train", args.seq, args.batch, step=step,
                       seed=args.seed)
        return jax.tree.map(jax.numpy.asarray, b)

    loop = TrainLoop(cfg, opt, loop_cfg, batch_fn, seed=args.seed)
    state, metrics = loop.run(args.steps, die_at_step=args.die_at_step)
    print(f"final step {loop.step} loss {float(metrics['loss']):.4f}")
    return state


if __name__ == "__main__":
    main()
