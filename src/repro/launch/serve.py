"""ANN serving CLI (the paper's deployment mode) over repro.api/repro.serve.

Two request paths, one flag apart:

  sync (default)      : `serve_loop` — fixed-stride batches straight into
                        `SearchService.search`; kept as the compatibility
                        shim that benchmarks/fig12 and the examples use.
  async (--serve-async): the repro.serve subsystem — per-query submission
                        through the dynamic batcher and the replica pool,
                        modeling the paper's host that feeds 4 SmartSSDs
                        (Fig. 10); prints the full ServeStats rollup
                        (QPS, queueing vs execution latency, batch-size
                        histogram, per-replica counters).

  PYTHONPATH=src python -m repro.launch.serve --n 20000 --partitions 4 \
      --batch 64 --num-batches 50 --backend partitioned --metric l2 \
      --serve-async --replicas 4 --max-batch 64 --max-wait-ms 2

With `--shards N` the index is built as a `repro.cluster` scatter-gather
cluster instead of one service (`--shard-replicas R` for per-shard
failover sets); either request path fronts the router unchanged.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset


def serve_loop(service, queries, batch: int, k: int, ef: int,
               rerank: bool = False, log=print):
    """Stream `queries` through in fixed batches; returns (ids, stats).

    Synchronous compatibility loop (fig12 / examples): no queue, no
    dynamic batching — one blocking `search` per stride. `service` is a
    SearchService (or MutableSearchService).
    """
    svc = service
    lat = []
    n = 0
    ids_all = []
    t_start = time.perf_counter()
    for i in range(0, len(queries) - batch + 1, batch):
        q = queries[i : i + batch]
        t0 = time.perf_counter()
        resp = svc.search(SearchRequest(queries=q, k=k, ef=ef, rerank=rerank))
        jax.block_until_ready(resp.ids)
        lat.append(time.perf_counter() - t0)
        ids_all.append(np.asarray(resp.ids))
        n += batch
    wall = time.perf_counter() - t_start
    lat_ms = np.array(lat) * 1e3
    stats = {
        "qps": n / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "batches": len(lat),
    }
    log(f"[serve] {n} queries  {stats['qps']:.1f} QPS  "
        f"p50 {stats['p50_ms']:.1f}ms  p99 {stats['p99_ms']:.1f}ms")
    return np.concatenate(ids_all) if ids_all else np.zeros((0, k)), stats


def serve_async(service, queries, *, k: int, ef: int, rerank: bool = False,
                replicas: int = 2, max_batch: int = 64,
                max_wait_ms: float = 2.0, slo=None,
                flight_out: str | None = None, log=print):
    """Per-query submission through repro.serve; returns (ids, stats dict).

    Queries are submitted one by one — the dynamic batcher, not the caller,
    decides the accelerator batch shapes. `slo` attaches an SLOTracker
    (breach summary printed at drain); `flight_out` writes the slow-query
    flight recorder's Perfetto dump there after drain.
    """
    from repro.serve import SearchServer

    svc = service
    with SearchServer(svc, replicas=replicas, max_batch=max_batch,
                      max_wait_ms=max_wait_ms, slo=slo) as srv:
        futs = srv.submit_many(queries, k=k, ef=ef, rerank=rerank)
        results = [f.result() for f in futs]
        srv.drain()
        roll = srv.stats()
        if srv.slo is not None:
            for line in srv.slo.summary().splitlines():
                log(f"[serve-async] {line}")
        if flight_out:
            log(f"[serve-async] flight  -> {srv.debug_dump(flight_out)}")
    log(f"[serve-async] {roll.summary()}")
    for r in roll.replicas:
        extra = ("" if "block_reads" not in r else
                 f"  block_reads={r['block_reads']} "
                 f"hit_rate={r['cache_hit_rate']:.2f}")
        log(f"[serve-async]   replica {r['replica']}: {r['queries']} queries "
            f"in {r['batches']} batches, busy {r['busy_s']:.2f}s{extra}")
    ids = np.stack([r.ids for r in results])
    stats = {
        "qps": roll.qps,
        "p50_ms": roll.e2e_ms["p50"],
        "p99_ms": roll.e2e_ms["p99"],
        "queue_p50_ms": roll.queue_ms["p50"],
        "exec_p50_ms": roll.exec_ms["p50"],
        "batches": int(sum(roll.batch_sizes.values())),
        "mean_batch": roll.mean_batch,
        "replicas": roll.replicas,
    }
    return ids, stats


def build_service(args, ds: VectorDataset) -> SearchService:
    storage = args.storage
    if args.backend == "csd" and not storage:
        storage = tempfile.mkdtemp(prefix="repro-serve-csd-")
        print(f"[serve] --storage not given; csd block store at {storage}")
    spec = IndexSpec(metric=args.metric, backend=args.backend,
                     num_partitions=args.partitions,
                     hnsw=HNSWConfig(M=args.M),
                     keep_vectors=args.rerank and args.backend != "csd",
                     storage_path=storage)
    if args.shards > 1:
        from repro.cluster import build_cluster
        print(f"[serve] building {args.shards}-shard {spec.backend} cluster "
              f"(x{args.shard_replicas} replicas, "
              f"{args.partitions} partitions/shard, metric={spec.metric}) "
              f"over {args.n} vectors ...")
        t0 = time.perf_counter()
        router = build_cluster(ds.vectors(), spec, args.shards,
                               replicas=args.shard_replicas,
                               path=storage)
        print(f"[serve] build {time.perf_counter()-t0:.1f}s")
        return router
    print(f"[serve] building {spec.backend} index "
          f"({args.partitions} partitions, metric={spec.metric}) over "
          f"{args.n} vectors ...")
    t0 = time.perf_counter()
    service = SearchService.build(ds.vectors(), spec)
    print(f"[serve] build {time.perf_counter()-t0:.1f}s")
    return service


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64,
                    help="sync stride / async submission window size")
    ap.add_argument("--num-batches", type=int, default=20)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=40)
    ap.add_argument("--M", type=int, default=16)
    ap.add_argument("--metric", default="l2",
                    choices=["l2", "ip", "cosine"])
    ap.add_argument("--backend", default="partitioned",
                    choices=["exact", "hnsw", "partitioned", "distributed",
                             "csd"])
    ap.add_argument("--rerank", action="store_true")
    ap.add_argument("--serve-async", action="store_true",
                    help="serve through repro.serve (queue + dynamic "
                         "batcher + replica pool) instead of the sync loop")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the index across N cluster workers "
                         "(repro.cluster scatter-gather router)")
    ap.add_argument("--shard-replicas", type=int, default=1,
                    help="replicas per shard (failover set)")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="dynamic batcher flush size (default: --batch)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--storage", default=None,
                    help="csd block-store directory (default: a tempdir)")
    ap.add_argument("--trace", action="store_true",
                    help="record hierarchical trace spans over the whole "
                         "request path (repro.obs)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="per-request trace sampling rate in [0, 1]")
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome/Perfetto trace-event JSON here "
                         "(implies --trace)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a metrics snapshot here (.json -> JSON, "
                         "else Prometheus text exposition)")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="with --metrics-out: re-emit the file every N "
                         "seconds while serving (0 = once, at the end)")
    ap.add_argument("--slo", action="store_true",
                    help="track the stock SLOs (p99 e2e latency, error "
                         "rate) and print a breach summary at drain "
                         "(async path only)")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="latency SLO: 99%% of requests under this many ms")
    ap.add_argument("--slo-error-rate", type=float, default=0.01,
                    help="error-rate SLO: failed-request budget fraction")
    ap.add_argument("--flight-out", default=None,
                    help="write the slow-query flight recorder's Perfetto "
                         "JSON dump here at drain (async path only)")
    args = ap.parse_args(argv)

    from repro.obs import PeriodicExporter, TRACER, write_snapshot
    if args.trace or args.trace_out:
        TRACER.configure(enabled=True, sample_rate=args.trace_sample)

    slo_tracker = None
    if args.slo:
        from repro.obs import SLOTracker, default_slos
        slo_tracker = SLOTracker(default_slos(
            p99_ms=args.slo_p99_ms, error_rate=args.slo_error_rate))
    if (args.slo or args.flight_out) and not args.serve_async:
        print("[serve] note: --slo/--flight-out need the async serve path; "
              "pass --serve-async (ignored on the sync loop)")

    ds = VectorDataset(args.n, args.dim)
    service = build_service(args, ds)
    queries = ds.queries(args.batch * args.num_batches)

    exporter = None
    if args.metrics_out and args.metrics_interval > 0:
        exporter = PeriodicExporter(
            args.metrics_out, args.metrics_interval,
            tracer=TRACER if (args.trace or args.trace_out) else None,
            trace_path=args.trace_out).start()
    try:
        if args.serve_async:
            _, stats = serve_async(
                service, queries, k=args.k, ef=args.ef, rerank=args.rerank,
                replicas=args.replicas,
                max_batch=args.max_batch or args.batch,
                max_wait_ms=args.max_wait_ms, slo=slo_tracker,
                flight_out=args.flight_out)
        else:
            _, stats = serve_loop(service, queries, args.batch, args.k,
                                  args.ef, rerank=args.rerank)
    finally:
        if exporter is not None:
            exporter.stop()                  # final complete snapshot
        elif args.metrics_out:
            print(f"[serve] metrics -> {write_snapshot(args.metrics_out)}")
        if args.trace_out:
            print(f"[serve] trace   -> {TRACER.write(args.trace_out)}")
    return stats


if __name__ == "__main__":
    main()
