"""Batched ANN serving loop (the paper's deployment mode) on repro.api.

The request path mirrors paper Fig. 4: the database (all partitions) is
resident on the accelerators; the host only batches `SearchRequest`s and
collects (gid, dist) results. QPS / latency percentiles are printed per
window — benchmarks/fig12_platforms.py reuses this loop. Backend and
metric come from the CLI, so the same loop serves the exact scan, the
monolithic graph, the paper's partitioned engine, or the distributed one:

  PYTHONPATH=src python -m repro.launch.serve --n 20000 --partitions 4 \
      --batch 64 --num-batches 50 --backend partitioned --metric l2
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset


def serve_loop(service, queries, batch: int, k: int, ef: int,
               rerank: bool = False, log=print):
    """Stream `queries` through in fixed batches; returns (ids, stats).

    `service` is a SearchService; the deprecated ANNEngine shim is accepted
    too (it exposes the same search contract through its service).
    """
    svc = getattr(service, "_service", service)
    lat = []
    n = 0
    ids_all = []
    t_start = time.perf_counter()
    for i in range(0, len(queries) - batch + 1, batch):
        q = queries[i : i + batch]
        t0 = time.perf_counter()
        resp = svc.search(SearchRequest(queries=q, k=k, ef=ef, rerank=rerank))
        jax.block_until_ready(resp.ids)
        lat.append(time.perf_counter() - t0)
        ids_all.append(np.asarray(resp.ids))
        n += batch
    wall = time.perf_counter() - t_start
    lat_ms = np.array(lat) * 1e3
    stats = {
        "qps": n / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "batches": len(lat),
    }
    log(f"[serve] {n} queries  {stats['qps']:.1f} QPS  "
        f"p50 {stats['p50_ms']:.1f}ms  p99 {stats['p99_ms']:.1f}ms")
    return np.concatenate(ids_all) if ids_all else np.zeros((0, k)), stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-batches", type=int, default=20)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--ef", type=int, default=40)
    ap.add_argument("--M", type=int, default=16)
    ap.add_argument("--metric", default="l2",
                    choices=["l2", "ip", "cosine"])
    ap.add_argument("--backend", default="partitioned",
                    choices=["exact", "hnsw", "partitioned", "distributed"])
    ap.add_argument("--rerank", action="store_true")
    args = ap.parse_args(argv)

    ds = VectorDataset(args.n, args.dim)
    spec = IndexSpec(metric=args.metric, backend=args.backend,
                     num_partitions=args.partitions,
                     hnsw=HNSWConfig(M=args.M),
                     keep_vectors=args.rerank)
    print(f"[serve] building {spec.backend} index "
          f"({args.partitions} partitions, metric={spec.metric}) over "
          f"{args.n} vectors ...")
    t0 = time.perf_counter()
    service = SearchService.build(ds.vectors(), spec)
    print(f"[serve] build {time.perf_counter()-t0:.1f}s")
    queries = ds.queries(args.batch * args.num_batches)
    _, stats = serve_loop(service, queries, args.batch, args.k, args.ef,
                          rerank=args.rerank)
    return stats


if __name__ == "__main__":
    main()
