import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (device count locks on
first init). This proves the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
here is a bug in the system.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --arch all --shape all --mesh both --out d.jsonl
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCHS, get_config, input_specs, SHAPES
from repro.configs.shapes import cache_spec, shape_runnable
from repro.launch.costmodel import cell_costs
from repro.launch.mesh import enter_mesh, make_production_mesh
from repro.launch.roofline import (
    HW, collective_bytes, model_flops, roofline_terms)
from repro.launch.sharding import (
    batch_specs, cache_specs, count_bytes, state_specs, param_specs)
from repro.models.model import (
    decode_step, make_train_state, prefill_step, train_step)
from repro.models.shard_ctx import activation_sharding
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig

OPT = AdamWConfig()


def _sds(shapes_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes_tree, spec_tree)


def count_params(cfg, params_shapes):
    """(total, active) param counts; expert weights scaled by top_k/E."""
    total = active = 0.0
    def visit(path, leaf):
        nonlocal total, active
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = float(leaf.size)
        total += n
        if "embed" in name or "head" in name:
            return
        if "moe" in name and ("w_in" in name or "w_out" in name) and "shared" not in name:
            n = n * cfg.moe.top_k / cfg.moe.num_experts
        active += n
    jax.tree_util.tree_map_with_path(visit, params_shapes)
    return total, active


def lower_cell(arch: str, shape_name: str, multi_pod: bool, fsdp_serve=None,
               variant: str = ""):
    """variant: comma-joined hillclimb levers applied on top of the config:
    'skip' (masked-block skipping), 'kvq' (int8 KV), 'zero1' (ZeRO-1
    sharding), 'accumN' (grad_accum=N)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    state_mode = "fsdp"
    for v in [x for x in variant.split(",") if x]:
        if v == "skip":
            cfg = _dc.replace(cfg, skip_masked_blocks=True)
        elif v == "kvq":
            cfg = _dc.replace(cfg, kv_quant=True)
        elif v == "zero1":
            state_mode = "zero1"
        elif v.startswith("accum"):
            cfg = _dc.replace(cfg, grad_accum=int(v[5:]))
        else:
            raise ValueError(f"unknown variant {v}")
    shape = SHAPES[shape_name]
    ok, why = shape_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "multi" if multi_pod else "single", "devices": int(n_dev)}
    t0 = time.time()

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda: init_params(key, cfg))
    total_p, active_p = count_params(cfg, params_shapes)
    rec["params_total"] = total_p
    rec["params_active"] = active_p

    # serving keeps params TP-only when they fit comfortably (< ~6 GB/chip
    # at bf16 over the model axis), else keeps the 2D (FSDP) layout.
    model_ax = 16
    serve_fsdp = (total_p * 2 / model_ax) > 6e9 if fsdp_serve is None else fsdp_serve

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    with enter_mesh(mesh), activation_sharding(dp):
        if shape.kind == "train":
            state_shapes = jax.eval_shape(lambda: make_train_state(key, cfg))
            sspec = state_specs(state_shapes, mesh, fsdp=True, mode=state_mode)
            batch_shapes = input_specs(cfg, shape)
            bspec = batch_specs(batch_shapes, mesh)
            args = (_sds(state_shapes, sspec, mesh),
                    _sds(batch_shapes, bspec, mesh))
            lowered = train_step.lower(*args, cfg=cfg, opt_cfg=OPT)
            tokens = shape.batch * shape.seq
        elif shape.kind == "prefill":
            pspec = param_specs(params_shapes, mesh, fsdp=serve_fsdp)
            batch_shapes = input_specs(cfg, shape)
            bspec = batch_specs(batch_shapes, mesh)
            cshapes = cache_spec(cfg, shape)
            cspec = cache_specs(cshapes, mesh)
            args = (_sds(params_shapes, pspec, mesh),
                    _sds(batch_shapes, bspec, mesh),
                    _sds(cshapes, cspec, mesh))
            lowered = prefill_step.lower(*args, cfg=cfg)
            tokens = shape.batch * shape.seq
        else:  # decode
            pspec = param_specs(params_shapes, mesh, fsdp=serve_fsdp)
            inp = input_specs(cfg, shape)
            tspec = batch_specs({"tokens": inp["tokens"]}, mesh)["tokens"]
            cshapes = cache_spec(cfg, shape)
            cspec = cache_specs(cshapes, mesh)
            args = (_sds(params_shapes, pspec, mesh),
                    _sds({"t": inp["tokens"]}, {"t": tspec}, mesh)["t"],
                    _sds(cshapes, cspec, mesh),
                    jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=NamedSharding(
                                             mesh, jax.sharding.PartitionSpec())))
            lowered = decode_step.lower(*args, cfg=cfg)
            tokens = shape.batch
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    # NOTE: XLA counts while-loop bodies once (verified experimentally), so
    # these raw numbers undercount scanned models; the roofline terms below
    # use the loop-aware analytic model (launch/costmodel.py), calibrated
    # against XLA on unrolled configs in tests/test_costmodel.py.
    rec["flops_hlo_raw"] = float(ca.get("flops", 0.0))
    rec["bytes_hlo_raw"] = float(ca.get("bytes accessed", 0.0))
    cost = cell_costs(cfg, shape.kind, shape.seq, shape.batch,
                      n_devices=n_dev, model_ax=16,
                      dp_ax=n_dev // 16, fsdp=(shape.kind == "train" or serve_fsdp),
                      state_mode=state_mode)
    rec["flops_per_dev"] = cost.flops_per_dev
    rec["bytes_per_dev"] = cost.bytes_per_dev
    rec["coll_bytes_analytic"] = cost.coll_bytes_per_dev
    ma = compiled.memory_analysis()
    if ma is not None:
        rec["mem"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        resident = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        rec["mem"]["resident_bytes"] = int(resident)
        rec["mem"]["fits_hbm"] = bool(resident < HW().hbm_bytes)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    rec["collectives_hlo_raw"] = {k: float(v) for k, v in coll.items()}
    rec["hlo_bytes"] = len(txt)

    terms = roofline_terms(
        rec["flops_per_dev"], rec["bytes_per_dev"],
        max(cost.coll_bytes_per_dev, coll.get("total", 0.0)))
    rec.update(terms)
    mf = model_flops(active_p, tokens, shape.kind)
    rec["model_flops_total"] = mf
    rec["model_flops_per_dev"] = mf / n_dev
    if rec["flops_per_dev"] > 0:
        rec["useful_flops_ratio"] = rec["model_flops_per_dev"] / rec["flops_per_dev"]
    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--variant", default="")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    rec = lower_cell(arch, shape, multi, variant=args.variant)
                except Exception as e:  # a failure here is a system bug
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                jax.clear_caches()   # keep the 80-cell sweep's RSS bounded
                line = json.dumps(rec)
                if out_f:
                    out_f.write(line + "\n")
                    out_f.flush()
                if not args.quiet:
                    brief = {k: rec.get(k) for k in
                             ("arch", "shape", "mesh", "status", "compile_s",
                              "dominant", "compute_fraction", "error")}
                    print(json.dumps(brief))
                if rec.get("mem"):
                    print(f"  memory_analysis: resident={rec['mem']['resident_bytes']/1e9:.2f}GB "
                          f"fits_hbm={rec['mem']['fits_hbm']}", file=sys.stderr)
    if out_f:
        out_f.close()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
