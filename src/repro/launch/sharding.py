"""Path-based sharding rules: DP / FSDP / TP / EP / SP from one rule table.

Strategy (baseline — §Perf iterates on the dominant roofline term):
  * params: TP on `model` (heads / d_ff / experts / d_inner), FSDP on `data`
    for the orthogonal dim. Serving replicates the FSDP dim for models whose
    bf16 params fit HBM at TP-only sharding (<= ~6 GB/chip), else keeps 2D.
  * optimizer state mirrors its param.
  * batch: global batch on (pod, data).
  * decode caches: batch on (pod, data) when divisible; KV sequence on
    `model` (flash-decoding-style partial softmax via GSPMD reductions);
    B==1 long-context shards the sequence on (data, model).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, mesh_shape

__all__ = ["param_specs", "batch_specs", "cache_specs", "state_specs",
           "named", "count_bytes"]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/" + "/".join(parts)


def _divisible(n: int, axes, sizes) -> bool:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= sizes[a]
    return n % total == 0


def _param_rule(path: str, shape, sizes, fsdp: bool):
    """Return a PartitionSpec for one param leaf."""
    dp = "data" if ("data" in sizes and fsdp) else None
    leaf = path.rsplit("/", 1)[-1]
    nd = len(shape)

    if leaf == "embed":
        return P("model", dp)                        # [V, d]
    if leaf == "head":
        return P(dp, None, "model")                  # [d, nH, V]
    if leaf in ("wq", "wk", "wv") and nd == 3:
        return P(dp, "model", None)                  # [d, H, hd]
    if leaf == "wo" and nd == 3:
        return P("model", None, dp)                  # [H, hd, d]
    if leaf in ("wq", "wk", "wv") and nd == 2:       # mlstm [di, di]
        return P(None, "model")
    if leaf == "w_dkv":
        return P(dp, None)                           # [d, lora+rope]
    if leaf in ("w_uk", "w_uv"):
        return P(None, "model", None)                # [lora, H, x]
    if leaf == "w_in" and nd == 4:
        return P("model", dp, None, None)            # MoE [E, d, 2, F]
    if leaf == "w_out" and nd == 3 and "moe" in path:
        return P("model", None, dp)                  # MoE [E, F, d]
    if leaf in ("w_in", "shared_w_in", "ffn_in") and nd == 3:
        return P(dp, None, "model")                  # GLU [d, 2, F]
    if leaf in ("w_in", "shared_w_in") and nd == 2:
        return P(dp, "model")                        # dense [d, F]
    if leaf in ("w_out", "shared_w_out", "ffn_out") and nd == 2:
        return P("model", dp)                        # [F, d]
    if leaf == "router":
        return P(dp, None)                           # [d, E]
    if leaf in ("in_proj",):
        return P(dp, None, "model")                  # [d, 2, di]
    if leaf == "dt_proj":
        return P(dp, "model")                        # [r, di]: di rides model
    if leaf == "out_proj":
        return P("model", dp) if nd == 2 else P("model")
    if leaf in ("x_proj",):
        return P("model", None)                      # [di, r+2S]
    if leaf in ("conv_w",):
        return P(None, "model")                      # [K, di]
    if leaf in ("A_log",):
        return P("model", None)                      # [di, S]
    if leaf in ("conv_b", "dt_bias", "D", "gn_scale", "skip", "w_i", "w_f"):
        return P("model") if nd == 1 else P("model", None)
    if leaf == "w_gates":
        return P(dp, None, None, "model")            # slstm [d, 4, H, dh]
    if leaf == "r_gates":
        return P(None, None, "model", None)          # [4, H, dh, dh]
    if leaf == "b_gates":
        return P(None, None, None)
    # norms / scalars / fallback: replicate
    return P(*([None] * nd))


def _sanitize(spec: P, shape, sizes) -> P:
    """Drop mesh axes whose size does not evenly divide the dim (explicit
    input shardings require exact tiling)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axs:
            total *= sizes[a]
        out.append(ax if (total and dim % total == 0) else None)
    return P(*out)


def _place_missing(spec: P, shape, sizes, want=("model",)) -> P:
    """If a wanted mesh axis was dropped (non-divisible dim), re-home it:
    first on an unsharded dim it divides, else combined with an existing
    axis tuple on a dim both divide. Keeps big-param leaves sharded even
    when the 'natural' dim is awkward (40 heads, 49155 vocab, ...)."""
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,) if e else ()):
            used.add(a)
    for ax in want:
        if ax in used:
            continue
        placed = False
        for i in range(len(shape) - 1, -1, -1):       # prefer trailing dims
            if entries[i] is None and shape[i] % sizes[ax] == 0:
                entries[i] = ax
                placed = True
                break
        if not placed:
            for i in range(len(shape)):
                e = entries[i]
                if e is None:
                    continue
                cur = e if isinstance(e, tuple) else (e,)
                total = sizes[ax]
                for a in cur:
                    total *= sizes[a]
                if shape[i] % total == 0:
                    entries[i] = tuple(cur) + (ax,)
                    break
    return P(*entries)


def param_specs(params_shapes, mesh, *, fsdp: bool = True):
    """Pytree of PartitionSpec matching a params (or m/v) shape tree.

    Leaves under /periods/ are scan-stacked with a leading period axis —
    the rule applies to shape[1:] with the stack axis replicated.
    """
    sizes = mesh_shape(mesh)

    def rule(path, leaf):
        p = _path_str(path)
        stacked = p.startswith("/periods/")
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = _param_rule(p, shape, sizes, fsdp)
        spec = _sanitize(spec, shape, sizes)
        if leaf.size >= 1 << 16:      # only big leaves worth re-homing
            spec = _place_missing(spec, shape, sizes)
        return P(None, *spec) if stacked else spec

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def state_specs(state_shapes, mesh, *, fsdp: bool = True, mode: str = "fsdp"):
    """Specs for {"params": ..., "opt": {"m","v","step"}}.

    mode="fsdp"  : params AND optimizer state sharded on `data` (ZeRO-3-ish;
                   params all-gather per layer fwd+bwd, grads reduce-scatter).
    mode="zero1" : params replicated on `data` (one all-reduce of grads per
                   step), optimizer m/v still data-sharded — trades param
                   memory for ~2x less per-step collective traffic on
                   collective-bound cells (EXPERIMENTS.md §Perf).
    """
    p = param_specs(state_shapes["params"], mesh,
                    fsdp=(fsdp and mode == "fsdp"))
    return {
        "params": p,
        "opt": {
            "m": param_specs(state_shapes["opt"]["m"], mesh, fsdp=fsdp),
            "v": param_specs(state_shapes["opt"]["v"], mesh, fsdp=fsdp),
            "step": P(),
        },
    }


def batch_specs(batch_shapes, mesh):
    sizes = mesh_shape(mesh)
    dp = dp_axes(mesh)

    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        lead = dp if _divisible(b, dp, sizes) else None
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_specs(cache_shapes, mesh):
    """Decode/prefill cache sharding (see module docstring)."""
    sizes = mesh_shape(mesh)
    dp = dp_axes(mesh)

    def rule(path, leaf):
        p = _path_str(path)
        name = p.rsplit("/", 1)[-1]
        stacked = p.startswith("/periods/")
        shape = leaf.shape[1:] if stacked else leaf.shape

        def out(spec):
            return P(None, *spec) if stacked else spec

        b = shape[0]
        nd = len(shape)
        b_ax = dp if _divisible(b, dp, sizes) else None
        if name in ("k", "v", "ks", "vs"):   # [B, S, KV, hd|1]
            s_ax = ("model",) if b_ax else ("data", "model")
            s_ax = s_ax if _divisible(shape[1], s_ax, sizes) else None
            return out(P(b_ax, s_ax, None, None))
        if name in ("c", "kr"):         # MLA [B, S, lora]
            s_ax = ("model",) if b_ax else ("data", "model")
            s_ax = s_ax if _divisible(shape[1], s_ax, sizes) else None
            return out(P(b_ax, s_ax, None))
        if name == "conv":              # [B, K-1, di]
            m = "model" if _divisible(shape[2], "model", sizes) else None
            return out(P(b_ax, None, m))
        if name == "ssm":               # [B, di, S]
            m = "model" if _divisible(shape[1], "model", sizes) else None
            return out(P(b_ax, m, None))
        if name == "C":                 # mlstm [B, H, dh, dh]
            m = "model" if _divisible(shape[2], "model", sizes) else None
            return out(P(b_ax, None, m, None))
        if name in ("n", "sc", "sn", "sh", "sm") and nd == 3:  # [B, H, dh]
            m = "model" if _divisible(shape[2], "model", sizes) else None
            return out(P(b_ax, None, m))
        if nd >= 1:
            return out(P(b_ax, *([None] * (nd - 1))))
        return out(P())

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def named(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def count_bytes(shapes_tree) -> int:
    return sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(shapes_tree))
