import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512").strip()

"""SIFT1B-scale dry-run of the paper's engine itself on the production mesh.

The paper's deployment: 1B x 128-dim vectors split into DRAM-sized
sub-graphs, graph parallelism across devices. Here: 256 partitions of
~3.9M vectors (cf. the paper's ~5M per SmartSSD), one per chip on the
single-pod mesh; queries shard over `data` (and `pod`). This lowers and
compiles the full two-stage distributed search (stage-1 beam + all-gather +
rank-merge) from ShapeDtypeStructs — no allocation — and reports the memory
and collective footprint.

  PYTHONPATH=src python -m repro.launch.ann_dryrun [--multi-pod]
"""

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import make_distributed_search
from repro.core.hnsw_graph import DeviceDB
from repro.core.search import SearchParams
from repro.launch.mesh import enter_mesh, make_production_mesh
from repro.launch.roofline import HW, collective_bytes


def sift1b_db_specs(mesh, n_total=1_000_000_000, dim=128, M=16, levels=7):
    """ShapeDtypeStruct stand-ins for the restructured SIFT1B database."""
    P_parts = 256
    n_pad = -(-(n_total // P_parts) // 32) * 32
    d_pad = 128 * -(-dim // 128)
    m0p, mp = 2 * M, M
    up_rows = -(-n_pad // 16) * 2        # ~1/(M-1) of points have level>=1
    sh = lambda spec: NamedSharding(mesh, spec)
    f = jax.ShapeDtypeStruct
    m = P(("data", "model"))   # one partition per chip within a pod
    return DeviceDB(
        vectors=f((P_parts, n_pad, d_pad), jnp.float32, sharding=sh(m)),
        sqnorms=f((P_parts, n_pad), jnp.float32, sharding=sh(m)),
        l0_nbrs=f((P_parts, n_pad, m0p), jnp.int32, sharding=sh(m)),
        up_nbrs=f((P_parts, levels, up_rows, mp), jnp.int32, sharding=sh(m)),
        up_ptr=f((P_parts, n_pad), jnp.int32, sharding=sh(m)),
        levels=f((P_parts, n_pad), jnp.int32, sharding=sh(m)),
        gids=f((P_parts, n_pad), jnp.int32, sharding=sh(m)),
        entry=f((P_parts,), jnp.int32, sharding=sh(m)),
        max_level=f((P_parts,), jnp.int32, sharding=sh(m)),
        n_valid=f((P_parts,), jnp.int32, sharding=sh(m)),
    ), n_pad, d_pad, m0p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--calibrated", default=None, metavar="METRICS_JSON",
                    help="fit the cost-model HW parameters (effective SSD "
                         "bandwidth, cache hit rate, dispatch overhead) "
                         "from this metrics snapshot (the exporter's .json "
                         "output) and report per-term modeled-vs-measured "
                         "error alongside the prior-based numbers")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    db, n_pad, d_pad, m0p = sift1b_db_specs(mesh)
    p = SearchParams(ef=40, k=10)                 # the paper's SIFT1B point
    qaxes = ("pod",) if args.multi_pod else ()
    search = make_distributed_search(mesh, p, m0p,
                                     graph_axes=("data", "model"),
                                     query_axes=qaxes)
    q = jax.ShapeDtypeStruct((args.batch, d_pad), jnp.float32,
                             sharding=NamedSharding(
                                 mesh, P(qaxes if qaxes else None, None)))
    with enter_mesh(mesh):
        lowered = search.lower(db, q)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    resident = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    coll = collective_bytes(compiled.as_text())
    hw = HW()
    # memory-bound engine roofline: per-query HBM traffic per hop-budget.
    reads_per_query = 4 * p.ef + 16                # hop budget (worst case)
    bytes_per_query = reads_per_query * m0p * (d_pad * 4 + 4)
    qps_chip = hw.hbm_bw / bytes_per_query

    # storage-bound alternative (repro.store csd mode): the same traversal
    # with the DB on flash — each vector read is one block read over the
    # SSD link; the PageCache absorbs part of it. This reproduces the
    # paper's storage-bound analysis (§6.5 / Fig. 12). SIFT1B itself is
    # uint8 (IndexSpec.dtype): rows shrink 4x, and because the SSD link is
    # byte-limited the effective blocks-per-read shrink with them — the
    # uint8 entry is the paper's actual operating point. The pq entry
    # (M=8 codes, 16x below uint8 at d=128) shows how far LUT-based ADC
    # pushes the same storage-bound roofline.
    from repro.launch.costmodel import storage_cost, vector_row_bytes
    block_size = 4096
    storage = {}
    for dtype in ("float32", "uint8", "pq"):
        row_b = vector_row_bytes(128, dtype)
        # row_bytes/block_size of a block per vector read: the byte-limited
        # SSD-link view (block-packing locality at 8..32 rows per block)
        blocks_per_query = reads_per_query * m0p * row_b / block_size
        per_hit = {}
        for hit in (0.0, 0.5, 0.9):
            sc = storage_cost(blocks_per_query, block_size,
                              cache_hit_rate=hit, ssd_bw=hw.ssd_bw)
            per_hit[f"hit_{hit:.1f}"] = {
                "bytes_from_flash_per_query": sc.bytes_from_flash,
                "modeled_qps_per_device": round(1.0 / sc.storage_s, 2),
            }
        storage[dtype] = {"vector_row_bytes": row_b,
                          "blocks_per_query": round(blocks_per_query, 1),
                          **per_hit}
    blocks_per_query = storage["float32"]["blocks_per_query"]

    # streaming-ingest term (repro.ingest): what growing the same database
    # online would cost in SSD writes. One day of heavy insert traffic at
    # ~1% of the corpus, sealed in SmartSSD-DRAM-sized memtables and
    # compacted every 8 seals (the merge-everything policy the compactor
    # implements), priced as write amplification on the same SSD link the
    # reads contend for.
    from repro.launch.costmodel import compaction_cost
    ingest = {}
    n_daily = 10_000_000
    seal_threshold = 1_000_000
    compact_every = 8
    for dtype in ("float32", "uint8", "pq"):
        row_b = vector_row_bytes(128, dtype)
        cc = compaction_cost(n_daily, row_b, seal_threshold, compact_every,
                             delete_frac=0.05, ssd_bw=hw.ssd_bw)
        ingest[dtype] = {
            "bytes_ingested": cc.bytes_ingested,
            "bytes_rewritten": cc.bytes_rewritten,
            "write_amplification": round(cc.write_amp, 2),
            "seals": cc.seals,
            "compactions": cc.compactions,
            "rewrite_s_on_ssd_link": round(cc.rewrite_s, 1),
        }
    ingest_note = (
        "mutable-index (repro.ingest) write path: {} inserts/day at "
        "seal_threshold={}, compact every {} seals, 5% churn; rewrite "
        "seconds come out of the same SSD link the storage-bound read "
        "roofline above prices".format(n_daily, seal_threshold,
                                       compact_every))

    # cluster fan-out term (repro.cluster): the same storage-bound search
    # sharded across nodes. Each shard replica brings its own SSD link, so
    # aggregate flash bandwidth scales with N*R, but every query pays the
    # router scatter-gather plus full-ef traversal on EVERY shard (the
    # over-fetch that keeps the merge bit-identical) — this row shows where
    # the cluster stops being storage-bound and the router NIC takes over.
    from repro.launch.costmodel import cluster_fanout_cost
    cluster = {}
    for n_shards in (1, 2, 4):
        for reps in (1, 2):
            fc = cluster_fanout_cost(
                n_shards, reps, dim=128, k=10,
                blocks_per_query=blocks_per_query, block_size=block_size,
                cache_hit_rate=0.5, ssd_bw=hw.ssd_bw)
            cluster[f"shards_{n_shards}x{reps}"] = {
                "router_bytes_per_query": fc.router_bytes_q,
                "flash_bytes_per_query": fc.flash_bytes_q,
                "aggregate_ssd_bw": fc.aggregate_ssd_bw,
                "modeled_qps": round(fc.modeled_qps, 1),
                "bound": fc.bound,
            }

    rec = {
        "mesh": "multi" if args.multi_pod else "single",
        "devices": int(mesh.devices.size),
        "partitions": 256,
        "vectors_per_partition": n_pad,
        "db_bytes_per_device": int(resident - ma.temp_size_in_bytes),
        "resident_bytes": int(resident),
        "fits_hbm": bool(resident < hw.hbm_bytes),
        "collectives": {k: float(v) for k, v in coll.items()},
        "modeled_worstcase_qps_per_chip": round(qps_chip, 1),
        "csd_storage_bound": {
            "block_size": block_size,
            "blocks_per_query": blocks_per_query,
            "ssd_bw": hw.ssd_bw,
            **storage,
            "note": ("out-of-core (backend='csd') roofline: storage term "
                     "dominates HBM by ~{:.0f}x at hit 0 — the paper's "
                     "SSD-bound regime (75.59 QPS on 4 SmartSSDs)".format(
                         (blocks_per_query * block_size / hw.ssd_bw)
                         / (bytes_per_query / hw.hbm_bw))),
        },
        "ingest_write_amplification": {**ingest, "note": ingest_note},
        "cluster_fanout": {
            **cluster,
            "note": ("repro.cluster scatter-gather at cache hit 0.5 over a "
                     "10 GbE router link: replicas scale storage QPS "
                     "linearly; shards add SSDs but also duplicate full-ef "
                     "traversal, so gains flatten until the router binds"),
        },
        "note": ("stage-2 merge traffic per query = P*k*(4+4)B across "
                 "`model` — negligible vs stage-1 HBM reads (paper: 0.2%)"),
    }

    if args.calibrated:
        # capacity planning on observed numbers (ROADMAP item 5): fit the
        # HW parameters from the snapshot, report per-term error, and
        # reprice the MEASURED workload with the fitted parameters
        from repro.launch.costmodel import dispatch_cost
        from repro.obs.calibrate import compare_terms, load_calibration
        cal = load_calibration(args.calibrated)
        section = {
            "source": args.calibrated,
            "fitted": cal.asdict(),
            "terms": compare_terms(cal, hw=hw),
        }
        if (cal.queries and cal.blocks_per_query and cal.block_size
                and cal.effective_ssd_bw):
            sc = storage_cost(cal.blocks_per_query, cal.block_size,
                              cache_hit_rate=cal.cache_hit_rate or 0.0,
                              ssd_bw=cal.effective_ssd_bw)
            dc = dispatch_cost(cal.supersteps_per_query or 0.0,
                               cal.dispatch_overhead_s or 0.0)
            total_s = sc.storage_s + dc.dispatch_s
            section["measured_workload"] = {
                "storage_s_per_query": sc.storage_s,
                "dispatch_s_per_query": dc.dispatch_s,
                "calibrated_qps_per_device": (round(1.0 / total_s, 2)
                                              if total_s > 0 else None),
            }
        rec["calibration"] = section

    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
