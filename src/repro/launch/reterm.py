"""Recompute analytic roofline terms for existing sweep records.

The compiled artifacts (memory analysis, HLO collective inventory) are
unchanged by cost-model fixes — only the analytic terms need refreshing.
Rewrites the JSONL in place.

  PYTHONPATH=src python -m repro.launch.reterm experiments/dryrun_all.jsonl
"""

from __future__ import annotations

import dataclasses
import json
import sys

from repro.configs import SHAPES, get_config
from repro.launch.costmodel import cell_costs
from repro.launch.roofline import model_flops, roofline_terms


def refresh(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return rec
    cfg = get_config(rec["arch"])
    state_mode = "fsdp"
    for v in [x for x in rec.get("variant", "").split(",") if x]:
        if v == "skip":
            cfg = dataclasses.replace(cfg, skip_masked_blocks=True)
        elif v == "kvq":
            cfg = dataclasses.replace(cfg, kv_quant=True)
        elif v == "zero1":
            state_mode = "zero1"
        elif v.startswith("accum"):
            cfg = dataclasses.replace(cfg, grad_accum=int(v[5:]))
    shape = SHAPES[rec["shape"]]
    n_dev = rec["devices"]
    serve_fsdp = (rec["params_total"] * 2 / 16) > 6e9
    cost = cell_costs(cfg, shape.kind, shape.seq, shape.batch,
                      n_devices=n_dev, model_ax=16, dp_ax=n_dev // 16,
                      fsdp=(shape.kind == "train" or serve_fsdp),
                      state_mode=state_mode)
    rec["flops_per_dev"] = cost.flops_per_dev
    rec["bytes_per_dev"] = cost.bytes_per_dev
    rec["coll_bytes_analytic"] = cost.coll_bytes_per_dev
    coll_hlo = rec.get("collectives_hlo_raw", {}).get("total", 0.0)
    rec.update(roofline_terms(cost.flops_per_dev, cost.bytes_per_dev,
                              max(cost.coll_bytes_per_dev, coll_hlo)))
    tokens = shape.batch * (1 if shape.kind == "decode" else shape.seq)
    mf = model_flops(rec["params_active"], tokens, shape.kind)
    rec["model_flops_total"] = mf
    rec["model_flops_per_dev"] = mf / n_dev
    if cost.flops_per_dev:
        rec["useful_flops_ratio"] = mf / n_dev / cost.flops_per_dev
    return rec


def main():
    for path in sys.argv[1:]:
        recs = []
        for line in open(path):
            line = line.strip()
            if not line or line == "ALLDONE":
                continue
            recs.append(refresh(json.loads(line)))
        with open(path, "w") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
        print(f"refreshed {len(recs)} records in {path}")


if __name__ == "__main__":
    main()
