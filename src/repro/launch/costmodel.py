"""Loop-aware analytic FLOPs/bytes/collective model per (arch x shape) cell.

Why this exists: XLA's `cost_analysis()` counts a `while` body ONCE, not
times its trip count (verified experimentally — a scan of 8 matmuls reports
1/8 of the unrolled FLOPs). Every model here runs under scan-over-periods
plus inner scans (flash-attention KV blocks, SSM time steps, loss chunks),
so compiled-artifact totals undercount by 1-2 orders of magnitude.

This module enumerates the einsums the model code actually performs (it is
the same source tree — drift is caught by the calibration test, which
compares this model against XLA cost_analysis on a small config compiled
with UNROLLED periods: tests/test_costmodel.py, agreement within ~10%).

Conventions:
  * 1 MAC = 2 FLOPs; train multiplies forward FLOPs by 4
    (fwd + 2x bwd + 1x remat recompute), inference by 1.
  * bytes = HBM traffic per device per step (params read + opt state r/w +
    carry/cache r/w + dominant activation traffic).
  * collectives = bytes crossing links per device per step given the
    baseline sharding of launch/sharding.py (FSDP gathers, grad
    reduce-scatters, SP gathers, MoE all-to-all, vocab psum).
"""

from __future__ import annotations

import dataclasses

from repro.models.transformer import LayerSpec, ModelConfig

__all__ = ["cell_costs", "StorageCost", "storage_cost",
           "CompactionCost", "compaction_cost",
           "ClusterFanoutCost", "cluster_fanout_cost",
           "DispatchCost", "dispatch_cost",
           "VECTOR_DTYPE_BYTES", "vector_row_bytes"]


def _attn_flops_tok(cfg, t_kv):
    """Per-token attention flops against t_kv keys (projections + scores)."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * d * hd * (2 * H + 2 * KV)          # q,o: H; k,v: KV
    sdpa = 2 * 2 * t_kv * H * hd                  # scores + AV
    return proj + sdpa


def _mla_flops_tok(cfg, t_kv, decode: bool):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qd = m.qk_nope + m.qk_rope
    f = 2 * d * H * qd + 2 * d * (m.kv_lora + m.qk_rope)       # wq + w_dkv
    if decode:  # absorbed: q_eff [H,lora], scores vs c, out via w_uv
        f += 2 * H * m.qk_nope * m.kv_lora                      # absorb per tok
        f += 2 * t_kv * H * (m.kv_lora + m.qk_rope)             # scores
        f += 2 * t_kv * H * m.kv_lora                           # AV over c
        f += 2 * H * m.kv_lora * m.v_dim                        # up-proj out
    else:
        f += 2 * m.kv_lora * H * (m.qk_nope + m.v_dim)          # k/v up-proj
        f += 2 * 2 * t_kv * H * qd                              # scores + AV
    f += 2 * H * m.v_dim * d                                    # wo
    return f


def _ffn_flops_tok(cfg, spec: LayerSpec):
    d = cfg.d_model
    if spec.ffn == "glu":
        return 2 * 3 * d * cfg.d_ff
    if spec.ffn == "dense":
        return 2 * 2 * d * cfg.d_ff
    if spec.ffn == "moe":
        mc = cfg.moe
        f = 2 * d * mc.num_experts                               # router
        f += mc.top_k * 2 * 3 * d * mc.d_ff * mc.capacity_factor
        if mc.n_shared:
            f += 2 * 3 * d * mc.shared_ff()
        return f
    return 0


def _mamba_flops_tok(cfg):
    d = cfg.d_model
    mc = cfg.mamba
    di, r, S = mc.inner(d), mc.rank(d), mc.d_state
    f = 2 * d * 2 * di + 2 * mc.d_conv * di                      # in_proj+conv
    f += 2 * di * (r + 2 * S) + 2 * r * di                       # x_proj + dt
    f += 8 * di * S                                              # scan step
    f += 2 * di * d + 3 * di                                     # out + gate
    return f


def _mlstm_flops_tok(cfg):
    d = cfg.d_model
    xc = cfg.xlstm
    di = int(xc.m_proj_factor * d)
    dh = di // xc.n_heads
    f = 2 * d * 2 * di + 2 * xc.d_conv * di
    f += 3 * 2 * di * di                                         # q,k,v
    f += 6 * di * dh                                             # cell update
    f += 2 * di * d + 4 * di
    return f


def _slstm_flops_tok(cfg):
    d = cfg.d_model
    xc = cfg.xlstm
    dh = d // xc.n_heads
    f = 2 * d * 4 * d + 2 * 4 * d * dh                           # gates + rec
    f += 2 * 3 * d * int(xc.s_ffn_factor * d)                    # block ffn
    return f + 10 * d


def _layer_flops_tok(cfg, spec: LayerSpec, t_kv, decode):
    if spec.kind == "attn":
        eff = min(t_kv, spec.window) if spec.window else t_kv
        f = _attn_flops_tok(cfg, eff)
    elif spec.kind == "mla":
        f = _mla_flops_tok(cfg, t_kv, decode)
    elif spec.kind == "mamba":
        f = _mamba_flops_tok(cfg)
    elif spec.kind == "mlstm":
        f = _mlstm_flops_tok(cfg)
    else:
        f = _slstm_flops_tok(cfg)
    return f + _ffn_flops_tok(cfg, spec)


def _head_flops_tok(cfg):
    return 2 * cfg.d_model * cfg.num_output_heads * cfg.padded_vocab + \
        5 * cfg.num_output_heads * cfg.padded_vocab                # softmax/lse


@dataclasses.dataclass
class CellCost:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    flops_total: float
    detail: dict


def cell_costs(cfg: ModelConfig, kind: str, seq: int, batch: int,
               n_devices: int = 256, model_ax: int = 16, dp_ax: int = 16,
               fsdp: bool = True, state_mode: str = "fsdp") -> CellCost:
    """Analytic per-device roofline inputs for one cell."""
    decode = kind == "decode"
    tokens = batch * (1 if decode else seq)
    specs = cfg.all_specs()

    # ---- FLOPs ------------------------------------------------------------
    # t_kv = seq (NOT seq/2): the blockwise attention computes every KV block
    # and masks — executed flops are full T^2. The causal-average "useful"
    # count is what MODEL_FLOPS captures; the gap is a hillclimb target
    # (masked-block skipping, EXPERIMENTS.md §Perf).
    f_tok = 0.0
    for s in specs:
        t_kv = seq
        if (not decode and s.kind in ("attn", "mla")
                and getattr(cfg, "skip_masked_blocks", False)):
            t_kv = seq / 2          # causal block skipping executes ~T^2/2
        f_tok += _layer_flops_tok(cfg, s, t_kv, decode)
    fwd = f_tok * tokens
    # head/logit flops: every position in train (loss), ONLY the last
    # position per sequence in prefill, the single new token in decode.
    head_positions = batch if kind == "prefill" else tokens
    fwd += _head_flops_tok(cfg) * head_positions
    mult = 4.0 if kind == "train" else 1.0
    flops_total = fwd * mult
    flops_per_dev = flops_total / n_devices

    # ---- params / state bytes ----------------------------------------------
    p_bytes = 2.0  # bf16
    n_params = _count_params(cfg)
    if kind == "train":
        # fwd+bwd weight reads (all-gathered once each under FSDP) + grad
        # reduce + AdamW m/v/param r/w in f32.
        w_traffic = 3 * n_params * p_bytes / model_ax
        opt_traffic = n_params * (6 * 4.0) / n_devices
        act_traffic = _act_bytes(cfg, tokens, seq, kind) / n_devices
        bytes_per_dev = w_traffic / dp_ax + opt_traffic + act_traffic \
            + 2 * n_params * p_bytes / n_devices
    else:
        shard = n_devices if fsdp else model_ax
        w_read = n_params * p_bytes / shard
        cache_rw = _cache_bytes(cfg, batch, seq) / n_devices * (2 if decode else 1)
        act_traffic = _act_bytes(cfg, tokens, seq, kind) / n_devices
        bytes_per_dev = w_read + cache_rw + act_traffic

    # ---- collectives --------------------------------------------------------
    coll = 0.0
    if kind == "train":
        if state_mode == "zero1":
            # one grad all-reduce (f32, ring 2x) + post-update param bcast.
            coll += 2 * n_params * 4.0 / model_ax
            coll += n_params * p_bytes / model_ax
        elif fsdp:
            coll += 2 * 2 * n_params * p_bytes / model_ax      # AG fwd+bwd(remat)
            coll += 2 * n_params * 4.0 / model_ax              # grad RS (f32)
        # SP all-gathers: per layer, x gathered from T/model shards (fwd+bwd).
        coll += len(specs) * 3 * tokens * cfg.d_model * p_bytes / n_devices * 2
        # vocab-parallel loss psum (logsumexp partials, f32).
        coll += 2 * tokens * 4.0 * 4 / n_devices
    else:
        if fsdp:
            coll += 2 * n_params * p_bytes / model_ax          # AG weights
        # TP activation reductions: ~2 all-reduce of [tokens, d] per layer.
        coll += len(specs) * 2 * 2 * tokens * cfg.d_model * p_bytes / n_devices
    moe_layers = sum(1 for s in specs if s.ffn == "moe")
    if moe_layers:
        mc = cfg.moe
        coll += moe_layers * 2 * tokens * mc.top_k * cfg.d_model * p_bytes \
            / n_devices * (2 if kind == "train" else 1)        # a2a disp+comb
    coll_per_dev = coll

    detail = {"fwd_flops_tok": f_tok, "n_params": n_params, "tokens": tokens}
    return CellCost(flops_per_dev, bytes_per_dev, coll_per_dev,
                    flops_total, detail)


# ---------------------------------------------------------------------------
# Storage tier (repro.store / the paper's SmartSSD flash, §6.5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StorageCost:
    """The storage-bandwidth roofline term for an out-of-core (csd) search.

    block_accesses : demand block accesses issued by the engine
    blocks_from_flash : accesses that miss the cache and touch flash
    bytes_from_flash  : blocks_from_flash * block_size (P2P-DMA traffic)
    storage_s         : seconds on the SSD link — compare against the
                        compute/memory/collective terms of roofline_terms;
                        at SIFT1B scale this term dominates (paper Fig. 12:
                        the platform is SSD-bound, 75.59 QPS)
    """

    block_accesses: float
    blocks_from_flash: float
    bytes_from_flash: float
    storage_s: float
    hit_rate: float


# Bytes per stored vector component, per IndexSpec.dtype. The paper's
# SIFT1B tables are uint8 — 1 byte/dim is the operating point that fits a
# billion rows on the SmartSSD and feeds the integer distance units.
# (dtype="pq" is priced per ROW, not per component — see vector_row_bytes.)
VECTOR_DTYPE_BYTES = {"float32": 4, "uint8": 1, "int8": 1}


def vector_row_bytes(dim: int, dtype: str = "float32",
                     lane: int = 128, pq_m: int = 8) -> int:
    """Bytes of one raw-data-table row (lane-padded, paper Fig. 5).

    This is the per-vector-read unit of the storage term: a quantized
    store (dtype uint8/int8) moves 4x fewer bytes per hop than float32 at
    identical traversal behavior — the `csd` backend's measured
    `QueryStats.bytes_read` reflects the same shrink (modulo unchanged
    neighbor-table traffic and block-granularity rounding).

    dtype="pq" breaks the bytes-per-component mold: a row is `pq_m` uint8
    subspace codes regardless of `dim` and is NOT lane-padded (the code
    row IS the stored unit — reader.d_pad == M for a PQ store), so at
    M=8, d=128 each hop moves 16x fewer raw-data bytes than uint8."""
    if dtype == "pq":
        if pq_m < 1:
            raise ValueError(f"pq_m must be >= 1, got {pq_m}")
        return int(pq_m)
    try:
        itemsize = VECTOR_DTYPE_BYTES[dtype]
    except KeyError:
        raise ValueError(
            f"unknown vector dtype {dtype!r}; "
            f"available: {sorted(VECTOR_DTYPE_BYTES) + ['pq']}") from None
    d_pad = ((dim + lane - 1) // lane) * lane
    return d_pad * itemsize


def storage_cost(block_accesses: float, block_size: int,
                 cache_hit_rate: float = 0.0,
                 ssd_bw: float | None = None) -> StorageCost:
    """Cache-hit-adjusted storage term: only misses cross the flash link.

    `block_accesses` is what the engine asks for (e.g. measured
    `QueryStats.block_reads` at hit rate 0, or the analytic
    hops * maxM0 * blocks-per-vector); the PageCache absorbs
    `cache_hit_rate` of it.
    """
    if not 0.0 <= cache_hit_rate <= 1.0:
        raise ValueError(f"cache_hit_rate must be in [0, 1], "
                         f"got {cache_hit_rate}")
    if ssd_bw is None:
        from repro.launch.roofline import HW
        ssd_bw = HW().ssd_bw
    misses = block_accesses * (1.0 - cache_hit_rate)
    nbytes = misses * block_size
    return StorageCost(
        block_accesses=float(block_accesses),
        blocks_from_flash=float(misses),
        bytes_from_flash=float(nbytes),
        storage_s=float(nbytes / ssd_bw),
        hit_rate=float(cache_hit_rate),
    )


@dataclasses.dataclass(frozen=True)
class DispatchCost:
    """Host-side dispatch tax of the superstep traversal loop.

    Each superstep is one host<->device round trip (launch + sync); the
    per-superstep overhead is NOT in the analytic flash/flops terms, and
    the fused-hop driver (fused_hops=H) exists precisely to divide it by
    H. The overhead itself is a measured quantity — `repro.obs.calibrate`
    fits it from the continuous profiler's superstep vs hop-kernel span
    times — so this term prices observed sync cost, not a guess.
    """

    supersteps: float                  # supersteps per query
    overhead_s_per_superstep: float
    dispatch_s: float                  # host seconds per query


def dispatch_cost(supersteps: float,
                  overhead_s_per_superstep: float) -> DispatchCost:
    """Price `supersteps` host round trips per query at a (measured)
    per-superstep overhead."""
    if supersteps < 0 or overhead_s_per_superstep < 0:
        raise ValueError("supersteps and overhead must be >= 0, got "
                         f"{supersteps}, {overhead_s_per_superstep}")
    return DispatchCost(
        supersteps=float(supersteps),
        overhead_s_per_superstep=float(overhead_s_per_superstep),
        dispatch_s=float(supersteps * overhead_s_per_superstep),
    )


# ---------------------------------------------------------------------------
# Ingest tier (repro.ingest): write amplification of the mutable index
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompactionCost:
    """Storage-write cost of a streaming-ingest workload (repro.ingest).

    The mutable index appends one sealed segment per `seal_threshold`
    inserts (bytes_ingested — the unavoidable write) and periodically
    compacts every live segment into one (bytes_rewritten — the
    maintenance tax). Write amplification is the LSM figure of merit:

        write_amp = (bytes_ingested + bytes_rewritten) / bytes_ingested

    `rewrite_s` prices the rewrites on the SSD link — compare against the
    read-side `StorageCost.storage_s` to see how much serving bandwidth a
    given compaction cadence steals (paper §6.5's SSD-bound regime means
    every rewritten byte is a byte not serving queries).
    """

    bytes_ingested: float
    bytes_rewritten: float
    write_amp: float
    seals: int
    compactions: int
    rewrite_s: float


def compaction_cost(n_inserted: int, row_bytes: float,
                    seal_threshold: int, compact_every: int,
                    delete_frac: float = 0.0,
                    ssd_bw: float | None = None) -> CompactionCost:
    """Simulate the seal/compact cadence of `repro.ingest` exactly.

    n_inserted     : total rows streamed in
    row_bytes      : bytes per stored row (launch.costmodel.vector_row_bytes)
    seal_threshold : memtable rows per sealed segment
    compact_every  : run compact() after this many seals (compaction merges
                     ALL live segments — the implemented policy)
    delete_frac    : fraction of live rows tombstoned between compactions
                     (compaction drops them, shrinking later rewrites)

    The simulation replays the policy seal by seal, so the quadratic
    growth of repeated merge-everything compactions is priced honestly
    instead of hidden behind a closed form.
    """
    if not 0.0 <= delete_frac < 1.0:
        raise ValueError(f"delete_frac must be in [0, 1), got {delete_frac}")
    if seal_threshold < 1 or compact_every < 1:
        raise ValueError("seal_threshold and compact_every must be >= 1")
    seals = int(n_inserted // seal_threshold)
    live_rows = 0.0            # rows in the one compacted segment
    pending = 0                # seals since the last compaction
    rewritten_rows = 0.0
    compactions = 0
    for _ in range(seals):
        pending += 1
        if pending >= compact_every:
            merged = live_rows + pending * seal_threshold
            live_rows = merged * (1.0 - delete_frac)
            rewritten_rows += live_rows
            compactions += 1
            pending = 0
    bytes_ingested = float(n_inserted) * row_bytes
    bytes_rewritten = rewritten_rows * row_bytes
    if ssd_bw is None:
        from repro.launch.roofline import HW
        ssd_bw = HW().ssd_bw
    return CompactionCost(
        bytes_ingested=bytes_ingested,
        bytes_rewritten=bytes_rewritten,
        write_amp=((bytes_ingested + bytes_rewritten) / bytes_ingested
                   if bytes_ingested else 1.0),
        seals=seals,
        compactions=compactions,
        rewrite_s=float(bytes_rewritten / ssd_bw),
    )


# ---------------------------------------------------------------------------
# Cluster tier (repro.cluster): router scatter-gather vs aggregate flash
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterFanoutCost:
    """Fan-out economics of a sharded cluster (repro.cluster).

    Sharding buys aggregate flash bandwidth (every shard replica brings its
    own SSD) but pays two taxes the single box does not: the router link
    (each query is scattered to all N shards and N top-k lists come back)
    and duplicated traversal (each shard runs the FULL-ef search over its
    1/N of the rows — that over-fetch is exactly what makes the merge
    bit-identical, so total flash work grows ~linearly with N).

    router_bytes_q     : per-query bytes on the router link (scatter+gather)
    flash_bytes_q      : per-query bytes from flash, summed over shards
    aggregate_ssd_bw   : n_shards * replicas * per-node ssd_bw
    router_qps / storage_qps : each side's throughput ceiling
    modeled_qps        : min of the two; `bound` names the binding side
    """

    n_shards: int
    replicas: int
    router_bytes_q: float
    flash_bytes_q: float
    aggregate_ssd_bw: float
    router_qps: float
    storage_qps: float
    modeled_qps: float
    bound: str


def cluster_fanout_cost(n_shards: int, replicas: int = 1, *, dim: int,
                        k: int, blocks_per_query: float, block_size: int,
                        cache_hit_rate: float = 0.0,
                        ssd_bw: float | None = None,
                        link_bw: float = 10e9) -> ClusterFanoutCost:
    """Price an N-shard x R-replica cluster for one query stream.

    blocks_per_query : PER-SHARD demand block accesses (a single shard's
                       measured `QueryStats.block_reads`, or the analytic
                       hops * blocks-per-hop — full-ef traversal over the
                       shard's rows, which is why it does not shrink 1/N)
    link_bw          : router NIC bandwidth, bytes/s (default 10 GbE)

    Router side: scatter `dim * 4` query bytes to each shard, gather
    `k * 12` result bytes (int64 id + f32 dist) back from each. Storage
    side: each query burns `flash_bytes_q` across its N owning replicas
    while the cluster's capacity is the aggregate of all N*R SSDs — so
    replicas raise storage QPS linearly, and shards raise it only through
    aggregation minus the duplicated-traversal tax.
    """
    if n_shards < 1 or replicas < 1:
        raise ValueError(
            f"n_shards and replicas must be >= 1, got {n_shards}, "
            f"{replicas}")
    if not 0.0 <= cache_hit_rate <= 1.0:
        raise ValueError(f"cache_hit_rate must be in [0, 1], "
                         f"got {cache_hit_rate}")
    if ssd_bw is None:
        from repro.launch.roofline import HW
        ssd_bw = HW().ssd_bw
    router_bytes_q = float(n_shards) * (dim * 4.0 + k * 12.0)
    per_shard_bytes = blocks_per_query * block_size * (1.0 - cache_hit_rate)
    flash_bytes_q = float(n_shards) * per_shard_bytes
    aggregate_ssd_bw = float(n_shards * replicas) * ssd_bw
    router_qps = link_bw / router_bytes_q if router_bytes_q else float("inf")
    storage_qps = (aggregate_ssd_bw / flash_bytes_q if flash_bytes_q
                   else float("inf"))
    modeled = min(router_qps, storage_qps)
    return ClusterFanoutCost(
        n_shards=int(n_shards), replicas=int(replicas),
        router_bytes_q=router_bytes_q, flash_bytes_q=flash_bytes_q,
        aggregate_ssd_bw=aggregate_ssd_bw, router_qps=float(router_qps),
        storage_qps=float(storage_qps), modeled_qps=float(modeled),
        bound="router" if router_qps <= storage_qps else "storage")


def _count_params(cfg: ModelConfig) -> float:
    """Total param count (matches init_params; calibrated in tests)."""
    d = cfg.d_model
    n = 0.0
    if cfg.embed_inputs:
        n += cfg.padded_vocab * d
    for s in cfg.all_specs():
        n += d  # ln1
        if s.kind == "attn":
            n += d * cfg.head_dim * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
            if cfg.qk_norm:
                n += 2 * cfg.head_dim
        elif s.kind == "mla":
            m = cfg.mla
            n += d * cfg.n_heads * (m.qk_nope + m.qk_rope)
            n += d * (m.kv_lora + m.qk_rope) + m.kv_lora
            n += m.kv_lora * cfg.n_heads * (m.qk_nope + m.v_dim)
            n += cfg.n_heads * m.v_dim * d
        elif s.kind == "mamba":
            mc = cfg.mamba
            di, r, S = mc.inner(d), mc.rank(d), mc.d_state
            n += d * 2 * di + mc.d_conv * di + di
            n += di * (r + 2 * S) + r * di + di + di * S + di + di * d
        elif s.kind == "mlstm":
            xc = cfg.xlstm
            di = int(xc.m_proj_factor * d)
            n += d * 2 * di + xc.d_conv * di + di
            n += 3 * di * di + 2 * di * xc.n_heads + 3 * di + di * d
        elif s.kind == "slstm":
            xc = cfg.xlstm
            dh = d // xc.n_heads
            ff = int(xc.s_ffn_factor * d)
            n += d * 4 * d + 4 * d * dh + 4 * d + d
            n += d * 2 * ff + ff * d
        if s.ffn == "glu":
            n += d + 3 * d * cfg.d_ff
        elif s.ffn == "dense":
            n += d + 2 * d * cfg.d_ff
        elif s.ffn == "moe":
            mc = cfg.moe
            n += d + d * mc.num_experts
            n += mc.num_experts * 3 * d * mc.d_ff
            if mc.n_shared:
                n += 3 * d * mc.shared_ff()
    n += d
    if not (cfg.tie_embeddings and cfg.embed_inputs):
        n += d * cfg.num_output_heads * cfg.padded_vocab
    return n


def _act_bytes(cfg: ModelConfig, tokens, seq, kind) -> float:
    """Dominant activation HBM traffic (global): layer inputs written+read,
    x2 for train (bwd reads the remat carry again)."""
    per_layer = tokens * cfg.d_model * 2.0
    mult = {"train": 4.0, "prefill": 2.0, "decode": 2.0}[kind]
    return cfg.num_layers * per_layer * mult


def _cache_bytes(cfg: ModelConfig, batch, seq) -> float:
    """Global KV/recurrent cache size in bytes (bf16 KV, f32 states)."""
    total = 0.0
    for s in cfg.all_specs():
        if s.kind == "attn":
            S = min(seq, s.window) if s.window else seq
            kv_b = 1.06 if getattr(cfg, "kv_quant", False) else 2.0
            total += 2 * batch * S * cfg.n_kv_heads * cfg.head_dim * kv_b
        elif s.kind == "mla":
            total += batch * seq * (cfg.mla.kv_lora + cfg.mla.qk_rope) * 2.0
        elif s.kind == "mamba":
            mc = cfg.mamba
            di = mc.inner(cfg.d_model)
            total += batch * di * (mc.d_state * 4.0 + (mc.d_conv - 1) * 2.0)
        elif s.kind == "mlstm":
            xc = cfg.xlstm
            di = int(xc.m_proj_factor * cfg.d_model)
            dh = di // xc.n_heads
            total += batch * (di * dh + di) * 4.0
        elif s.kind == "slstm":
            total += batch * 4 * cfg.d_model * 4.0
    return total
