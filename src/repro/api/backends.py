"""Backend registry: five engines, one search contract.

Every backend answers the same call — `search(queries, k, ef, rerank,
with_stats)` over metric-prepared queries — and exposes a `state_tree()` /
`from_state()` pair the service uses for versioned save/load. Selection
happens through `IndexSpec.backend`:

  exact       : blocked brute-force scan (paper Fig. 9 baseline); ignores ef
  hnsw        : one monolithic graph (partitioned with P=1)
  partitioned : the paper's two-stage engine — P sub-graphs + device merge
  distributed : partitions sharded over the mesh `model` axis with an
                all-gather stage-2 merge (paper Fig. 10/11)
  csd         : out-of-core over the block store (repro.store) — the
                database stays on "flash", host memory is bounded by the
                PageCache, stats count block reads (the paper's platform)

`register_backend` is open: NDSEARCH-style near-data engines or quantized
variants plug in without touching the service layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.rerank import batched_rerank
from repro.api.types import IndexSpec, QueryStats
from repro.core import hnsw_graph as hg
from repro.core.bruteforce import bruteforce_topk
from repro.core.partitioned import (
    PartitionedDB,
    build_partitioned_db,
    quantize_db_vectors,
    search_partitioned,
    search_partitioned_candidates,
)
from repro.core.search import SearchParams

__all__ = ["register_backend", "get_backend", "available_backends",
           "ExactBackend", "HNSWBackend", "PartitionedBackend",
           "DistributedBackend", "CSDBackend"]

_BACKENDS: dict[str, type] = {}


def register_backend(name: str):
    def deco(cls):
        cls.name = name
        _BACKENDS[name] = cls
        return cls
    return deco


def get_backend(name: str) -> type:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {sorted(_BACKENDS)}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def _device_vectors(vectors: np.ndarray):
    """Raw vectors + sqnorms as device arrays (rerank / exact scoring)."""
    v = jnp.asarray(vectors, jnp.float32)
    return v, jnp.einsum("nd,nd->n", v, v)


# ---------------------------------------------------------------------------
# exact
# ---------------------------------------------------------------------------


@register_backend("exact")
class ExactBackend:
    """Blocked exact scan; the ground-truth engine and the Fig. 9 baseline."""

    uses_graph = False
    CHUNK = 512

    def __init__(self, spec: IndexSpec, raw: np.ndarray):
        self.spec = spec
        self.quant = spec.quantizer()
        self.is_pq = spec.dtype == "pq"
        if self.is_pq:
            # raw is either the original float32 rows (build) or the
            # checkpointed [n, m] uint8 code table (from_state) — the scan
            # runs the fused Pallas ADC top-k over the codes either way
            raw = np.asarray(raw)
            if raw.dtype != np.uint8 or raw.shape[-1] != self.quant.m:
                raw = self.quant.encode(np.asarray(raw, np.float32))
            self.raw = raw
            self.codes = jnp.asarray(raw)
            self._cbs = jnp.asarray(self.quant.codebooks)
            self.n = raw.shape[0]
            self.vectors = self.sqnorms = None
            return
        # quantized: raw IS the code table (uint8/int8); scan it as-is
        self.raw = (np.asarray(raw) if self.quant is not None
                    else np.asarray(raw, np.float32))
        n, d = self.raw.shape
        n_pad = ((n + self.CHUNK - 1) // self.CHUNK) * self.CHUNK
        vp = np.zeros((n_pad, d), self.raw.dtype)
        vp[:n] = self.raw
        rf = self.raw.astype(np.float32)
        sq = np.full(n_pad, np.inf, np.float32)   # +inf == pad marker
        sq[:n] = np.einsum("nd,nd->n", rf, rf)
        self.vectors = jnp.asarray(vp)
        self.sqnorms = jnp.asarray(sq)
        self.n = n

    @classmethod
    def build(cls, vectors: np.ndarray, spec: IndexSpec, mesh=None):
        return cls(spec, vectors)

    def search(self, queries, k: int, ef: int, rerank: bool,
               with_stats: bool):
        if self.is_pq:
            from repro.kernels.ops import pq_topk
            from repro.optim.compression import build_pq_lut
            luts = build_pq_lut(jnp.asarray(queries, jnp.float32),
                                self._cbs)
            dists, ids = pq_topk(luts, self.codes, k=k)
        else:
            ids, dists = bruteforce_topk(
                self.vectors, self.sqnorms, jnp.asarray(queries), k=k,
                chunk=self.CHUNK, metric=self.spec.metric)
            if self.quant is not None:  # code-space -> real-space distances
                dists = dists * jnp.float32(self.quant.dist_scale)
        stats = None
        if with_stats:
            b = ids.shape[0]
            stats = QueryStats(dist_calcs=jnp.full((b,), self.n, jnp.int32))
        return ids, dists, stats

    def state_tree(self) -> dict:
        return {"exact": {"raw": self.raw},
                "meta": {"n": jnp.int32(self.n),
                         "dim": jnp.int32(self.raw.shape[1])}}

    @classmethod
    def from_state(cls, spec: IndexSpec, leaves: dict, mesh=None):
        return cls(spec, leaves["exact/raw"])


# ---------------------------------------------------------------------------
# partitioned (and its P=1 alias, hnsw)
# ---------------------------------------------------------------------------


@register_backend("partitioned")
class PartitionedBackend:
    """The paper's engine: P accelerator-resident sub-graphs, stage-2 merge
    on device, optional exact rerank over the P*K intermediates."""

    uses_graph = True
    forced_partitions: int | None = None

    def __init__(self, spec: IndexSpec, pdb: PartitionedDB,
                 raw: np.ndarray | None = None):
        self.spec = spec
        self.pdb = pdb
        self.quant = spec.quantizer()
        self.is_pq = spec.dtype == "pq"
        self._cbs = (jnp.asarray(self.quant.codebooks)
                     if self.is_pq else None)
        # quantized: `raw` holds the codes; rerank re-scores over the
        # DEQUANTIZED rows (stage 2 stays float32, paper Fig. 4). PQ is
        # different: `raw` holds the TRUE float32 rows — reranking over
        # decoded PQ rows would be a no-op (ADC already IS the distance to
        # the reconstruction), so stage 2 needs the real vectors to
        # recover recall.
        if self.is_pq:
            self.raw = None if raw is None else np.asarray(raw, np.float32)
        else:
            self.raw = (None if raw is None else
                        np.asarray(raw) if self.quant is not None else
                        np.asarray(raw, np.float32))
        if self.raw is not None:
            flt = (self.raw if (self.quant is None or self.is_pq)
                   else self.quant.decode(self.raw))
            self.dev_vectors, self.dev_sqnorms = _device_vectors(flt)
        else:
            self.dev_vectors = self.dev_sqnorms = None

    @classmethod
    def build(cls, vectors: np.ndarray, spec: IndexSpec, mesh=None):
        p = cls.forced_partitions or spec.num_partitions
        # dtype="pq": `vectors` are the ORIGINAL float32 rows — the graphs
        # are built full-precision and quantize_db_vectors re-encodes the
        # raw-data leaf to M-byte code rows afterwards (DiskANN-style:
        # full-precision graph, PQ traversal)
        pdb = build_partitioned_db(vectors, p, spec.hnsw)
        pdb = quantize_db_vectors(
            pdb, spec.dtype,
            spec.quantizer() if spec.dtype == "pq" else None)
        pdb = PartitionedDB(db=jax.tree.map(jnp.asarray, pdb.db),
                            num_partitions=pdb.num_partitions, dim=pdb.dim)
        return cls(spec, pdb, raw=vectors if spec.keep_vectors else None)

    def params(self, k: int, ef: int) -> SearchParams:
        return SearchParams(ef=ef, k=k, metric=self.spec.metric,
                            fused_hops=self.spec.fused_hops)

    def _lut(self, q):
        """Per-query ADC tables for dtype='pq' (None otherwise)."""
        if not self.is_pq:
            return None
        from repro.optim.compression import build_pq_lut
        return build_pq_lut(q.astype(jnp.float32), self._cbs)

    def search(self, queries, k: int, ef: int, rerank: bool,
               with_stats: bool):
        p = self.params(k, ef)
        q = jnp.asarray(queries)
        lut = self._lut(q)
        if rerank:
            if self.dev_vectors is None:
                raise ValueError(
                    "rerank=True needs the raw vectors: build the index "
                    "with IndexSpec(keep_vectors=True)")
            cand, _, st = search_partitioned_candidates(self.pdb, q, p, lut)
            rq = (q if (self.quant is None or self.is_pq)
                  else self.quant.decode(q))
            ids, dists = batched_rerank(
                self.dev_vectors, self.dev_sqnorms, rq, cand, k,
                self.spec.metric)
        else:
            ids, dists, st = search_partitioned(self.pdb, q, p, lut)
            if self.quant is not None and not self.is_pq:
                # code-space -> real-space (PQ is already real-space)
                dists = dists * jnp.float32(self.quant.dist_scale)
        stats = None
        if with_stats:
            stats = QueryStats(hops=st.hops.sum(axis=0),
                               dist_calcs=st.dist_calcs.sum(axis=0))
        return ids, dists, stats

    def state_tree(self) -> dict:
        tree = {"db": self.pdb.db._asdict(),
                "meta": {"num_partitions": jnp.int32(self.pdb.num_partitions),
                         "dim": jnp.int32(self.pdb.dim)}}
        if self.raw is not None:
            tree["vectors"] = {"raw": self.raw}
        return tree

    @classmethod
    def from_state(cls, spec: IndexSpec, leaves: dict, mesh=None):
        db = hg.DeviceDB(**{k.split("/", 1)[1]: jnp.asarray(v)
                            for k, v in leaves.items()
                            if k.startswith("db/")})
        pdb = PartitionedDB(db=db,
                            num_partitions=int(leaves["meta/num_partitions"]),
                            dim=int(leaves["meta/dim"]))
        return cls(spec, pdb, raw=leaves.get("vectors/raw"))


@register_backend("hnsw")
class HNSWBackend(PartitionedBackend):
    """Single monolithic graph — partitioned with exactly one partition."""

    forced_partitions = 1


# ---------------------------------------------------------------------------
# distributed
# ---------------------------------------------------------------------------


@register_backend("distributed")
class DistributedBackend(PartitionedBackend):
    """Graph parallelism over the mesh `model` axis (paper §6.3): each
    device searches only its resident sub-graphs; stage 2 is an all-gather
    + rank merge. Jitted search fns are cached per (k, ef)."""

    def __init__(self, spec: IndexSpec, pdb: PartitionedDB, mesh,
                 raw: np.ndarray | None = None):
        super().__init__(spec, pdb, raw=raw)
        self.mesh = mesh
        self._fns: dict = {}

    @classmethod
    def build(cls, vectors: np.ndarray, spec: IndexSpec, mesh=None):
        from repro.core.distributed import shard_db
        mesh = mesh or _default_mesh()
        n_model = mesh.shape["model"]
        if spec.num_partitions % n_model != 0:
            raise ValueError(
                f"num_partitions={spec.num_partitions} must divide over "
                f"the mesh model axis ({n_model})")
        pdb = build_partitioned_db(vectors, spec.num_partitions, spec.hnsw)
        pdb = quantize_db_vectors(
            pdb, spec.dtype,
            spec.quantizer() if spec.dtype == "pq" else None)
        pdb = shard_db(pdb, mesh)
        return cls(spec, pdb, mesh,
                   raw=vectors if spec.keep_vectors else None)

    def params(self, k: int, ef: int) -> SearchParams:
        # the fused Pallas traversal is not wired through shard_map — the
        # distributed engine always runs the hop-stepped lockstep path
        return SearchParams(ef=ef, k=k, metric=self.spec.metric)

    def _fn(self, k: int, ef: int, merge: bool = True):
        key = (k, ef, merge)
        if key not in self._fns:
            from repro.core.distributed import make_distributed_search
            from repro.launch.mesh import dp_axes
            maxM0 = int(self.pdb.db.l0_nbrs.shape[-1])
            self._fns[key] = make_distributed_search(
                self.mesh, self.params(k, ef), maxM0,
                graph_axes=("model",), query_axes=dp_axes(self.mesh),
                merge=merge, pq=self.is_pq)
        return self._fns[key]

    def search(self, queries, k: int, ef: int, rerank: bool,
               with_stats: bool):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import dp_axes
        dp = dp_axes(self.mesh)
        q = jax.device_put(
            jnp.asarray(queries),
            NamedSharding(self.mesh, P(dp if dp else None, None)))
        extra = ()
        if self.is_pq:
            # LUTs shard exactly like the query rows they belong to
            extra = (jax.device_put(
                self._lut(jnp.asarray(queries)),
                NamedSharding(self.mesh, P(dp if dp else None, None,
                                           None))),)
        if rerank:
            if self.dev_vectors is None:
                raise ValueError(
                    "rerank=True needs the raw vectors: build the index "
                    "with IndexSpec(keep_vectors=True)")
            # unmerged P*k candidate pool, exactly re-scored (stage 2)
            cand, _, calcs = self._fn(k, ef, merge=False)(
                self.pdb.db, q, *extra)
            rq = jnp.asarray(queries)
            if self.quant is not None and not self.is_pq:
                rq = self.quant.decode(rq)
            ids, dists = batched_rerank(
                self.dev_vectors, self.dev_sqnorms, rq,
                cand, k, self.spec.metric)
        else:
            ids, dists, calcs = self._fn(k, ef)(self.pdb.db, q, *extra)
            if self.quant is not None and not self.is_pq:
                # code-space -> real-space (PQ is already real-space)
                dists = dists * jnp.float32(self.quant.dist_scale)
        stats = None
        if with_stats:
            stats = QueryStats(dist_calcs=calcs[:, 0])
        return ids, dists, stats

    @classmethod
    def from_state(cls, spec: IndexSpec, leaves: dict, mesh=None):
        from repro.core.distributed import shard_db
        mesh = mesh or _default_mesh()
        db = hg.DeviceDB(**{k.split("/", 1)[1]: np.asarray(v)
                            for k, v in leaves.items()
                            if k.startswith("db/")})
        pdb = PartitionedDB(db=db,
                            num_partitions=int(leaves["meta/num_partitions"]),
                            dim=int(leaves["meta/dim"]))
        pdb = shard_db(pdb, mesh)
        return cls(spec, pdb, mesh, raw=leaves.get("vectors/raw"))


def _default_mesh():
    from repro.launch.mesh import make_mesh
    return make_mesh((len(jax.devices()),), ("model",))


# ---------------------------------------------------------------------------
# csd — out-of-core over the block store (defined in repro.store.csd, which
# imports repro.api only lazily inside methods, so this registration import
# is acyclic whichever package loads first)
# ---------------------------------------------------------------------------

from repro.store.csd import CSDBackend  # noqa: E402

register_backend("csd")(CSDBackend)
