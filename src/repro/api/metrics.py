"""Metric registry: the one place that knows what each metric needs.

HNSW is metric-agnostic (Malkov & Yashunin 2016) — the traversal only ever
compares distances. Each registered metric states how the raw data and the
queries must be preprocessed at the edge, and the kernels
(core/search.py, core/bruteforce.py, kernels/l2dist.py) receive the metric
name and evaluate the matching distance-from-dot-product form:

  l2     : ||x||^2 - 2 x.q + ||q||^2       (the paper's metric)
  ip     : -x.q                            (MIPS as a minimization)
  cosine : 1 - x.q over unit-norm inputs   (so graph build == L2 on the
                                            normalized vectors; ranking is
                                            identical, values are 1 - cos)

Register a new metric with `register_metric` to make it available to the
spec/ground-truth machinery; the jitted kernels additionally need a matching
branch in `core.search.metric_distance` (the dispatch there is trace-time
static, so it cannot read a runtime registry).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = ["Metric", "register_metric", "get_metric", "available_metrics",
           "exact_topk_np"]


def _l2_from_dot(dot, xsq, qsq):
    return xsq - 2.0 * dot + qsq


def _ip_from_dot(dot, xsq, qsq):
    return -dot


def _cos_from_dot(dot, xsq, qsq):
    return 1.0 - dot                             # unit-norm inputs


@dataclasses.dataclass(frozen=True)
class Metric:
    """name is what IndexSpec.metric / SearchParams.metric carry; the
    normalize flags are applied once at the build/search edge; dist_from_dot
    maps (q.x, ||x||^2, ||q||^2) to the distance being minimized.

    graph_safe: whether an L2-built HNSW graph searches correctly under
    this metric. True for l2 and cosine (normalization makes the L2 build
    equivalent); False for raw inner product, where the MIPS winners
    (large-norm points) need not be L2 neighbors of the query — graph
    backends reject such metrics at build time."""

    name: str
    dist_from_dot: Callable
    normalize_data: bool = False
    normalize_queries: bool = False
    graph_safe: bool = True

    def prepare_data(self, vectors: np.ndarray) -> np.ndarray:
        vectors = np.ascontiguousarray(vectors, dtype=np.float32)
        return _unit(vectors) if self.normalize_data else vectors

    def prepare_queries(self, queries: np.ndarray) -> np.ndarray:
        queries = np.ascontiguousarray(queries, dtype=np.float32)
        return _unit(queries) if self.normalize_queries else queries

    def pairwise_np(self, queries: np.ndarray, vectors: np.ndarray) -> np.ndarray:
        """Reference distance matrix [B, N] (numpy; for ground truth)."""
        q = self.prepare_queries(queries)
        x = self.prepare_data(vectors)
        return self.dist_from_dot(
            q @ x.T,
            np.einsum("nd,nd->n", x, x)[None],
            np.einsum("bd,bd->b", q, q)[:, None])


def _unit(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


_REGISTRY: dict[str, Metric] = {}


def register_metric(metric: Metric) -> Metric:
    _REGISTRY[metric.name] = metric
    return metric


def get_metric(name: str) -> Metric:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown metric {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available_metrics() -> list[str]:
    return sorted(_REGISTRY)


register_metric(Metric("l2", _l2_from_dot))
register_metric(Metric("ip", _ip_from_dot, graph_safe=False))
register_metric(Metric("cosine", _cos_from_dot,
                       normalize_data=True, normalize_queries=True))


def exact_topk_np(metric_name: str, vectors: np.ndarray, queries: np.ndarray,
                  k: int) -> np.ndarray:
    """Exact top-k ids under a metric (numpy; test/ground-truth helper)."""
    d = get_metric(metric_name).pairwise_np(queries, vectors)
    return np.argsort(d, axis=1, kind="stable")[:, :k]
