"""SearchService: the single public entry point over every backend.

Mirrors the platform dataflow of paper Fig. 4 — build (or load) once, then
stream batched requests — but with the backend, metric, and persistence
story behind one typed surface:

    spec = IndexSpec(metric="cosine", backend="partitioned",
                     num_partitions=4)
    svc = SearchService.build(vectors, spec)
    resp = svc.search(SearchRequest(queries, k=10, ef=40, rerank=True))
    svc.save("/ckpt/index")                 # versioned; step auto-advances
    svc2 = SearchService.load("/ckpt/index")  # latest committed version

On-disk layout:  <path>/index_manifest.json   (format version + IndexSpec)
                 <path>/step_<N>/             (checkpoint-store versions;
                                               load opens the latest
                                               committed one)
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.api import metrics as _metrics
from repro.api.backends import get_backend
from repro.api.types import (
    FORMAT_VERSION,
    PQ_FORMAT_VERSION,
    IndexSpec,
    SearchRequest,
    SearchResponse,
)
from repro.checkpoint import latest_step, save_checkpoint, step_dir
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER

__all__ = ["SearchService", "MANIFEST_NAME", "read_step_leaves"]

MANIFEST_NAME = "index_manifest.json"


def read_step_leaves(path: str, step: int) -> dict:
    """Flat {leaf-path: np.ndarray} view of one committed checkpoint step."""
    d = step_dir(path, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    return {e["path"]: np.load(os.path.join(d, e["file"] + ".npy"))
            for e in manifest["leaves"]}


class SearchService:
    """Build/load once, search many times — any backend, any metric."""

    def __init__(self, spec: IndexSpec, backend):
        self.spec = spec
        self.backend = backend
        self.metric = _metrics.get_metric(spec.metric)
        self.quantizer = spec.quantizer()

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, vectors, spec: IndexSpec | None = None, *,
              mesh=None) -> "SearchService":
        """Build an index over raw vectors according to the spec. The
        metric's data preprocessing (e.g. cosine normalization) happens
        here — backends only ever see metric-prepared vectors. For a
        quantized spec (dtype uint8/int8) the quantizer is fitted here and
        its scale/zero-point are written back onto the spec (and thus into
        the index manifest); backends then receive *codes*, not floats."""
        spec = spec or IndexSpec()
        metric = _metrics.get_metric(spec.metric)     # validates the name
        backend_cls = get_backend(spec.backend)       # validates the name
        if getattr(backend_cls, "uses_graph", True) and not metric.graph_safe:
            raise ValueError(
                f"metric {spec.metric!r} is not graph-safe: the HNSW graphs "
                f"are built with L2 geometry, so graph search under it is "
                f"unreliable — use backend='exact', or normalize your data "
                f"(then ip == cosine)")
        prepared = metric.prepare_data(np.asarray(vectors))
        if spec.dtype != "float32":
            if spec.metric != "l2":
                raise ValueError(
                    f"dtype={spec.dtype!r} supports metric='l2' only (the "
                    f"paper's metric): code-space squared-L2 is a pure "
                    f"rescaling of real-space squared-L2, which does not "
                    f"hold for {spec.metric!r}")
            if spec.dtype == "pq":
                # PQ: fit codebooks (or REUSE pre-fitted ones riding the
                # spec — that's how cluster shards share one code space),
                # then hand backends the ORIGINAL float32 vectors: graphs
                # are built full-precision (DiskANN-style) and the backend
                # swaps code rows in afterwards.
                from repro.optim.compression import PQQuantizer
                if spec.pq_codebooks is None:
                    quant = PQQuantizer.fit(prepared, spec.pq_m,
                                            seed=spec.hnsw.seed)
                    spec = dataclasses.replace(
                        spec, pq_codebooks=quant.to_json()["codebooks"])
            else:
                from repro.optim.compression import VectorQuantizer
                quant = VectorQuantizer.fit(prepared, spec.dtype)
                spec = dataclasses.replace(spec, qscale=quant.scale,
                                           qzero=quant.zero_point)
                prepared = quant.encode(prepared)
        return cls(spec, backend_cls.build(prepared, spec, mesh=mesh))

    # -- serving ------------------------------------------------------------

    def search(self, request: SearchRequest) -> SearchResponse:
        """One batched request; accepts a raw query array as shorthand."""
        if not isinstance(request, SearchRequest):
            request = SearchRequest(queries=request)
        # nest under this thread's open span when there is one (the replica
        # dispatch span); fall back to the batcher-stamped request ctx when
        # the thread is cold (direct-dispatch path crosses no thread)
        if request.trace is not None and TRACER.current_ctx() is None:
            span = TRACER.span("search", parent=request.trace,
                               backend=self.spec.backend, k=request.k,
                               ef=request.ef)
        else:
            span = TRACER.span("search", backend=self.spec.backend,
                               k=request.k, ef=request.ef)
        with span:
            q = request.queries
            if self.metric.normalize_queries:
                q = self.metric.prepare_queries(np.asarray(q))
            # else: leave device arrays on device — the kernels cast to f32
            # themselves, so no host round-trip on the hot path
            if self.quantizer is not None and self.spec.dtype != "pq":
                # one edge quantization feeds every backend the same codes —
                # this is what keeps quantized partitioned/csd bit-identical.
                # PQ queries stay float32 (asymmetric distance): each
                # backend builds the per-query LUT from the spec's
                # codebooks through the one shared jitted builder.
                q = self.quantizer.encode_f32(np.asarray(q))
            ids, dists, stats = self.backend.search(
                q, k=request.k, ef=request.ef, rerank=request.rerank,
                with_stats=request.with_stats)
        REGISTRY.counter("api_searches_total",
                         backend=self.spec.backend).inc()
        # shape, not np.asarray: never force a device array to host here
        shape = getattr(request.queries, "shape", None)
        nq = int(shape[0]) if shape else len(request.queries)
        REGISTRY.counter("api_queries_total",
                         backend=self.spec.backend).inc(nq)
        return SearchResponse(ids=ids, dists=dists, stats=stats)

    # -- persistence --------------------------------------------------------

    def save(self, path: str, step: int | None = None) -> str:
        """Persist a new version. Steps auto-advance (0, 1, 2, ...) so
        repeated saves never clobber a committed version; `load` opens the
        latest committed one."""
        if step is None:
            prev = latest_step(path)
            step = 0 if prev is None else prev + 1
        out = save_checkpoint(path, step, self.backend.state_tree())
        version = (PQ_FORMAT_VERSION if self.spec.dtype == "pq"
                   else FORMAT_VERSION)
        manifest = {"format_version": version,
                    "spec": self.spec.to_json(),
                    "latest_saved_step": step}
        with open(os.path.join(path, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, indent=1)
        return out

    @classmethod
    def load(cls, path: str, *, mesh=None) -> "SearchService":
        """Re-open the latest committed version of a saved index.

        Indexes saved before the manifest existed (bare step dirs — the
        pre-`repro.api` era; this fallback used to live in the retired
        `ANNEngine` shim) still load: the spec is synthesized from the
        stored partition count, with default HNSW knobs."""
        manifest_path = os.path.join(path, MANIFEST_NAME)
        step = latest_step(path)
        if not os.path.exists(manifest_path):
            if step is None:
                raise FileNotFoundError(
                    f"no index manifest or committed checkpoint "
                    f"under {path!r}")
            leaves = read_step_leaves(path, step)
            spec = IndexSpec(backend="partitioned",
                             num_partitions=int(leaves["meta/num_partitions"]))
            backend = get_backend(spec.backend).from_state(spec, leaves,
                                                           mesh=mesh)
            return cls(spec, backend)
        with open(manifest_path) as f:
            manifest = json.load(f)
        version = manifest.get("format_version")
        if version not in (FORMAT_VERSION, PQ_FORMAT_VERSION):
            hint = (" (a mutable segmented index — open it with "
                    "repro.api.MutableSearchService.load)"
                    if version == 2 else "")
            raise ValueError(
                f"index at {path!r} has format_version={version}; "
                f"this build reads versions {FORMAT_VERSION} and "
                f"{PQ_FORMAT_VERSION}{hint}")
        spec = IndexSpec.from_json(manifest["spec"])
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint step under {path!r}")
        leaves = read_step_leaves(path, step)
        backend = get_backend(spec.backend).from_state(spec, leaves,
                                                       mesh=mesh)
        return cls(spec, backend)
