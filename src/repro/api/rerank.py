"""Batched on-device stage-2 rerank (paper Fig. 4 stage 2).

The whole [B, C] candidate pool (C = P*K stage-1 intermediates) is
deduplicated, gathered, and exactly re-scored in one jitted call — this is
the single rerank implementation every engine (partitioned, distributed,
csd, and each segment of a mutable index) routes through. Dedup is done by
sorting ids within each row — duplicates become adjacent and are masked to
+inf, which also reproduces an np.unique tie-break (among equal distances
the smallest id wins).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.search import metric_distance

__all__ = ["batched_rerank"]


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def batched_rerank(vectors, sqnorms, queries, cand_ids, k: int,
                   metric: str = "l2"):
    """Exact top-k over per-query candidate pools.

    vectors : [N, D] raw (metric-prepared) database vectors
    sqnorms : [N] ||x||^2 (only read for metric="l2")
    queries : [B, D]
    cand_ids: [B, C] int32 global ids; -1 marks empty slots
    returns : ids [B, k] int32 (-1 padded), dists [B, k] f32 (+inf padded)
    """
    b = cand_ids.shape[0]
    ids_s = jnp.sort(cand_ids, axis=1)            # -1s first, dups adjacent
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=1)
    valid = (ids_s >= 0) & ~dup
    safe = jnp.maximum(ids_s, 0)

    q = queries.astype(jnp.float32)
    qsq = jnp.einsum("bd,bd->b", q, q)
    vecs = vectors[safe]                          # [B, C, D]
    dot = jnp.einsum("bcd,bd->bc", vecs, q)
    d = metric_distance(metric, dot, sqnorms[safe], qsq[:, None])
    d = jnp.where(valid, d, jnp.inf)

    order = jnp.argsort(d, axis=1, stable=True)[:, :k]
    out_d = jnp.take_along_axis(d, order, axis=1)
    out_i = jnp.where(jnp.isfinite(out_d),
                      jnp.take_along_axis(ids_s, order, axis=1), -1)
    return out_i.astype(jnp.int32), out_d
