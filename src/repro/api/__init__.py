"""repro.api — the unified search-service surface.

One request/response API over every engine in the repo: exact brute force,
monolithic HNSW, the paper's partitioned two-stage engine, the
mesh-distributed variant, and the out-of-core block store. Mutable
(insert/delete/compact) indexes are `MutableSearchService` from
`repro.ingest`. See api/README.md for the backend matrix.
"""

from repro.api.backends import (
    available_backends,
    get_backend,
    register_backend,
)
from repro.api.metrics import (
    Metric,
    available_metrics,
    exact_topk_np,
    get_metric,
    register_metric,
)
from repro.api.rerank import batched_rerank
from repro.api.service import SearchService
from repro.api.types import (
    FORMAT_VERSION,
    IndexSpec,
    QueryStats,
    SearchRequest,
    SearchResponse,
)

__all__ = [
    "FORMAT_VERSION",
    "MutableSearchService",
    "IndexSpec",
    "SearchRequest",
    "SearchResponse",
    "QueryStats",
    "SearchService",
    "Metric",
    "register_metric",
    "get_metric",
    "available_metrics",
    "exact_topk_np",
    "register_backend",
    "get_backend",
    "available_backends",
    "batched_rerank",
]


def __getattr__(name):
    """Lazy export of the mutable service (PEP 562): repro.ingest composes
    the objects defined above, so an eager tail import here would be a
    cycle whenever repro.ingest itself is the import entry point."""
    if name == "MutableSearchService":
        from repro.ingest.service import MutableSearchService
        return MutableSearchService
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
