"""repro.api — the unified search-service surface.

One request/response API over every engine in the repo: exact brute force,
monolithic HNSW, the paper's partitioned two-stage engine, and the
mesh-distributed variant. See api/README.md for the backend matrix.
"""

from repro.api.backends import (
    available_backends,
    get_backend,
    register_backend,
)
from repro.api.metrics import (
    Metric,
    available_metrics,
    exact_topk_np,
    get_metric,
    register_metric,
)
from repro.api.rerank import batched_rerank
from repro.api.service import SearchService
from repro.api.types import (
    FORMAT_VERSION,
    IndexSpec,
    QueryStats,
    SearchRequest,
    SearchResponse,
)

__all__ = [
    "FORMAT_VERSION",
    "IndexSpec",
    "SearchRequest",
    "SearchResponse",
    "QueryStats",
    "SearchService",
    "Metric",
    "register_metric",
    "get_metric",
    "available_metrics",
    "exact_topk_np",
    "register_backend",
    "get_backend",
    "available_backends",
    "batched_rerank",
]
