"""Typed request/response surface of the search service.

These are the only objects a client needs: an `IndexSpec` describes *what*
to build (metric, backend, partitioning, HNSW knobs), a `SearchRequest`
describes *one batched call* (k, ef, rerank, stats), and a `SearchResponse`
carries the results plus optional per-query statistics (the paper's
"number of vector reads", Fig. 9).

The spec round-trips through JSON — it is embedded verbatim in the on-disk
index manifest (service.save/load), so a saved index knows how to
reconstruct itself.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.hnsw_graph import HNSWConfig

__all__ = ["IndexSpec", "SearchRequest", "SearchResponse", "QueryStats",
           "FORMAT_VERSION", "PQ_FORMAT_VERSION"]

# Version of the on-disk index layout (manifest + checkpoint step dirs).
# Bump when the backend state trees change incompatibly.
FORMAT_VERSION = 1
# Product-quantized indexes (dtype="pq"): same layout as version 1 plus
# fitted PQ codebooks riding the spec (and an extra f32 rerank table in csd
# stores). Written only when spec.dtype == "pq"; SearchService.load reads
# both. (Version 2 is the mutable/ingest layout — see repro.ingest.)
PQ_FORMAT_VERSION = 3


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Everything needed to build (or re-open) an index.

    metric  : "l2" | "ip" | "cosine" (see api.metrics for the registry)
    backend : "exact" | "hnsw" | "partitioned" | "distributed" | "csd"
              (see api.backends; "hnsw" == "partitioned" with one partition)
    num_partitions : stage-1 sub-graph count (paper §4.1)
    dtype   : stored vector precision — "float32" (default) or a quantized
              code type "uint8" / "int8" (the paper's SIFT1B operating
              point is uint8: 1 byte/dim is what fits a billion points on
              the SmartSSD). Quantized indexes store codes everywhere
              (HBM tables, block store, checkpoints), traverse in integer
              code space with f32 accumulation, and rescale stage-1
              distances by qscale**2; stage-2 rerank stays float32 over
              dequantized rows. l2 metric only.
    qscale / qzero : the symmetric scalar quantizer's scale / zero-point
              (optim.compression.VectorQuantizer). Fitted from the data by
              SearchService.build — never set them by hand; they ride the
              spec into the index manifest so a saved quantized index is
              self-describing.
              dtype="pq" is product quantization (m subspaces x 256
              centroids, 1 byte per subspace — the 16-64x that fits
              SIFT1B-class data): codes are stored everywhere vectors
              live, traversal computes asymmetric distances through a
              per-query [m, 256] LUT, and stage-2 rerank uses true
              float32 rows. l2 metric only; saved with manifest
              format_version 3.
    pq_m    : dtype="pq" only — number of subspaces (must divide the
              vector dim). Row size becomes pq_m bytes.
    pq_codebooks : the fitted PQ codebooks as nested lists
              ([pq_m][256][dsub], JSON-ready). Fitted by
              SearchService.build (or reused verbatim when pre-set, which
              is how cluster shards share one code space) — they ride the
              spec into the manifest so a saved PQ index is
              self-describing and bit-reproducible.
    hnsw    : graph construction knobs (ignored by the exact backend)
    keep_vectors : retain the raw vectors alongside the graph — required
              for `SearchRequest.rerank` on the in-memory graph backends and
              saved with the index. Off by default: it costs a second copy
              of the dataset in device memory (and in every saved version).
              The `csd` backend ignores it — stage-2 rerank reads vectors
              back from the block store.
    storage_path : `csd` only — directory of the block-aligned store
              (paper Fig. 5 tables on "flash"). Required at build; embedded
              in the manifest so `load` can re-open the store.
    block_size : `csd` only — bytes per storage block; one block read
              stands in for one flash read / P2P-DMA transfer.
    cache_bytes : `csd` only — PageCache capacity (the SmartSSD DRAM tier
              in front of NAND). Peak resident store memory is bounded by
              this, not by the dataset size.
    prefetch : `csd` only — run the async next-hop prefetcher thread.
    fused_hops : layer-0 hops per kernel invocation / host superstep
              (SearchParams.fused_hops). 1 = the legacy hop-stepped path;
              >1 switches the in-memory graph backends to the fused Pallas
              traversal kernel and the csd backend to speculative H-hop
              supersteps (one host sync + one jitted dispatch per
              superstep). Bit-identical results at every value; rides the
              manifest so a saved index keeps its tuning.
    """

    metric: str = "l2"
    backend: str = "partitioned"
    num_partitions: int = 1
    hnsw: HNSWConfig = dataclasses.field(default_factory=HNSWConfig)
    keep_vectors: bool = False
    storage_path: str | None = None
    block_size: int = 4096
    cache_bytes: int = 64 << 20
    prefetch: bool = True
    dtype: str = "float32"
    qscale: float | None = None
    qzero: int | None = None
    fused_hops: int = 1
    pq_m: int = 8
    pq_codebooks: Any = None  # nested lists [pq_m][256][dsub], JSON-ready

    def quantizer(self):
        """The fitted quantizer (VectorQuantizer or PQQuantizer), or None
        for the float32 path."""
        if self.dtype == "float32":
            return None
        if self.dtype == "pq":
            from repro.optim.compression import PQQuantizer
            if self.pq_codebooks is None:
                raise ValueError(
                    "dtype='pq' spec has no fitted pq_codebooks — build PQ "
                    "indexes through SearchService.build")
            cb = self.pq_codebooks
            dsub = len(cb[0][0])
            return PQQuantizer.from_json(
                {"m": self.pq_m, "dsub": dsub, "codebooks": cb})
        from repro.optim.compression import VectorQuantizer
        if self.qscale is None or self.qzero is None:
            raise ValueError(
                f"dtype={self.dtype!r} spec has no fitted qscale/qzero — "
                f"build quantized indexes through SearchService.build")
        return VectorQuantizer(dtype=self.dtype, scale=float(self.qscale),
                               zero_point=int(self.qzero))

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["hnsw"] = dataclasses.asdict(self.hnsw)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "IndexSpec":
        d = dict(d)
        hnsw_fields = {f.name for f in dataclasses.fields(HNSWConfig)}
        hnsw = HNSWConfig(**{k: v for k, v in d.pop("hnsw", {}).items()
                             if k in hnsw_fields})
        known = {f.name for f in dataclasses.fields(cls)} - {"hnsw"}
        return cls(hnsw=hnsw, **{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One batched search call.

    queries : [B, D] array-like
    k       : results per query
    ef      : beam width (graph backends; the exact backend ignores it)
    rerank  : recompute exact distances over the stage-1 candidate pool on
              device (the paper's host-side stage 2, folded into the batch)
    with_stats : return per-query hop / distance-evaluation counts
    """

    queries: Any
    k: int = 10
    ef: int = 40
    rerank: bool = False
    with_stats: bool = False
    # trace ctx (repro.obs.SpanCtx) linking this batch to the request spans
    # it serves — set by the dynamic batcher, ignored everywhere else; not
    # part of request identity/equality and never serialized
    trace: Any = dataclasses.field(default=None, compare=False, repr=False)


@dataclasses.dataclass(frozen=True)
class QueryStats:
    """Per-query counters; `None` where a backend does not track one.

    The storage counters (csd backend) are per-*request* scalars — the
    PageCache is shared across the batch, so per-query attribution is not
    well defined. `block_reads` is the paper's P2P-DMA traffic unit: the
    number of flash blocks actually transferred (demand misses + prefetches);
    `cache_hit_rate` is hits / demand accesses.
    """

    hops: Any = None            # [B] candidate pops at layer 0
    dist_calcs: Any = None      # [B] distance evaluations == "vector reads"
    block_reads: Any = None     # scalar: flash blocks transferred (Fig. 9)
    cache_hits: Any = None      # scalar: demand accesses served from cache
    cache_misses: Any = None    # scalar: demand accesses that hit flash —
                                # hits + misses == demand, which is what
                                # demand-weighted hit-rate aggregation
                                # (ingest segments, cluster shards) needs
    cache_hit_rate: Any = None  # scalar in [0, 1]
    bytes_read: Any = None      # scalar: block_reads * block_size
    supersteps: Any = None      # scalar (csd): host-sync'd traversal steps —
                                # one per hop on the legacy path, one per
                                # fused_hops-hop superstep on the fused path
                                # (the per-hop round-trip the fused kernel
                                # amortizes; compare against sum(hops))
    segments: Any = None        # mutable index only: per-segment stat dicts
                                # ({segment, n, hops, dist_calcs, ...}) —
                                # per-request, like the storage counters


@dataclasses.dataclass(frozen=True)
class SearchResponse:
    """ids/dists are [B, k]; -1 / +inf mark empty slots. Arrays are
    whatever the backend produced (device arrays on the hot path) — call
    `np.asarray` at the edge if host copies are needed."""

    ids: Any
    dists: Any
    stats: QueryStats | None = None
