"""Period-structured decoder stack covering all 10 assigned architectures.

A model is `prefix_pattern` (irregular leading layers, e.g. DeepSeek's dense
layer 0) followed by `num_periods` repetitions of `pattern` (e.g. Jamba's
[mamba, mamba, mamba, mamba, attn, mamba, mamba, mamba] with alternating
MoE). The repeated period is executed under `jax.lax.scan` with stacked
params — compile time and HLO size stay O(period), not O(layers), which is
what keeps 80 dry-run compiles tractable and is also the right shape for
FSDP all-gather prefetch overlap.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import shard_ctx
from repro.models import ssm as S

_BARRIER_AD: bool | None = None


def _barrier_ad() -> bool:
    """Old jax lacks the optimization_barrier differentiation rule; the
    barrier is only a layout hint, so skip it there (2x carry-stack memory
    on 0.4-era CPU builds is acceptable; correctness is unchanged)."""
    global _BARRIER_AD
    if _BARRIER_AD is None:
        try:
            jax.grad(lambda v: jax.lax.optimization_barrier(v).sum())(
                jnp.ones((2,)))
            _BARRIER_AD = True
        except NotImplementedError:
            _BARRIER_AD = False
    return _BARRIER_AD


__all__ = ["LayerSpec", "ModelConfig", "init_params", "forward", "init_cache",
           "compute_logits", "chunked_xent"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"        # attn | mla | mamba | mlstm | slstm
    ffn: str = "glu"          # glu | relu2 | moe | none
    window: int = 0           # sliding-window size for kind == attn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...]
    num_periods: int
    prefix_pattern: tuple[LayerSpec, ...] = ()
    qk_norm: bool = False
    rope_theta: float = 1e4
    act: str = "silu"
    mla: Any = None           # layers.MLAConfig
    moe: Any = None           # moe.MoEConfig
    mamba: Any = None         # ssm.MambaConfig
    xlstm: Any = None         # ssm.XLSTMConfig
    embed_inputs: bool = True
    num_output_heads: int = 1
    prefix_lm: bool = False   # bidirectional prefix (paligemma)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    remat: bool = True
    loss_chunk: int = 512
    block_q: int = 512
    block_k: int = 1024
    family: str = "dense"     # dense | moe | ssm | vlm | audio | hybrid
    sub_quadratic: bool = False
    grad_accum: int = 1       # microbatches per step (activation memory / N)
    kv_quant: bool = False    # int8 KV cache (decode cells)
    skip_masked_blocks: bool = False  # causal block skipping (attn)

    @property
    def num_layers(self) -> int:
        return len(self.prefix_pattern) + self.num_periods * len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim always
        shards on the model axis (e.g. granite's 49155 -> 49408). Padded
        logit columns are masked to -inf in the loss / sampling paths."""
        return -(-self.vocab_size // 256) * 256

    def all_specs(self):
        return list(self.prefix_pattern) + list(self.pattern) * self.num_periods


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def _layer_init(key, spec: LayerSpec, cfg: ModelConfig):
    kmix, kffn = jax.random.split(key)
    dt = cfg.param_dtype
    p: dict = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if spec.kind == "attn":
        p["attn"] = L.attn_init(kmix, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, qk_norm=cfg.qk_norm, dtype=dt)
    elif spec.kind == "mla":
        p["attn"] = L.mla_init(kmix, cfg.d_model, cfg.n_heads, cfg.mla, dtype=dt)
    elif spec.kind == "mamba":
        p["mixer"] = S.mamba_init(kmix, cfg.d_model, cfg.mamba, dtype=dt)
    elif spec.kind == "mlstm":
        p["mixer"] = S.mlstm_init(kmix, cfg.d_model, cfg.xlstm, dtype=dt)
    elif spec.kind == "slstm":
        p["mixer"] = S.slstm_init(kmix, cfg.d_model, cfg.xlstm, dtype=dt)
    else:
        raise ValueError(spec.kind)
    if spec.ffn in ("glu", "dense"):
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = L.mlp_init(kffn, cfg.d_model, cfg.d_ff, spec.ffn, dtype=dt)
    elif spec.ffn == "moe":
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["moe"] = M.moe_init(kffn, cfg.d_model, cfg.moe, dtype=dt)
    return p


def _layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, s_max: int, dtype):
    if spec.kind == "attn":
        return L.attn_cache_init(batch, s_max, cfg.n_kv_heads, cfg.head_dim,
                                 window=spec.window, dtype=dtype,
                                 quant=cfg.kv_quant)
    if spec.kind == "mla":
        return L.mla_cache_init(batch, s_max, cfg.mla, dtype=dtype)
    if spec.kind == "mamba":
        return S.mamba_cache_init(batch, cfg.d_model, cfg.mamba, dtype=dtype)
    if spec.kind == "mlstm":
        return S.mlstm_cache_init(batch, cfg.d_model, cfg.xlstm, dtype=dtype)
    if spec.kind == "slstm":
        return S.slstm_cache_init(batch, cfg.d_model, cfg.xlstm, dtype=dtype)
    raise ValueError(spec.kind)


def _layer_apply(p, spec: LayerSpec, cfg: ModelConfig, x, *, mode, cache, pos,
                 prefix_len=None):
    aux = 0.0
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        h, new_cache = L.attn_apply(
            p["attn"], h, mode=mode, cache=cache, pos=pos, window=spec.window,
            prefix_len=prefix_len if cfg.prefix_lm else None,
            rope_theta=cfg.rope_theta, block_q=cfg.block_q,
            block_k=cfg.block_k, skip_masked_blocks=cfg.skip_masked_blocks)
    elif spec.kind == "mla":
        h, new_cache = L.mla_apply(
            p["attn"], h, mode=mode, cache=cache, pos=pos, mla=cfg.mla,
            rope_theta=cfg.rope_theta, block_q=cfg.block_q, block_k=cfg.block_k)
    elif spec.kind == "mamba":
        h, new_cache = S.mamba_apply(p["mixer"], h, mode=mode, cache=cache,
                                     pos=pos, mc=cfg.mamba)
    elif spec.kind == "mlstm":
        h, new_cache = S.mlstm_apply(p["mixer"], h, mode=mode, cache=cache,
                                     pos=pos, xc=cfg.xlstm)
    else:  # slstm
        h, new_cache = S.slstm_apply(p["mixer"], h, mode=mode, cache=cache,
                                     pos=pos, xc=cfg.xlstm)
    # residual-stream pins: with_sharding_constraint also constrains the
    # cotangent in the transpose, keeping backward gathers batch-sharded.
    x = shard_ctx.constrain(x + h, ("dp", "tp", None))
    if "ffn" in p:
        x = x + L.mlp_apply(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                            act=cfg.act)
        x = shard_ctx.constrain(x, ("dp", "tp", None))
    elif "moe" in p:
        y, aux = M.moe_apply(p["moe"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                             cfg.moe, train=(mode == "train"))
        x = shard_ctx.constrain(x + y, ("dp", "tp", None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Embedding with a partition-friendly backward
# ---------------------------------------------------------------------------


@jax.custom_vjp
def embed_lookup(table, tokens):
    """table[V, d], tokens[B, T] -> [B, T, d].

    Forward is a plain gather (GSPMD slices it fine). Backward REPLACES the
    scatter-add — which the SPMD partitioner replicates at [V, d] f32 per
    device for vocab-sharded tables — with a chunked one-hot einsum:
    elementwise iota-compare + matmul partition as (dp x model) with a psum,
    keeping the gradient sharded like the table. ~12 GB/device saved on
    dbrx-132b train.
    """
    return table[tokens]


def _embed_fwd(table, tokens):
    # zero-size marker array carries the table's (V, dtype) statically.
    marker = jnp.zeros((table.shape[0], 0), table.dtype)
    return table[tokens], (tokens, marker)


def _embed_bwd(res, g):
    tokens, marker = res
    V, dt = marker.shape[0], marker.dtype
    B, T, d = g.shape
    chunk = min(T, 512)
    while T % chunk:
        chunk -= 1
    n = T // chunk
    toks = tokens.reshape(B, n, chunk).swapaxes(0, 1)
    gs = g.reshape(B, n, chunk, d).swapaxes(0, 1)

    def step(acc, xs):
        tok_c, g_c = xs
        oh = (tok_c[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (B, chunk, V), 2))
        oh = shard_ctx.constrain(oh.astype(g.dtype), ("dp", None, "tp"))
        acc = acc + jnp.einsum("bcv,bcd->vd", oh, g_c)
        return acc, None

    acc0 = shard_ctx.constrain(jnp.zeros((V, d), g.dtype), ("tp", "dp"))
    dtab, _ = jax.lax.scan(step, acc0, (toks, gs))
    return dtab.astype(dt), None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    dt = cfg.param_dtype
    params: dict = {}
    if cfg.embed_inputs:
        params["embed"] = (
            jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model), dt) * 0.02)
    if cfg.prefix_pattern:
        pk = jax.random.split(keys[1], len(cfg.prefix_pattern))
        params["prefix"] = {
            str(i): _layer_init(pk[i], s, cfg)
            for i, s in enumerate(cfg.prefix_pattern)
        }
    pk = jax.random.split(keys[2], cfg.num_periods)

    def one_period(k):
        lk = jax.random.split(k, len(cfg.pattern))
        return {str(i): _layer_init(lk[i], s, cfg)
                for i, s in enumerate(cfg.pattern)}

    params["periods"] = jax.vmap(one_period)(pk)
    params["final_norm"] = jnp.ones((cfg.d_model,), dt)
    if not (cfg.tie_embeddings and cfg.embed_inputs):
        params["head"] = (
            jax.random.normal(
                keys[3], (cfg.d_model, cfg.num_output_heads, cfg.padded_vocab), dt
            ) / (cfg.d_model ** 0.5))
    return params


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.float32):
    cache: dict = {}
    if cfg.prefix_pattern:
        cache["prefix"] = {
            str(i): _layer_cache(s, cfg, batch, s_max, dtype)
            for i, s in enumerate(cfg.prefix_pattern)
        }

    def one_period(_):
        return {str(i): _layer_cache(s, cfg, batch, s_max, dtype)
                for i, s in enumerate(cfg.pattern)}

    cache["periods"] = jax.vmap(one_period)(jnp.arange(cfg.num_periods))
    return cache


def forward(params, cfg: ModelConfig, inputs, *, mode: str, cache=None, pos=0,
            prefix_len=None):
    """inputs: tokens [B, T] int32 (embed_inputs) or embeds [B, T, d].

    Returns (hidden [B, T, d], new_cache, aux_loss_sum).
    """
    if cfg.embed_inputs:
        x = embed_lookup(params["embed"], inputs)
    else:
        x = inputs
    x = shard_ctx.constrain(x, ("dp", "tp", None))
    aux_total = 0.0
    new_cache: dict = {} if cache is not None else None

    for i, spec in enumerate(cfg.prefix_pattern):
        c = cache["prefix"][str(i)] if cache is not None else None
        x, nc, aux = _layer_apply(params["prefix"][str(i)], spec, cfg, x,
                                  mode=mode, cache=c, pos=pos,
                                  prefix_len=prefix_len)
        aux_total = aux_total + aux
        if cache is not None:
            new_cache.setdefault("prefix", {})[str(i)] = nc

    def period_fn(x, xs):
        pparams, pcache = xs
        # sequence-parallel carry: the scan residual saved per period for
        # backward is stored T-sharded on the model axis (Megatron-SP);
        # GSPMD all-gathers transiently inside the layer. For decode (T=1)
        # the tp factor doesn't divide and the constraint drops to DP-only.
        # The optimization barrier keeps XLA from hoisting the layer-entry
        # bf16->f32 convert out of the scan — without it the carry stack is
        # stored f32 AND full-T (2x + gather blowup on 40-period models).
        if _barrier_ad():
            x = jax.lax.optimization_barrier(x)
        x = shard_ctx.constrain(x, ("dp", "tp", None))
        new_pc = {}
        aux_p = 0.0
        for i, spec in enumerate(cfg.pattern):
            c = pcache[str(i)] if pcache is not None else None
            x, nc, aux = _layer_apply(pparams[str(i)], spec, cfg, x,
                                      mode=mode, cache=c, pos=pos,
                                      prefix_len=prefix_len)
            aux_p = aux_p + aux
            if nc is not None:
                new_pc[str(i)] = nc
        return x, (new_pc if new_pc else None, aux_p)

    body = period_fn
    if mode == "train" and cfg.remat:
        body = jax.checkpoint(period_fn)
    pcaches = cache["periods"] if cache is not None else None
    if pcaches is None:
        x, (_, auxs) = jax.lax.scan(lambda h, pp: body(h, (pp, None)),
                                    x, params["periods"])
    else:
        x, (ncs, auxs) = jax.lax.scan(body, x, (params["periods"], pcaches))
        new_cache["periods"] = ncs
    aux_total = aux_total + jnp.sum(auxs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, aux_total


def _head_matrix(params, cfg: ModelConfig):
    if "head" in params:
        return params["head"]
    # tied: [d, 1, V]
    return params["embed"].T[:, None, :]


def compute_logits(params, cfg: ModelConfig, hidden):
    """hidden [B, T, d] -> logits [B, T, (nH,) padded_V] (f32); padded vocab
    columns are -inf so sampling/argmax never selects them."""
    head = _head_matrix(params, cfg)
    logits = jnp.einsum("btd,dhv->bthv", hidden.astype(jnp.float32),
                        head.astype(jnp.float32))
    if cfg.padded_vocab != cfg.vocab_size:
        cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 3)
        logits = jnp.where(cols < cfg.vocab_size, logits, -jnp.inf)
    if cfg.num_output_heads == 1:
        logits = logits[:, :, 0]
    return logits


def chunked_xent(params, cfg: ModelConfig, hidden, labels, mask=None):
    """Cross-entropy without materializing [B, T, V] logits.

    Scans over sequence chunks — each step sees [B, chunk, V], which under
    (data, model)=(batch, vocab) sharding is a few hundred KB per chip even
    at vocab 256k. labels: [B, T] or [B, T, nH] (multi-head: musicgen).
    """
    B, T, d = hidden.shape
    head = _head_matrix(params, cfg)
    chunk = min(cfg.loss_chunk, T)
    if T % chunk:
        chunk = 1 if T < 2 else [c for c in range(chunk, 0, -1) if T % c == 0][0]
    n = T // chunk
    if labels.ndim == 2:
        labels = labels[..., None]
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    elif mask.ndim == 2:
        mask = mask[..., None].astype(jnp.float32)
    hs = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk, -1).swapaxes(0, 1)
    ms = mask.reshape(B, n, chunk, -1).swapaxes(0, 1)

    @jax.checkpoint
    def step(carry, xs):
        h_c, l_c, m_c = xs
        logits = jnp.einsum("bcd,dhv->bchv", h_c.astype(jnp.float32),
                            head.astype(jnp.float32))
        logits = shard_ctx.constrain(logits, ("dp", None, None, "tp"))
        cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 3)
        logits = jnp.where(cols < cfg.vocab_size, logits, -jnp.inf)  # vocab pad
        logz = jax.nn.logsumexp(logits, axis=-1)
        # label logit via masked sum, NOT take_along_axis: a gather across a
        # vocab-sharded axis forces GSPMD to replicate the logits; the masked
        # sum partitions as elementwise + psum.
        ll = jnp.sum(jnp.where(cols == l_c[..., None], logits, 0.0), axis=-1)
        loss = ((logz - ll) * m_c).sum()
        return (carry[0] + loss, carry[1] + m_c.sum()), None

    (loss_sum, count), _ = jax.lax.scan(step, (0.0, 0.0), (hs, ls, ms))
    return loss_sum / jnp.maximum(count, 1.0)
