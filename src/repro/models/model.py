"""Step functions: train / prefill / decode over any ModelConfig.

These are the functions the dry-run lowers for every (arch x shape) cell:
  * train_step   — fwd + chunked-vocab loss + bwd + AdamW (train_4k)
  * prefill_step — build the KV cache, return last-position logits (prefill_32k)
  * decode_step  — one token against a seq_len cache (decode_32k, long_500k)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import (
    ModelConfig, chunked_xent, compute_logits, forward, init_cache, init_params,
)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "make_train_state", "train_step", "prefill_step", "decode_step",
    "loss_fn", "ModelConfig",
]


def make_train_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig | None = None):
    params = init_params(key, cfg)
    return {"params": params, "opt": adamw_init(params)}


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: {"inputs": tokens|embeds, "labels": [B,T] or [B,T,nH]}."""
    hidden, _, aux = forward(params, cfg, batch["inputs"], mode="train",
                             prefix_len=batch.get("prefix_len"))
    loss = chunked_xent(params, cfg, hidden, batch["labels"],
                        mask=batch.get("mask"))
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


@functools.partial(jax.jit, static_argnames=("cfg", "opt_cfg"),
                   donate_argnums=(0,))
def train_step(state, batch, cfg: ModelConfig, opt_cfg: AdamWConfig):
    import math as _math

    from repro.models import shard_ctx
    B = jax.tree.leaves(batch)[0].shape[0]
    M = _math.gcd(max(cfg.grad_accum, 1), B)   # smoke batches may be tiny
    # never shrink a microbatch below the DP extent: an unshardable batch
    # replicates every activation across data shards (jamba multi-pod).
    dpn = shard_ctx.dp_size()
    while M > 1 and (B // M) % dpn != 0:
        M //= 2
    if M == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], cfg, batch)
    else:
        # gradient accumulation: scan over microbatches, f32 accumulator —
        # activation/carry memory scales 1/M at the cost of M smaller steps
        # (compute identical; the collective schedule repeats per microbatch).
        scalars = {k: v for k, v in batch.items() if jnp.ndim(v) == 0}
        arrays = {k: v for k, v in batch.items() if jnp.ndim(v) > 0}
        mb = jax.tree.map(
            lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]), arrays)

        def micro(acc, mbatch):
            (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], cfg, {**mbatch, **scalars})
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / M, acc, g)
            return acc, (l, met)

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
        grads, (losses, mets) = jax.lax.scan(micro, zeros, mb)
        loss = losses.mean()
        metrics = jax.tree.map(lambda x: x.mean(), mets)
    params, opt, opt_metrics = adamw_update(
        opt_cfg, state["params"], grads, state["opt"])
    metrics = {"loss": loss, **metrics, **opt_metrics}
    return {"params": params, "opt": opt}, metrics


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(2,))
def prefill_step(params, batch, cache, cfg: ModelConfig):
    """Fill the cache with batch["inputs"] ([B, T]); return last logits."""
    hidden, new_cache, _ = forward(params, cfg, batch["inputs"], mode="prefill",
                                   cache=cache, pos=0,
                                   prefix_len=batch.get("prefix_len"))
    logits = compute_logits(params, cfg, hidden[:, -1:])
    return logits, new_cache


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(2,))
def decode_step(params, tokens, cache, pos, cfg: ModelConfig):
    """One decode step. tokens: [B, 1] int32 (or [B, 1, d] embeds);
    pos: scalar int32 current position. Cache is donated (updated in place
    on device)."""
    hidden, new_cache, _ = forward(params, cfg, tokens, mode="decode",
                                   cache=cache, pos=pos)
    logits = compute_logits(params, cfg, hidden)
    return logits, new_cache
