"""Recurrent blocks: Mamba-1 (Jamba), mLSTM and sLSTM (xLSTM).

All three expose the same (init, apply) contract as attention layers:
apply(params, x, mode, cache, pos) -> (y, new_cache). Sequence processing
uses a *chunked, rematerialized* scan: the outer scan checkpoints only the
recurrent state every `chunk` steps, so train-time memory is
O(T/chunk * state) instead of O(T * state) — this is what makes the
`long_500k` cells feasible for the SSM/hybrid architectures.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import shard_ctx
from repro.models.layers import rms_norm

__all__ = [
    "MambaConfig", "mamba_init", "mamba_apply", "mamba_cache_init",
    "XLSTMConfig", "mlstm_init", "mlstm_apply", "mlstm_cache_init",
    "slstm_init", "slstm_apply", "slstm_cache_init",
]


def chunked_scan(step, init, xs, chunk: int, remat: bool = True):
    """lax.scan over time with per-chunk remat. xs leaves: [T, ...]."""
    T = jax.tree.leaves(xs)[0].shape[0]
    if T % chunk != 0:
        chunk = math.gcd(T, chunk) or T
    n = T // chunk

    def chunk_fn(carry, xc):
        return jax.lax.scan(step, carry, xc)

    if remat:
        chunk_fn = jax.checkpoint(chunk_fn)
    xs_c = jax.tree.map(lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)
    carry, ys = jax.lax.scan(chunk_fn, init, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return carry, ys


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv along T. x: [B, T, D], w: [K, D], state: [B, K-1, D]."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out + b[None, None, :], new_state


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM) — Jamba's sequence mixer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0      # 0 -> ceil(d_model / 16)
    chunk: int = 256

    def inner(self, d_model):
        return self.expand * d_model

    def rank(self, d_model):
        return self.dt_rank or -(-d_model // 16)


def mamba_init(key, d_model, mc: MambaConfig, dtype=jnp.float32):
    di, r = mc.inner(d_model), mc.rank(d_model)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    A = jnp.broadcast_to(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (di, mc.d_state))
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, 2, di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (mc.d_conv, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, r + 2 * mc.d_state), dtype) / math.sqrt(di),
        "dt_proj": jax.random.normal(ks[3], (r, di), dtype) / math.sqrt(r),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))).astype(dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[4], (di, d_model), dtype) / math.sqrt(di),
    }


def mamba_apply(p, x, *, mode, cache=None, pos=0, mc: MambaConfig):
    # recurrent mixers iterate time sequentially: replicate T across
    # the model axis here (a tp-sharded scan axis forces a full gather
    # per step); dp stays on batch.
    x = shard_ctx.constrain(x, ("dp", None, None))
    B, T, d_model = x.shape
    di, r, S = p["D"].shape[0], mc.rank(d_model), mc.d_state
    xz = jnp.einsum("btd,dge->btge", x, p["in_proj"])
    xb, z = xz[:, :, 0], xz[:, :, 1]   # gate/up split on an UNSHARDED axis
    xb = shard_ctx.constrain(xb, ("dp", None, "tp"))
    z = shard_ctx.constrain(z, ("dp", None, "tp"))
    conv_state = cache["conv"] if (cache is not None and mode == "decode") else None
    xc, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    if mode != "decode":
        # prefill must still hand the decoder a valid conv state.
        K = p["conv_w"].shape[0]
        pad = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv = jax.lax.dynamic_slice_in_dim(pad, pad.shape[1] - (K - 1), K - 1, 1)
    xc = shard_ctx.constrain(jax.nn.silu(xc), ("dp", None, "tp"))
    proj = jnp.einsum("bti,ie->bte", xc, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("btr,ri->bti", proj[..., :r], p["dt_proj"]) + p["dt_bias"]
    )                                                     # [B, T, di]
    dt = shard_ctx.constrain(dt, ("dp", None, "tp"))
    Bm, Cm = proj[..., r : r + S], proj[..., r + S :]     # [B, T, S]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # [di, S]

    def step(h, xs):
        dt_t, B_t, C_t, x_t = xs                          # [B,di],[B,S],[B,S],[B,di]
        dA = jnp.exp(dt_t[..., None] * A[None])           # [B, di, S]
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bis,bs->bi", h, C_t)
        return h, y

    xs_t = (
        dt.swapaxes(0, 1), Bm.swapaxes(0, 1), Cm.swapaxes(0, 1), xc.swapaxes(0, 1)
    )
    h0 = (
        cache["ssm"] if (cache is not None and mode == "decode")
        else jnp.zeros((B, di, S), jnp.float32)
    )
    # shard the recurrent state (and thus every per-step backward residual)
    # on the model axis: T/chunk boundary states + chunk-length inner
    # residuals are the memory wall of recurrent backward.
    h0 = shard_ctx.constrain(h0, ("dp", "tp", None))
    if mode == "decode":
        h, ys = jax.lax.scan(step, h0, xs_t)
    else:
        h, ys = chunked_scan(step, h0, xs_t, mc.chunk, remat=(mode == "train"))
    y = ys.swapaxes(0, 1) + xc * p["D"][None, None, :]
    y = shard_ctx.constrain(y, ("dp", None, "tp"))
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", y.astype(x.dtype), p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": h}
    return out, new_cache


def mamba_cache_init(batch, d_model, mc: MambaConfig, dtype=jnp.float32):
    di = mc.inner(d_model)
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, parallelizable) + sLSTM (scalar, recurrent)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    n_heads: int = 4
    m_proj_factor: float = 2.0
    s_ffn_factor: float = 4.0 / 3.0
    d_conv: int = 4
    chunk: int = 256


def mlstm_init(key, d_model, xc: XLSTMConfig, dtype=jnp.float32):
    di = int(xc.m_proj_factor * d_model)
    H = xc.n_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d_model)
    si = 1.0 / math.sqrt(di)
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, 2, di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (xc.d_conv, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "wq": jax.random.normal(ks[2], (di, di), dtype) * si,
        "wk": jax.random.normal(ks[3], (di, di), dtype) * si,
        "wv": jax.random.normal(ks[4], (di, di), dtype) * si,
        "w_i": jax.random.normal(ks[5], (di, H), dtype) * si,
        "w_f": jax.random.normal(ks[6], (di, H), dtype) * si + 3.0,  # open f-gate
        "gn_scale": jnp.ones((di,), dtype),
        "skip": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[7], (di, d_model), dtype) * si,
    }


def _mlstm_cell(q, k, v, log_i, log_f, state):
    """One stabilized mLSTM step. q,k,v: [B,H,dh]; log_i/f: [B,H]."""
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_p * n + i_p * k
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new)
    )[..., None]
    h = jnp.einsum("bhvd,bhd->bhv", C, q) / denom
    return (C, n, m_new), h


def mlstm_apply(p, x, *, mode, cache=None, pos=0, xc: XLSTMConfig):
    # recurrent mixers iterate time sequentially: replicate T across
    # the model axis here (a tp-sharded scan axis forces a full gather
    # per step); dp stays on batch.
    x = shard_ctx.constrain(x, ("dp", None, None))
    B, T, d_model = x.shape
    di = p["conv_b"].shape[0]
    H = xc.n_heads
    dh = di // H
    xz = jnp.einsum("btd,dge->btge", x, p["in_proj"])
    xb, z = xz[:, :, 0], xz[:, :, 1]   # gate/up split on an UNSHARDED axis
    xb = shard_ctx.constrain(xb, ("dp", None, "tp"))
    z = shard_ctx.constrain(z, ("dp", None, "tp"))
    conv_state = cache["conv"] if (cache is not None and mode == "decode") else None
    xcv, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    if mode != "decode":
        K = p["conv_w"].shape[0]
        pad = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
        new_conv = jax.lax.dynamic_slice_in_dim(pad, pad.shape[1] - (K - 1), K - 1, 1)
    xcv = shard_ctx.constrain(jax.nn.silu(xcv), ("dp", None, "tp"))
    q = jnp.einsum("bti,ij->btj", xcv, p["wq"]).reshape(B, T, H, dh)
    k = jnp.einsum("bti,ij->btj", xcv, p["wk"]).reshape(B, T, H, dh) / math.sqrt(dh)
    v = jnp.einsum("bti,ij->btj", xb, p["wv"]).reshape(B, T, H, dh)
    q = shard_ctx.constrain(q, ("dp", None, None, "tp"))
    k = shard_ctx.constrain(k, ("dp", None, None, "tp"))
    v = shard_ctx.constrain(v, ("dp", None, None, "tp"))
    log_i = jnp.einsum("bti,ih->bth", xb, p["w_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bti,ih->bth", xb, p["w_f"]).astype(jnp.float32))

    def step(state, xs):
        q_t, k_t, v_t, li, lf = xs
        return _mlstm_cell(q_t, k_t, v_t, li, lf, state)

    if cache is not None and mode == "decode":
        state0 = (cache["C"], cache["n"], cache["m"])
    else:
        state0 = (
            shard_ctx.constrain(jnp.zeros((B, H, dh, dh), jnp.float32),
                                ("dp", None, "tp", None)),
            shard_ctx.constrain(jnp.zeros((B, H, dh), jnp.float32),
                                ("dp", None, "tp")),
            jnp.full((B, H), -jnp.inf, jnp.float32),
        )
    xs_t = tuple(
        a.swapaxes(0, 1).astype(jnp.float32)
        for a in (q, k, v, log_i, log_f)
    )
    if mode == "decode":
        state, hs = jax.lax.scan(step, state0, xs_t)
    else:
        state, hs = chunked_scan(step, state0, xs_t, xc.chunk, remat=(mode == "train"))
    h = hs.swapaxes(0, 1).reshape(B, T, di)               # [B, T, di]
    h = rms_norm(h.astype(x.dtype), p["gn_scale"])        # per-channel norm
    h = h + p["skip"][None, None, :] * xcv
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", h, p["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "C": state[0], "n": state[1], "m": state[2]}
    return out, new_cache


def mlstm_cache_init(batch, d_model, xc: XLSTMConfig, dtype=jnp.float32):
    di = int(xc.m_proj_factor * d_model)
    H, dh = xc.n_heads, int(xc.m_proj_factor * d_model) // xc.n_heads
    return {
        "conv": jnp.zeros((batch, xc.d_conv - 1, di), dtype),
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


def slstm_init(key, d_model, xc: XLSTMConfig, dtype=jnp.float32):
    H = xc.n_heads
    dh = d_model // H
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    ff = int(xc.s_ffn_factor * d_model)
    return {
        "w_gates": jax.random.normal(ks[0], (d_model, 4, H, dh), dtype) * s,
        "r_gates": jax.random.normal(ks[1], (4, H, dh, dh), dtype) / math.sqrt(dh),
        "b_gates": jnp.zeros((4, H, dh), dtype).at[1].set(3.0),  # open f-gate
        "gn_scale": jnp.ones((d_model,), dtype),
        "ffn_in": jax.random.normal(ks[2], (d_model, 2, ff), dtype) * s,
        "ffn_out": jax.random.normal(ks[3], (ff, d_model), dtype) / math.sqrt(ff),
    }


def _slstm_cell(gx, r, state):
    """gx: [B, 4, H, dh] input contributions; r: [4, H, dh, dh]."""
    c, n, h, m = state
    rec = jnp.einsum("bhd,ghde->bghe", h, r)              # [B, 4, H, dh]
    z_in, f_in, i_in, o_in = [gx[:, g] + rec[:, g] for g in range(4)]
    z = jnp.tanh(z_in)
    o = jax.nn.sigmoid(o_in)
    log_f = jax.nn.log_sigmoid(f_in)
    m_new = jnp.maximum(log_f + m, i_in)
    i_p = jnp.exp(i_in - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h = o * (c / jnp.maximum(n, 1e-6))
    return (c, n, h, m_new), h


def slstm_apply(p, x, *, mode, cache=None, pos=0, xc: XLSTMConfig):
    # recurrent mixers iterate time sequentially: replicate T across
    # the model axis here (a tp-sharded scan axis forces a full gather
    # per step); dp stays on batch.
    x = shard_ctx.constrain(x, ("dp", None, None))
    B, T, d_model = x.shape
    H = xc.n_heads
    dh = d_model // H
    gx = jnp.einsum("btd,dghe->btghe", x, p["w_gates"]) + p["b_gates"][None, None]
    gx = gx.astype(jnp.float32)

    def step(state, gx_t):
        return _slstm_cell(gx_t, p["r_gates"].astype(jnp.float32), state)

    if cache is not None and mode == "decode":
        state0 = (cache["sc"], cache["sn"], cache["sh"], cache["sm"])
    else:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        state0 = (zeros, zeros, zeros, jnp.full((B, H, dh), -jnp.inf))
    gx_t = gx.swapaxes(0, 1)
    if mode == "decode":
        state, hs = jax.lax.scan(step, state0, gx_t)
    else:
        state, hs = chunked_scan(step, state0, gx_t, xc.chunk, remat=(mode == "train"))
    h = hs.swapaxes(0, 1).reshape(B, T, d_model).astype(x.dtype)
    h = rms_norm(h, p["gn_scale"])
    ff = jnp.einsum("btd,dgf->btgf", h, p["ffn_in"])
    ff = jax.nn.gelu(ff[:, :, 0], approximate=True) * ff[:, :, 1]
    out = jnp.einsum("btf,fd->btd", ff, p["ffn_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"sc": state[0], "sn": state[1], "sh": state[2], "sm": state[3]}
    return out, new_cache


def slstm_cache_init(batch, d_model, xc: XLSTMConfig, dtype=jnp.float32):
    H, dh = xc.n_heads, d_model // xc.n_heads
    zeros = jnp.zeros((batch, H, dh), jnp.float32)
    return {"sc": zeros, "sn": zeros, "sh": zeros,
            "sm": jnp.full((batch, H, dh), -jnp.inf, jnp.float32)}
