"""Ambient activation-sharding context for model code.

The launcher (dryrun / train / serve) declares the mesh axes once; model
code sprinkles `constrain(x, dims)` on the tensors whose sharding GSPMD
tends to get wrong without help (MoE dispatch buffers, big-vocab logits,
post-embedding activations). When no context is set (unit tests, single
device) every constraint is a no-op.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

_CTX: dict | None = None

__all__ = ["activation_sharding", "constrain", "dp", "tp"]


def _mesh_shape():
    """Axis-name -> size of the ambient mesh, across jax versions."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh().shape
    from jax._src.mesh import thread_resources   # legacy global-mesh context
    return dict(thread_resources.env.physical_mesh.shape)


@contextlib.contextmanager
def activation_sharding(dp_axes: tuple[str, ...], model_axis: str = "model"):
    global _CTX
    prev = _CTX
    _CTX = {"dp": tuple(dp_axes), "tp": model_axis}
    try:
        yield
    finally:
        _CTX = prev


def dp():
    return _CTX["dp"] if _CTX else None


def tp():
    return _CTX["tp"] if _CTX else None


def dp_size() -> int:
    if _CTX is None:
        return 1
    shape = _mesh_shape()
    n = 1
    for a in _CTX["dp"]:
        n *= shape[a]
    return n


def tp_size() -> int:
    if _CTX is None:
        return 1
    return _mesh_shape()[_CTX["tp"]]


def constrain(x, dims):
    """dims: tuple over x's axes of 'dp' | 'tp' | 'dpt' (dp+tp combined) |
    None. Axes that don't divide the dim are dropped. No-op w/o context."""
    if _CTX is None:
        return x
    mesh_shape = _mesh_shape()
    spec = []
    for d, size in zip(dims, x.shape):
        if d is None:
            spec.append(None)
            continue
        axes = {"dp": _CTX["dp"], "tp": (_CTX["tp"],),
                "dpt": tuple(_CTX["dp"]) + (_CTX["tp"],)}[d]
        total = 1
        for a in axes:
            total *= mesh_shape[a]
        spec.append(axes if size % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
