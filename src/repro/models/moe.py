"""Mixture-of-Experts with sort-based (dropping) token dispatch.

Routing is literally a 1-hop nearest-centroid search — the same top-k
primitive as the paper's stage-2 merge — so the router can optionally run
through kernels/topk (`use_kernel=True`).

Dispatch avoids the GShard dense [tokens, experts, capacity] one-hot (which
is O(S*E*C) memory — intractable at 64 experts x 64k tokens): tokens are
repeated k times, sorted by expert id, truncated at per-expert capacity, and
moved with one scatter/gather pair — O(k*S*d). Experts shard on the `model`
mesh axis (EP); GSPMD turns the scatter/gather across the expert axis into
all-to-alls.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import shard_ctx

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    d_ff: int = 1408          # per-expert hidden
    n_shared: int = 0         # always-on shared experts (DeepSeek)
    shared_d_ff: int = 0      # 0 -> n_shared * d_ff
    capacity_factor: float = 1.25
    router_use_kernel: bool = False   # route via kernels/topk

    def shared_ff(self):
        return self.shared_d_ff or self.n_shared * self.d_ff


def moe_init(key, d_model, mc: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    E, F = mc.num_experts, mc.d_ff
    p = {
        "router": jax.random.normal(ks[0], (d_model, E), dtype) * s,
        "w_in": jax.random.normal(ks[1], (E, d_model, 2, F), dtype) * s,
        "w_out": jax.random.normal(ks[2], (E, F, d_model), dtype) / math.sqrt(F),
    }
    if mc.n_shared > 0:
        Fs = mc.shared_ff()
        p["shared_w_in"] = jax.random.normal(ks[3], (d_model, 2, Fs), dtype) * s
        p["shared_w_out"] = jax.random.normal(ks[4], (Fs, d_model), dtype) / math.sqrt(Fs)
    return p


def _route(logits, k: int, use_kernel: bool):
    """Top-k expert choice + normalized gates. logits: [S, E]."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if use_kernel:
        from repro.kernels import ops  # local import: kernels are optional here
        neg, idx = ops.topk(-probs, k)
        gate = -neg
    else:
        gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, idx, probs


def _factor_groups(B: int, T: int) -> tuple[int, int]:
    """(Gb, Gt): batch-block x seq-block group factors.

    Groups tile (B, T) the same way the residual stream is sharded
    (dp on batch, SP/tp on sequence), so the [B,T,d] <-> [Gb,Gt,Sg,d]
    reshapes cost ZERO communication in forward AND backward — a flat
    token grouping makes the cotangent reshard pathological (GSPMD falls
    back to full replication; +6 GB/device on dbrx-132b train).

    Mesh-aware: Gb*Gt must be a multiple of dp*tp or the 'dpt' dispatch
    pins drop and every buffer replicates (jamba multi-pod: +50 GB/device
    when a grad-accum microbatch caps Gb below the dp size)."""
    dpn, tpn = shard_ctx.dp_size(), shard_ctx.tp_size()
    world = max(dpn * tpn, 1)
    gb = next((g for g in (dpn, 32, 16, 8, 4, 2, 1)
               if g >= 1 and B % g == 0))
    gt = None
    for cand in (tpn * 8, tpn * 4, tpn * 2, tpn, 16, 8, 4, 2, 1):
        if cand >= 1 and T % cand == 0 and (gb * cand) % world == 0:
            gt = cand
            break
    if gt is None:
        gt = next((g for g in (16, 8, 4, 2, 1) if T % g == 0))
    return gb, gt


def _dispatch_plan(idx, gate, E: int, C: int):
    """Per-group sort-based routing plan (vmapped over the group axis —
    integer arrays only, cheap). Returns (dest, st, sg, keep): [G, Sg*K]."""

    def one(idx_g, gate_g):
        S, K = idx_g.shape
        flat_e = idx_g.reshape(S * K)
        flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
        flat_g = gate_g.reshape(S * K)
        order = jnp.argsort(flat_e, stable=True)      # group slots by expert
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        start = jnp.searchsorted(se, jnp.arange(E), side="left")
        pos = jnp.arange(S * K, dtype=jnp.int32) - start[jnp.minimum(se, E - 1)]
        keep = (pos < C) & (se < E)                   # capacity drop
        dest = jnp.where(keep, se * C + pos, E * C)   # E*C = spill row
        return dest, st, sg, keep

    return jax.vmap(one)(idx, gate)


def moe_apply(p, x, mc: MoEConfig, *, act=jax.nn.silu, train: bool = False):
    """x: [B, T, d] -> (y, aux_loss).

    Hierarchical (grouped) dispatch: tokens split into G groups riding the
    DP axis; each group dispatches locally with capacity C_g; constraining
    the expert buffers to (dp, model) makes GSPMD emit the dispatch/combine
    all-to-alls along `model` (EP) while the group axis stays data-local.
    """
    B, T, d = x.shape
    S = B * T
    E, K = mc.num_experts, mc.top_k
    Gb, Gt = _factor_groups(B, T)
    G = Gb * Gt
    Sg = S // G
    # aligned tiling: [B,T,d] -> [Gb, B/Gb, Gt, T/Gt, d] -> [G, Sg, d];
    # the group factors land exactly on the (dp, tp) activation sharding.
    xf = x.reshape(Gb, B // Gb, Gt, T // Gt, d).transpose(0, 2, 1, 3, 4)
    xf = shard_ctx.constrain(xf, ("dp", "tp", None, None, None))
    xf = xf.reshape(G, Sg, d)
    logits = jnp.einsum("gsd,de->gse", xf, p["router"])
    gate, idx, probs = _route(logits.reshape(S, E), K, mc.router_use_kernel)
    gate, idx = gate.reshape(G, Sg, K), idx.reshape(G, Sg, K)

    C = max(int(math.ceil(K * Sg / E * mc.capacity_factor)), 4)
    dest, st, sg, keep = _dispatch_plan(idx, gate, E, C)
    # ---- dispatch: batched gather + batched scatter; every [G, *, d]
    # intermediate pinned to the dp x model group tiling -------------------
    gathered = jnp.take_along_axis(xf, st[..., None], axis=1)   # [G, SgK, d]
    gathered = shard_ctx.constrain(gathered, ("dpt", None, None))
    buf = jax.vmap(
        lambda de, g: jnp.zeros((E * C + 1, d), x.dtype).at[de].set(g)
    )(dest, gathered)
    buf = shard_ctx.constrain(buf, ("dpt", None, None))
    h = buf[:, : E * C].reshape(G, E, C, d)
    # EP layout: experts on `model`, groups on DP -> dispatch all-to-all.
    h = shard_ctx.constrain(h, ("dp", "tp", None, None))
    # ---- expert FFN (per-expert GLU) -----------------------------------
    hh = jnp.einsum("gecd,edif->gecif", h, p["w_in"])
    hh = act(hh[..., 0, :]) * hh[..., 1, :]
    out = jnp.einsum("gecf,efd->gecd", hh, p["w_out"])
    out = shard_ctx.constrain(out, ("dp", "tp", None, None))
    # ---- combine (all-to-all back, then group-local scatter) -------------
    out = out.reshape(G, E * C, d)
    out = shard_ctx.constrain(out, ("dpt", None, None))
    out = jnp.concatenate([out, jnp.zeros((G, 1, d), out.dtype)], axis=1)
    contrib = jnp.take_along_axis(out, dest[..., None], axis=1)
    contrib = contrib * jnp.where(keep, sg, 0.0)[..., None].astype(out.dtype)
    contrib = shard_ctx.constrain(contrib, ("dpt", None, None))
    y = jax.vmap(
        lambda t, c: jnp.zeros((Sg, d), x.dtype).at[t].add(c)
    )(st, contrib)
    # invert the aligned tiling (still communication-free).
    y = y.reshape(Gb, Gt, B // Gb, T // Gt, d)
    y = shard_ctx.constrain(y, ("dp", "tp", None, None, None))
    y = y.transpose(0, 2, 1, 3, 4).reshape(B, T, d)
    y = shard_ctx.constrain(y, ("dp", "tp", None))
    # ---- shared experts (DeepSeek) --------------------------------------
    if "shared_w_in" in p:
        sh = jnp.einsum("btd,dif->btif", x, p["shared_w_in"])
        sh = act(sh[..., 0, :]) * sh[..., 1, :]
        y = y + jnp.einsum("btf,fd->btd", sh, p["shared_w_out"])
    # ---- load-balancing aux loss (Switch) --------------------------------
    aux = 0.0
    if train:
        me = probs.mean(0)                             # mean router prob / expert
        ce = jnp.zeros(E).at[idx.reshape(-1)].add(
            1.0, mode="drop") / (S * K)
        aux = E * jnp.sum(me * ce)
    return y, aux
