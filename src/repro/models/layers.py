"""Attention + MLP building blocks for the assigned architectures.

Everything is written as pure init/apply function pairs over plain dict
pytrees (no flax dependency) so param trees can be stacked for
scan-over-periods and sharded with path-based rules.

Attention comes in three execution paths:
  * blockwise (flash-style) streaming softmax for train/prefill — O(block)
    memory, mandatory at 32k context;
  * direct single-token decode against a KV cache (full or ring-buffer for
    sliding-window);
  * MLA (DeepSeek) with the compressed-KV cache and the *absorbed* decode
    path (w_uk/w_uv folded into the query/output projections).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import shard_ctx  # noqa: F401  (used by attention pins)

__all__ = [
    "rms_norm",
    "apply_rope",
    "blockwise_attn",
    "attn_init",
    "attn_apply",
    "mla_init",
    "mla_apply",
    "mlp_init",
    "mlp_apply",
]

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def _pin_btd(t):
    if t.ndim == 3:
        return shard_ctx.constrain(t, ("dp", "tp", None))
    return t


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    xf = _pin_btd(x.astype(jnp.float32))
    xh = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (_pin_btd(xh) * scale.astype(jnp.float32)).astype(dt)


def _rms_fwd(x, scale, eps):
    return rms_norm(x, scale, eps), (x, scale)


def _rms_bwd(eps, res, dy):
    """Hand-written backward: per-token math only, with explicit sharding
    pins — the autodiff transpose otherwise loses (dp, tp) on the f32
    cotangents and GSPMD all-gathers [B, T, d] per layer (~6 GB/layer on
    dbrx-132b). rms is recomputed (cheaper than saving it)."""
    x, scale = res
    xf = _pin_btd(x.astype(jnp.float32))
    r = _pin_btd(jax.lax.rsqrt(
        jnp.mean(jnp.square(xf), -1, keepdims=True) + eps))
    xh = _pin_btd(xf * r)
    g = dy.astype(jnp.float32) * scale.astype(jnp.float32)
    g = _pin_btd(g)
    proj = _pin_btd(jnp.mean(xh * g, -1, keepdims=True))
    dx = _pin_btd(r * (g - xh * proj)).astype(x.dtype)
    axes = tuple(range(dy.ndim - 1))
    dscale = jnp.sum(dy.astype(jnp.float32) * xh, axis=axes).astype(scale.dtype)
    return dx, dscale


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def _rope_angles(positions, dim, theta):
    """positions [...,T] -> (cos, sin) [..., T, dim/2] (f32)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta=1e4):
    """Rotate pairs (x[..., :half], x[..., half:]). x: [B, T, H, hd]."""
    hd = x.shape[-1]
    half = hd // 2
    cos, sin = _rope_angles(positions, hd, theta)     # [B, T, half] or [T, half]
    cos, sin = cos[..., :, None, :], sin[..., :, None, :]  # head axis
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style blockwise attention (jnp; the Pallas twin lives in kernels/)
# ---------------------------------------------------------------------------


def _mask_block(row, col, *, causal, window, prefix_len, s_valid):
    ok = col < s_valid
    if causal:
        cm = col[None, :] <= row[:, None]
        if prefix_len is not None:
            cm = cm | (col[None, :] < prefix_len)
        ok = ok[None, :] & cm
    else:
        ok = jnp.broadcast_to(ok[None, :], (row.shape[0], col.shape[0]))
    if window and window > 0:
        ok = ok & (col[None, :] > row[:, None] - window)
    return ok


def blockwise_attn(
    q,                    # [B, T, H, hd]
    k,                    # [B, S, KV, hd]
    v,                    # [B, S, KV, hd]
    *,
    causal: bool = True,
    window: int = 0,
    prefix_len=None,      # scalar or None: bidirectional prefix (prefix-LM)
    q_offset=0,           # global position of q[0] (prefill continuation)
    block_q: int = 512,
    block_k: int = 1024,
    skip_masked_blocks: bool = False,
):
    """Memory-efficient attention with running-softmax over KV blocks."""
    B, T, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    bq, bk = min(block_q, T), min(block_k, S)
    Tp, Sp = -(-T // bq) * bq, -(-S // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    qb = qp.reshape(B, Tp // bq, bq, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = kp.reshape(B, Sp // bk, bk, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = vp.reshape(B, Sp // bk, bk, KV, hd).transpose(1, 0, 3, 2, 4)

    def one_q_block(args):
        qi, qblk = args                                # qblk [B, KV, G, bq, hd]
        row = q_offset + qi * bq + jnp.arange(bq)

        @jax.checkpoint
        def inner(carry, xs):
            m, l, acc = carry
            kj, kblk, vblk = xs                        # [B, KV, bk, hd]
            col = kj * bk + jnp.arange(bk)

            def compute(carry):
                m, l, acc = carry
                s = jnp.einsum(
                    "bKgqh,bKkh->bKgqk", qblk.astype(jnp.float32),
                    kblk.astype(jnp.float32)) * scale
                ok = _mask_block(row, col, causal=causal, window=window,
                                 prefix_len=prefix_len, s_valid=S)
                s = jnp.where(ok[None, None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, s.max(-1))
                # guard fully-masked rows (exp(-inf - -inf))
                m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p = jnp.exp(s - m_safe[..., None])
                corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
                l = l * corr + p.sum(-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bKgqk,bKkh->bKgqh", p, vblk.astype(jnp.float32))
                return m_new, l, acc

            if skip_masked_blocks and causal and prefix_len is None:
                # §Perf: a KV block strictly in the causal future of every
                # query row in this block contributes nothing — skip the two
                # matmuls entirely (upper triangle of the block grid ~= half
                # the attention FLOPs at long T).
                live = kj * bk <= row[-1]
                carry = jax.lax.cond(live, compute, lambda c: c, carry)
            else:
                carry = compute(carry)
            return carry, None

        m0 = jnp.full((B, KV, G, bq), -jnp.inf)
        l0 = jnp.zeros((B, KV, G, bq))
        a0 = jnp.zeros((B, KV, G, bq, hd))
        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0),
            (jnp.arange(Sp // bk), kb, vb))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out                                      # [B, KV, G, bq, hd]

    # checkpoint per q-block: backward recomputes scores (flash-attention
    # remat) instead of storing [B,KV,G,bq,bk] probabilities per block.
    outs = jax.lax.map(jax.checkpoint(one_q_block), (jnp.arange(Tp // bq), qb))
    # outs: [nq, B, KV, G, bq, hd] -> [B, (nq bq), (KV G), hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Tp, H, hd)[:, :T]
    return out.astype(q.dtype)


def _decode_attn(q, k, v, *, s_valid, window=0, pos=None):
    """Single-token attention against the cache. q: [B, 1, H, hd]."""
    B, _, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bKgh,bsKh->bKgs", qf, k.astype(jnp.float32)) * scale
    col = jnp.arange(S)
    ok = col[None, :] < s_valid if jnp.ndim(s_valid) == 0 else col[None, :] < s_valid[:, None]
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKgs,bsKh->bKgh", p, v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (per-token-per-head scale)
# ---------------------------------------------------------------------------


def quant_kv(x):
    """[..., hd] -> (int8 values, bf16 scale[..., 1]). Halves decode-cell
    cache residency (musicgen-large decode_32k: 12.9 -> 6.5 GB/device)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequant_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Standard GQA attention layer (full / sliding-window, optional qk_norm)
# ---------------------------------------------------------------------------


def attn_init(key, d_model, n_heads, n_kv, head_dim, qk_norm=False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": jax.random.normal(ks[0], (d_model, n_heads, head_dim), dtype) * s,
        "wk": jax.random.normal(ks[1], (d_model, n_kv, head_dim), dtype) * s,
        "wv": jax.random.normal(ks[2], (d_model, n_kv, head_dim), dtype) * s,
        "wo": jax.random.normal(ks[3], (n_heads, head_dim, d_model), dtype)
        * (1.0 / math.sqrt(n_heads * head_dim)),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def attn_apply(
    p,
    x,                       # [B, T, d]
    *,
    mode: str,               # "train" | "prefill" | "decode"
    cache=None,              # {"k": [B, S, KV, hd], "v": ...} or None
    pos=0,                   # scalar int: position of x[:, 0]
    window: int = 0,
    prefix_len=None,
    rope_theta: float = 1e4,
    block_q: int = 512,
    block_k: int = 1024,
    skip_masked_blocks: bool = False,
):
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    positions = pos + jnp.arange(T)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    # Megatron-SP contract: sequence sharding outside, head sharding inside.
    # (axes that don't divide — e.g. 8 KV heads on a 16-way model axis —
    # drop automatically and GSPMD replicates those heads instead.)
    q = shard_ctx.constrain(q, ("dp", None, "tp", None))
    if mode != "decode":
        # K/V must span the full sequence for attention: pin them
        # T-replicated so the SP->attention boundary gathers these small
        # bf16 tensors, not the f32 residual stream.
        k = shard_ctx.constrain(k, ("dp", None, None, None))
        v = shard_ctx.constrain(v, ("dp", None, None, None))
    else:
        k = shard_ctx.constrain(k, ("dp", None, "tp", None))
        v = shard_ctx.constrain(v, ("dp", None, "tp", None))

    if mode == "decode":
        S = cache["k"].shape[1]
        quant = "ks" in cache
        if window and window > 0:
            slot = pos % S                                # ring-buffer write
            k_all = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            v_all = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            s_valid = jnp.minimum(pos + 1, S)
            new_cache = {"k": k_all, "v": v_all}
        elif quant:
            kq, ks = quant_kv(k)
            vq, vs = quant_kv(v)
            k_all = jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0, 0))
            v_all = jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0, 0))
            ks_all = jax.lax.dynamic_update_slice(cache["ks"], ks, (0, pos, 0, 0))
            vs_all = jax.lax.dynamic_update_slice(cache["vs"], vs, (0, pos, 0, 0))
            new_cache = {"k": k_all, "v": v_all, "ks": ks_all, "vs": vs_all}
            k_all = dequant_kv(k_all, ks_all, k.dtype)
            v_all = dequant_kv(v_all, vs_all, v.dtype)
            s_valid = pos + 1
        else:
            k_all = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
            v_all = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
            s_valid = pos + 1
            new_cache = {"k": k_all, "v": v_all}
        out = _decode_attn(q, k_all, v_all, s_valid=s_valid, window=window)
    else:
        out = blockwise_attn(
            q, k, v, causal=True, window=window, prefix_len=prefix_len,
            q_offset=pos, block_q=block_q, block_k=block_k,
            skip_masked_blocks=skip_masked_blocks)
        new_cache = None
        if mode == "prefill" and cache is not None:
            S = cache["k"].shape[1]
            if window and window > 0:
                # keep the last `window` positions in the ring buffer, laid out
                # so slot = position % S (S == window here).
                W = S
                last = jnp.maximum(T - W, 0)
                k_tail = jax.lax.dynamic_slice_in_dim(k, last, min(W, T), 1)
                v_tail = jax.lax.dynamic_slice_in_dim(v, last, min(W, T), 1)
                tail_pos = (pos + last + jnp.arange(min(W, T))) % W
                kc = cache["k"].at[:, tail_pos].set(k_tail)
                vc = cache["v"].at[:, tail_pos].set(v_tail)
                new_cache = {"k": kc, "v": vc}
            else:
                kw, vw = k, v
                if "ks" in cache:
                    kw, ks = quant_kv(k)
                    vw, vs = quant_kv(v)
                kc = jax.lax.dynamic_update_slice(cache["k"], kw, (0, pos, 0, 0))
                vc = jax.lax.dynamic_update_slice(cache["v"], vw, (0, pos, 0, 0))
                # keep the written cache in its resident layout (B: dp,
                # S: model) — the T-replicated k/v above otherwise drag the
                # whole cache into an unsharded copy (4x 5.4GB on qwen3).
                kc = shard_ctx.constrain(kc, ("dp", "tp", None, None))
                vc = shard_ctx.constrain(vc, ("dp", "tp", None, None))
                new_cache = {"k": kc, "v": vc}
                if "ks" in cache:
                    ksc = jax.lax.dynamic_update_slice(cache["ks"], ks, (0, pos, 0, 0))
                    vsc = jax.lax.dynamic_update_slice(cache["vs"], vs, (0, pos, 0, 0))
                    new_cache["ks"] = shard_ctx.constrain(ksc, ("dp", "tp", None, None))
                    new_cache["vs"] = shard_ctx.constrain(vsc, ("dp", "tp", None, None))
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return y, new_cache


def attn_cache_init(batch, s_max, n_kv, head_dim, window=0, dtype=jnp.float32,
                    quant=False):
    S = min(window, s_max) if window and window > 0 else s_max
    if quant and not (window and window > 0):
        return {
            "k": jnp.zeros((batch, S, n_kv, head_dim), jnp.int8),
            "v": jnp.zeros((batch, S, n_kv, head_dim), jnp.int8),
            "ks": jnp.zeros((batch, S, n_kv, 1), jnp.bfloat16),
            "vs": jnp.zeros((batch, S, n_kv, 1), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, S, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, S, n_kv, head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache + absorbed decode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


def mla_init(key, d_model, n_heads, mla: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    qd = mla.qk_nope + mla.qk_rope
    return {
        "wq": jax.random.normal(ks[0], (d_model, n_heads, qd), dtype) * s,
        "w_dkv": jax.random.normal(ks[1], (d_model, mla.kv_lora + mla.qk_rope), dtype) * s,
        "kv_norm": jnp.ones((mla.kv_lora,), dtype),
        "w_uk": jax.random.normal(ks[2], (mla.kv_lora, n_heads, mla.qk_nope), dtype)
        * (1.0 / math.sqrt(mla.kv_lora)),
        "w_uv": jax.random.normal(ks[3], (mla.kv_lora, n_heads, mla.v_dim), dtype)
        * (1.0 / math.sqrt(mla.kv_lora)),
        "wo": jax.random.normal(ks[4], (n_heads, mla.v_dim, d_model), dtype)
        * (1.0 / math.sqrt(n_heads * mla.v_dim)),
    }


def mla_apply(p, x, *, mode, cache=None, pos=0, mla: MLAConfig,
              rope_theta=1e4, block_q=512, block_k=1024):
    """MLA attention. Cache stores only (c_kv, k_rope): kv_lora + qk_rope
    floats per token — the technique's entire point for decode cells."""
    B, T, _ = x.shape
    H = p["wq"].shape[1]
    nope, rope_d, lora = mla.qk_nope, mla.qk_rope, mla.kv_lora
    scale_dim = nope + rope_d

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = jnp.einsum("btd,dk->btk", x, p["w_dkv"])
    c_kv = rms_norm(dkv[..., :lora], p["kv_norm"])
    k_rope = dkv[..., lora:][:, :, None, :]              # single shared head
    positions = pos + jnp.arange(T)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    k_rope = apply_rope(k_rope, positions, rope_theta)

    if mode == "decode":
        # absorbed path: q_eff = q_nope @ w_uk -> score against cached c_kv.
        c_all = jax.lax.dynamic_update_slice(cache["c"], c_kv, (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(cache["kr"], k_rope[:, :, 0, :], (0, pos, 0))
        s_valid = pos + 1
        q_eff = jnp.einsum("bthn,lhn->bthl", q_nope, p["w_uk"])   # [B,1,H,lora]
        s = (
            jnp.einsum("bthl,bsl->bhts", q_eff.astype(jnp.float32), c_all.astype(jnp.float32))
            + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32), kr_all.astype(jnp.float32))
        ) / math.sqrt(scale_dim)
        ok = jnp.arange(c_all.shape[1])[None, None, None, :] < s_valid
        s = jnp.where(ok, s, -jnp.inf)
        pa = jax.nn.softmax(s, axis=-1)
        out_c = jnp.einsum("bhts,bsl->bthl", pa, c_all.astype(jnp.float32))
        out = jnp.einsum("bthl,lhv->bthv", out_c, p["w_uv"].astype(jnp.float32))
        y = jnp.einsum("bthv,hvd->btd", out.astype(x.dtype), p["wo"])
        return y, {"c": c_all, "kr": kr_all}

    # train/prefill: materialize per-head k, v (naive path).
    k_nope = jnp.einsum("btl,lhn->bthn", c_kv, p["w_uk"])
    v = jnp.einsum("btl,lhv->bthv", c_kv, p["w_uv"])
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, H, rope_d))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v to qk dim so the shared blockwise kernel applies, then slice.
    vd = mla.v_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, scale_dim - vd)))
    # MLA has KV == H == 16: heads shard exactly onto the model axis.
    q_full = shard_ctx.constrain(q_full, ("dp", None, "tp", None))
    k_full = shard_ctx.constrain(k_full, ("dp", None, "tp", None))
    v_pad = shard_ctx.constrain(v_pad, ("dp", None, "tp", None))
    out = blockwise_attn(q_full, k_full, v_pad, causal=True, q_offset=pos,
                         block_q=block_q, block_k=block_k)[..., :vd]
    out = shard_ctx.constrain(out, ("dp", None, "tp", None))
    y = jnp.einsum("bthv,hvd->btd", out, p["wo"])
    new_cache = None
    if mode == "prefill" and cache is not None:
        c_all = jax.lax.dynamic_update_slice(cache["c"], c_kv, (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(cache["kr"], k_rope[:, :, 0, :], (0, pos, 0))
        c_all = shard_ctx.constrain(c_all, ("dp", "tp", None))
        kr_all = shard_ctx.constrain(kr_all, ("dp", "tp", None))
        new_cache = {"c": c_all, "kr": kr_all}
    return y, new_cache


def mla_cache_init(batch, s_max, mla: MLAConfig, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, s_max, mla.kv_lora), dtype),
        "kr": jnp.zeros((batch, s_max, mla.qk_rope), dtype),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, kind="glu", dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    s = 1.0 / math.sqrt(d_model)
    if kind == "glu":
        return {
            "w_in": jax.random.normal(k1, (d_model, 2, d_ff), dtype) * s,
            "w_out": jax.random.normal(k2, (d_ff, d_model), dtype) / math.sqrt(d_ff),
        }
    return {  # non-gated (e.g. nemotron relu^2)
        "w_in": jax.random.normal(k1, (d_model, d_ff), dtype) * s,
        "w_out": jax.random.normal(k2, (d_ff, d_model), dtype) / math.sqrt(d_ff),
    }


def mlp_apply(p, x, act="silu"):
    f = ACTS[act]
    if p["w_in"].ndim == 3:  # gated
        h = jnp.einsum("btd,dgf->btgf", x, p["w_in"])
        h = f(h[:, :, 0]) * h[:, :, 1]
    else:
        h = f(jnp.einsum("btd,df->btf", x, p["w_in"]))
    return jnp.einsum("btf,fd->btd", h, p["w_out"])
