"""LM substrate: the 10 assigned architectures served/trained by the same
runtime that hosts the paper's ANN engine."""
