"""Shard health: heartbeat probing with timeouts, detection, and revival.

`ShardClient.request` already handles the *reactive* path (a fault during
a query fails over immediately). `HealthMonitor` adds the *proactive*
path: a background loop pings every replica of every shard and flips
health flags from the outcome, so

  * a replica that died while idle is discovered before a query hits it,
  * a replica that recovered (`ShardWorker.revive`) is brought back into
    the dispatch rotation without operator action,
  * a replica whose heartbeat is stale past `timeout_s` is treated as
    down even if its executor still accepts work (hung-node semantics).

`probe_now()` runs one synchronous sweep — tests drive it directly
instead of sleeping on the background thread.
"""

from __future__ import annotations

import threading
import time

from repro.obs.metrics import REGISTRY

__all__ = ["HealthMonitor"]


class HealthMonitor:
    """Periodic health sweep over a `ClusterRouter`'s shards."""

    def __init__(self, router, *, interval_s: float = 1.0,
                 timeout_s: float = 5.0):
        self.router = router
        self.interval_s = float(interval_s)
        self.timeout_s = float(timeout_s)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.sweeps = 0
        router._monitor = self

    def probe_now(self) -> dict:
        """One synchronous sweep: ping every replica, apply heartbeat
        timeouts, return {shard: [replica healthy flags]}."""
        now = time.monotonic()
        states = {}
        down = 0
        for client in self.router.shards:
            flags = client.probe()
            for i, rep in enumerate(client.replicas):
                if flags[i] and now - rep.last_beat > self.timeout_s:
                    client.mark(i, False)      # heartbeat stale: hung node
                    flags[i] = False
            down += flags.count(False)
            states[client.name] = flags
        self.sweeps += 1
        REGISTRY.counter("cluster_health_sweeps_total").inc()
        REGISTRY.gauge("cluster_replicas_down").set(down)
        return states

    def start(self) -> "HealthMonitor":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="cluster-health")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.probe_now()
            except Exception:                  # a dying shard must not
                pass                           # take the monitor with it

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
