"""Shard worker: one self-contained search engine behind a wire boundary.

A `ShardWorker` is the *server* side of one shard replica: it owns a
normal `SearchService` (or `MutableSearchService`) over the shard's rows,
a local→global id map, and a single-threaded executor standing in for the
remote node's request loop. Every request and reply crosses a real
serialization boundary — `to_wire`/`from_wire` encode messages as one JSON
header plus raw little-endian array payloads — so the in-process loopback
transport can be swapped for a socket without touching the router: the
router only ever sees bytes in, bytes out, futures in between.

Ops (all wire-encoded dicts with an "op" key):

  search     : queries/k/ef/rerank/with_stats -> global ids/dists + stats
  candidates : stage-1 unmerged candidate pool (global ids) — what the
               router's global rerank consumes (graph backends only)
  fetch_rows : float32 rows for global ids this shard owns (stage-2 data)
  ping       : heartbeat — name/replica/row count, refreshes last_beat
  stats      : per-replica counters (queries, latency, cache, failures)

Fault injection (`inject_faults`) and hard kill (`kill`) make every
failover path testable: a faulted request raises on the worker thread and
surfaces to the router as a transport error, exactly like a dead node.
"""

from __future__ import annotations

import json
import struct
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.api.types import SearchRequest
from repro.obs.stats import latency_summary
from repro.obs.trace import SpanCtx, TRACER

__all__ = ["ShardFault", "to_wire", "from_wire", "ShardWorker"]

_MAGIC = b"RWP1"                   # repro wire protocol v1


class ShardFault(RuntimeError):
    """A shard replica failed to serve a request (fault or kill)."""


# ---------------------------------------------------------------------------
# Wire codec: one JSON header + contiguous array payloads
# ---------------------------------------------------------------------------


def to_wire(msg: dict) -> bytes:
    """Serialize a flat message dict. Values are either JSON-encodable
    (str/int/float/bool/None/lists of those) or numpy arrays; arrays ride
    after the header as raw bytes, described by dtype + shape."""
    plain, arrays = {}, []
    for key, val in msg.items():
        if isinstance(val, np.ndarray):
            arr = np.ascontiguousarray(val)
            arrays.append((key, arr))
        else:
            plain[key] = val
    header = {"plain": plain,
              "arrays": [{"key": k, "dtype": str(a.dtype),
                          "shape": list(a.shape)} for k, a in arrays]}
    hb = json.dumps(header).encode("utf-8")
    parts = [_MAGIC, struct.pack("<I", len(hb)), hb]
    parts += [a.tobytes() for _, a in arrays]
    return b"".join(parts)


def from_wire(buf: bytes) -> dict:
    """Decode a `to_wire` message back into its dict."""
    if buf[:4] != _MAGIC:
        raise ValueError(f"bad wire magic {buf[:4]!r}")
    (hlen,) = struct.unpack("<I", buf[4:8])
    header = json.loads(buf[8: 8 + hlen].decode("utf-8"))
    msg = dict(header["plain"])
    off = 8 + hlen
    for ent in header["arrays"]:
        dt = np.dtype(ent["dtype"])
        count = int(np.prod(ent["shape"], dtype=np.int64))
        nbytes = count * dt.itemsize
        msg[ent["key"]] = np.frombuffer(
            buf[off: off + nbytes], dtype=dt).reshape(ent["shape"]).copy()
        off += nbytes
    return msg


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------


class ShardWorker:
    """One shard replica: service + gid map + serial request thread."""

    def __init__(self, name: str, service, gid_map, *, rid: int = 0,
                 owns_backend: bool = False):
        self.name = name
        self.rid = rid
        self.service = service
        self.gid_map = np.asarray(gid_map, np.int64)
        if self.gid_map.ndim != 1 or (self.gid_map.size > 1 and
                                      not (np.diff(self.gid_map) > 0).all()):
            raise ValueError("gid_map must be a strictly-ascending 1-D "
                             "array of global ids")
        self.owns_backend = owns_backend
        self.last_beat = time.monotonic()
        self._lock = threading.Lock()
        self._fail_next = 0
        self._dead = False
        self.queries = 0
        self.batches = 0
        self.failures = 0
        self.busy_s = 0.0
        self._lat_ms: deque = deque(maxlen=512)
        self._ex = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"shard-{name}-r{rid}")

    @property
    def n(self) -> int:
        return int(self.gid_map.size)

    # -- fault injection / lifecycle ----------------------------------------

    def inject_faults(self, n: int = 1) -> None:
        """The next `n` requests raise ShardFault (transient fault)."""
        with self._lock:
            self._fail_next += int(n)

    def kill(self) -> None:
        """Permanent failure: every request from now on raises — the
        in-process stand-in for a crashed node."""
        self._dead = True

    def revive(self) -> None:
        self._dead = False

    def close(self) -> None:
        self._ex.shutdown(wait=True)
        if self.owns_backend:
            reader = getattr(self.service.backend, "reader", None)
            if reader is not None:
                reader.close()

    # -- request path --------------------------------------------------------

    def submit(self, payload: bytes) -> "Future[bytes]":
        """Enqueue one wire-encoded request on this replica's thread."""
        return self._ex.submit(self._handle, payload)

    def _check_fault(self) -> None:
        if self._dead:
            raise ShardFault(f"shard {self.name!r} replica {self.rid} "
                             f"is down")
        with self._lock:
            if self._fail_next > 0:
                self._fail_next -= 1
                raise ShardFault(f"shard {self.name!r} replica {self.rid} "
                                 f"injected fault")

    def _handle(self, payload: bytes) -> bytes:
        t0 = time.perf_counter()
        msg = from_wire(payload)
        try:
            self._check_fault()
            # the trace ctx crosses the wire in the JSON header: enter a
            # worker-side span only when the caller sent one (pings and
            # health probes stay span-free)
            w = msg.pop("trace", None)
            if w is not None:
                with TRACER.span("shard-exec", parent=SpanCtx.from_wire(w),
                                 shard=self.name, replica=self.rid,
                                 op=msg.get("op")):
                    out = self._dispatch(msg)
            else:
                out = self._dispatch(msg)
            out["ok"] = True
        except Exception as exc:          # serialize the failure — a real
            self.failures += 1            # transport cannot raise across it
            out = {"ok": False, "error": f"{type(exc).__name__}: {exc}"}
        self.last_beat = time.monotonic()
        dt = time.perf_counter() - t0
        self.busy_s += dt
        if msg.get("op") in ("search", "candidates"):
            self.batches += 1
            self._lat_ms.append(dt * 1e3)
        return to_wire(out)

    def _dispatch(self, msg: dict) -> dict:
        op = msg.get("op")
        if op == "search":
            return self._op_search(msg)
        if op == "candidates":
            return self._op_candidates(msg)
        if op == "fetch_rows":
            return self._op_fetch_rows(msg)
        if op == "ping":
            return {"name": self.name, "rid": self.rid, "n": self.n}
        if op == "stats":
            return self.stats()
        raise ValueError(f"unknown shard op {op!r}")

    def _op_search(self, msg: dict) -> dict:
        queries = msg["queries"]
        self.queries += int(queries.shape[0])
        resp = self.service.search(SearchRequest(
            queries=queries, k=int(msg["k"]), ef=int(msg["ef"]),
            rerank=bool(msg.get("rerank", False)),
            with_stats=bool(msg.get("with_stats", False))))
        ids = np.asarray(resp.ids)
        gids = np.where(ids >= 0,
                        self.gid_map[np.maximum(ids, 0)], np.int64(-1))
        out = {"ids": gids,
               "dists": np.asarray(resp.dists, np.float32)}
        if resp.stats is not None:
            out.update(_wire_stats(resp.stats))
        return out

    def _op_candidates(self, msg: dict) -> dict:
        """Stage-1 unmerged candidate pool in partition-major order — the
        router's global stage-2 rerank consumes this (global ids)."""
        queries = msg["queries"]
        self.queries += int(queries.shape[0])
        cand, stats = _stage1_candidates(
            self.service, queries, int(msg["k"]), int(msg["ef"]))
        gids = np.where(cand >= 0,
                        self.gid_map[np.maximum(cand, 0)], np.int64(-1))
        out = {"ids": gids}
        if stats:
            out.update(stats)
        return out

    def _op_fetch_rows(self, msg: dict) -> dict:
        """Float32 rows for global ids this shard owns (ascending order is
        the caller's job — the compact-id rerank contract)."""
        gids = np.asarray(msg["ids"], np.int64)
        pos = np.searchsorted(self.gid_map, gids)
        pos = np.minimum(pos, max(self.gid_map.size - 1, 0))
        if self.gid_map.size == 0 or not (self.gid_map[pos] == gids).all():
            missing = gids[self.gid_map[pos] != gids] if self.gid_map.size \
                else gids
            raise ValueError(
                f"shard {self.name!r} does not own ids {missing[:4]}...")
        return {"rows": _rows_f32(self.service, pos)}

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        lat = latency_summary(self._lat_ms)
        d = {"shard": self.name, "replica": self.rid, "n": self.n,
             "queries": self.queries, "batches": self.batches,
             "failures": self.failures, "busy_s": self.busy_s,
             "p50_ms": lat["p50"], "p99_ms": lat["p99"],
             "p999_ms": lat["p999"]}
        reader = getattr(self.service.backend, "reader", None)
        if reader is not None:             # csd: this replica's own cache
            snap = reader.cache.snapshot()
            demand = snap["hits"] + snap["misses"]
            d.update(block_reads=snap["block_reads"],
                     bytes_read=snap["bytes_read"],
                     cache_hits=snap["hits"],
                     cache_misses=snap["misses"],
                     cache_hit_rate=(snap["hits"] / demand if demand
                                     else 0.0))
        return d


# ---------------------------------------------------------------------------
# Backend adapters (stage-1 candidates / stage-2 row gather)
# ---------------------------------------------------------------------------


def _wire_stats(stats) -> dict:
    """QueryStats -> wire-encodable per-request scalars/arrays."""
    out = {}
    for f in ("hops", "dist_calcs"):
        v = getattr(stats, f)
        if v is not None:
            out[f] = np.asarray(v, np.int64)
    for f in ("block_reads", "cache_hits", "cache_misses", "bytes_read"):
        v = getattr(stats, f)
        if v is not None:
            out[f] = int(v)
    return out


def _stage1_candidates(service, queries, k: int, ef: int):
    """The unmerged [B, P*k] local-id candidate pool of one shard."""
    from repro.core.search import SearchParams
    backend = service.backend
    p = SearchParams(ef=ef, k=k, metric=service.spec.metric)
    is_pq = service.spec.dtype == "pq"
    if hasattr(backend, "reader"):                       # csd
        from repro.store.csd import store_search
        cand, _, hops, calcs, _ = store_search(
            backend.reader, queries, p, merge=False,
            pq_quant=backend.quant if is_pq else None)
        return (np.asarray(cand),
                {"hops": np.asarray(hops, np.int64),
                 "dist_calcs": np.asarray(calcs, np.int64)})
    if hasattr(backend, "pdb"):                          # partitioned/hnsw
        import jax.numpy as jnp
        from repro.core.partitioned import search_partitioned_candidates
        q = jnp.asarray(queries)
        cand, _, st = search_partitioned_candidates(
            backend.pdb, q, p, backend._lut(q))
        return (np.asarray(cand),
                {"hops": np.asarray(st.hops.sum(axis=0), np.int64),
                 "dist_calcs": np.asarray(st.dist_calcs.sum(axis=0),
                                          np.int64)})
    raise ValueError(
        f"backend {service.spec.backend!r} has no stage-1 candidate pool "
        f"(exact search is already exact — rerank at the router is a no-op)")


def _rows_f32(service, local_ids: np.ndarray) -> np.ndarray:
    """Gather float32 rows by local id — the shard side of the router's
    compact-table stage-2 rerank (mirrors CSDBackend._rerank_from_store)."""
    backend = service.backend
    if hasattr(backend, "reader"):                       # csd: store reads
        r = backend.reader
        if r.partition_starts is None:
            raise ValueError("store partition ids are not contiguous; "
                             "rerank over this shard is unsupported")
        part = np.searchsorted(r.partition_starts, local_ids,
                               side="right") - 1
        local = local_ids - r.partition_starts[part]
        rows = part * r.n_pad + local
        if service.spec.dtype == "pq":
            # TRUE float32 rows for the router's global stage 2 — the
            # code rows would just reproduce the ADC distances
            return r.read_rows("rerank_vectors", rows).astype(np.float32)
        return r.read_rows("vectors", rows)[:, : r.dim].astype(np.float32)
    if getattr(backend, "dev_vectors", None) is not None:  # keep_vectors
        return np.asarray(backend.dev_vectors)[local_ids]
    if hasattr(backend, "raw") and backend.raw is not None \
            and not hasattr(backend, "pdb"):             # exact
        return np.asarray(backend.raw, np.float32)[local_ids]
    raise ValueError(
        "rerank=True needs the raw vectors on every shard: build the "
        "cluster with IndexSpec(keep_vectors=True) (csd shards read them "
        "back from their block stores instead)")
