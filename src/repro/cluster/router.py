"""Cluster router: scatter-gather search over shard replicas.

`ClusterRouter` presents the `SearchService` interface (`.spec`,
`.search(SearchRequest) -> SearchResponse`) over N shards, each fronted by
a `ShardClient` that owns the shard's replica set. One request flows:

    router.search ──scatter──> shard 0 client ──> replica (least in-flight)
                 ├──────────> shard 1 client ──> ...
                 └──────────> shard N-1 client
    gather: per-shard sorted top-k, concatenated shard-major,
    reduced by `core.merge.rank_merge` (stable argsort) ──> global top-k

Because shards hold the SAME row split and construction seeds as the
partitions of one big index (`topology.shard_spec`), the gathered merge is
bit-identical to single-index search. With `rerank=True` the router runs
stage 2 itself: it gathers every shard's *unmerged* stage-1 candidate
pool, fetches the unique candidate rows back from their owning shards,
and reranks the union in one `batched_rerank` call over a compact id
space — the same global reduction a single index performs, which is what
keeps rerank bit-identical too (per-shard rerank would not be: a [B, k]
einsum and a [B, P*K] einsum round differently).

Failover lives in `ShardClient.request`: a replica that faults is marked
unhealthy and the request is retried verbatim on the next live replica —
the caller never sees the fault unless every replica of a shard is down.

Elastic changes (`add_shard` / `remove_shard` / `add_replica` /
`remove_replica`) swap the shard list under a lock and publish a new
versioned `cluster.json`; in-flight searches keep the snapshot they
started with.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.api.types import QueryStats, SearchRequest, SearchResponse
from repro.core.merge import rank_merge
from repro.cluster.shard import ShardFault, ShardWorker, to_wire, from_wire
from repro.cluster.topology import (ClusterTopology, ShardInfo,
                                    write_topology)
from repro.obs.metrics import REGISTRY, next_uid
from repro.obs.slo import SLOTracker
from repro.obs.trace import TRACER

__all__ = ["ShardClient", "ClusterRouter", "ClusterStats"]


class ShardClient:
    """The router's handle to one shard: a replica set with least-in-flight
    dispatch and transparent failover."""

    def __init__(self, name: str, replicas):
        if not replicas:
            raise ValueError(f"shard {name!r} needs at least one replica")
        self.name = name
        self.replicas: list[ShardWorker] = list(replicas)
        self._healthy = [True] * len(self.replicas)
        self._inflight = [0] * len(self.replicas)
        self._rr = 0
        self._lock = threading.Lock()
        self.failovers = 0

    @property
    def n(self) -> int:
        return self.replicas[0].n

    @property
    def gid_lo(self) -> int:
        return int(self.replicas[0].gid_map[0]) if self.n else 0

    def live(self) -> int:
        with self._lock:
            return sum(self._healthy)

    def mark(self, rid_index: int, healthy: bool) -> None:
        with self._lock:
            self._healthy[rid_index] = healthy

    def _pick(self, exclude: set) -> int | None:
        """Least-in-flight among healthy replicas, round-robin tiebreak."""
        with self._lock:
            best, best_load = None, None
            order = range(self._rr, self._rr + len(self.replicas))
            for j in order:
                i = j % len(self.replicas)
                if i in exclude or not self._healthy[i]:
                    continue
                if best_load is None or self._inflight[i] < best_load:
                    best, best_load = i, self._inflight[i]
            if best is not None:
                self._rr = (best + 1) % len(self.replicas)
                self._inflight[best] += 1
            return best

    def request(self, msg: dict) -> dict:
        """Send one request, failing over across replicas. Each attempt
        goes to exactly one replica; a faulted attempt is marked unhealthy
        and retried on the next live one, so no request is ever lost or
        served twice."""
        payload = to_wire(msg)
        tried: set = set()
        while True:
            i = self._pick(tried)
            if i is None:
                raise ShardFault(
                    f"shard {self.name!r}: no live replicas "
                    f"({len(self.replicas)} configured, all down)")
            try:
                resp = from_wire(self.replicas[i].submit(payload).result())
            except Exception as exc:       # transport-level death
                resp = {"ok": False, "error": f"ShardFault: {exc}"}
            finally:
                with self._lock:
                    self._inflight[i] -= 1
            if resp.get("ok"):
                return resp
            err = resp.get("error", "")
            if err.startswith("ShardFault"):
                self.mark(i, False)
                tried.add(i)
                self.failovers += 1
                continue                   # fail over, request intact
            raise RuntimeError(f"shard {self.name!r}: {err}")

    def probe(self) -> list[bool]:
        """Ping every replica directly (no failover); refresh health flags
        from the outcome — a revived replica comes back on success."""
        payload = to_wire({"op": "ping"})
        states = []
        for i, rep in enumerate(self.replicas):
            try:
                ok = from_wire(rep.submit(payload).result()).get("ok", False)
            except Exception:
                ok = False
            self.mark(i, bool(ok))
            states.append(bool(ok))
        return states

    def close(self) -> None:
        for rep in self.replicas:
            rep.close()


@dataclasses.dataclass(frozen=True)
class ClusterStats:
    """Rolled-up cluster health: per-shard load, latency, storage traffic,
    and how skewed the row/query distribution is."""

    n_shards: int
    n_replicas: int                 # total live replicas
    queries: int                    # sum over shards (each query hits all)
    failovers: int
    shards: tuple                   # per-replica stat dicts
    qps: dict                       # shard -> queries / busy_s
    p50_ms: dict                    # shard -> max over replicas
    p99_ms: dict
    block_reads: int
    bytes_read: int
    cache_hit_rate: float | None    # weighted over csd replicas
    row_skew: float                 # max/mean shard rows (1.0 == balanced)
    query_skew: float               # max/mean replica queries
    # per-shard SLO status rows (slo-enabled routers only): each entry is
    # {"shard": name, "slo": [per-objective status dicts]}
    slo: tuple = ()
    slo_breaching: tuple = ()       # names of shards currently breaching


def _collect_router(router: "ClusterRouter"):
    """Snapshot-time metric samples for the whole cluster (repro.obs)."""
    shards = router.shards
    labels = {"router": router.uid}
    out = [("gauge", "cluster_shards", labels, len(shards)),
           ("gauge", "cluster_replicas_live", labels,
            sum(c.live() for c in shards)),
           ("counter", "cluster_failovers_total", labels,
            sum(c.failovers for c in shards))]
    for c in shards:
        sl = {"router": router.uid, "shard": c.name}
        out.append(("counter", "cluster_shard_queries_total", sl,
                    sum(rep.queries for rep in c.replicas)))
        out.append(("counter", "cluster_shard_failures_total", sl,
                    sum(rep.failures for rep in c.replicas)))
    return out


class ClusterRouter:
    """One logical index over N shards. Quacks like a `SearchService`
    (`.spec` / `.search`) so `repro.serve.SearchServer` can front it."""

    backend = None                  # no single-box backend behind this

    def __init__(self, spec, shards, *, path: str | None = None,
                 version: int = 0, publish: bool = True, slo=None):
        dtype = getattr(spec, "dtype", "float32")
        if dtype == "pq":
            # PQ is the one quantized dtype clusters support: the fitted
            # codebooks ride the IndexSpec (build_cluster fits them ONCE
            # over the union), so every shard shares a single code space
            # and the gathered merge stays comparable — and bit-identical
            # to the equivalent single index, whose deterministic fit over
            # the same rows/seed yields the same codebooks.
            if getattr(spec, "pq_codebooks", None) is None:
                raise ValueError(
                    "a pq cluster needs pre-fitted codebooks riding the "
                    "spec (build_cluster fits them over the union); "
                    "per-shard fits would not share one code space")
        elif dtype != "float32":
            raise ValueError(
                "clusters are float32 or pq only: scalar quantizer scales "
                "are fit per build, so per-shard quantized code spaces "
                "would not be comparable across shards")
        self.spec = spec
        self.path = path
        self._shards: list[ShardClient] = list(shards)
        self._version = version
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(
            max_workers=16, thread_name_prefix="cluster-router")
        self._monitor = None        # HealthMonitor attaches here
        self.uid = next_uid()
        # optional per-shard SLO tracking: `slo` is an iterable of
        # obs.slo.SLO objects; each shard gets its OWN tracker (labeled
        # {router, shard}) fed from the scatter path, so a breaching shard
        # is attributable in ClusterStats and the slo_* series
        self._slo_spec = None if slo is None else tuple(slo)
        self._slo_trackers: dict[str, SLOTracker] = {}
        REGISTRY.register_collector(self, _collect_router)
        if publish and path is not None:
            self._publish()

    # -- topology ------------------------------------------------------------

    @property
    def shards(self) -> list[ShardClient]:
        with self._lock:
            return list(self._shards)

    @property
    def version(self) -> int:
        return self._version

    def topology(self) -> ClusterTopology:
        with self._lock:
            return ClusterTopology(
                shards=tuple(ShardInfo(name=c.name, replicas=c.live(),
                                       rows=c.n) for c in self._shards),
                version=self._version)

    def _publish(self) -> None:
        with self._lock:
            self._version += 1
        if self.path is not None:
            write_topology(self.path, self.topology())

    def add_shard(self, client: ShardClient) -> None:
        """Attach a shard under live traffic. In-flight searches keep the
        snapshot they scattered over; new searches see the new shard."""
        with self._lock:
            if any(c.name == client.name for c in self._shards):
                raise ValueError(f"shard {client.name!r} already attached")
            self._shards.append(client)
        self._publish()

    def remove_shard(self, name: str) -> ShardClient:
        with self._lock:
            for i, c in enumerate(self._shards):
                if c.name == name:
                    if len(self._shards) == 1:
                        raise ValueError("cannot remove the last shard")
                    client = self._shards.pop(i)
                    break
            else:
                raise KeyError(f"no shard named {name!r}")
        self._publish()
        return client

    def add_replica(self, name: str, worker: ShardWorker) -> None:
        client = self._client(name)
        with client._lock:
            client.replicas.append(worker)
            client._healthy.append(True)
            client._inflight.append(0)
        self._publish()

    def remove_replica(self, name: str, rid_index: int) -> ShardWorker:
        client = self._client(name)
        with client._lock:
            if len(client.replicas) == 1:
                raise ValueError(
                    f"cannot remove the last replica of shard {name!r}")
            worker = client.replicas.pop(rid_index)
            client._healthy.pop(rid_index)
            client._inflight.pop(rid_index)
        self._publish()
        return worker

    def _client(self, name: str) -> ShardClient:
        with self._lock:
            for c in self._shards:
                if c.name == name:
                    return c
        raise KeyError(f"no shard named {name!r}")

    # -- search --------------------------------------------------------------

    def search(self, request: SearchRequest) -> SearchResponse:
        queries = np.ascontiguousarray(
            np.asarray(request.queries, np.float32))
        shards = self.shards             # snapshot: elastic-change safe
        rerank = bool(request.rerank) and self.spec.backend != "exact"
        # same span contract as SearchService.search: ambient nesting wins
        # (replica dispatch span); batcher ctx only on a cold thread
        if request.trace is not None and TRACER.current_ctx() is None:
            span = TRACER.span("search", parent=request.trace,
                               backend="cluster", shards=len(shards))
        else:
            span = TRACER.span("search", backend="cluster",
                               shards=len(shards))
        with span:
            if rerank:
                return self._search_rerank(shards, queries, request)
            msg = {"op": "search", "queries": queries, "k": int(request.k),
                   "ef": int(request.ef), "rerank": False,
                   "with_stats": bool(request.with_stats)}
            resps = self._scatter(shards, msg)
            ids, dists = rank_merge(
                [r["ids"] for r in resps],
                [r["dists"] for r in resps], int(request.k))
            stats = self._roll_stats(resps) if request.with_stats else None
            return SearchResponse(ids=ids, dists=dists, stats=stats)

    def _search_rerank(self, shards, queries, request) -> SearchResponse:
        """Global stage 2: gather every shard's stage-1 candidate pool,
        fetch the unique rows from their owners, rerank the union exactly
        as a single index would (compact monotone id space, one einsum)."""
        import jax.numpy as jnp
        from repro.api.rerank import batched_rerank

        k = int(request.k)
        msg = {"op": "candidates", "queries": queries, "k": k,
               "ef": int(request.ef)}
        resps = self._scatter(shards, msg)
        pools = [r["ids"] for r in resps]          # [B, P_i*K] global ids
        cand = np.concatenate(pools, axis=1)       # shard-major == global
        valid = cand >= 0                          # partition-major order

        per_shard_uniq = [np.unique(p[p >= 0]) for p in pools]
        uniq = np.unique(cand[valid])              # sorted union (disjoint)
        futs = [self._pool.submit(c.request,
                                  {"op": "fetch_rows", "ids": su})
                for c, su in zip(shards, per_shard_uniq) if su.size]
        table = None
        for (c, su), fut in zip(
                [(c, su) for c, su in zip(shards, per_shard_uniq)
                 if su.size], futs):
            rows = fut.result()["rows"]
            if table is None:
                table = np.empty((uniq.size, rows.shape[1]), np.float32)
            table[np.searchsorted(uniq, su)] = rows
        if table is None:                          # no candidates at all
            b = queries.shape[0]
            return SearchResponse(
                ids=np.full((b, k), -1, np.int64),
                dists=np.full((b, k), np.inf, np.float32))

        vt = jnp.asarray(table)
        sqs = jnp.einsum("nd,nd->n", vt, vt)
        compact = np.where(
            valid, np.searchsorted(uniq, np.where(valid, cand, 0)),
            -1).astype(np.int32)
        ids_c, dists = batched_rerank(vt, sqs, jnp.asarray(queries),
                                      jnp.asarray(compact), k,
                                      self.spec.metric)
        ids_c = np.asarray(ids_c)
        ids = np.where(ids_c >= 0, uniq[np.maximum(ids_c, 0)], -1)
        stats = self._roll_stats(resps) if request.with_stats else None
        return SearchResponse(ids=ids, dists=np.asarray(dists),
                              stats=stats)

    def _slo_for(self, name: str) -> SLOTracker:
        tr = self._slo_trackers.get(name)
        if tr is None:
            tr = self._slo_trackers.setdefault(
                name, SLOTracker(self._slo_spec,
                                 labels={"router": self.uid, "shard": name}))
        return tr

    def _scatter(self, shards, msg: dict) -> list:
        # the fan-out crosses onto the router pool threads: capture the
        # caller's ctx here and parent each per-shard span on it explicitly
        ctx = TRACER.current_ctx()

        def _one(c):
            slo = (self._slo_for(c.name) if self._slo_spec is not None
                   else None)
            t0 = time.perf_counter()
            try:
                if ctx is None:
                    r = c.request(msg)
                else:
                    with TRACER.span("shard", parent=ctx,
                                     shard=c.name) as sp:
                        m = dict(msg)
                        m["trace"] = sp.ctx.wire()   # JSON wire header
                        r = c.request(m)
            except Exception:
                # failover already exhausted inside ShardClient.request —
                # what escapes here is a real per-shard failure
                if slo is not None:
                    slo.record_error()
                raise
            if slo is not None:
                slo.record_latency((time.perf_counter() - t0) * 1e3)
            return r

        futs = [self._pool.submit(_one, c) for c in shards]
        return [f.result() for f in futs]          # shard order preserved

    def _roll_stats(self, resps) -> QueryStats:
        def _sum(key, scalar=False):
            vals = [r[key] for r in resps if key in r]
            if not vals:
                return None
            return (int(sum(vals)) if scalar
                    else np.sum(np.stack(vals), axis=0))
        hits = _sum("cache_hits", scalar=True)
        misses = _sum("cache_misses", scalar=True)
        # demand-weighted over shards: one rate from the summed counters,
        # identical in form to a single cache's hits / (hits + misses)
        demand = (hits or 0) + (misses or 0)
        hit_rate = (((hits or 0) / demand) if demand else 0.0) \
            if (hits is not None or misses is not None) else None
        return QueryStats(hops=_sum("hops"), dist_calcs=_sum("dist_calcs"),
                          block_reads=_sum("block_reads", scalar=True),
                          cache_hits=hits, cache_misses=misses,
                          cache_hit_rate=hit_rate,
                          bytes_read=_sum("bytes_read", scalar=True))

    # -- introspection -------------------------------------------------------

    def stats(self) -> ClusterStats:
        shards = self.shards
        per_rep = [rep.stats() for c in shards for rep in c.replicas]
        qps, p50, p99 = {}, {}, {}
        for c in shards:
            reps = [r for r in per_rep if r["shard"] == c.name]
            busy = sum(r["busy_s"] for r in reps)
            qs = sum(r["queries"] for r in reps)
            qps[c.name] = qs / busy if busy > 0 else 0.0
            p50[c.name] = max(r["p50_ms"] for r in reps)
            p99[c.name] = max(r["p99_ms"] for r in reps)
        rows = np.asarray([c.n for c in shards], np.float64)
        rep_q = np.asarray([r["queries"] for r in per_rep], np.float64)
        csd = [r for r in per_rep if "cache_hit_rate" in r]
        # exact demand-weighting from the summed counters (the per-replica
        # stats now carry cache_hits/cache_misses), not an average of rates
        dh = sum(r.get("cache_hits", 0) for r in csd)
        dm = sum(r.get("cache_misses", 0) for r in csd)
        hit = ((dh / (dh + dm) if (dh + dm) else 0.0) if csd else None)
        slo_rows: list = []
        breaching: list = []
        if self._slo_spec is not None:
            for name in sorted(self._slo_trackers):
                status = self._slo_trackers[name].evaluate()
                slo_rows.append({"shard": name, "slo": status})
                if any(row["breaching"] for row in status):
                    breaching.append(name)
        return ClusterStats(
            n_shards=len(shards),
            n_replicas=sum(c.live() for c in shards),
            queries=int(rep_q.sum()),
            failovers=sum(c.failovers for c in shards),
            shards=tuple(per_rep),
            qps=qps, p50_ms=p50, p99_ms=p99,
            block_reads=sum(r.get("block_reads", 0) for r in per_rep),
            bytes_read=sum(r.get("bytes_read", 0) for r in per_rep),
            cache_hit_rate=hit,
            row_skew=float(rows.max() / rows.mean()) if rows.size and
            rows.mean() > 0 else 1.0,
            query_skew=float(rep_q.max() / rep_q.mean()) if rep_q.size and
            rep_q.mean() > 0 else 1.0,
            slo=tuple(slo_rows),
            slo_breaching=tuple(breaching))

    def close(self) -> None:
        if self._monitor is not None:
            self._monitor.stop()
        for c in self.shards:
            c.close()
        self._pool.shutdown(wait=True)
