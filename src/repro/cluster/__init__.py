"""repro.cluster — one logical index sharded across N workers.

The missing layer between "one box" (the paper's 4-SmartSSD server) and
"a fleet": shard workers behind a wire-serializable transport boundary, a
scatter-gather router whose merged results are bit-identical to a single
index over the union of rows, replica failover, heartbeat health checks,
and elastic topology changes published through an atomically-swapped
`cluster.json`. See `src/repro/cluster/README.md` for the dataflow.
"""

from repro.cluster.health import HealthMonitor
from repro.cluster.rebalance import build_cluster, make_shard
from repro.cluster.router import ClusterRouter, ClusterStats, ShardClient
from repro.cluster.shard import ShardFault, ShardWorker, from_wire, to_wire
from repro.cluster.topology import (CLUSTER_FORMAT, CLUSTER_MANIFEST,
                                    ClusterTopology, ShardInfo,
                                    read_topology, shard_bounds, shard_spec,
                                    write_topology)

__all__ = [
    "HealthMonitor", "build_cluster", "make_shard", "ClusterRouter",
    "ClusterStats", "ShardClient", "ShardFault", "ShardWorker",
    "from_wire", "to_wire", "CLUSTER_FORMAT", "CLUSTER_MANIFEST",
    "ClusterTopology", "ShardInfo", "read_topology", "shard_bounds",
    "shard_spec", "write_topology",
]
