"""Cluster construction and elastic growth.

`build_cluster` is the one-call path from a vector table to a serving
cluster: it splits rows with `topology.shard_bounds` (the same linspace
split `build_partitioned_db` applies inside one index), builds each shard
as an independent `SearchService` with `topology.shard_spec` (per-shard
seed offset), clones replicas with the same backend-aware logic
`repro.serve` uses (csd replicas get their own reader + page cache, like
independent nodes would), and hands the shard clients to a
`ClusterRouter`. The two shared choices — row split and seed schedule —
are exactly what makes `router.search` bit-identical to a single index
built over the full table.

`make_shard` is the elastic unit: build one shard over an arbitrary row
set (contiguous range or any ascending gid assignment) so tests and
operators can grow a live cluster with `router.add_shard`.
"""

from __future__ import annotations

import os

import numpy as np

from repro.api.service import SearchService
from repro.cluster.router import ClusterRouter, ShardClient
from repro.cluster.shard import ShardWorker
from repro.cluster.topology import shard_bounds, shard_spec

__all__ = ["build_cluster", "make_shard"]


def make_shard(vectors, spec, *, name: str, gid_map, shard_index: int = 0,
               replicas: int = 1,
               storage_root: str | None = None) -> ShardClient:
    """Build one shard (primary + replicas) over `vectors`, whose global
    ids are `gid_map` (ascending). `shard_index` positions the shard in
    the cluster's seed schedule; csd shards persist under
    `storage_root/<name>`."""
    from repro.serve.dispatch import _clone_service

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    storage_path = None
    if spec.backend == "csd":
        if storage_root is None and spec.storage_path is None:
            raise ValueError(
                "csd shards need a storage directory: pass storage_root "
                "(or set spec.storage_path)")
        storage_path = os.path.join(storage_root or spec.storage_path, name)
    sspec = shard_spec(spec, shard_index, storage_path=storage_path)
    service = SearchService.build(np.ascontiguousarray(vectors), sspec)
    gid_map = np.asarray(gid_map, np.int64)
    workers = [ShardWorker(name, service, gid_map, rid=0)]
    for r in range(1, replicas):
        svc, owns = _clone_service(service, r)
        workers.append(ShardWorker(name, svc, gid_map, rid=r,
                                   owns_backend=owns))
    return ShardClient(name, workers)


def build_cluster(vectors, spec, n_shards: int, *, replicas: int = 1,
                  path: str | None = None, slo=None) -> ClusterRouter:
    """Shard `vectors` N ways and stand up the full serving cluster.

    The returned router's results are bit-identical to a single
    `SearchService` built over `vectors` with
    `num_partitions = n_shards * spec.num_partitions`.

    dtype="pq": the codebooks are fit ONCE here, over the union, and ride
    the spec into every shard (SearchService.build reuses pre-fitted
    codebooks instead of fitting per shard) — one code space cluster-wide.
    The deterministic fit makes them bitwise equal to what the equivalent
    single index would fit over the same rows and seed, which is what
    extends the bit-parity contract to PQ.
    """
    vectors = np.ascontiguousarray(np.asarray(vectors, np.float32))
    if getattr(spec, "dtype", "float32") == "pq" \
            and spec.pq_codebooks is None:
        import dataclasses

        from repro.optim.compression import PQQuantizer
        quant = PQQuantizer.fit(vectors, spec.pq_m, seed=spec.hnsw.seed)
        spec = dataclasses.replace(
            spec, pq_codebooks=quant.to_json()["codebooks"])
    bounds = shard_bounds(vectors.shape[0], n_shards)
    storage_root = None
    if spec.backend == "csd":
        storage_root = spec.storage_path or (
            os.path.join(path, "shards") if path is not None else None)
    clients = []
    for i in range(n_shards):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        clients.append(make_shard(
            vectors[lo:hi], spec, name=f"shard-{i:03d}",
            gid_map=np.arange(lo, hi, dtype=np.int64), shard_index=i,
            replicas=replicas, storage_root=storage_root))
    return ClusterRouter(spec, clients, path=path, slo=slo)
