"""Cluster topology: the shard layout and the atomically-swapped manifest.

One logical index spans N shards; each shard owns a contiguous row range
(the SAME `linspace` split `core.partitioned.build_partitioned_db` uses,
which is what makes a cluster of per-shard builds bit-identical to one
index built over the union — see `rebalance.build_cluster`) and runs R
replicas. The layout is described by a `ClusterTopology` and, when the
cluster is given a directory, published as `cluster.json` with the same
commit-then-swap discipline as the block store's `segments.json`:

    <dir>/cluster.json          {"format": ..., "version": N,
                                 "shards": [{"name", "replicas", "rows"}]}

Every elastic change (add/remove shard, add/remove replica) writes a full
tmp manifest, fsyncs, and renames — a crash at any point leaves either the
old or the new manifest, never a torn one, and the version number makes
stale manifests refuse to regress.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.api.types import IndexSpec
from repro.core.hnsw_graph import HNSWConfig

__all__ = ["CLUSTER_MANIFEST", "CLUSTER_FORMAT", "ShardInfo",
           "ClusterTopology", "shard_bounds", "shard_spec",
           "read_topology", "write_topology"]

CLUSTER_MANIFEST = "cluster.json"
CLUSTER_FORMAT = "repro-cluster-v1"


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    """One shard's manifest entry."""

    name: str
    replicas: int = 1
    rows: int = 0                  # live row count (skew reporting)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "ShardInfo":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class ClusterTopology:
    """The live shard set plus a monotonically-advancing version."""

    shards: tuple = ()
    version: int = 0

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def to_json(self) -> dict:
        return {"format": CLUSTER_FORMAT, "version": self.version,
                "shards": [s.to_json() for s in self.shards]}

    @classmethod
    def from_json(cls, d: dict) -> "ClusterTopology":
        if d.get("format") != CLUSTER_FORMAT:
            raise ValueError(
                f"cluster manifest has format {d.get('format')!r}; this "
                f"build reads {CLUSTER_FORMAT!r}")
        return cls(shards=tuple(ShardInfo.from_json(s)
                                for s in d.get("shards", [])),
                   version=int(d.get("version", 0)))


def shard_bounds(n: int, n_shards: int) -> np.ndarray:
    """Row boundaries of an N-way shard split — identical to the partition
    split inside `build_partitioned_db`, so shard i's rows are exactly the
    rows partition i of a single N-partition index would hold."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    return np.linspace(0, n, n_shards + 1).astype(np.int64)


def shard_spec(spec: IndexSpec, shard_index: int, *,
               storage_path: str | None = None) -> IndexSpec:
    """The per-shard IndexSpec derived from the cluster's base spec.

    `spec.num_partitions` is interpreted as partitions PER SHARD; the HNSW
    seed advances by `shard_index * num_partitions` so shard i's local
    partitions get the same construction seeds as global partitions
    [i*q, (i+1)*q) of the equivalent single index — the second half of the
    bit-parity contract (row split being the first).
    """
    hnsw = HNSWConfig(**{**spec.hnsw.__dict__,
                         "seed": spec.hnsw.seed
                         + shard_index * spec.num_partitions})
    kw = dict(hnsw=hnsw)
    if storage_path is not None:
        kw["storage_path"] = storage_path
    return dataclasses.replace(spec, **kw)


def read_topology(path: str) -> ClusterTopology:
    """The committed topology under `path` (empty when none published)."""
    mf = os.path.join(path, CLUSTER_MANIFEST)
    if not os.path.exists(mf):
        return ClusterTopology()
    with open(mf) as f:
        return ClusterTopology.from_json(json.load(f))


def write_topology(path: str, topo: ClusterTopology) -> ClusterTopology:
    """Atomic manifest swap (full tmp write + fsync + rename). Refuses to
    regress: the incoming version must advance past the committed one."""
    committed = read_topology(path)
    if topo.version <= committed.version and committed.shards:
        raise ValueError(
            f"stale topology: version {topo.version} does not advance "
            f"past committed version {committed.version}")
    os.makedirs(path, exist_ok=True)
    mf = os.path.join(path, CLUSTER_MANIFEST)
    tmp = mf + ".tmp"
    with open(tmp, "w") as f:
        json.dump(topo.to_json(), f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, mf)
    return topo
