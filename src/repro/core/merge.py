"""The one host-side rank-merge every fan-out in the repo shares.

Three layers reduce ragged per-source top-k lists to one global top-k:

  * `repro.ingest` merges memtable + sealed segments (tombstoned lanes
    masked dead first),
  * `repro.cluster` merges per-shard scatter-gather results at the router,
  * both are the host-side mirror of `core.partitioned.merge_topk`, the
    on-device stage-2 reduction (paper §4.1).

The contract that makes the merge *bit-identical* to a single index built
over the union of rows: every source list is already sorted ascending by
distance, sources are concatenated in global partition order, and the
reduction is one stable argsort — so ties resolve exactly as the single
index's partition-major stable sort resolves them. Dead lanes carry
(+inf, -1) and can never displace a live id.
"""

from __future__ import annotations

import numpy as np

__all__ = ["mask_dead_lanes", "rank_merge"]


def mask_dead_lanes(ids, dists, dead):
    """Mask candidate lanes out of a (ids, dists) list: masked lanes become
    (-1, +inf) so the downstream rank-merge can never surface them. Used
    for tombstones (ingest) and for any source whose rows must not win."""
    ids = np.asarray(ids)
    dists = np.asarray(dists)
    return (np.where(dead, ids.dtype.type(-1), ids),
            np.where(dead, np.float32(np.inf), dists.astype(np.float32)))


def rank_merge(ids_list, dists_list, k: int):
    """Merge per-source sorted top-k lists into one global top-k.

    ids_list   : sequence of [B, k_i] id arrays (-1 marks empty lanes)
    dists_list : matching [B, k_i] float32 distances (+inf on empty lanes)
    returns    : (ids [B, k], dists [B, k]) — -1 / +inf padded when fewer
                 than k finite candidates exist.

    The reduction is a stable argsort over the concatenated candidate
    axis — the same tie-break as `core.partitioned.merge_topk`'s flat
    partition-major sort, which is what pins cluster == single-index and
    segment-fan-out == fresh-build bit-identity.
    """
    cat_i = np.concatenate([np.asarray(i) for i in ids_list], axis=1)
    cat_d = np.concatenate([np.asarray(d, np.float32) for d in dists_list],
                           axis=1)
    order = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
    out_i = np.take_along_axis(cat_i, order, axis=1)
    out_d = np.take_along_axis(cat_d, order, axis=1)
    out_i = np.where(np.isfinite(out_d), out_i, -1)
    if out_i.shape[1] < k:                 # fewer candidates than k
        pad = k - out_i.shape[1]
        out_i = np.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
        out_d = np.pad(out_d, ((0, 0), (0, pad)),
                       constant_values=np.inf)
    return out_i, out_d
