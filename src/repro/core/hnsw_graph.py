"""Host-side HNSW graph construction and the restructured device database.

The construction path is a numpy re-implementation of hnswlib's insertion
algorithm (Malkov & Yashunin, Algorithms 1-5): per-point level sampling,
greedy descent through upper layers, ef_construction beam at the insertion
level, and heuristic neighbor selection with reverse-link pruning.

The *restructured database* follows the paper's Fig. 5: instead of hnswlib's
compact variable-stride layout (which forces unaligned, multi-read accesses),
we emit fixed-stride, padded structure-of-arrays tables:

  - raw-data table   : vectors[N, D_pad]            (lane-aligned, D_pad % 128 == 0)
  - layer-0 table    : l0_nbrs[N, maxM0_pad] int32  (-1 padded)
  - upper list table : up_nbrs[L_max, U, maxM_pad]  (rows only for points with
                       level >= 1; U is the padded count of such points)
  - index table      : up_ptr[N] int32 (row into the upper tables, -1 if the
                       point only exists at layer 0) + levels[N]

A single index-table read per point yields everything needed to address its
neighbor lists — the paper's "one access per point" property. Degrees are not
stored separately: padding with -1 encodes list length (the paper stores an
explicit size; a sentinel is the SoA equivalent and removes one fetch).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import numpy as np

__all__ = [
    "HNSWConfig",
    "HostGraph",
    "DeviceDB",
    "GraphBuilder",
    "build_hnsw",
    "restructure",
    "db_size_bytes",
    "db_to_tables",
    "db_from_tables",
]


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    """Construction/search parameters (paper Table nomenclature).

    maxM is the per-node list budget in upper layers; maxM0 = 2*maxM at
    layer 0, both exactly as hnswlib / the paper set them.
    """

    M: int = 16
    ef_construction: int = 100
    max_level_cap: int = 8          # fixed upper bound so device shapes are static
    seed: int = 0
    # Device-layout padding knobs (the paper's 64B alignment analogue).
    lane: int = 128                 # vector feature padding (TPU lane width)
    nbr_pad: int = 8                # neighbor-list stride rounding

    @property
    def maxM(self) -> int:
        return self.M

    @property
    def maxM0(self) -> int:
        return 2 * self.M

    @property
    def ml(self) -> float:
        return 1.0 / math.log(self.M)


class HostGraph(NamedTuple):
    """Mutable-free snapshot of a built HNSW graph (host representation)."""

    vectors: np.ndarray          # [N, D] float32
    levels: np.ndarray           # [N] int32, level of each point (0-based)
    l0_nbrs: np.ndarray          # [N, maxM0] int32, -1 padded
    up_nbrs: np.ndarray          # [L_max, N_up, maxM] int32 (-1 padded)
    up_ptr: np.ndarray           # [N] int32 row into up_nbrs, -1 if level==0
    entry: int                   # entry point id
    max_level: int               # current top layer
    cfg: HNSWConfig


class DeviceDB(NamedTuple):
    """Restructured, alignment-padded database (pytree of arrays).

    This is the object that lives in HBM (the paper's DRAM-resident
    per-partition database). All shapes are static given (N_pad, D_pad,
    strides), so it can be stacked across partitions and sharded.
    """

    vectors: np.ndarray          # [N_pad, D_pad] float32 (rows >= n_valid are 0)
    sqnorms: np.ndarray          # [N_pad] float32, ||x||^2 (pad rows = +inf)
    l0_nbrs: np.ndarray          # [N_pad, maxM0_pad] int32, -1 padded
    up_nbrs: np.ndarray          # [L_max, U_pad, maxM_pad] int32, -1 padded
    up_ptr: np.ndarray           # [N_pad] int32 (-1 for level-0-only/pad rows)
    levels: np.ndarray           # [N_pad] int32 (pad rows = -1)
    gids: np.ndarray             # [N_pad] int32 global ids (pad rows = -1)
    entry: np.ndarray            # [] int32
    max_level: np.ndarray        # [] int32
    n_valid: np.ndarray          # [] int32


# ---------------------------------------------------------------------------
# Construction (hnswlib-equivalent, numpy)
# ---------------------------------------------------------------------------


def _dist(vectors: np.ndarray, ids: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Squared L2 distance between q and vectors[ids] (batched)."""
    diff = vectors[ids] - q[None, :]
    return np.einsum("nd,nd->n", diff, diff)


def _search_layer_host(
    vectors: np.ndarray,
    nbr_of,                      # callable(point_id) -> np.ndarray of neighbor ids
    q: np.ndarray,
    eps: list[int],
    ef: int,
) -> tuple[list[int], list[float]]:
    """Algorithm 2 of the HNSW paper: beam search at one layer (host)."""
    visited = set(eps)
    ep_d = _dist(vectors, np.asarray(eps, dtype=np.int64), q)
    # candidate min-heap and result max-heap emulated with sorted lists —
    # sizes here are tiny (<= ef + maxM0), simplicity over asymptotics.
    cand: list[tuple[float, int]] = sorted(zip(ep_d.tolist(), eps))
    found: list[tuple[float, int]] = sorted(zip(ep_d.tolist(), eps))[:ef]
    while cand:
        d_c, c = cand.pop(0)
        if found and d_c > found[-1][0] and len(found) >= ef:
            break
        nbrs = [int(e) for e in nbr_of(c) if e >= 0 and int(e) not in visited]
        if not nbrs:
            continue
        visited.update(nbrs)
        ds = _dist(vectors, np.asarray(nbrs, dtype=np.int64), q)
        bound = found[-1][0] if len(found) >= ef else np.inf
        for d_e, e in zip(ds.tolist(), nbrs):
            if d_e < bound or len(found) < ef:
                _insort(cand, (d_e, e))
                _insort(found, (d_e, e))
                if len(found) > ef:
                    found.pop()
                    bound = found[-1][0]
    return [i for _, i in found], [d for d, _ in found]


def _insort(lst: list[tuple[float, int]], item: tuple[float, int]) -> None:
    lo, hi = 0, len(lst)
    while lo < hi:
        mid = (lo + hi) // 2
        if lst[mid][0] < item[0]:
            lo = mid + 1
        else:
            hi = mid
    lst.insert(lo, item)


def _select_heuristic(
    vectors: np.ndarray, cand_ids: list[int], cand_ds: list[float], m: int
) -> list[int]:
    """Algorithm 4: heuristic neighbor selection (keeps diverse neighbors)."""
    order = np.argsort(cand_ds)
    selected: list[int] = []
    for idx in order:
        if len(selected) >= m:
            break
        e, d_e = cand_ids[idx], cand_ds[idx]
        ok = True
        for s in selected:
            diff = vectors[e] - vectors[s]
            if float(diff @ diff) < d_e:
                ok = False
                break
        if ok:
            selected.append(e)
    # hnswlib keepPrunedConnections: fill remaining slots by distance order.
    if len(selected) < m:
        for idx in order:
            e = cand_ids[idx]
            if e not in selected:
                selected.append(e)
                if len(selected) >= m:
                    break
    return selected


class GraphBuilder:
    """Incremental HNSW construction: one `insert_point` call per vector.

    This is the insertion loop of Algorithm 1, factored out of `build_hnsw`
    so mutable indexes (`repro.ingest`) can grow a graph point by point:
    `build_hnsw` is now exactly `GraphBuilder` + one `insert_point` per row
    and produces bit-identical graphs to the pre-factoring implementation
    (levels are drawn from the same seeded stream, upper-table rows are
    assigned in the same ascending-id order, and the beam/heuristic logic
    is byte-for-byte the same helpers).

    Arrays grow by doubling; `graph()` snapshots the current state as a
    `HostGraph` (trimmed to the live prefix) at any point — a sealed
    memtable is just `restructure(builder.graph())`.
    """

    def __init__(self, dim: int, cfg: HNSWConfig):
        self.cfg = cfg
        self.dim = int(dim)
        self._rng = np.random.default_rng(cfg.seed)
        self.n = 0
        self.entry = 0
        self.max_level = 0
        cap = 64
        self._vectors = np.zeros((cap, self.dim), dtype=np.float32)
        self._levels = np.zeros(cap, dtype=np.int32)
        self._l0 = np.full((cap, cfg.maxM0), -1, dtype=np.int32)
        self._up_ptr = np.full(cap, -1, dtype=np.int32)
        self.n_up = 0
        up_cap = 16
        self._up = np.full((cfg.max_level_cap - 1, up_cap, cfg.maxM), -1,
                           dtype=np.int32)

    # -- growth --------------------------------------------------------------

    def _grow_points(self, need: int) -> None:
        cap = self._vectors.shape[0]
        if need <= cap:
            return
        new = max(need, 2 * cap)
        for name in ("_vectors", "_levels", "_l0", "_up_ptr"):
            old = getattr(self, name)
            fill = -1 if old.dtype == np.int32 and name != "_levels" else 0
            grown = np.full((new,) + old.shape[1:], fill, dtype=old.dtype)
            grown[:cap] = old
            setattr(self, name, grown)

    def _grow_upper(self, need: int) -> None:
        cap = self._up.shape[1]
        if need <= cap:
            return
        new = max(need, 2 * cap)
        grown = np.full((self.cfg.max_level_cap - 1, new, self.cfg.maxM), -1,
                        dtype=np.int32)
        grown[:, :cap] = self._up
        self._up = grown

    # -- the factored insertion routine --------------------------------------

    def draw_level(self) -> int:
        """Next level from the seeded exponential stream (Algorithm 1 l.4)."""
        u = float(self._rng.uniform(1e-12, 1.0))
        return min(int(-math.log(u) * self.cfg.ml), self.cfg.max_level_cap - 1)

    def _nbrs_at(self, layer: int):
        if layer == 0:
            return lambda p: self._l0[p]
        return lambda p: self._up[layer - 1, self._up_ptr[p]]

    def _set_nbrs(self, layer: int, p: int, ids: list[int]) -> None:
        cfg = self.cfg
        if layer == 0:
            row, width = self._l0[p], cfg.maxM0
        else:
            row, width = self._up[layer - 1, self._up_ptr[p]], cfg.maxM
        row[:] = -1
        row[: min(len(ids), width)] = ids[:width]

    def insert_point(self, q: np.ndarray, level: int | None = None) -> int:
        """Insert one vector (HNSW paper Algorithm 1); returns its local id.

        `level` overrides the sampled layer (used by `build_hnsw` to keep
        the vectorized level stream; incremental callers leave it None).
        """
        cfg = self.cfg
        q = np.ascontiguousarray(q, dtype=np.float32)
        if q.shape != (self.dim,):
            raise ValueError(f"expected a [{self.dim}] vector, "
                             f"got shape {q.shape}")
        lvl = self.draw_level() if level is None else int(level)
        i = self.n
        self._grow_points(i + 1)
        self._vectors[i] = q
        self._levels[i] = lvl
        self._l0[i] = -1
        if lvl >= 1:
            self._grow_upper(self.n_up + 1)
            self._up_ptr[i] = self.n_up
            self._up[:, self.n_up] = -1
            self.n_up += 1
        else:
            self._up_ptr[i] = -1
        self.n = i + 1
        if i == 0:
            self.entry, self.max_level = 0, lvl
            return i

        vectors = self._vectors
        eps = [self.entry]
        # 1) greedy descent from the top to lvl+1.
        for layer in range(self.max_level, lvl, -1):
            changed = True
            cur_d = float(_dist(vectors, np.asarray(eps[:1]), q)[0])
            cur = eps[0]
            while changed:
                changed = False
                nb = [int(e) for e in self._nbrs_at(layer)(cur) if e >= 0]
                if nb:
                    ds = _dist(vectors, np.asarray(nb), q)
                    j = int(np.argmin(ds))
                    if float(ds[j]) < cur_d:
                        cur, cur_d, changed = nb[j], float(ds[j]), True
            eps = [cur]
        # 2) beam insert from min(max_level, lvl) down to 0.
        for layer in range(min(self.max_level, lvl), -1, -1):
            width = cfg.maxM0 if layer == 0 else cfg.maxM
            cand_ids, cand_ds = _search_layer_host(
                vectors, self._nbrs_at(layer), q, eps, cfg.ef_construction
            )
            sel = _select_heuristic(vectors, cand_ids, cand_ds, cfg.M)
            self._set_nbrs(layer, i, sel)
            # reverse links with pruning (Algorithm 1 lines 10-17).
            for e in sel:
                row = self._nbrs_at(layer)(e)
                cur = [int(x) for x in row if x >= 0]
                if i not in cur:
                    cur.append(i)
                if len(cur) > width:
                    ds = _dist(vectors, np.asarray(cur), vectors[e]).tolist()
                    cur = _select_heuristic(vectors, cur, ds, width)
                self._set_nbrs(layer, e, cur)
            eps = cand_ids
        if lvl > self.max_level:
            self.entry, self.max_level = i, lvl
        return i

    # -- snapshot ------------------------------------------------------------

    def graph(self) -> HostGraph:
        """Immutable `HostGraph` view of the points inserted so far."""
        if self.n == 0:
            raise ValueError("cannot snapshot an empty graph")
        n, n_up = self.n, max(1, self.n_up)
        return HostGraph(
            vectors=self._vectors[:n].copy(),
            levels=self._levels[:n].copy(),
            l0_nbrs=self._l0[:n].copy(),
            up_nbrs=self._up[:, :n_up].copy(),
            up_ptr=self._up_ptr[:n].copy(),
            entry=self.entry,
            max_level=self.max_level,
            cfg=self.cfg,
        )


def build_hnsw(vectors: np.ndarray, cfg: HNSWConfig) -> HostGraph:
    """Insert all points (Algorithm 1 of the HNSW paper), return the graph.

    Levels are sampled for the whole batch up front (one vectorized draw
    from the seeded rng — the historical stream) and fed to the factored
    `GraphBuilder.insert_point`, so batch builds stay bit-identical across
    the incremental-construction refactor.
    """
    vectors = np.ascontiguousarray(vectors, dtype=np.float32)
    n, dim = vectors.shape
    rng = np.random.default_rng(cfg.seed)
    levels = np.minimum(
        (-np.log(rng.uniform(1e-12, 1.0, size=n)) * cfg.ml).astype(np.int32),
        cfg.max_level_cap - 1,
    )
    b = GraphBuilder(dim, cfg)
    for i in range(n):
        b.insert_point(vectors[i], level=int(levels[i]))
    return b.graph()


# ---------------------------------------------------------------------------
# Restructuring (paper Fig. 5) — host graph -> aligned device DB
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _dedup_rows(table: np.ndarray) -> np.ndarray:
    """Mask duplicate ids within each neighbor list to -1 (keep first).

    The device search kernel's visited-bitmap update scatter-adds one
    power-of-two bit per list entry; uniqueness within a row makes that
    exactly bitwise-OR. Construction already produces unique lists — this is
    the enforcement point for externally-loaded graphs.
    """
    flat = table.reshape(-1, table.shape[-1])
    out = flat.copy()
    srt = np.sort(flat, axis=1)
    has_dup = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] >= 0)
    for r in np.flatnonzero(has_dup.any(axis=1)):
        seen: set[int] = set()
        for j, v in enumerate(flat[r]):
            if v < 0:
                continue
            if int(v) in seen:
                out[r, j] = -1
            else:
                seen.add(int(v))
    return out.reshape(table.shape)


def restructure(
    g: HostGraph,
    gids: np.ndarray | None = None,
    n_pad: int | None = None,
    up_pad: int | None = None,
) -> DeviceDB:
    """Emit the aligned SoA tables. Padding makes shapes partition-uniform."""
    cfg = g.cfg
    n, d = g.vectors.shape
    n_pad = n_pad or _round_up(n, 32)   # multiple of 32 -> whole bitmap words
    d_pad = _round_up(d, cfg.lane)
    m0p = _round_up(cfg.maxM0, cfg.nbr_pad)
    mp = _round_up(cfg.maxM, cfg.nbr_pad)
    n_up = g.up_nbrs.shape[1]
    up_pad_n = up_pad or _round_up(max(n_up, 1), 8)

    vec = np.zeros((n_pad, d_pad), dtype=np.float32)
    vec[:n, :d] = g.vectors
    sq = np.full((n_pad,), np.inf, dtype=np.float32)
    sq[:n] = np.einsum("nd,nd->n", g.vectors, g.vectors)
    l0 = np.full((n_pad, m0p), -1, dtype=np.int32)
    l0[:n, : cfg.maxM0] = _dedup_rows(g.l0_nbrs)
    up = np.full((cfg.max_level_cap - 1, up_pad_n, mp), -1, dtype=np.int32)
    up[:, :n_up, : cfg.maxM] = _dedup_rows(g.up_nbrs)
    ptr = np.full((n_pad,), -1, dtype=np.int32)
    ptr[:n] = g.up_ptr
    lv = np.full((n_pad,), -1, dtype=np.int32)
    lv[:n] = g.levels
    if gids is None:
        gids = np.arange(n, dtype=np.int32)
    gid = np.full((n_pad,), -1, dtype=np.int32)
    gid[:n] = gids.astype(np.int32)
    return DeviceDB(
        vectors=vec,
        sqnorms=sq,
        l0_nbrs=l0,
        up_nbrs=up,
        up_ptr=ptr,
        levels=lv,
        gids=gid,
        entry=np.asarray(g.entry, dtype=np.int32),
        max_level=np.asarray(g.max_level, dtype=np.int32),
        n_valid=np.asarray(n, dtype=np.int32),
    )


# ---------------------------------------------------------------------------
# Block-layout serialization (repro.store) — DeviceDB <-> row-major tables
# ---------------------------------------------------------------------------

# The paper's Fig. 5 tables, in on-flash order: raw-data table, layer-0
# table, upper-list table, index table (up_ptr/levels/gids/sqnorms are the
# per-point index records; sqnorms ride along so one row read yields the
# ||x||^2 term of the distance).
TABLE_ORDER = ("vectors", "sqnorms", "l0_nbrs", "up_nbrs", "up_ptr",
               "levels", "gids")


def db_to_tables(db: DeviceDB) -> tuple[dict[str, np.ndarray], dict]:
    """Flatten a (possibly partition-stacked) DeviceDB into 2-D row-major
    tables addressable as fixed-stride rows — the unit the block store
    persists. Returns (tables, meta); `db_from_tables` inverts exactly.

    Row addressing for a stacked DB with P partitions:
      vectors/sqnorms/l0_nbrs/up_ptr/levels/gids : row = p * n_pad + i
      up_nbrs                                    : row = (p * L + layer) * u_pad + r
    """
    v = np.asarray(db.vectors)
    stacked = v.ndim == 3
    P = v.shape[0] if stacked else 1

    def flat(name, width):
        a = np.asarray(getattr(db, name))
        return np.ascontiguousarray(a.reshape(-1, width))

    n_pad, d_pad = v.shape[-2], v.shape[-1]
    up = np.asarray(db.up_nbrs)
    n_layers, u_pad, mp = up.shape[-3], up.shape[-2], up.shape[-1]
    tables = {
        "vectors": flat("vectors", d_pad),
        "sqnorms": flat("sqnorms", 1),
        "l0_nbrs": flat("l0_nbrs", np.asarray(db.l0_nbrs).shape[-1]),
        "up_nbrs": flat("up_nbrs", mp),
        "up_ptr": flat("up_ptr", 1),
        "levels": flat("levels", 1),
        "gids": flat("gids", 1),
    }
    as_list = lambda x: np.atleast_1d(np.asarray(x)).astype(int).tolist()
    meta = {
        "stacked": stacked,
        "num_partitions": P,
        "n_pad": n_pad,
        "d_pad": d_pad,
        "m0_pad": int(tables["l0_nbrs"].shape[1]),
        "n_layers": n_layers,
        "up_pad": u_pad,
        "m_pad": mp,
        "entry": as_list(db.entry),
        "max_level": as_list(db.max_level),
        "n_valid": as_list(db.n_valid),
    }
    return tables, meta


def db_from_tables(tables: dict[str, np.ndarray], meta: dict) -> DeviceDB:
    """Rebuild the DeviceDB from row-major tables (inverse of db_to_tables)."""
    P, n_pad = meta["num_partitions"], meta["n_pad"]
    lead = (P,) if meta["stacked"] else ()
    scalar = lambda xs: (np.asarray(xs, np.int32) if meta["stacked"]
                         else np.asarray(xs[0], np.int32))
    shp = lambda *tail: lead + tail
    return DeviceDB(
        vectors=np.asarray(tables["vectors"]).reshape(shp(n_pad, meta["d_pad"])),
        sqnorms=np.asarray(tables["sqnorms"]).reshape(shp(n_pad)),
        l0_nbrs=np.asarray(tables["l0_nbrs"]).reshape(shp(n_pad, meta["m0_pad"])),
        up_nbrs=np.asarray(tables["up_nbrs"]).reshape(
            shp(meta["n_layers"], meta["up_pad"], meta["m_pad"])),
        up_ptr=np.asarray(tables["up_ptr"]).reshape(shp(n_pad)),
        levels=np.asarray(tables["levels"]).reshape(shp(n_pad)),
        gids=np.asarray(tables["gids"]).reshape(shp(n_pad)),
        entry=scalar(meta["entry"]),
        max_level=scalar(meta["max_level"]),
        n_valid=scalar(meta["n_valid"]),
    )


def db_size_bytes(db: DeviceDB) -> dict[str, int]:
    """Table sizes — used to reproduce the paper's '+4% size' observation."""
    out = {}
    for name in ("vectors", "l0_nbrs", "up_nbrs", "up_ptr", "sqnorms"):
        out[name] = getattr(db, name).nbytes
    out["total"] = sum(out.values())
    return out


def original_size_bytes(g: HostGraph) -> int:
    """Size of the hnswlib-style compact layout (paper §4.3 baseline):
    layer0: per point [size:4B][maxM0 links][raw vector]; upper: variable."""
    cfg = g.cfg
    n, d = g.vectors.shape
    l0 = n * (4 + 4 * cfg.maxM0 + 4 * d)
    upper = 0
    for i in range(n):
        lvl = int(g.levels[i])
        if lvl >= 1:
            upper += 4 + lvl * (4 + 4 * cfg.maxM)
    return l0 + upper
