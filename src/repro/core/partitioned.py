"""Two-stage partitioned HNSW (paper §4.1, Fig. 3).

Stage 1: the dataset is split into P segments; each segment gets its own
independent HNSW graph sized for the fast memory tier (the paper: < 4 GB
SmartSSD DRAM; here: an HBM shard). Every partition is searched independently
for each query.

Stage 2: the P x K intermediate results are reduced to the final K by exact
distance ("brute-force" in the paper). Our per-partition distances are
already exact squared-L2 values, so the reduction is a k-way merge of sorted
lists; an optional `rerank` recomputes distances from raw vectors to mirror
the paper's host-side stage 2 bit-for-bit.

All partitions are padded to identical static shapes so the stacked DeviceDB
(leading axis P) can be vmapped over on one device or shard_mapped across the
`model` mesh axis (graph parallelism, core/distributed.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw_graph as hg
from repro.core.search import SearchParams, batch_search, merge_sorted

__all__ = [
    "PartitionedDB",
    "build_partitioned_db",
    "quantize_db_vectors",
    "search_partitioned",
    "search_partitioned_candidates",
    "merge_topk",
]


class PartitionedDB(NamedTuple):
    """Stacked DeviceDB: every field has a leading partition axis P."""

    db: hg.DeviceDB              # each leaf: [P, ...]
    num_partitions: int
    dim: int


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def build_partitioned_db(
    vectors: np.ndarray,
    num_partitions: int,
    cfg: hg.HNSWConfig,
) -> PartitionedDB:
    """Split -> build P independent graphs -> restructure to uniform shapes."""
    n = vectors.shape[0]
    bounds = np.linspace(0, n, num_partitions + 1).astype(np.int64)
    graphs, gids = [], []
    for p in range(num_partitions):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        part_cfg = hg.HNSWConfig(**{**cfg.__dict__, "seed": cfg.seed + p})
        graphs.append(hg.build_hnsw(vectors[lo:hi], part_cfg))
        gids.append(np.arange(lo, hi, dtype=np.int32))
    n_pad = _round_up(max(int(b1 - b0) for b0, b1 in zip(bounds, bounds[1:])), 32)
    up_pad = _round_up(max(g.up_nbrs.shape[1] for g in graphs), 8)
    dbs = [
        hg.restructure(g, gids=gid, n_pad=n_pad, up_pad=up_pad)
        for g, gid in zip(graphs, gids)
    ]
    stacked = hg.DeviceDB(*(np.stack([getattr(d, f) for d in dbs]) for f in hg.DeviceDB._fields))
    return PartitionedDB(db=stacked, num_partitions=num_partitions, dim=vectors.shape[1])


def quantize_db_vectors(pdb: PartitionedDB, dtype: str,
                        quant=None) -> PartitionedDB:
    """Swap the stacked DB's raw-data leaf to stored codes.

    The single source of the codes-swap invariant for BOTH the in-memory
    backends and the block store (csd): for uint8/int8 the graphs were
    built over code-valued float32, so the integer cast is exact; only the
    storage representation shrinks (4x for uint8). For dtype="pq" pass the
    fitted PQQuantizer: the graphs were built over the ORIGINAL float32
    vectors (full-precision graph, PQ traversal — DiskANN-style) and each
    [n_pad, d] row is re-encoded to an [n_pad, pq_m] uint8 code row (pad
    rows encode garbage but stay unreachable: neighbor lists never point
    at them and sqnorms keep their +inf markers). No-op for
    dtype="float32" or a leaf that already holds codes."""
    if dtype == "float32":
        return pdb
    from repro.optim.compression import code_dtype
    vecs = np.asarray(pdb.db.vectors)
    if vecs.dtype == code_dtype(dtype) and (
            dtype != "pq" or vecs.shape[-1] == quant.m):
        return pdb
    if dtype == "pq":
        if quant is None:
            raise ValueError("dtype='pq' needs the fitted PQQuantizer")
        p_ax, n_pad, _ = vecs.shape
        flat = vecs.reshape(p_ax * n_pad, -1)[:, :pdb.dim]
        codes = quant.encode(np.ascontiguousarray(flat, np.float32))
        db = pdb.db._replace(vectors=codes.reshape(p_ax, n_pad, quant.m))
        return pdb._replace(db=db)
    db = pdb.db._replace(vectors=vecs.astype(code_dtype(dtype)))
    return pdb._replace(db=db)


def merge_topk(ids, dists, k: int):
    """Stage-2 reduction: [..., P, K] -> top-k by exact distance.

    Implemented as the same rank-merge primitive the search kernel uses —
    sorting the concatenated P*K candidates would also work, but the merge is
    what generalizes to the distributed tree reduction.
    """
    *lead, P, K = ids.shape
    flat_i = ids.reshape(*lead, P * K)
    flat_d = dists.reshape(*lead, P * K)
    order = jnp.argsort(flat_d, axis=-1, stable=True)
    top = order[..., :k]
    return (
        jnp.take_along_axis(flat_i, top, axis=-1),
        jnp.take_along_axis(flat_d, top, axis=-1),
    )


@functools.partial(jax.jit, static_argnames=("p",))
def search_partitioned(pdb: PartitionedDB, queries, p: SearchParams,
                       lut=None):
    """Single-host two-stage search: vmap stage 1 over partitions + merge.

    Returns (ids[B, k], dists[B, k], stats) with global ids. `lut`
    ([B, M, 256]) is the per-query ADC table for dtype="pq" — shared
    across partitions (one code space per index).
    """
    ids, ds, stats = jax.vmap(
        lambda db: batch_search(db, queries, p, lut))(pdb.db)
    # ids: [P, B, k] -> [B, P, k]
    ids = jnp.swapaxes(ids, 0, 1)
    ds = jnp.swapaxes(ds, 0, 1)
    out_i, out_d = merge_topk(ids, ds, p.k)
    return out_i, out_d, stats


@functools.partial(jax.jit, static_argnames=("p",))
def search_partitioned_candidates(pdb: PartitionedDB, queries,
                                  p: SearchParams, lut=None):
    """Stage 1 only: the P*K intermediate candidates, unmerged.

    Returns (ids[B, P*k], dists[B, P*k], stats) — the pool the paper's
    stage-2 brute force re-scores (api.rerank.batched_rerank consumes it).
    """
    ids, ds, stats = jax.vmap(
        lambda db: batch_search(db, queries, p, lut))(pdb.db)
    b = queries.shape[0]
    ids = jnp.swapaxes(ids, 0, 1).reshape(b, -1)
    ds = jnp.swapaxes(ds, 0, 1).reshape(b, -1)
    return ids, ds, stats
