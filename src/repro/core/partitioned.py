"""Two-stage partitioned HNSW (paper §4.1, Fig. 3).

Stage 1: the dataset is split into P segments; each segment gets its own
independent HNSW graph sized for the fast memory tier (the paper: < 4 GB
SmartSSD DRAM; here: an HBM shard). Every partition is searched independently
for each query.

Stage 2: the P x K intermediate results are reduced to the final K by exact
distance ("brute-force" in the paper). Our per-partition distances are
already exact squared-L2 values, so the reduction is a k-way merge of sorted
lists; an optional `rerank` recomputes distances from raw vectors to mirror
the paper's host-side stage 2 bit-for-bit.

All partitions are padded to identical static shapes so the stacked DeviceDB
(leading axis P) can be vmapped over on one device or shard_mapped across the
`model` mesh axis (graph parallelism, core/distributed.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw_graph as hg
from repro.core.search import SearchParams, batch_search, merge_sorted

__all__ = [
    "PartitionedDB",
    "build_partitioned_db",
    "quantize_db_vectors",
    "search_partitioned",
    "search_partitioned_candidates",
    "merge_topk",
]


class PartitionedDB(NamedTuple):
    """Stacked DeviceDB: every field has a leading partition axis P."""

    db: hg.DeviceDB              # each leaf: [P, ...]
    num_partitions: int
    dim: int


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def build_partitioned_db(
    vectors: np.ndarray,
    num_partitions: int,
    cfg: hg.HNSWConfig,
) -> PartitionedDB:
    """Split -> build P independent graphs -> restructure to uniform shapes."""
    n = vectors.shape[0]
    bounds = np.linspace(0, n, num_partitions + 1).astype(np.int64)
    graphs, gids = [], []
    for p in range(num_partitions):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        part_cfg = hg.HNSWConfig(**{**cfg.__dict__, "seed": cfg.seed + p})
        graphs.append(hg.build_hnsw(vectors[lo:hi], part_cfg))
        gids.append(np.arange(lo, hi, dtype=np.int32))
    n_pad = _round_up(max(int(b1 - b0) for b0, b1 in zip(bounds, bounds[1:])), 32)
    up_pad = _round_up(max(g.up_nbrs.shape[1] for g in graphs), 8)
    dbs = [
        hg.restructure(g, gids=gid, n_pad=n_pad, up_pad=up_pad)
        for g, gid in zip(graphs, gids)
    ]
    stacked = hg.DeviceDB(*(np.stack([getattr(d, f) for d in dbs]) for f in hg.DeviceDB._fields))
    return PartitionedDB(db=stacked, num_partitions=num_partitions, dim=vectors.shape[1])


def quantize_db_vectors(pdb: PartitionedDB, dtype: str) -> PartitionedDB:
    """Swap the stacked DB's raw-data leaf to stored codes (uint8/int8).

    The single source of the codes-swap invariant for BOTH the in-memory
    backends and the block store (csd): the graphs were built over
    code-valued float32, so the integer cast is exact; only the storage
    representation shrinks (4x for uint8). No-op for dtype="float32" or a
    leaf that already holds codes."""
    if dtype == "float32":
        return pdb
    from repro.optim.compression import code_dtype
    db = pdb.db._replace(
        vectors=np.asarray(pdb.db.vectors).astype(code_dtype(dtype)))
    return pdb._replace(db=db)


def merge_topk(ids, dists, k: int):
    """Stage-2 reduction: [..., P, K] -> top-k by exact distance.

    Implemented as the same rank-merge primitive the search kernel uses —
    sorting the concatenated P*K candidates would also work, but the merge is
    what generalizes to the distributed tree reduction.
    """
    *lead, P, K = ids.shape
    flat_i = ids.reshape(*lead, P * K)
    flat_d = dists.reshape(*lead, P * K)
    order = jnp.argsort(flat_d, axis=-1, stable=True)
    top = order[..., :k]
    return (
        jnp.take_along_axis(flat_i, top, axis=-1),
        jnp.take_along_axis(flat_d, top, axis=-1),
    )


@functools.partial(jax.jit, static_argnames=("p",))
def search_partitioned(pdb: PartitionedDB, queries, p: SearchParams):
    """Single-host two-stage search: vmap stage 1 over partitions + merge.

    Returns (ids[B, k], dists[B, k], stats) with global ids.
    """
    ids, ds, stats = jax.vmap(lambda db: batch_search(db, queries, p))(pdb.db)
    # ids: [P, B, k] -> [B, P, k]
    ids = jnp.swapaxes(ids, 0, 1)
    ds = jnp.swapaxes(ds, 0, 1)
    out_i, out_d = merge_topk(ids, ds, p.k)
    return out_i, out_d, stats


@functools.partial(jax.jit, static_argnames=("p",))
def search_partitioned_candidates(pdb: PartitionedDB, queries, p: SearchParams):
    """Stage 1 only: the P*K intermediate candidates, unmerged.

    Returns (ids[B, P*k], dists[B, P*k], stats) — the pool the paper's
    stage-2 brute force re-scores (api.rerank.batched_rerank consumes it).
    """
    ids, ds, stats = jax.vmap(lambda db: batch_search(db, queries, p))(pdb.db)
    b = queries.shape[0]
    ids = jnp.swapaxes(ids, 0, 1).reshape(b, -1)
    ds = jnp.swapaxes(ds, 0, 1).reshape(b, -1)
    return ids, ds, stats
