"""Deprecated single-host engine shim.

`ANNEngine` predates the unified `repro.api` surface and is kept so
existing callers and tests continue to work. It is now a thin wrapper over
`repro.api.SearchService` with the `partitioned` backend — new code should
use `repro.api` directly:

    from repro.api import IndexSpec, SearchRequest, SearchService
    svc = SearchService.build(vectors, IndexSpec(num_partitions=4))
    resp = svc.search(SearchRequest(queries, k=10, ef=40))
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import hnsw_graph as hg
from repro.core.bruteforce import bruteforce_topk
from repro.core.partitioned import PartitionedDB, search_partitioned
from repro.core.search import SearchParams

__all__ = ["ANNEngine"]


class ANNEngine:
    """Build once, search many times (deprecated: use repro.api).

    >>> eng = ANNEngine.build(vectors, num_partitions=4)
    >>> ids, dists = eng.search(queries, k=10, ef=40)
    """

    def __init__(self, service):
        self._service = service

    # -- legacy attribute surface (benchmarks poke at these) ----------------

    @property
    def pdb(self) -> PartitionedDB:
        return self._service.backend.pdb

    @property
    def cfg(self) -> hg.HNSWConfig:
        return self._service.spec.hnsw

    @property
    def vectors(self) -> np.ndarray | None:
        return self._service.backend.raw

    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        num_partitions: int = 1,
        cfg: hg.HNSWConfig | None = None,
        keep_vectors: bool = False,
    ) -> "ANNEngine":
        from repro.api import IndexSpec, SearchService

        spec = IndexSpec(backend="partitioned",
                         num_partitions=num_partitions,
                         hnsw=cfg or hg.HNSWConfig(),
                         keep_vectors=keep_vectors)
        return cls(SearchService.build(vectors, spec))

    def search(self, queries, k: int = 10, ef: int = 40, rerank: bool = False):
        from repro.api import SearchRequest

        resp = self._service.search(
            SearchRequest(queries=queries, k=k, ef=ef, rerank=rerank))
        if rerank:                       # the old _rerank returned host arrays
            return np.asarray(resp.ids), np.asarray(resp.dists)
        return resp.ids, resp.dists

    def search_with_stats(self, queries, k: int = 10, ef: int = 40):
        """Raw (ids, dists, SearchStats) with per-partition [P, B] counters
        — the historical shape benchmarks reduce themselves."""
        svc = self._service
        q = svc.metric.prepare_queries(np.asarray(queries))
        p = SearchParams(ef=ef, k=k, metric=svc.spec.metric)
        return search_partitioned(self.pdb, jnp.asarray(q), p)

    def save(self, path: str):
        """Persist via the versioned api manifest (Fig. 4 step 1)."""
        return self._service.save(path)

    @classmethod
    def load(cls, path: str, cfg: hg.HNSWConfig | None = None) -> "ANNEngine":
        """Restore the latest committed version (Fig. 4 step 2). The step
        is discovered through the checkpoint store — no hardcoded paths.
        `cfg` overrides the persisted HNSW knobs (the pre-manifest format
        could not store them; honored for legacy callers). Indexes saved
        before the manifest existed (bare step dirs) still load: the spec
        is synthesized from `cfg` and the stored partition count."""
        import dataclasses
        import os

        from repro.api import IndexSpec, SearchService
        from repro.api.backends import PartitionedBackend
        from repro.api.service import MANIFEST_NAME, read_step_leaves
        from repro.checkpoint import latest_step

        if os.path.exists(os.path.join(path, MANIFEST_NAME)):
            svc = SearchService.load(path)
            if cfg is not None:
                svc.spec = dataclasses.replace(svc.spec, hnsw=cfg)
            return cls(svc)
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(
                f"no index manifest or committed checkpoint under {path!r}")
        leaves = read_step_leaves(path, step)
        spec = IndexSpec(backend="partitioned",
                         num_partitions=int(leaves["meta/num_partitions"]),
                         hnsw=cfg or hg.HNSWConfig())
        return cls(SearchService(spec,
                                 PartitionedBackend.from_state(spec, leaves)))

    def bruteforce(self, queries, k: int = 10):
        """Exact search over the restructured DB (Fig. 9 baseline)."""
        db = self.pdb.db
        P, Np, Dp = db.vectors.shape
        vecs = db.vectors.reshape(P * Np, Dp)
        sq = db.sqnorms.reshape(P * Np)
        queries = jnp.asarray(queries)
        if queries.shape[-1] < Dp:       # lane-padding, as in batch_search
            queries = jnp.pad(queries, ((0, 0), (0, Dp - queries.shape[-1])))
        ids, dists = bruteforce_topk(vecs, sq, queries, k=k, chunk=Np)
        gids = db.gids.reshape(P * Np)
        return jnp.where(ids >= 0, gids[jnp.maximum(ids, 0)], -1), dists
