"""High-level ANN engine API (single-host; distributed version in
core/distributed.py).

Mirrors the platform dataflow of paper Fig. 4: the bulk tier (host / object
store) holds all partitions, the engine loads them into the accelerator
memory once, and queries stream through without touching the bulk tier
again. `rerank=True` reproduces the paper's host-side stage-2 brute force
over raw vectors exactly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw_graph as hg
from repro.core.bruteforce import bruteforce_topk
from repro.core.partitioned import (
    PartitionedDB,
    build_partitioned_db,
    search_partitioned,
)
from repro.core.search import SearchParams

__all__ = ["ANNEngine"]


@dataclasses.dataclass
class ANNEngine:
    """Build once, search many times.

    >>> eng = ANNEngine.build(vectors, num_partitions=4)
    >>> ids, dists = eng.search(queries, k=10, ef=40)
    """

    pdb: PartitionedDB
    cfg: hg.HNSWConfig
    vectors: np.ndarray | None = None   # kept only if rerank is requested

    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        num_partitions: int = 1,
        cfg: hg.HNSWConfig | None = None,
        keep_vectors: bool = False,
    ) -> "ANNEngine":
        cfg = cfg or hg.HNSWConfig()
        pdb = build_partitioned_db(vectors, num_partitions, cfg)
        pdb = PartitionedDB(
            db=jax.tree.map(jnp.asarray, pdb.db),
            num_partitions=pdb.num_partitions,
            dim=pdb.dim,
        )
        return cls(pdb=pdb, cfg=cfg, vectors=vectors if keep_vectors else None)

    def search(self, queries, k: int = 10, ef: int = 40, rerank: bool = False):
        p = SearchParams(ef=ef, k=k)
        ids, dists, _ = search_partitioned(self.pdb, jnp.asarray(queries), p)
        if rerank:
            ids, dists = self._rerank(np.asarray(queries), np.asarray(ids), k)
        return ids, dists

    def search_with_stats(self, queries, k: int = 10, ef: int = 40):
        p = SearchParams(ef=ef, k=k)
        return search_partitioned(self.pdb, jnp.asarray(queries), p)

    def _rerank(self, queries: np.ndarray, ids: np.ndarray, k: int):
        """Paper stage 2: exact distances over the P*K intermediate results."""
        assert self.vectors is not None, "build with keep_vectors=True to rerank"
        out_i = np.full((ids.shape[0], k), -1, np.int32)
        out_d = np.full((ids.shape[0], k), np.inf, np.float32)
        for b, (q, row) in enumerate(zip(queries, ids)):
            cand = np.unique(row[row >= 0])
            d = np.einsum("nd,nd->n", self.vectors[cand] - q, self.vectors[cand] - q)
            order = np.argsort(d, kind="stable")[:k]
            out_i[b, : len(order)] = cand[order]
            out_d[b, : len(order)] = d[order]
        return out_i, out_d

    def save(self, path: str):
        """Persist the restructured partitioned DB (the paper's one-time SSD
        initialization, Fig. 4 step 1) via the checkpoint store."""
        from repro.checkpoint import save_checkpoint
        tree = {"db": self.pdb.db._asdict(),
                "meta": {"num_partitions": jnp.int32(self.pdb.num_partitions),
                         "dim": jnp.int32(self.pdb.dim)}}
        return save_checkpoint(path, 0, tree)

    @classmethod
    def load(cls, path: str, cfg: hg.HNSWConfig | None = None) -> "ANNEngine":
        """Restore a saved engine (the SSD -> HBM fetch of Fig. 4 step 2)."""
        import json as _json
        import os as _os

        import numpy as _np
        from repro.checkpoint import restore_checkpoint
        d = _os.path.join(path, "step_00000000")
        with open(_os.path.join(d, "manifest.json")) as f:
            manifest = _json.load(f)
        leaves = {}
        for e in manifest["leaves"]:
            arr = _np.load(_os.path.join(d, e["file"] + ".npy"))
            leaves[e["path"]] = arr
        db = hg.DeviceDB(**{k.split("/", 1)[1]: jnp.asarray(v)
                            for k, v in leaves.items()
                            if k.startswith("db/")})
        pdb = PartitionedDB(db=db,
                            num_partitions=int(leaves["meta/num_partitions"]),
                            dim=int(leaves["meta/dim"]))
        return cls(pdb=pdb, cfg=cfg or hg.HNSWConfig())

    def bruteforce(self, queries, k: int = 10):
        """Exact search over the restructured DB (Fig. 9 baseline)."""
        db = self.pdb.db
        P, Np, Dp = db.vectors.shape
        vecs = db.vectors.reshape(P * Np, Dp)
        sq = db.sqnorms.reshape(P * Np)
        queries = jnp.asarray(queries)
        if queries.shape[-1] < Dp:       # lane-padding, as in batch_search
            queries = jnp.pad(queries, ((0, 0), (0, Dp - queries.shape[-1])))
        ids, dists = bruteforce_topk(vecs, sq, queries, k=k, chunk=Np)
        gids = db.gids.reshape(P * Np)
        return jnp.where(ids >= 0, gids[jnp.maximum(ids, 0)], -1), dists
