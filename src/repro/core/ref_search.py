"""Pure-numpy oracle for the fixed-shape search kernel (tests compare exactly).

This mirrors core/search.py operation-for-operation (same list sizes, same
hop budgets, same tie-breaking: existing list entries win ties over the new
batch, and the batch is stably sorted) so tests can assert bit-identical ids.
It plays the role of the paper's HLS baseline: a readable, obviously-correct
rendition of the modified algorithm.
"""

from __future__ import annotations

import numpy as np

from repro.core.hnsw_graph import DeviceDB
from repro.core.search import SearchParams

__all__ = ["ref_search_one", "ref_batch_search"]


def _metric_dist(metric: str, dot, xsq, qsq):
    from repro.api.metrics import get_metric   # registry owns the formulas
    d = get_metric(metric).dist_from_dot(dot, xsq, qsq)
    return np.maximum(d, 0.0) if metric == "l2" else d


def _dists(db: DeviceDB, q: np.ndarray, qsq: float, ids: np.ndarray,
           valid: np.ndarray, metric: str = "l2"):
    safe = np.where(valid, ids, 0)
    d = _metric_dist(metric, db.vectors[safe] @ q, db.sqnorms[safe], qsq)
    return np.where(valid, d, np.inf), safe


def _merge(ad, ai, bd, bi, out):
    """Stable merge with existing (a) winning ties — matches merge_sorted."""
    d = np.concatenate([ad, bd])
    i = np.concatenate([ai, bi])
    order = np.argsort(d, kind="stable")
    return d[order][:out], i[order][:out]


def ref_search_one(db: DeviceDB, q: np.ndarray, p: SearchParams):
    p = p.resolve(db.l0_nbrs.shape[1])
    q = np.asarray(q, np.float32)
    d_pad = db.vectors.shape[-1]
    if q.shape[-1] < d_pad:
        q = np.pad(q, (0, d_pad - q.shape[-1]))
    qsq = float(q @ q)
    n_layers = db.up_nbrs.shape[0]
    max_level = int(db.max_level)

    # --- upper layers: greedy descent --------------------------------------
    cur = int(db.entry)
    cur_d = float(_metric_dist(p.metric, float(db.vectors[cur] @ q),
                               float(db.sqnorms[cur]), qsq))
    calcs = 1
    for layer in range(n_layers, 0, -1):
        if layer > max_level:
            continue
        hops = 0
        improved = True
        while improved and hops < p.upper_hops:
            row = int(db.up_ptr[cur])
            nbrs = db.up_nbrs[layer - 1, max(row, 0)]
            valid = (nbrs >= 0) & (row >= 0)
            d, safe = _dists(db, q, qsq, nbrs, valid, p.metric)
            calcs += int(valid.sum())
            j = int(np.argmin(d))
            improved = bool(d[j] < cur_d)
            if improved:
                cur, cur_d = int(safe[j]), float(d[j])
            hops += 1

    # --- layer 0: beam ------------------------------------------------------
    C, EF = p.cand_size, p.ef
    n_pad = db.vectors.shape[0]
    visited = np.zeros(n_pad, bool)
    visited[cur] = True
    cand_d = np.full(C, np.inf); cand_d[0] = cur_d
    cand_i = np.full(C, -1, np.int64); cand_i[0] = cur
    fin_d = np.full(EF, np.inf); fin_d[0] = cur_d
    fin_i = np.full(EF, -1, np.int64); fin_i[0] = cur

    hops = 0
    while cand_d[0] < fin_d[-1] and hops < p.max_hops:
        c = int(cand_i[0])
        cand_d = np.roll(cand_d, -1); cand_d[-1] = np.inf
        cand_i = np.roll(cand_i, -1); cand_i[-1] = -1

        nbrs = db.l0_nbrs[c]
        valid = nbrs >= 0
        safe0 = np.where(valid, nbrs, 0)
        active = valid & ~visited[safe0]
        visited[safe0[active]] = True
        d, safe = _dists(db, q, qsq, nbrs, active, p.metric)
        calcs += int(active.sum())
        d = np.where(d < fin_d[-1], d, np.inf)
        ids = np.where(np.isfinite(d), safe, -1)
        order = np.argsort(d, kind="stable")
        bd, bi = d[order], ids[order]

        fin_d, fin_i = _merge(fin_d, fin_i, bd, bi, EF)
        cand_d, cand_i = _merge(cand_d, cand_i, bd, bi, C)
        hops += 1

    k_i = fin_i[: p.k]
    k_d = fin_d[: p.k]
    k_g = np.where(k_i >= 0, db.gids[np.maximum(k_i, 0)], -1)
    return k_g.astype(np.int32), k_d.astype(np.float32), hops, calcs


def ref_batch_search(db: DeviceDB, queries: np.ndarray, p: SearchParams):
    outs = [ref_search_one(db, q, p) for q in np.asarray(queries)]
    ids = np.stack([o[0] for o in outs])
    ds = np.stack([o[1] for o in outs])
    hops = np.array([o[2] for o in outs], np.int32)
    calcs = np.array([o[3] for o in outs], np.int32)
    return ids, ds, hops, calcs
