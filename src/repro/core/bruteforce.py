"""Exact brute-force top-K baseline (paper Fig. 9 comparison).

The paper sizes a hypothetical brute-force FPGA design (1968 DSPs, 200 MHz ->
3 GV/s, 3 QPS on SIFT1B) against HNSW. Here the baseline is real: a blocked
scan over the database with a running top-k merge, so benchmarks can report
both QPS and the "number of vector reads" on identical footing.

The chunked scan keeps the distance matrix out of HBM-resident temporaries —
only [B, chunk] tiles exist at once. kernels/l2topk.py is the Pallas-fused
version of exactly this loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.search import merge_sorted, metric_distance

__all__ = ["bruteforce_topk"]


@functools.partial(jax.jit, static_argnames=("k", "chunk", "metric"))
def bruteforce_topk(vectors, sqnorms, queries, k: int = 10, chunk: int = 4096,
                    metric: str = "l2"):
    """Exact k smallest ids/distances for each query under `metric`.

    vectors: [N, D] (N % chunk == 0 after padding; pad rows have sqnorm=+inf —
             the +inf sqnorm is the pad marker for every metric). May hold
             uint8/int8 codes (quantized path): each chunk is cast to f32
             at the matmul, so distances are exact code-space values.
    queries: [B, D]
    returns: ids [B, k] int32, dists [B, k] float32
    """
    n, d = vectors.shape
    b = queries.shape[0]
    assert n % chunk == 0, "pad the database to a multiple of `chunk`"
    queries = queries.astype(jnp.float32)
    qsq = jnp.einsum("bd,bd->b", queries, queries)

    vecs = vectors.reshape(n // chunk, chunk, d)
    sqs = sqnorms.reshape(n // chunk, chunk)

    def step(carry, xs):
        run_d, run_i = carry               # [B, k] sorted ascending
        v, s, off = xs
        dot = queries @ v.T.astype(jnp.float32)
        d2 = metric_distance(metric, dot, s[None, :], qsq[:, None])
        d2 = jnp.where(jnp.isinf(s)[None, :], jnp.inf, d2)
        cd, ci = jax.lax.top_k(-d2, k)     # [B, k] largest of -d2 == smallest d2
        cd = -cd
        cids = off + ci.astype(jnp.int32)
        md, mi = jax.vmap(merge_sorted)(run_d, run_i, cd, cids)
        return (md[:, :k], mi[:, :k]), None

    init = (jnp.full((b, k), jnp.inf), jnp.full((b, k), -1, jnp.int32))
    offs = (jnp.arange(n // chunk, dtype=jnp.int32) * chunk)
    (fd, fi), _ = jax.lax.scan(step, init, (vecs, sqs, offs))
    return fi, fd
