"""Fixed-shape HNSW search kernel in JAX (paper Algorithm 1, HW-modified).

This is the TPU analogue of the paper's RTL search kernel (§5.2). All of the
paper's hardware modifications carry over:

  * single-bit visited list       -> packed uint32 bitmap, N/8 bytes/query
                                     (the paper's 0.62 MB for 5M points)
  * parallel distance calculator  -> MXU-friendly ||q-x||^2 = ||x||^2 - 2 x.q + ||q||^2
                                     over a whole (padded) neighbor list at once
  * parallel insertion sort via   -> rank-based merge of two sorted arrays:
    comparison bit-vector            pos = index + searchsorted(other)
                                     (searchsorted == popcount of "smaller" bits)
  * multi-query processing        -> vmap over the query batch; the masked
                                     lockstep while_loop is the many-module
                                     generalization of the paper's 2 modules
  * fixed-size candidate list     -> the paper sets |C| "larger than ef";
                                     we default to ef + maxM0

Shapes are fully static: candidate/final lists are sorted arrays padded with
+inf, neighbor lists are -1-padded fixed-stride rows (the restructured DB of
hnsw_graph.py), and the data-dependent traversal runs under
``jax.lax.while_loop`` with an explicit hop budget (returned in the stats so
benchmarks can report the paper's "number of vector reads", Fig. 9).

Quantized databases (IndexSpec.dtype uint8/int8 — the paper's SIFT1B
operating point): ``db.vectors`` may hold integer codes and ``queries``
code-valued float32; every distance evaluation casts the gathered rows to
f32 and accumulates in f32 (exact for 8-bit codes up to ~256 dims, since
all partial dot products are integers < 2^24), so the traversal is the
same kernel in code space. ``db.sqnorms`` stays float32 (code norms; +inf
pad markers). The caller rescales distances by ``scale**2`` at the edge.

Product-quantized databases (IndexSpec.dtype "pq"): ``db.vectors`` holds
[n_pad, M] uint8 PQ codes and the caller passes ``lut`` — the per-query
[M, 256] asymmetric-distance table (optim.compression.build_pq_lut).
Every distance evaluation becomes `pq_lut_distances`: a table gather
followed by `jnp.sum(..., axis=-1)` over subspaces — the LUT extension of
the mul+sum reduction-order rule below. Queries are NOT padded to the
code width (the LUT is the per-query operand), and layer 0 always runs
the hop-stepped path (the in-memory fused traversal kernel has no PQ
variant; bit-identity across `fused_hops` then holds trivially — the csd
backend's PQ supersteps replay these exact semantics).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hnsw_graph import DeviceDB

__all__ = [
    "SearchParams",
    "SearchStats",
    "bitmap_words",
    "merge_sorted",
    "metric_distance",
    "pq_lut_distances",
    "visited_test_and_set",
    "search_one",
    "batch_search",
]


def bitmap_words(n: int) -> int:
    """uint32 words needed for an n-bit visited bitmap: ceil(n / 32).

    Floor division here was a real bug: with n % 32 != 0 the last partial
    word was never allocated, so test-and-set on the tail ids indexed past
    the bitmap (JAX clamps the gather/scatter to the last word — tail ids
    silently aliased onto bits 0..31 of the wrong word)."""
    return (n + 31) // 32


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Search-time knobs (paper: ef=40, K=10 for all SIFT1B results).

    `metric` selects the distance the traversal minimizes:
      l2     : squared Euclidean (the paper's metric)
      ip     : negative inner product (MIPS as a minimization)
      cosine : 1 - q.x, assuming the DB vectors and queries are unit-norm
               (repro.api normalizes both at the build/search edge)
    HNSW itself is metric-agnostic — only the distance evaluations change.
    """

    ef: int = 40
    k: int = 10
    cand_size: int = 0        # 0 -> resolved to ef + maxM0
    max_hops: int = 0         # 0 -> resolved to 4*ef + 16
    upper_hops: int = 32      # per-layer greedy budget in upper layers
    metric: str = "l2"
    # layer-0 hops executed per kernel invocation / per host superstep.
    # 1 = the legacy hop-stepped lockstep path; >1 switches the in-memory
    # backends to the fused Pallas traversal kernel (kernels/traversal.py)
    # and the csd backend to speculative H-hop supersteps (one host sync
    # and one batched store read per superstep). Results are bit-identical
    # at every value — this knob trades work per dispatch for round-trips.
    fused_hops: int = 1

    def resolve(self, maxM0: int) -> "SearchParams":
        cand = self.cand_size or (self.ef + maxM0)
        hops = self.max_hops or (4 * self.ef + 16)
        return dataclasses.replace(self, cand_size=cand, max_hops=hops)


class SearchStats(NamedTuple):
    hops: jnp.ndarray         # candidate pops at layer 0 (per query)
    dist_calcs: jnp.ndarray   # distance evaluations == "vector reads" (Fig. 9)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def merge_sorted(ad, ai, bd, bi):
    """Merge two ascending (dist, id) arrays; ties keep `a` first.

    The paper's parallel insertion sort computes an insert position as the
    popcount of a comparison bit-vector; ``searchsorted`` computes exactly
    that rank, vectorized over every element of both lists at once.
    """
    na, nb = ad.shape[0], bd.shape[0]
    pa = jnp.arange(na, dtype=jnp.int32) + jnp.searchsorted(
        bd, ad, side="left"
    ).astype(jnp.int32)
    pb = jnp.arange(nb, dtype=jnp.int32) + jnp.searchsorted(
        ad, bd, side="right"
    ).astype(jnp.int32)
    od = jnp.zeros(na + nb, ad.dtype).at[pa].set(ad).at[pb].set(bd)
    oi = jnp.zeros(na + nb, ai.dtype).at[pa].set(ai).at[pb].set(bi)
    return od, oi


def visited_test_and_set(bitmap, ids, valid):
    """Packed-bitmap visited list (paper §5.1.1 / §5.2.6).

    Returns (was_visited[bool], new_bitmap). `ids` must be unique where
    `valid` (guaranteed by the restructured DB's de-duplicated rows), so the
    scatter-add of distinct power-of-two bits within a word equals bitwise OR.
    """
    w = jax.lax.shift_right_logical(ids, 5)
    b = (ids & 31).astype(jnp.uint32)
    bit = jax.lax.shift_left(jnp.uint32(1), b)
    old = bitmap[w]
    was = (jax.lax.shift_right_logical(old, b) & jnp.uint32(1)) > 0
    was = was | ~valid
    add = jnp.where(~was, bit, jnp.uint32(0))
    return was, bitmap.at[w].add(add)


def metric_distance(metric: str, dot, xsq, qsq):
    """Distance-from-dot-product for every supported metric (ascending ==
    better). `metric` is trace-time static, so the branch costs nothing."""
    if metric == "l2":
        return jnp.maximum(xsq - 2.0 * dot + qsq, 0.0)
    if metric == "ip":
        return -dot
    if metric == "cosine":                       # unit-norm inputs assumed
        return 1.0 - dot
    raise ValueError(f"unknown metric {metric!r}")


def pq_lut_distances(lut, codes):
    """ADC distances for PQ code rows: lut [M, 256] x codes [N, M] -> [N].

    `jnp.take_along_axis(lut.T, codes, axis=0)` then `jnp.sum(..., -1)` is
    the ONE accumulation every engine path uses (in-memory traversal, csd
    hop kernels and supersteps, rerank candidate pools) — the PQ analogue
    of the mul+sum rule in `_batch_distances`. Re-deriving it with a
    different gather shape or reduction order gives last-ulp-different
    sums and breaks the partitioned==csd==cluster bit-identity contract.
    """
    vals = jnp.take_along_axis(lut.T, codes.astype(jnp.int32), axis=0)
    return jnp.sum(vals, axis=-1)


def _batch_distances(db: DeviceDB, q, qsq, ids, valid, metric: str = "l2",
                     lut=None):
    """Distances from q to db.vectors[ids]; invalid lanes -> +inf.

    One fused gather + matvec: the whole (padded) neighbor list is evaluated
    at once — the analogue of the paper's 8x16-PE distance array consuming a
    full 128-dim vector per cycle. With `lut` set (dtype="pq"), the gather
    pulls M-byte code rows and the matvec becomes a LUT gather + sum.
    """
    safe = jnp.where(valid, ids, 0)
    if lut is not None:
        d = pq_lut_distances(lut, db.vectors[safe])
        return jnp.where(valid, d, jnp.inf), safe
    vecs = db.vectors[safe].astype(jnp.float32)  # [M, D_pad] (codes -> f32)
    # mul+sum instead of `vecs @ q`: XLA compiles a matvec with a
    # context-dependent reduction order (gather-fused vs pre-gathered vs
    # Pallas-interpreted give last-ulp-different sums), while an explicit
    # elementwise product + axis reduction is bitwise-stable across every
    # context we run in — the property the fused-kernel parity matrix pins.
    d = metric_distance(metric, jnp.sum(vecs * q, axis=-1),
                        db.sqnorms[safe], qsq)
    return jnp.where(valid, d, jnp.inf), safe


# ---------------------------------------------------------------------------
# Upper layers: greedy descent (ef = 1), paper §5.2.2
# ---------------------------------------------------------------------------


def _greedy_upper(db: DeviceDB, q, qsq, p: SearchParams, lut=None):
    """Descend from db.max_level to layer 1, returning the layer-0 entry."""
    ep = db.entry.astype(jnp.int32)
    if lut is not None:
        ep_d = pq_lut_distances(lut, db.vectors[ep][None])[0]
    else:
        ep_vec = db.vectors[ep].astype(jnp.float32)
        ep_d = metric_distance(p.metric, jnp.sum(ep_vec * q, axis=-1),
                               db.sqnorms[ep], qsq)
    n_layers = db.up_nbrs.shape[0]               # static cap - 1

    def layer_body(i, carry):
        cur, cur_d, calcs = carry
        layer = n_layers - i                      # n_layers .. 1
        active_layer = layer <= db.max_level

        def hop_cond(s):
            _, _, improved, hops, _ = s
            return improved & (hops < p.upper_hops)

        def hop_body(s):
            c, c_d, _, hops, calcs = s
            row = db.up_ptr[c]
            nbrs = db.up_nbrs[layer - 1, jnp.maximum(row, 0)]
            valid = (nbrs >= 0) & (row >= 0)
            d, safe = _batch_distances(db, q, qsq, nbrs, valid, p.metric,
                                       lut)
            j = jnp.argmin(d)
            best_d, best = d[j], safe[j]
            improved = best_d < c_d
            c = jnp.where(improved, best, c)
            c_d = jnp.where(improved, best_d, c_d)
            return c, c_d, improved, hops + 1, calcs + jnp.sum(valid)

        cur2, cur_d2, _, _, calcs2 = jax.lax.while_loop(
            hop_cond,
            hop_body,
            (cur, cur_d, jnp.bool_(True), jnp.int32(0), calcs),
        )
        cur = jnp.where(active_layer, cur2, cur)
        cur_d = jnp.where(active_layer, cur_d2, cur_d)
        calcs = jnp.where(active_layer, calcs2, calcs)
        return cur, cur_d, calcs

    cur, cur_d, calcs = jax.lax.fori_loop(
        0, n_layers, layer_body, (ep, ep_d, jnp.int32(1))
    )
    return cur, cur_d, calcs


# ---------------------------------------------------------------------------
# Layer 0: beam search with candidate/final lists (paper §5.2.3)
# ---------------------------------------------------------------------------


def _search_layer0(db: DeviceDB, q, qsq, ep, ep_d, p: SearchParams,
                   lut=None):
    n_words = bitmap_words(db.vectors.shape[0])
    C, EF = p.cand_size, p.ef

    visited = jnp.zeros((n_words,), jnp.uint32)
    _, visited = visited_test_and_set(
        visited, ep[None], jnp.ones((1,), jnp.bool_)
    )
    cand_d = jnp.full((C,), jnp.inf).at[0].set(ep_d)
    cand_i = jnp.full((C,), -1, jnp.int32).at[0].set(ep)
    fin_d = jnp.full((EF,), jnp.inf).at[0].set(ep_d)
    fin_i = jnp.full((EF,), -1, jnp.int32).at[0].set(ep)

    def cond(s):
        cand_d, _, fin_d, _, _, hops, _ = s
        # Algorithm 1 lines 2&5: candidates remain AND the nearest candidate
        # can still improve the final list. inf < inf is False, so an empty
        # candidate list terminates naturally.
        return (cand_d[0] < fin_d[-1]) & (hops < p.max_hops)

    def body(s):
        cand_d, cand_i, fin_d, fin_i, visited, hops, calcs = s
        c = cand_i[0]
        # pop: shift the sorted array left (line 3).
        cand_d = jnp.roll(cand_d, -1).at[-1].set(jnp.inf)
        cand_i = jnp.roll(cand_i, -1).at[-1].set(-1)

        nbrs = db.l0_nbrs[c]                       # [maxM0_pad]
        valid = nbrs >= 0
        was, visited = visited_test_and_set(visited, jnp.where(valid, nbrs, 0), valid)
        active = valid & ~was
        d, safe = _batch_distances(db, q, qsq, nbrs, active, p.metric, lut)
        calcs = calcs + jnp.sum(active)
        # line 11 guard: only candidates that can enter the final list.
        d = jnp.where(d < fin_d[-1], d, jnp.inf)
        ids = jnp.where(jnp.isfinite(d), safe, -1)
        order = jnp.argsort(d, stable=True)
        bd, bi = d[order], ids[order]

        fd, fi = merge_sorted(fin_d, fin_i, bd, bi)
        fin_d, fin_i = fd[:EF], fi[:EF]
        cd, ci = merge_sorted(cand_d, cand_i, bd, bi)
        cand_d, cand_i = cd[:C], ci[:C]
        return cand_d, cand_i, fin_d, fin_i, visited, hops + 1, calcs

    cand_d, cand_i, fin_d, fin_i, visited, hops, calcs = jax.lax.while_loop(
        cond,
        body,
        (cand_d, cand_i, fin_d, fin_i, visited, jnp.int32(0), jnp.int32(0)),
    )
    return fin_d, fin_i, hops, calcs


# ---------------------------------------------------------------------------
# Layer 0, fused: H hops per kernel invocation (paper §5.2, Fig. 6)
# ---------------------------------------------------------------------------


def _search_layer0_fused(db: DeviceDB, queries, qsq, ep, ep_d,
                         p: SearchParams):
    """Batched layer-0 beam search driven by the fused multi-hop Pallas
    kernel: the `lax.while_loop` body executes `p.fused_hops` hops per
    invocation with the beam state resident in VMEM (kernels/traversal.py),
    instead of one hop of small ops. Bit-identical to the vmapped
    `_search_layer0` — same merge semantics, same per-lane hop guard, same
    hops/dist_calcs accounting."""
    from repro.kernels.ops import fused_layer0   # lazy: kernels -> core is
                                                 # the only allowed direction
    B = queries.shape[0]
    n_words = bitmap_words(db.vectors.shape[0])
    C, EF = p.cand_size, p.ef

    visited = jnp.zeros((B, n_words), jnp.uint32)
    _, visited = jax.vmap(visited_test_and_set)(
        visited, ep[:, None], jnp.ones((B, 1), jnp.bool_))
    cand_d = jnp.full((B, C), jnp.inf).at[:, 0].set(ep_d)
    cand_i = jnp.full((B, C), -1, jnp.int32).at[:, 0].set(ep)
    fin_d = jnp.full((B, EF), jnp.inf).at[:, 0].set(ep_d)
    fin_i = jnp.full((B, EF), -1, jnp.int32).at[:, 0].set(ep)

    def cond(s):
        cand_d, _, fin_d, _, _, hops, _ = s
        return jnp.any((cand_d[:, 0] < fin_d[:, -1]) & (hops < p.max_hops))

    def body(s):
        cand_d, cand_i, fin_d, fin_i, visited, hops, calcs = s
        return fused_layer0(
            db.vectors, db.sqnorms, db.l0_nbrs, queries, qsq,
            cand_d, cand_i, fin_d, fin_i, visited, hops, calcs,
            fused_hops=p.fused_hops, max_hops=p.max_hops, metric=p.metric)

    s0 = (cand_d, cand_i, fin_d, fin_i, visited,
          jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32))
    cand_d, cand_i, fin_d, fin_i, visited, hops, calcs = jax.lax.while_loop(
        cond, body, s0)
    return fin_d, fin_i, hops, calcs


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def search_one(db: DeviceDB, q, p: SearchParams, lut=None):
    """Full multi-layer search for one query. Returns (ids[k], dists[k], stats).

    Returned ids are *global* ids (db.gids applied); -1 marks empty slots.
    `lut` is the per-query [M, 256] ADC table for dtype="pq" databases.
    """
    q = q.astype(jnp.float32)
    qsq = q @ q
    ep, ep_d, up_calcs = _greedy_upper(db, q, qsq, p, lut)
    fin_d, fin_i, hops, calcs = _search_layer0(db, q, qsq, ep, ep_d, p, lut)
    k_d, k_i = fin_d[: p.k], fin_i[: p.k]
    k_g = jnp.where(k_i >= 0, db.gids[jnp.maximum(k_i, 0)], -1)
    return k_g, k_d, SearchStats(hops, calcs + up_calcs)


@functools.partial(jax.jit, static_argnames=("p",))
def batch_search(db: DeviceDB, queries, p: SearchParams, lut=None):
    """Multi-query search (paper §5.1.3): lockstep-masked vmap.

    `p.fused_hops > 1` swaps the layer-0 stage for the fused multi-hop
    Pallas kernel (H hops per invocation, beam state in VMEM); the upper
    layers and the k-extraction are shared, and results stay bit-identical
    to the hop-stepped path.

    `lut` ([B, M, 256]) switches distances to PQ asymmetric lookups. PQ
    always runs the hop-stepped layer 0 (no PQ variant of the fused
    in-memory kernel), so results are trivially identical at every
    `fused_hops` — matching the csd backend, whose PQ supersteps replay
    these semantics. Queries are not padded: db.vectors holds M-byte code
    rows and the LUT is the per-query operand.
    """
    p = p.resolve(db.l0_nbrs.shape[1])
    if lut is not None:
        return jax.vmap(lambda q, t: search_one(db, q, p, t))(
            queries.astype(jnp.float32), lut)
    d_pad = db.vectors.shape[-1]
    if queries.shape[-1] < d_pad:  # zero-pad to the lane-aligned raw-data table
        queries = jnp.pad(queries, ((0, 0), (0, d_pad - queries.shape[-1])))
    if p.fused_hops <= 1:
        return jax.vmap(lambda q: search_one(db, q, p))(queries)
    queries = queries.astype(jnp.float32)
    # same per-query ops as search_one, vmapped — not an einsum, so the
    # reduction order (and thus every distance bit) matches the legacy path
    qsq = jax.vmap(lambda q: q @ q)(queries)
    ep, ep_d, up_calcs = jax.vmap(
        lambda q, qs: _greedy_upper(db, q, qs, p))(queries, qsq)
    fin_d, fin_i, hops, calcs = _search_layer0_fused(
        db, queries, qsq, ep, ep_d, p)
    k_d, k_i = fin_d[:, : p.k], fin_i[:, : p.k]
    k_g = jnp.where(k_i >= 0, db.gids[jnp.maximum(k_i, 0)], -1)
    return k_g, k_d, SearchStats(hops, calcs + up_calcs)
