"""The paper's contribution: two-stage partitioned HNSW search for
accelerator-resident graph databases (SmartSSD -> TPU adaptation).

These are the engine primitives. The public serving surface lives in
`repro.api` (IndexSpec / SearchRequest / SearchService); `ANNEngine` is a
deprecated shim kept for existing callers."""

from repro.core.hnsw_graph import HNSWConfig, DeviceDB, build_hnsw, restructure
from repro.core.search import SearchParams, batch_search
from repro.core.partitioned import PartitionedDB, build_partitioned_db, search_partitioned
from repro.core.bruteforce import bruteforce_topk
from repro.core.engine import ANNEngine

__all__ = [
    "HNSWConfig",
    "DeviceDB",
    "build_hnsw",
    "restructure",
    "SearchParams",
    "batch_search",
    "PartitionedDB",
    "build_partitioned_db",
    "search_partitioned",
    "bruteforce_topk",
    "ANNEngine",
]
