"""The paper's contribution: two-stage partitioned HNSW search for
accelerator-resident graph databases (SmartSSD -> TPU adaptation).

These are the engine primitives. The public serving surface lives in
`repro.api` (IndexSpec / SearchRequest / SearchService, plus the mutable
MutableSearchService from repro.ingest). The deprecated `ANNEngine` shim
has been removed — its behaviors live on in `SearchService` (including
pre-manifest index loading)."""

from repro.core.hnsw_graph import (
    DeviceDB,
    GraphBuilder,
    HNSWConfig,
    build_hnsw,
    restructure,
)
from repro.core.search import SearchParams, batch_search
from repro.core.partitioned import PartitionedDB, build_partitioned_db, search_partitioned
from repro.core.bruteforce import bruteforce_topk

__all__ = [
    "HNSWConfig",
    "DeviceDB",
    "GraphBuilder",
    "build_hnsw",
    "restructure",
    "SearchParams",
    "batch_search",
    "PartitionedDB",
    "build_partitioned_db",
    "search_partitioned",
    "bruteforce_topk",
]
