"""Distributed two-stage search: graph parallelism + query parallelism
(paper Fig. 10/11) as explicit shard_map collectives.

Graph parallelism (the paper's winning strategy — 3.67x at 4 devices):
partitions shard over the `model` axis; each device searches only its
resident sub-graphs; per-device top-K results are all-gathered along
`model` and rank-merged (stage 2). The merge is O(P*K) — the paper measured
0.2% of runtime for its host-side equivalent.

Query parallelism: the query batch shards over `data` (and `pod` across
pods). Unlike the paper's variant — where every device had to LOAD THE
WHOLE DATABASE and scaling collapsed to 1.56x — here partitions stay
resident in HBM, so sharding queries across the replicas of the *graph-
sharded* engine is free. The hybrid (graph || within `model`, query ||
across `data`/`pod`) is the scale-out story for 1000+ nodes: pods never
exchange database shards, only (gid, dist) result tuples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.hnsw_graph import DeviceDB
from repro.core.partitioned import PartitionedDB, merge_topk
from repro.core.search import SearchParams, batch_search

__all__ = ["shard_db", "make_distributed_search"]


def shard_db(pdb: PartitionedDB, mesh) -> PartitionedDB:
    """Place partitions round-robin over the `model` axis (P % model == 0)."""
    spec = P("model")
    db = jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P(*( ("model",) + (None,) * (a.ndim - 1))))),
        pdb.db)
    return PartitionedDB(db=db, num_partitions=pdb.num_partitions, dim=pdb.dim)


def make_distributed_search(mesh, p: SearchParams, maxM0: int,
                            graph_axes=("model",), query_axes=None,
                            merge: bool = True, pq: bool = False):
    """Builds the jitted two-stage distributed search for a mesh.

    graph_axes : mesh axes the partitions shard over. For the SIFT1B-scale
        deployment this is the WHOLE pod ("data", "model") — one ~3.9M-vector
        partition per chip, the paper's one-sub-graph-per-SmartSSD mapping.
    query_axes : mesh axes the query batch shards over (e.g. ("pod",) across
        pods). None -> queries replicated over the graph axes.
    merge : True -> (ids[B, k], dists[B, k], calcs[B, 1]) after the stage-2
        rank merge. False -> the gathered unmerged candidate pool
        (ids[B, P*k], dists[B, P*k], calcs[B, 1]) for an external rerank.
    pq : dtype="pq" — the returned function takes a third argument, the
        per-query [B, M, 256] ADC LUT, sharded like the queries (codebooks
        are global, so the tables replicate over the graph axes exactly
        like the query rows they belong to).
    calcs is the per-query distance-evaluation count summed over every
    partition on every device (the Fig. 9 "vector reads").
    """
    p = p.resolve(maxM0)
    query_axes = tuple(query_axes or ())
    qspec = P(query_axes if query_axes else None, None)
    in_specs = (
        DeviceDB(*(P(graph_axes) for _ in DeviceDB._fields)),
        qspec,
    )
    if pq:
        in_specs = in_specs + (
            P(query_axes if query_axes else None, None, None),)
    out_specs = (qspec, qspec, qspec)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False)
    def _search(db_local: DeviceDB, queries, *lut):
        lut = lut[0] if lut else None
        # stage 1: every local partition searches the local query shard.
        ids, ds, stats = jax.vmap(
            lambda db: batch_search(db, queries, p, lut))(db_local)
        # [P_loc, B_loc, k] -> [B_loc, P_loc * k]
        ids = jnp.swapaxes(ids, 0, 1).reshape(queries.shape[0], -1)
        ds = jnp.swapaxes(ds, 0, 1).reshape(queries.shape[0], -1)
        calcs = jnp.sum(stats.dist_calcs, axis=0)      # [B_loc] local reads
        # stage 2: gather candidates across the graph axes, rank-merge.
        all_ids = ids
        all_ds = ds
        for ax in graph_axes:
            all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
            all_ds = jax.lax.all_gather(all_ds, ax, axis=1, tiled=True)
            calcs = jax.lax.psum(calcs, ax)
        if merge:
            order = jnp.argsort(all_ds, axis=1, stable=True)[:, : p.k]
            all_ids = jnp.take_along_axis(all_ids, order, axis=1)
            all_ds = jnp.take_along_axis(all_ds, order, axis=1)
        return all_ids, all_ds, calcs[:, None]

    return jax.jit(_search)
