"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""

from __future__ import annotations

import jax.numpy as jnp
import jax

__all__ = ["l2dist_ref", "topk_ref", "l2topk_ref",
           "l2dist_q_ref", "l2topk_q_ref", "pq_adc_ref", "pq_topk_ref"]


def l2dist_ref(queries, xs, qsq=None, xsq=None):
    """D2[i, j] = ||q_i - x_j||^2 (squared L2, f32 accumulate)."""
    q = queries.astype(jnp.float32)
    x = xs.astype(jnp.float32)
    if qsq is None:
        qsq = jnp.einsum("bd,bd->b", q, q)
    if xsq is None:
        xsq = jnp.einsum("bd,bd->b", x, x)
    return qsq[:, None] + xsq[None, :] - 2.0 * (q @ x.T)


def topk_ref(x, k: int):
    """Per-row k smallest (ascending) values and their column ids."""
    v, i = jax.lax.top_k(-x.astype(jnp.float32), k)
    return -v, i.astype(jnp.int32)


def l2topk_ref(queries, xs, qsq=None, xsq=None, *, k: int = 10):
    d2 = jnp.maximum(l2dist_ref(queries, xs, qsq, xsq), 0.0)
    return topk_ref(d2, k)


def l2dist_q_ref(queries, xs, qsq=None, xsq=None, *, out_scale: float = 1.0):
    """Integer-code oracle: out_scale * max(||q - x||^2, 0) over uint8/int8
    codes, f32 accumulation (exact for 8-bit codes up to ~256 dims)."""
    d2 = jnp.maximum(l2dist_ref(queries, xs, qsq, xsq), 0.0)
    return d2 * jnp.float32(out_scale)


def l2topk_q_ref(queries, xs, qsq=None, xsq=None, *, k: int = 10,
                 out_scale: float = 1.0):
    v, i = topk_ref(jnp.maximum(l2dist_ref(queries, xs, qsq, xsq), 0.0), k)
    return v * jnp.float32(out_scale), i


def pq_adc_ref(luts, codes, xpad=None):
    """PQ asymmetric-distance oracle: [Bq, M, 256] LUTs x [Bx, M] codes ->
    [Bq, Bx] f32. One gather + one add per subspace, in subspace order —
    the same accumulation the Pallas kernel performs, so parity is
    bitwise. `xpad` is +inf on database padding rows."""
    luts = luts.astype(jnp.float32)
    codes = codes.astype(jnp.int32)
    acc = jnp.zeros((luts.shape[0], codes.shape[0]), jnp.float32)
    if xpad is not None:
        acc = acc + xpad.astype(jnp.float32)[None, :]
    for mi in range(luts.shape[1]):
        acc = acc + jnp.take(luts[:, mi, :], codes[:, mi], axis=1)
    return acc


def pq_topk_ref(luts, codes, xpad=None, *, k: int = 10):
    return topk_ref(pq_adc_ref(luts, codes, xpad), k)


def flash_attention_ref(q, k, v, *, causal=True):
    """Naive softmax attention oracle. q/k/v: [BH, T|S, hd]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bth,bsh->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        t, S = s.shape[1], s.shape[2]
        row = jnp.arange(t)[:, None]
        col = jnp.arange(S)[None, :]
        s = jnp.where(col <= row, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsh->bth", p, v.astype(jnp.float32)).astype(q.dtype)
