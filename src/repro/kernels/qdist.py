"""Pallas TPU kernels: integer (uint8/int8) L2 distance, plain and fused.

The paper's distance hardware consumes *integer* vectors — SIFT1B rows are
uint8, and both the SmartSSD RTL (§5.2.5) and the NDSEARCH/Proxima
near-data engines build low-precision distance units because 1 byte/dim is
what matches NAND bandwidth. These kernels are the TPU analogue of that
operating point:

  * blocks stream the *codes* (1 byte/lane — a quarter of the f32 HBM and
    VMEM traffic of `l2dist.py`),
  * each tile is cast to f32 on-core and hits the MXU with f32
    accumulation, which is EXACT for 8-bit codes up to ~256 dims: every
    partial dot product is an integer < 2^24, below the f32 mantissa;
  * `out_scale` (the quantizer's `scale**2`) converts code-space squared
    L2 back to real units inside the kernel, so callers never see codes.

`l2dist_q_pallas` is the blocked distance matrix; `l2topk_q_pallas` fuses
the running per-row top-k (same "never spill the matrix" argument as
`l2topk.py` — now with the streamed database 4x smaller again).
References live in `kernels/ref.py` (`l2dist_q_ref` / `l2topk_q_ref`);
`kernels/ops.py` wraps both with padding for arbitrary shapes.

`pq_adc_pallas` / `pq_topk_pallas` are the product-quantization analogue
(dtype="pq"): the database streams M uint8 codes per row (16x less than
uint8 at M=8/d=128), the per-query [M, 256] LUT lives in VMEM, and the
inner loop is a table-gather + accumulate over the codes — one add per
subspace, in subspace order, which the numpy refs reproduce exactly
(bitwise parity). The LUT itself is built once per query on-device by
`optim.compression.build_pq_lut` and passed in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS
from repro.kernels.topk import _select_k

__all__ = ["l2dist_q_pallas", "l2topk_q_pallas",
           "pq_adc_pallas", "pq_topk_pallas"]


def _code_sqnorms(x):
    xf = x.astype(jnp.float32)
    return jnp.einsum("bd,bd->b", xf, xf)


def _dist_kernel(qsq_ref, xsq_ref, q_ref, x_ref, out_ref, *, out_scale):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = qsq_ref[...][:, None] + xsq_ref[...][None, :]

    # codes live in VMEM at 1 byte/lane; the cast to f32 happens on-core
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += -2.0 * jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        out_ref[...] = jnp.maximum(out_ref[...], 0.0) * out_scale


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_x", "block_d", "interpret",
                     "out_scale"),
)
def l2dist_q_pallas(
    queries,          # [Bq, D] uint8/int8 codes (or code-valued floats)
    xs,               # [Bx, D] uint8/int8 codes
    qsq=None,         # [Bq] optional precomputed code ||q||^2 (f32)
    xsq=None,         # [Bx] optional code ||x||^2 (+inf marks padding)
    *,
    block_q: int = 128,
    block_x: int = 512,
    block_d: int = 128,
    interpret: bool = True,
    out_scale: float = 1.0,
):
    """Returns D2[Bq, Bx] float32 = out_scale * ||q - x||^2 over the codes.

    Dims must divide by the block sizes (ops.l2dist_q pads arbitrary
    shapes). Pass out_scale = quantizer.dist_scale for real-space output.
    """
    bq, d = queries.shape
    bx, _ = xs.shape
    assert bq % block_q == 0 and bx % block_x == 0 and d % block_d == 0
    if qsq is None:
        qsq = _code_sqnorms(queries)
    if xsq is None:
        xsq = _code_sqnorms(xs)
    grid = (bq // block_q, bx // block_x, d // block_d)
    return pl.pallas_call(
        functools.partial(_dist_kernel, out_scale=out_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_x,), lambda i, j, k: (j,)),
            pl.BlockSpec((block_q, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_x, block_d), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_x), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bq, bx), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qsq, xsq, queries, xs)


def _topk_kernel(k: int, block_x: int, out_scale: float):
    def _kernel(qsq_ref, xsq_ref, q_ref, x_ref, out_v_ref, out_i_ref,
                run_v, run_i):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            run_v[...] = jnp.full_like(run_v, jnp.inf)
            run_i[...] = jnp.full_like(run_i, -1)

        q = q_ref[...].astype(jnp.float32)                  # [bq, D] codes
        x = x_ref[...].astype(jnp.float32)                  # [bx, D] codes
        d2 = qsq_ref[...][:, None] + xsq_ref[...][None, :] - 2.0 * \
            jax.lax.dot_general(
                q, x, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        d2 = jnp.maximum(d2, 0.0)                           # +inf pad survives
        cols = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) + j * block_x
        bv, bi = _select_k(d2, cols, k)
        cat_v = jnp.concatenate([run_v[...], bv], axis=1)
        cat_i = jnp.concatenate([run_i[...], bi], axis=1)
        mv, mi = _select_k(cat_v, cat_i, k)
        run_v[...] = mv
        run_i[...] = mi

        @pl.when(j == pl.num_programs(1) - 1)
        def _flush():
            # monotone rescale AFTER selection: code-space order == real order
            out_v_ref[...] = run_v[...] * out_scale
            out_i_ref[...] = run_i[...]

    return _kernel


@functools.partial(
    jax.jit,
    static_argnames=("k", "block_q", "block_x", "interpret", "out_scale"),
)
def l2topk_q_pallas(
    queries,              # [Bq, D] codes
    xs,                   # [Bx, D] codes
    qsq=None,
    xsq=None,             # +inf marks database padding rows
    *,
    k: int = 10,
    block_q: int = 128,
    block_x: int = 1024,
    interpret: bool = True,
    out_scale: float = 1.0,
):
    """Fused integer k-NN: (dists [Bq, k] ascending * out_scale, ids)."""
    bq, d = queries.shape
    bx, _ = xs.shape
    assert bq % block_q == 0 and bx % block_x == 0
    if qsq is None:
        qsq = _code_sqnorms(queries)
    if xsq is None:
        xsq = _code_sqnorms(xs)
    grid = (bq // block_q, bx // block_x)
    return pl.pallas_call(
        _topk_kernel(k, block_x, out_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
            pl.BlockSpec((block_x,), lambda i, j: (j,)),
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_x, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qsq, xsq, queries, xs)


# ---------------------------------------------------------------------------
# Product quantization: asymmetric distance (ADC) over uint8 codes
# ---------------------------------------------------------------------------


def _pq_block_dists(lut, codes, xpad):
    """[bq, m, 256] LUT x [bx, m] codes -> [bq, bx] ADC distances.

    One gather + one add PER SUBSPACE, in subspace order m=0..M-1 — the
    PQ extension of core.search's mul+sum reduction-order rule. The numpy
    refs accumulate in the same order, so kernel == ref bitwise; every
    engine path gathers from the same `build_pq_lut` tables, so changing
    this order (tree reduction, einsum) breaks cross-backend parity.
    `xpad` is +inf on database padding rows (inf + finite stays inf, the
    same marker trick as the xsq pad in the integer kernels).
    """
    m = lut.shape[1]
    codes = codes.astype(jnp.int32)
    acc = jnp.zeros((lut.shape[0], codes.shape[0]), jnp.float32)
    acc = acc + xpad.astype(jnp.float32)[None, :]
    for mi in range(m):
        # lut[:, mi, :] is [bq, 256]; codes[:, mi] is [bx] -> [bq, bx]
        acc = acc + jnp.take(lut[:, mi, :], codes[:, mi], axis=1)
    return acc


def _pq_adc_kernel(lut_ref, codes_ref, xpad_ref, out_ref):
    out_ref[...] = _pq_block_dists(lut_ref[...], codes_ref[...],
                                   xpad_ref[...])


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_x", "interpret"))
def pq_adc_pallas(
    luts,             # [Bq, M, 256] f32 per-query LUTs (build_pq_lut)
    codes,            # [Bx, M] uint8 PQ codes
    xpad=None,        # [Bx] f32, +inf marks database padding rows
    *,
    block_q: int = 8,
    block_x: int = 512,
    interpret: bool = True,
):
    """ADC distance matrix D2[Bq, Bx] = sum_m lut[q, m, codes[x, m]].

    The streamed database is M bytes/row; each program holds block_q LUTs
    (block_q * M * 1KB of VMEM) and a block_x x M code tile. Dims must
    divide the block sizes (ops.pq_adc pads arbitrary shapes). Note the
    code tile's last dim is M (not lane-padded): fine in interpret mode
    and exactly the point of PQ — on a real TPU lowering the codes would
    ride an int8-tiled layout.
    """
    bq, m, k256 = luts.shape
    bx = codes.shape[0]
    assert bq % block_q == 0 and bx % block_x == 0 and k256 == 256
    if xpad is None:
        xpad = jnp.zeros((bx,), jnp.float32)
    grid = (bq // block_q, bx // block_x)
    return pl.pallas_call(
        _pq_adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, m, 256), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_x, m), lambda i, j: (j, 0)),
            pl.BlockSpec((block_x,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_q, block_x), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bq, bx), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(luts, codes, xpad)


def _pq_topk_kernel(k: int, block_x: int):
    def _kernel(lut_ref, codes_ref, xpad_ref, out_v_ref, out_i_ref,
                run_v, run_i):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            run_v[...] = jnp.full_like(run_v, jnp.inf)
            run_i[...] = jnp.full_like(run_i, -1)

        d2 = _pq_block_dists(lut_ref[...], codes_ref[...], xpad_ref[...])
        cols = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) + j * block_x
        bv, bi = _select_k(d2, cols, k)
        cat_v = jnp.concatenate([run_v[...], bv], axis=1)
        cat_i = jnp.concatenate([run_i[...], bi], axis=1)
        mv, mi = _select_k(cat_v, cat_i, k)
        run_v[...] = mv
        run_i[...] = mi

        @pl.when(j == pl.num_programs(1) - 1)
        def _flush():
            out_v_ref[...] = run_v[...]
            out_i_ref[...] = run_i[...]

    return _kernel


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_x", "interpret"))
def pq_topk_pallas(
    luts,                 # [Bq, M, 256] f32 per-query LUTs
    codes,                # [Bx, M] uint8 PQ codes
    xpad=None,            # [Bx] f32, +inf marks padding rows
    *,
    k: int = 10,
    block_q: int = 8,
    block_x: int = 1024,
    interpret: bool = True,
):
    """Fused PQ k-NN: (dists [Bq, k] ascending, ids). The top-k never
    leaves VMEM; the database streams at M bytes/row."""
    bq, m, k256 = luts.shape
    bx = codes.shape[0]
    assert bq % block_q == 0 and bx % block_x == 0 and k256 == 256
    if xpad is None:
        xpad = jnp.zeros((bx,), jnp.float32)
    grid = (bq // block_q, bx // block_x)
    return pl.pallas_call(
        _pq_topk_kernel(k, block_x),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, m, 256), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_x, m), lambda i, j: (j, 0)),
            pl.BlockSpec((block_x,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(luts, codes, xpad)
