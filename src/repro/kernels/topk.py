"""Pallas TPU kernel: per-row k-smallest (value, index) of a matrix.

The paper keeps the final list sorted with a *parallel insertion sort*: all
list entries are compared against the incoming distance at once and the
insert rank is the popcount of the comparison bit-vector (§5.2.6, Fig. 7).
The TPU rendition below streams column blocks through VMEM and maintains a
running sorted top-k per row in scratch; each block is reduced with k
vectorized argmin/mask passes (a k-step selection network — every comparison
of the paper's bit-vector happens lane-parallel on the VPU).

Also reused by MoE routing (top-k expert choice = 1-hop nearest-centroid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

__all__ = ["topk_pallas"]


def _select_k(vals, ids, k):
    """k-step selection: returns ([rows, k] ascending values, ids)."""
    rows, _ = vals.shape

    def step(t, carry):
        vals, out_v, out_i = carry
        j = jnp.argmin(vals, axis=1)                       # [rows]
        row = jnp.arange(rows)
        v = vals[row, j]
        out_v = jax.lax.dynamic_update_index_in_dim(out_v, v, t, 1)
        out_i = jax.lax.dynamic_update_index_in_dim(out_i, ids[row, j], t, 1)
        vals = vals.at[row, j].set(jnp.inf)
        return vals, out_v, out_i

    out_v = jnp.zeros((rows, k), vals.dtype)
    out_i = jnp.zeros((rows, k), ids.dtype)
    _, out_v, out_i = jax.lax.fori_loop(0, k, step, (vals, out_v, out_i))
    return out_v, out_i


def _make_kernel(k: int, block_x: int):
    def _kernel(x_ref, out_v_ref, out_i_ref, run_v, run_i):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            run_v[...] = jnp.full_like(run_v, jnp.inf)
            run_i[...] = jnp.full_like(run_i, -1)

        x = x_ref[...].astype(jnp.float32)                 # [block_b, block_x]
        cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * block_x
        bv, bi = _select_k(x, cols, k)                     # block top-k
        # merge running + block candidates (2k) down to k.
        cat_v = jnp.concatenate([run_v[...], bv], axis=1)
        cat_i = jnp.concatenate([run_i[...], bi], axis=1)
        mv, mi = _select_k(cat_v, cat_i, k)
        run_v[...] = mv
        run_i[...] = mi

        @pl.when(j == pl.num_programs(1) - 1)
        def _flush():
            out_v_ref[...] = run_v[...]
            out_i_ref[...] = run_i[...]

    return _kernel


@functools.partial(
    jax.jit, static_argnames=("k", "block_b", "block_x", "interpret")
)
def topk_pallas(
    x,                   # [B, N]; +inf marks padding
    k: int,
    *,
    block_b: int = 8,
    block_x: int = 1024,
    interpret: bool = True,
):
    """Returns (values [B, k] ascending, ids [B, k] int32)."""
    b, n = x.shape
    assert b % block_b == 0 and n % block_x == 0
    grid = (b // block_b, n // block_x)
    return pl.pallas_call(
        _make_kernel(k, block_x),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, block_x), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, k), jnp.float32),
            pltpu.VMEM((block_b, k), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x)
