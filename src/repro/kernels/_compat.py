"""Version shims shared by the Pallas kernels."""

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either.
COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
