"""Jitted public wrappers around the Pallas kernels.

Handles arbitrary (unaligned) shapes by padding to block multiples — the
software analogue of the paper's database restructuring: callers never pay
for unaligned accesses because alignment is established once at the edge.

`interpret` defaults to True off-TPU (this container is CPU-only; interpret
mode executes the kernel bodies exactly, so correctness tests are real),
and to False on TPU where the Mosaic lowering runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.l2dist import l2dist_pallas
from repro.kernels.l2topk import l2topk_pallas
from repro.kernels.attention import flash_attention_pallas
from repro.kernels.qdist import (
    l2dist_q_pallas,
    l2topk_q_pallas,
    pq_adc_pallas,
    pq_topk_pallas,
)
from repro.kernels.topk import topk_pallas
from repro.kernels.traversal import fused_traversal_pallas

__all__ = ["l2dist", "topk", "l2topk", "l2dist_q", "l2topk_q",
           "pq_adc", "pq_topk",
           "flash_attention", "fused_layer0", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_rows(a, to_rows, fill=0.0):
    pad = to_rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=fill)


def _block_d(d_p: int) -> int:
    """Largest K-block <= 512 that divides the 128-padded feature dim
    (d_p = 640 must not pick 512 — the kernels assert divisibility)."""
    b = min(d_p, 512)
    while d_p % b:
        b -= 128
    return b


@functools.partial(jax.jit, static_argnames=("block_q", "block_x", "interpret",
                                             "metric"))
def l2dist(queries, xs, *, block_q=128, block_x=512, interpret=None,
           metric="l2"):
    """Pairwise distance for arbitrary shapes; returns [Bq, Bx] f32.

    metric: "l2" (squared Euclidean), "ip" (-q.x), or "cosine" (1 - q.x over
    unit-norm inputs) — same registry as repro.api.metrics."""
    interpret = default_interpret() if interpret is None else interpret
    bq, d = queries.shape
    bx, _ = xs.shape
    bq_p, bx_p = _round_up(bq, block_q), _round_up(bx, block_x)
    d_p = _round_up(d, 128)
    q = jnp.pad(queries, ((0, bq_p - bq), (0, d_p - d)))
    x = jnp.pad(xs, ((0, bx_p - bx), (0, d_p - d)))
    out = l2dist_pallas(
        q, x, block_q=block_q, block_x=block_x, block_d=_block_d(d_p),
        interpret=interpret, metric=metric,
    )
    return out[:bq, :bx]


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_x", "interpret"))
def topk(x, k: int, *, block_b=8, block_x=1024, interpret=None):
    """Per-row k smallest of x [B, N] -> (values, ids) ascending."""
    interpret = default_interpret() if interpret is None else interpret
    b, n = x.shape
    b_p, n_p = _round_up(b, block_b), _round_up(n, block_x)
    xp = jnp.pad(x, ((0, b_p - b), (0, n_p - n)), constant_values=jnp.inf)
    v, i = topk_pallas(xp, k, block_b=block_b, block_x=block_x, interpret=interpret)
    return v[:b], i[:b]


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_x", "interpret"))
def l2topk(queries, xs, xsq=None, *, k=10, block_q=128, block_x=1024, interpret=None):
    """Fused exact k-NN: (dists [Bq, k], ids [Bq, k]); xs padding gets +inf."""
    interpret = default_interpret() if interpret is None else interpret
    bq, d = queries.shape
    bx, _ = xs.shape
    bq_p, bx_p = _round_up(bq, block_q), _round_up(bx, block_x)
    d_p = _round_up(d, 128)
    q = jnp.pad(queries, ((0, bq_p - bq), (0, d_p - d)))
    x = jnp.pad(xs, ((0, bx_p - bx), (0, d_p - d)))
    if xsq is None:
        xf = xs.astype(jnp.float32)
        xsq = jnp.einsum("bd,bd->b", xf, xf)
    xsq = jnp.pad(xsq, (0, bx_p - bx), constant_values=jnp.inf)
    v, i = l2topk_pallas(
        q, x, xsq=xsq, k=k, block_q=block_q, block_x=block_x, interpret=interpret
    )
    return v[:bq], i[:bq]


@functools.partial(jax.jit, static_argnames=("block_q", "block_x", "interpret",
                                             "out_scale"))
def l2dist_q(queries, xs, *, block_q=128, block_x=512, interpret=None,
             out_scale=1.0):
    """Integer-code pairwise squared L2 for arbitrary shapes -> [Bq, Bx] f32.

    queries/xs are uint8/int8 codes (IndexSpec.dtype path); out_scale is
    the quantizer's dist_scale (scale**2) for real-space output. Codes are
    zero-padded — pad lanes contribute 0 to every distance."""
    interpret = default_interpret() if interpret is None else interpret
    bq, d = queries.shape
    bx, _ = xs.shape
    bq_p, bx_p = _round_up(bq, block_q), _round_up(bx, block_x)
    d_p = _round_up(d, 128)
    q = jnp.pad(queries, ((0, bq_p - bq), (0, d_p - d)))
    x = jnp.pad(xs, ((0, bx_p - bx), (0, d_p - d)))
    out = l2dist_q_pallas(
        q, x, block_q=block_q, block_x=block_x, block_d=_block_d(d_p),
        interpret=interpret, out_scale=out_scale,
    )
    return out[:bq, :bx]


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_x",
                                             "interpret", "out_scale"))
def l2topk_q(queries, xs, xsq=None, *, k=10, block_q=128, block_x=1024,
             interpret=None, out_scale=1.0):
    """Fused integer k-NN over codes: (dists [Bq, k], ids [Bq, k]).

    The streamed database stays uint8/int8 end to end (4x less traffic
    than the f32 `l2topk`); xs row padding gets +inf via xsq."""
    interpret = default_interpret() if interpret is None else interpret
    bq, d = queries.shape
    bx, _ = xs.shape
    bq_p, bx_p = _round_up(bq, block_q), _round_up(bx, block_x)
    d_p = _round_up(d, 128)
    q = jnp.pad(queries, ((0, bq_p - bq), (0, d_p - d)))
    x = jnp.pad(xs, ((0, bx_p - bx), (0, d_p - d)))
    if xsq is None:
        xf = xs.astype(jnp.float32)
        xsq = jnp.einsum("bd,bd->b", xf, xf)
    xsq = jnp.pad(xsq, (0, bx_p - bx), constant_values=jnp.inf)
    v, i = l2topk_q_pallas(
        q, x, xsq=xsq, k=k, block_q=block_q, block_x=block_x,
        interpret=interpret, out_scale=out_scale,
    )
    return v[:bq], i[:bq]


@functools.partial(jax.jit, static_argnames=("block_q", "block_x",
                                             "interpret"))
def pq_adc(luts, codes, xpad=None, *, block_q=8, block_x=512,
           interpret=None):
    """PQ asymmetric distances for arbitrary shapes -> [Bq, Bx] f32.

    luts are the per-query [M, 256] tables (optim.compression.build_pq_lut);
    codes are [Bx, M] uint8 rows. Optional xpad carries +inf markers for
    database padding rows (padding added here also gets +inf)."""
    interpret = default_interpret() if interpret is None else interpret
    bq = luts.shape[0]
    bx = codes.shape[0]
    bq_p, bx_p = _round_up(bq, block_q), _round_up(bx, block_x)
    lp = _pad_rows(luts, bq_p)
    cp = _pad_rows(codes, bx_p)
    if xpad is None:
        xpad = jnp.zeros((bx,), jnp.float32)
    xp = jnp.pad(xpad, (0, bx_p - bx), constant_values=jnp.inf)
    out = pq_adc_pallas(lp, cp, xp, block_q=block_q, block_x=block_x,
                        interpret=interpret)
    return out[:bq, :bx]


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_x",
                                             "interpret"))
def pq_topk(luts, codes, xpad=None, *, k=10, block_q=8, block_x=1024,
            interpret=None):
    """Fused PQ k-NN over codes: (dists [Bq, k] ascending, ids [Bq, k]).

    The streamed database stays M bytes/row end to end (16x less traffic
    than uint8 at M=8/d=128); padding rows are masked out via +inf."""
    interpret = default_interpret() if interpret is None else interpret
    bq = luts.shape[0]
    bx = codes.shape[0]
    bq_p, bx_p = _round_up(bq, block_q), _round_up(bx, block_x)
    lp = _pad_rows(luts, bq_p)
    cp = _pad_rows(codes, bx_p)
    if xpad is None:
        xpad = jnp.zeros((bx,), jnp.float32)
    xp = jnp.pad(xpad, (0, bx_p - bx), constant_values=jnp.inf)
    v, i = pq_topk_pallas(lp, cp, xp, k=k, block_q=block_q,
                          block_x=block_x, interpret=interpret)
    return v[:bq], i[:bq]


def fused_layer0(vectors, sqnorms, l0_nbrs, queries, qsq,
                 cand_d, cand_i, fin_d, fin_i, visited, hops, calcs, *,
                 fused_hops: int, max_hops: int, metric="l2",
                 interpret=None):
    """One H-hop superstep of the fused layer-0 traversal over the whole
    query batch (kernels/traversal.py — the paper's Fig. 6 engine).

    Unlike the other wrappers, no padding happens here: the restructured
    DB's tables (hnsw_graph.restructure) are already lane-aligned, and the
    beam-state shapes come from SearchParams.resolve. The wrapper exists
    for the interpret dispatch (CPU containers run the kernel body exactly;
    TPU runs the Mosaic lowering) and is called from inside batch_search's
    jit, so it does not re-jit."""
    interpret = default_interpret() if interpret is None else interpret
    return fused_traversal_pallas(
        vectors, sqnorms, l0_nbrs, queries, qsq,
        cand_d, cand_i, fin_d, fin_i, visited, hops, calcs,
        fused_hops=fused_hops, max_hops=max_hops, metric=metric,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=256, block_k=256,
                    interpret=None):
    """Causal flash attention for arbitrary [BH, T, hd]; pads T/S to blocks."""
    interpret = default_interpret() if interpret is None else interpret
    bh, t, hd = q.shape
    s = k.shape[1]
    bq, bk = min(block_q, max(t, 8)), min(block_k, max(s, 8))
    t_p, s_p = _round_up(t, bq), _round_up(s, bk)
    qp = jnp.pad(q, ((0, 0), (0, t_p - t), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, s_p - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, s_p - s), (0, 0)))
    out = flash_attention_pallas(qp, kp, vp, causal=causal, block_q=bq,
                                 block_k=bk, interpret=interpret, s_valid=s)
    return out[:, :t]
