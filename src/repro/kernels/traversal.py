"""Pallas TPU kernel: fused multi-hop layer-0 traversal (paper §5.2, Fig. 6).

The paper's RTL search engine wins by *pipelining* the hop loop next to the
data: neighbor fetch, distance compute, and candidate-list update run as
stages of one persistent engine, and the host is only consulted when the
beam terminates. The hop-stepped JAX path (core/search.py) instead runs one
`lax.while_loop` iteration of small ops per hop — correct, but every hop
re-reads the beam state from HBM and re-dispatches the whole op graph.

This kernel is the jax_pallas analogue of that engine. One invocation:

  * holds the whole beam state in VMEM — candidate list, final list, and
    the packed uint32 visited bitmap (the paper's single-bit visited list,
    §5.1.1) live in per-lane VMEM blocks for the duration;
  * executes ``fused_hops`` (H) layer-0 hops back to back, so the
    while-loop body costs one kernel dispatch per H hops instead of one
    op-graph dispatch per hop;
  * expresses the neighbor-row gather as async copies (`make_async_copy`
    DMAs from the ANY/HBM-resident tables) issued *before* the visited
    test-and-set, so the fetch overlaps the bookkeeping stage exactly like
    the paper's Fig. 6 pipeline overlaps FetchNeighbors with VisitedCheck;
  * applies every hop under a per-lane `live` guard, which makes the
    result bit-identical to the vmapped-while lockstep path: a lane whose
    termination condition fires mid-superstep keeps its state unchanged
    for the remaining unrolled hops.

Bit-parity is load-bearing, so the in-kernel merge/sort are the *same
mathematics* as core/search.py's `merge_sorted` / stable argsort, expressed
as comparison-matrix rank computations (the paper's parallel insertion sort
computes insert positions as popcounts of comparison bit-vectors — §5.2.4):
``searchsorted(b, a, 'left') == #(b_j < a_i)`` and stable-argsort position
``pos_i == #(d_j < d_i) + #(j < i, d_j == d_i)``. Identical outputs, but
matmul/reduction-shaped instead of sort-shaped — which is what lowers on a
TPU. The kernel imports nothing from repro.core (core imports kernels.ops
lazily for dispatch, so the dependency arrow must point one way only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

__all__ = ["fused_traversal_pallas"]


# ---------------------------------------------------------------------------
# In-kernel primitives: rank-based sort/merge + visited bitmap, identical in
# value to core/search.py's argsort/searchsorted/scatter formulations.
# ---------------------------------------------------------------------------


def _metric_dist(metric: str, dot, xsq, qsq):
    """Same formulas as core.search.metric_distance (trace-time branch)."""
    if metric == "l2":
        return jnp.maximum(xsq - 2.0 * dot + qsq, 0.0)
    if metric == "ip":
        return -dot
    if metric == "cosine":
        return 1.0 - dot
    raise ValueError(f"unknown metric {metric!r}")


def _stable_sort_pairs(d, ids):
    """Stable ascending sort of (d, ids) — value-identical to
    ``order = argsort(d, stable=True); d[order], ids[order]``.

    pos_i = #(d_j < d_i) + #(j < i with d_j == d_i) is exactly the slot a
    stable sort assigns; the scatter to sorted order is a one-hot masked
    reduction (pos is a permutation, so each output row selects one lane).
    """
    m = d.shape[0]
    di, dj = d[:, None], d[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    pos = (jnp.sum(dj < di, axis=1, dtype=jnp.int32)
           + jnp.sum((dj == di) & (jj < ii), axis=1, dtype=jnp.int32))
    onehot = pos[None, :] == jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    sd = jnp.sum(jnp.where(onehot, d[None, :], 0.0), axis=1)
    si = jnp.sum(jnp.where(onehot, ids[None, :], 0), axis=1).astype(ids.dtype)
    return sd, si


def _rank_merge(ad, ai, bd, bi):
    """Merge two ascending (dist, id) arrays; ties keep `a` first.

    Value-identical to core.search.merge_sorted: the searchsorted ranks are
    computed as comparison-matrix popcounts (paper §5.2.4's comparison
    bit-vector), and the position scatter as one-hot masked reductions.
    """
    na, nb = ad.shape[0], bd.shape[0]
    n = na + nb
    pa = (jax.lax.broadcasted_iota(jnp.int32, (na,), 0)
          + jnp.sum(bd[None, :] < ad[:, None], axis=1, dtype=jnp.int32))
    pb = (jax.lax.broadcasted_iota(jnp.int32, (nb,), 0)
          + jnp.sum(ad[None, :] <= bd[:, None], axis=1, dtype=jnp.int32))
    rows_a = pa[None, :] == jax.lax.broadcasted_iota(jnp.int32, (n, na), 0)
    rows_b = pb[None, :] == jax.lax.broadcasted_iota(jnp.int32, (n, nb), 0)
    od = (jnp.sum(jnp.where(rows_a, ad[None, :], 0.0), axis=1)
          + jnp.sum(jnp.where(rows_b, bd[None, :], 0.0), axis=1))
    oi = (jnp.sum(jnp.where(rows_a, ai[None, :], 0), axis=1)
          + jnp.sum(jnp.where(rows_b, bi[None, :], 0), axis=1)).astype(ai.dtype)
    return od, oi


def _visited_tas(vis, ids, valid):
    """core.search.visited_test_and_set on a VMEM-resident value: `ids`
    must be unique where valid (the restructured DB's de-duplicated rows),
    so the scatter-add of distinct bits within a word equals bitwise OR."""
    w = jax.lax.shift_right_logical(ids, 5)
    b = (ids & 31).astype(jnp.uint32)
    bit = jax.lax.shift_left(jnp.uint32(1), b)
    old = vis[w]
    was = (jax.lax.shift_right_logical(old, b) & jnp.uint32(1)) > 0
    was = was | ~valid
    add = jnp.where(~was, bit, jnp.uint32(0))
    return was, vis.at[w].add(add)


# ---------------------------------------------------------------------------
# The kernel: one grid step == one query lane, H hops per invocation
# ---------------------------------------------------------------------------


def _make_kernel(fused_hops: int, max_hops: int, metric: str, maxM0: int):
    H, M0 = fused_hops, maxM0

    def kernel(qsq_ref, q_ref, cand_d_ref, cand_i_ref, fin_d_ref, fin_i_ref,
               vis_ref, hops_ref, calcs_ref, vec_ref, sq_ref, nbr_ref,
               ocand_d_ref, ocand_i_ref, ofin_d_ref, ofin_i_ref, ovis_ref,
               ohops_ref, ocalcs_ref,
               nbr_s, vec_s, sq_s, nbr_sem, vec_sem, sq_sem):
        q = q_ref[0, :]
        qsq = qsq_ref[0, 0]
        cand_d = cand_d_ref[0, :]
        cand_i = cand_i_ref[0, :]
        fin_d = fin_d_ref[0, :]
        fin_i = fin_i_ref[0, :]
        vis = vis_ref[0, :]
        hops = hops_ref[0, 0]
        calcs = calcs_ref[0, 0]
        C, EF = cand_d.shape[0], fin_d.shape[0]

        for _ in range(H):                       # static unroll: H hops
            # Algorithm 1 lines 2&5 — the same per-lane guard the batched
            # while_loop applies; a lane done mid-superstep stays frozen.
            live = (cand_d[0] < fin_d[-1]) & (hops < max_hops)
            c = jnp.maximum(cand_i[0], 0)        # frozen lanes fetch row 0

            # stage 1 (Fig. 6 FetchNeighbors): DMA the popped node's
            # neighbor row; the pop shift proceeds while it is in flight
            ncp = pltpu.make_async_copy(
                nbr_ref.at[pl.ds(c, 1), :], nbr_s, nbr_sem)
            ncp.start()
            pcand_d = jnp.roll(cand_d, -1).at[-1].set(jnp.inf)
            pcand_i = jnp.roll(cand_i, -1).at[-1].set(-1)
            ncp.wait()
            nbrs = nbr_s[0, :]
            valid = nbrs >= 0
            safe = jnp.where(valid, nbrs, 0)

            # stage 2 (FetchVectors): per-neighbor row DMAs from the
            # ANY-resident raw-data/index tables, overlapped with the
            # visited test-and-set below (pad lanes fetch row 0 — their
            # distance is masked to +inf, so the tile content is inert)
            copies = []
            for m in range(M0):
                vcp = pltpu.make_async_copy(
                    vec_ref.at[pl.ds(safe[m], 1), :],
                    vec_s.at[pl.ds(m, 1), :], vec_sem.at[m])
                scp = pltpu.make_async_copy(
                    sq_ref.at[pl.ds(safe[m], 1), :],
                    sq_s.at[pl.ds(m, 1), :], sq_sem.at[m])
                vcp.start()
                scp.start()
                copies.append((vcp, scp))

            # stage 3 (VisitedCheck, §5.1.1): packed-bitmap test-and-set on
            # the VMEM-resident bitmap while the vector rows stream in
            was, vis2 = _visited_tas(vis, safe, valid)
            act = valid & ~was

            for vcp, scp in copies:
                vcp.wait()
                scp.wait()

            # stage 4 (DistCompute): whole neighbor list at once — the
            # 8x16-PE distance array analogue; codes cast to f32. mul+sum
            # (not `vecs @ q`) so the reduction order is bitwise-identical
            # to _batch_distances in core/search.py — a matvec's order is
            # context-dependent, an explicit axis reduction is not.
            vecs = vec_s[...].astype(jnp.float32)
            d = _metric_dist(metric, jnp.sum(vecs * q, axis=-1),
                             sq_s[...][:, 0], qsq)
            d = jnp.where(act, d, jnp.inf)
            ncalcs = calcs + jnp.sum(act)
            # line 11 guard: only candidates that can enter the final list
            d = jnp.where(d < fin_d[-1], d, jnp.inf)
            ids = jnp.where(jnp.isfinite(d), safe, -1)

            # stage 5 (ListUpdate, §5.2.4): rank-based parallel insertion
            bd, bi = _stable_sort_pairs(d, ids)
            fd, fi = _rank_merge(fin_d, fin_i, bd, bi)
            cd, ci = _rank_merge(pcand_d, pcand_i, bd, bi)

            cand_d = jnp.where(live, cd[:C], cand_d)
            cand_i = jnp.where(live, ci[:C], cand_i)
            fin_d = jnp.where(live, fd[:EF], fin_d)
            fin_i = jnp.where(live, fi[:EF], fin_i)
            vis = jnp.where(live, vis2, vis)
            hops = hops + live.astype(jnp.int32)
            calcs = jnp.where(live, ncalcs, calcs)

        ocand_d_ref[0, :] = cand_d
        ocand_i_ref[0, :] = cand_i
        ofin_d_ref[0, :] = fin_d
        ofin_i_ref[0, :] = fin_i
        ovis_ref[0, :] = vis
        ohops_ref[0, 0] = hops
        ocalcs_ref[0, 0] = calcs

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("fused_hops", "max_hops", "metric", "interpret"),
)
def fused_traversal_pallas(
    vectors,              # [N, D_pad] f32 or integer codes (ANY/HBM)
    sqnorms,              # [N] f32 (+inf pad markers)
    l0_nbrs,              # [N, maxM0_pad] int32, -1-padded unique rows
    queries,              # [B, D_pad] f32
    qsq,                  # [B] f32
    cand_d,               # [B, C] f32 ascending, +inf padded
    cand_i,               # [B, C] int32, -1 padded
    fin_d,                # [B, EF] f32
    fin_i,                # [B, EF] int32
    visited,              # [B, W] uint32 packed bitmap, W = ceil(N/32)
    hops,                 # [B] int32
    calcs,                # [B] int32
    *,
    fused_hops: int,
    max_hops: int,
    metric: str = "l2",
    interpret: bool = True,
):
    """Advance every lane of the beam state by up to `fused_hops` hops.

    Returns the updated (cand_d, cand_i, fin_d, fin_i, visited, hops,
    calcs) — bit-identical to `fused_hops` iterations of the hop-stepped
    lockstep body, including the per-lane termination guard.
    """
    B, D = queries.shape
    N, M0 = l0_nbrs.shape
    C, EF, W = cand_d.shape[1], fin_d.shape[1], visited.shape[1]
    lane = lambda w: pl.BlockSpec((1, w), lambda i: (i, 0))  # noqa: E731
    outs = pl.pallas_call(
        _make_kernel(fused_hops, max_hops, metric, M0),
        grid=(B,),
        in_specs=[
            lane(1), lane(D), lane(C), lane(C), lane(EF), lane(EF),
            lane(W), lane(1), lane(1),
            pl.BlockSpec(memory_space=pl.ANY),   # vectors
            pl.BlockSpec(memory_space=pl.ANY),   # sqnorms [N, 1]
            pl.BlockSpec(memory_space=pl.ANY),   # l0_nbrs
        ],
        out_specs=[lane(C), lane(C), lane(EF), lane(EF), lane(W),
                   lane(1), lane(1)],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.int32),
            jax.ShapeDtypeStruct((B, EF), jnp.float32),
            jax.ShapeDtypeStruct((B, EF), jnp.int32),
            jax.ShapeDtypeStruct((B, W), jnp.uint32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, M0), jnp.int32),      # neighbor row landing pad
            pltpu.VMEM((M0, D), vectors.dtype),  # gathered vector rows
            pltpu.VMEM((M0, 1), jnp.float32),    # gathered sqnorm rows
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((M0,)),
            pltpu.SemaphoreType.DMA((M0,)),
        ],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(qsq[:, None], queries, cand_d, cand_i, fin_d, fin_i, visited,
      hops[:, None], calcs[:, None], vectors, sqnorms.reshape(N, 1),
      l0_nbrs)
    ncand_d, ncand_i, nfin_d, nfin_i, nvis, nhops, ncalcs = outs
    return (ncand_d, ncand_i, nfin_d, nfin_i, nvis,
            nhops[:, 0], ncalcs[:, 0])
