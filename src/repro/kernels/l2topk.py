"""Pallas TPU kernel: FUSED pairwise-L2 + top-k ("never spill the matrix").

This is the paper's central memory lesson (§5.2/§6.2: the RTL design wins by
*minimizing external memory accesses*) applied to the brute-force/stage-2
path: computing D2[B, N] to HBM and re-reading it for top-k costs
2*B*N*4 bytes of traffic that the fusion eliminates entirely. Each grid step
computes one (block_q x block_x) distance tile in VMEM from a single MXU
matmul and immediately folds it into the per-row running top-k scratch.

The arithmetic-intensity argument: for D=128, k=10 the unfused pipeline moves
~8 bytes/FLOP/lane of distance-matrix traffic; fused, the only HBM traffic is
the streamed database (read once) and the [B, k] result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

from repro.kernels.topk import _select_k

__all__ = ["l2topk_pallas"]


def _make_kernel(k: int, block_x: int):
    def _kernel(qsq_ref, xsq_ref, q_ref, x_ref, out_v_ref, out_i_ref, run_v, run_i):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            run_v[...] = jnp.full_like(run_v, jnp.inf)
            run_i[...] = jnp.full_like(run_i, -1)

        q = q_ref[...].astype(jnp.float32)                  # [bq, D]
        x = x_ref[...].astype(jnp.float32)                  # [bx, D]
        d2 = qsq_ref[...][:, None] + xsq_ref[...][None, :] - 2.0 * jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        d2 = jnp.maximum(d2, 0.0)                           # +inf padding survives
        cols = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) + j * block_x
        bv, bi = _select_k(d2, cols, k)
        cat_v = jnp.concatenate([run_v[...], bv], axis=1)
        cat_i = jnp.concatenate([run_i[...], bi], axis=1)
        mv, mi = _select_k(cat_v, cat_i, k)
        run_v[...] = mv
        run_i[...] = mi

        @pl.when(j == pl.num_programs(1) - 1)
        def _flush():
            out_v_ref[...] = run_v[...]
            out_i_ref[...] = run_i[...]

    return _kernel


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_x", "interpret")
)
def l2topk_pallas(
    queries,              # [Bq, D]
    xs,                   # [Bx, D]
    qsq=None,
    xsq=None,             # +inf marks database padding rows
    *,
    k: int = 10,
    block_q: int = 128,
    block_x: int = 1024,
    interpret: bool = True,
):
    """Returns (dists [Bq, k] ascending, ids [Bq, k] int32) — exact top-k."""
    bq, d = queries.shape
    bx, _ = xs.shape
    assert bq % block_q == 0 and bx % block_x == 0
    if qsq is None:
        qsq = jnp.einsum("bd,bd->b", queries.astype(jnp.float32), queries.astype(jnp.float32))
    if xsq is None:
        xsq = jnp.einsum("bd,bd->b", xs.astype(jnp.float32), xs.astype(jnp.float32))
    grid = (bq // block_q, bx // block_x)
    return pl.pallas_call(
        _make_kernel(k, block_x),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
            pl.BlockSpec((block_x,), lambda i, j: (j,)),
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_x, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qsq, xsq, queries, xs)
