"""Pallas TPU kernel: blocked pairwise squared-L2 distance.

TPU adaptation of the paper's distance calculator (§5.2.5): the FPGA uses
8 units x 16 PEs + adder trees to consume one 128-dim vector pair per cycle;
the MXU-native formulation is

    D2[i, j] = ||q_i||^2 + ||x_j||^2 - 2 * Q @ X^T

i.e. one 128x128 systolic matmul per (block_q x block_x x block_d) tile with
the norm terms added on the first K-step. Blocks are sized so a
(block_q x block_d) query tile, a (block_x x block_d) database tile and the
f32 accumulator tile all fit VMEM, and every matmul dim is a multiple of the
128-lane / 8-sublane hardware tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

__all__ = ["l2dist_pallas"]


def _kernel(qsq_ref, xsq_ref, q_ref, x_ref, out_ref, *, metric):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # metric-specific constant term; the dot-product accumulation below
        # is shared. l2: ||q||^2 + ||x||^2 - 2 q.x; ip: -q.x; cosine
        # (unit-norm inputs): 1 - q.x.
        if metric == "l2":
            out_ref[...] = qsq_ref[...][:, None] + xsq_ref[...][None, :]
        elif metric == "cosine":
            out_ref[...] = jnp.ones_like(out_ref[...])
        else:
            out_ref[...] = jnp.zeros_like(out_ref[...])

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    scale = -2.0 if metric == "l2" else -1.0
    out_ref[...] += scale * jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_x", "block_d", "interpret", "metric"),
)
def l2dist_pallas(
    queries,          # [Bq, D]
    xs,               # [Bx, D]
    qsq=None,         # [Bq] optional precomputed ||q||^2
    xsq=None,         # [Bx] optional precomputed ||x||^2 (+inf marks padding)
    *,
    block_q: int = 128,
    block_x: int = 512,
    block_d: int = 128,
    interpret: bool = True,
    metric: str = "l2",
):
    """Returns D[Bq, Bx] float32 under `metric` (l2 / ip / cosine; cosine
    assumes unit-norm inputs). Dims must divide by the block sizes
    (ops.l2dist pads arbitrary shapes before calling this)."""
    bq, d = queries.shape
    bx, _ = xs.shape
    assert bq % block_q == 0 and bx % block_x == 0 and d % block_d == 0
    if qsq is None:
        qsq = jnp.einsum("bd,bd->b", queries.astype(jnp.float32), queries.astype(jnp.float32))
    if xsq is None:
        xsq = jnp.einsum("bd,bd->b", xs.astype(jnp.float32), xs.astype(jnp.float32))
    grid = (bq // block_q, bx // block_x, d // block_d)
    return pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_x,), lambda i, j, k: (j,)),
            pl.BlockSpec((block_q, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_x, block_d), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_q, block_x), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bq, bx), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qsq, xsq, queries, xs)
