"""Pallas TPU kernel: causal flash attention (the LM substrate's hotspot).

Target layout: one (batch*head, q-block) program per grid cell, streaming KV
blocks through VMEM with the running-softmax carried in scratch — the same
schedule as models/layers.blockwise_attn (its jnp twin / oracle), but with
explicit BlockSpec tiling so on TPU the scores tile lives in VMEM and each
(bq x hd) @ (hd x bk) product maps onto the MXU.

Causal block skipping is structural here: the kernel masks per-element and
relies on the grid executing kj <= qi blocks usefully; fully-future blocks
contribute nothing and are skipped with pl.when (no MXU issue at all) —
the Pallas rendition of the §Perf `skip_masked_blocks` lever.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _make_kernel(bq: int, bk: int, causal: bool, scale: float,
                 s_valid: int):
    def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        qi = pl.program_id(1)
        kj = pl.program_id(2)

        @pl.when(kj == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        live = (not causal) or (kj * bk <= qi * bq + bq - 1)

        @pl.when(live)
        def _compute():
            q = q_ref[0].astype(jnp.float32)            # [bq, hd]
            k = k_ref[0].astype(jnp.float32)            # [bk, hd]
            v = v_ref[0].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            col = kj * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(col < s_valid, s, NEG_INF)   # key padding
            if causal:
                row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
                s = jnp.where(col <= row, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
            acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
                p, v, preferred_element_type=jnp.float32)
            m_scr[...] = m_new

        @pl.when(kj == pl.num_programs(2) - 1)
        def _flush():
            o_ref[0] = (acc_scr[...] /
                        jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)

    return _kernel


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret",
                              "s_valid"))
def flash_attention_pallas(
    q,                     # [BH, T, hd]  (batch*heads flattened)
    k,                     # [BH, S, hd]
    v,                     # [BH, S, hd]
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,
    s_valid: int | None = None,
):
    """Returns [BH, T, hd]. T % block_q == 0 and S % block_k == 0 (the ops
    wrapper pads; s_valid masks padded key columns)."""
    bh, t, hd = q.shape
    _, s, _ = k.shape
    assert t % block_q == 0 and s % block_k == 0
    scale = 1.0 / math.sqrt(hd)
    grid = (bh, t // block_q, s // block_k)
    return pl.pallas_call(
        _make_kernel(block_q, block_k, causal, scale,
                     s if s_valid is None else s_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
