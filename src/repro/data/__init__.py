from repro.data.pipeline import (
    TokenDataset, VectorDataset, make_batch, sift_like_vectors, clustered_vectors,
)

__all__ = ["TokenDataset", "VectorDataset", "make_batch",
           "sift_like_vectors", "clustered_vectors"]
