"""Deterministic synthetic data pipelines.

Determinism contract: batch contents are a pure function of (seed, step),
independent of worker count or restart point. This is what makes
checkpoint-restart bit-exact (tests/test_runtime.py) and is the standard
large-fleet reproducibility discipline — a restarted job replays the exact
token stream.

Vector datasets mirror SIFT's statistics (128-dim uint8-range features,
clustered) so ANN recall numbers are meaningful without the 119 GB download.
"""

from __future__ import annotations

import dataclasses
import threading
import queue as _queue

import numpy as np

__all__ = ["TokenDataset", "VectorDataset", "make_batch",
           "sift_like_vectors", "clustered_vectors", "Prefetcher"]


@dataclasses.dataclass
class TokenDataset:
    """Synthetic LM token stream with Zipfian unigram statistics plus a
    repeated-ngram structure so the loss actually decreases."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_output_heads: int = 1

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        """Per-step batch; `shard` selects this host's slice."""
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        # Zipf over vocab, clipped.
        raw = rng.zipf(1.3, size=(b, self.seq_len + 1, self.num_output_heads))
        toks = (raw % self.vocab_size).astype(np.int32)
        # inject copy structure: second half repeats the first half shifted.
        half = self.seq_len // 2
        toks[:, half : 2 * half] = toks[:, :half]
        if self.num_output_heads == 1:
            toks = toks[..., 0]
            return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
        return {"inputs": toks[:, :-1, 0], "labels": toks[:, 1:, :]}


@dataclasses.dataclass
class VectorDataset:
    """Clustered feature vectors (SIFT-like)."""

    n: int
    dim: int = 128
    n_clusters: int = 64
    seed: int = 0

    def vectors(self) -> np.ndarray:
        return clustered_vectors(self.n, self.dim, self.n_clusters, self.seed)

    def queries(self, n_q: int, seed: int = 1) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, seed]))
        centers = _centers(self.n_clusters, self.dim, self.seed)
        idx = rng.integers(0, self.n_clusters, n_q)
        return (centers[idx] + rng.normal(scale=12.0, size=(n_q, self.dim))
                ).astype(np.float32)


def _centers(k: int, dim: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xC]))
    return rng.uniform(0, 218, size=(k, dim)).astype(np.float32)


def clustered_vectors(n: int, dim: int = 128, k: int = 64, seed: int = 0):
    """SIFT-like: non-negative, bounded [0, 255], clustered."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 1]))
    centers = _centers(k, dim, seed)
    idx = rng.integers(0, k, n)
    out = centers[idx] + rng.normal(scale=12.0, size=(n, dim))
    return np.clip(out, 0, 255).astype(np.float32)


def sift_like_vectors(n: int, seed: int = 0) -> np.ndarray:
    return clustered_vectors(n, 128, max(8, n // 2000), seed)


def make_batch(cfg, shape_kind: str, seq: int, batch: int, step: int = 0,
               seed: int = 0):
    """Concrete batch for a ModelConfig (embeds for stub-frontend archs)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if cfg.embed_inputs:
        ds = TokenDataset(cfg.vocab_size, seq, batch, seed,
                          cfg.num_output_heads)
        return ds.batch(step)
    emb = rng.normal(scale=0.02, size=(batch, seq, cfg.d_model)).astype(np.float32)
    if cfg.num_output_heads == 1:
        labels = rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
    else:
        labels = rng.integers(0, cfg.vocab_size,
                              (batch, seq, cfg.num_output_heads)).astype(np.int32)
    out = {"inputs": emb, "labels": labels}
    if cfg.prefix_lm:
        out["prefix_len"] = np.int32(min(256, seq // 4))
    return out


class Prefetcher:
    """Background-thread batch prefetch (depth-bounded queue)."""

    def __init__(self, fn, depth: int = 2, start_step: int = 0):
        self._fn = fn
        self._q: _queue.Queue = _queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._fn(self._step), timeout=0.5)
                self._step += 1
            except _queue.Full:
                continue

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
