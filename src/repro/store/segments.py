"""Segment directory of a *mutable* block store (repro.ingest's flash side).

An immutable store is one block file (`store/blockfile.py`). A mutable
index instead owns a directory of them — one committed block store per
sealed segment — plus one `segments.json` naming the live set:

    <dir>/segments.json         {"format": ..., "version": N,
                                 "segments": ["seg_00000000", ...]}
    <dir>/seg_00000000/         a normal committed block store
    <dir>/seg_00000001/         ...

Append-only by construction: sealing a memtable writes a NEW segment store
(its own data file, manifest, and commit marker — existing segment blocks
are never rewritten) and then atomically swaps `segments.json` to include
it. Compaction writes the merged segment the same way and swaps the old
names out in one manifest update; only after the swap are the dead
segment directories deleted. A crash at any point leaves either the old
or the new manifest, both of which name only fully-committed stores.
"""

from __future__ import annotations

import json
import os
import shutil

from repro.store.blockfile import COMMIT_NAME, StoreFormatError

__all__ = ["SEGMENTS_MANIFEST", "SEGMENTS_FORMAT", "segment_dir",
           "list_segments", "append_segment", "replace_segments"]

SEGMENTS_MANIFEST = "segments.json"
SEGMENTS_FORMAT = "repro-segmented-store-v1"


def segment_dir(path: str, name: str) -> str:
    """The on-disk directory of one named segment store."""
    return os.path.join(path, name)


def _read(path: str) -> dict:
    mf = os.path.join(path, SEGMENTS_MANIFEST)
    if not os.path.exists(mf):
        return {"format": SEGMENTS_FORMAT, "version": 0, "segments": []}
    with open(mf) as f:
        manifest = json.load(f)
    if manifest.get("format") != SEGMENTS_FORMAT:
        raise StoreFormatError(
            f"segmented store at {path!r} has format "
            f"{manifest.get('format')!r}; this build reads "
            f"{SEGMENTS_FORMAT!r}")
    return manifest


def _write(path: str, manifest: dict) -> None:
    """Atomic manifest swap: full tmp write + fsync + rename."""
    os.makedirs(path, exist_ok=True)
    mf = os.path.join(path, SEGMENTS_MANIFEST)
    tmp = mf + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, mf)


def list_segments(path: str) -> list[str]:
    """Live segment names, in append order (oldest first)."""
    return list(_read(path)["segments"])


def _check_committed(path: str, name: str) -> None:
    if not os.path.exists(os.path.join(segment_dir(path, name), COMMIT_NAME)):
        raise StoreFormatError(
            f"segment {name!r} under {path!r} has no commit marker — "
            f"refusing to publish a partial write")


def append_segment(path: str, name: str) -> list[str]:
    """Publish one newly-written (committed) segment store; returns the
    live set. Existing segment blocks are untouched — this is the
    append-friendly grow path of the mutable index."""
    manifest = _read(path)
    if name in manifest["segments"]:
        raise ValueError(f"segment {name!r} already published")
    _check_committed(path, name)
    manifest["segments"].append(name)
    manifest["version"] += 1
    _write(path, manifest)
    return list(manifest["segments"])


def replace_segments(path: str, old: list[str], new: list[str]) -> list[str]:
    """Compaction commit: atomically swap `old` names for `new` ones, then
    reclaim the dead segment directories. The manifest swap is the commit
    point — a crash before it keeps the old set, after it the new one."""
    manifest = _read(path)
    live = manifest["segments"]
    missing = [s for s in old if s not in live]
    if missing:
        raise ValueError(f"cannot replace unpublished segments {missing}")
    for name in new:
        _check_committed(path, name)
    manifest["segments"] = [s for s in live if s not in old] + list(new)
    manifest["version"] += 1
    _write(path, manifest)
    for name in old:                       # space reclaim, post-commit
        shutil.rmtree(segment_dir(path, name), ignore_errors=True)
    return list(manifest["segments"])
