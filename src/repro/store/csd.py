"""Out-of-core two-stage search over the block store (the `csd` backend).

This is the repo's model of the paper's computational-storage dataflow: the
restructured DB lives on "flash" (the block store), a small PageCache
stands in for the SmartSSD DRAM, and only block-granular reads flow to the
compute side — host memory stays bounded by `cache_bytes` no matter how
large the dataset is.

The traversal is the *same algorithm* as the accelerator-resident kernel
(core/search.py), re-driven from the host so every data access becomes a
batched block read:

  per hop : pop the best candidates for the whole query batch in lockstep,
            read their neighbor-list rows (layer-0 table), test the visited
            bitmap, read only the unvisited neighbors' vector + sqnorm rows
            (raw-data + index tables), and feed the gathered tile to a
            jitted hop kernel built from the SAME primitives the device
            kernel uses (`metric_distance`, `merge_sorted`) — so the csd
            backend returns bit-identical top-k to the `partitioned`
            backend at equal ef/K/metric.

Stage 2 (`rerank=True`) gathers the candidate vectors back from the store
and re-scores them with `api.rerank.batched_rerank` over a compact,
monotonically-remapped id space — again exactly matching the in-memory
backends. The async Prefetcher overlaps hop t+1's neighbor-block fetches
with hop t's device compute (paper §5.2).

Quantized stores (IndexSpec.dtype uint8/int8 — the paper's SIFT1B regime):
the raw-data table holds 1-byte codes, so every vector row is 4x smaller
and `QueryStats.bytes_read` drops accordingly — this is exactly why the
paper's billion-point database fits the SmartSSD. The traversal runs in
code space (gathered tiles cast to f32, same as the resident kernel),
stage-1 distances are rescaled by `scale**2` at the edge, and stage-2
rerank dequantizes the gathered rows back to float32.

Product-quantized stores (IndexSpec.dtype "pq"): the raw-data table holds
M-byte PQ code rows (16x smaller than uint8 at M=8/d=128) and every hop
kernel takes the per-query [M, 256] ADC LUT instead of (q, qsq) — each
distance is the `core.search.pq_lut_distances` gather + sum, so the csd
traversal stays bit-identical to the in-memory PQ backends. Stage 1 skips
the sqnorm reads entirely (ADC needs no norms), the superstep shadow
predicts pops with a numpy twin of the same LUT (prediction-only:
mispredictions roll back exactly like the f32 path), and stage-2 rerank
reads TRUE float32 rows back from the extra `rerank_vectors` table —
reranking over decoded PQ rows would recover nothing, since ADC already
IS the distance to the reconstruction.
"""

from __future__ import annotations

import functools
import threading
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioned import (build_partitioned_db, merge_topk,
                                    quantize_db_vectors)
from repro.core.search import (SearchParams, bitmap_words, merge_sorted,
                               metric_distance, pq_lut_distances)
from repro.optim.compression import build_pq_lut
from repro.obs.metrics import REGISTRY, next_uid
from repro.obs.trace import TRACER
from repro.store.layout import StoreReader, open_store, write_store

if typing.TYPE_CHECKING:  # repro.api imports this module to register the
    from repro.api.types import IndexSpec  # backend — keep runtime acyclic
                                           # by importing api lazily

__all__ = ["CSDBackend", "store_search"]


# ---------------------------------------------------------------------------
# Jitted hop kernels — the device-side compute fed by store gathers.
# The arithmetic mirrors core/search.py line for line; gathers that the
# resident kernel does from HBM arrive here as host-assembled tiles.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("metric",))
def _query_prep(q, ep_vec, ep_sq, metric):
    """qsq per query + distance to the partition entry point."""
    def one(qq):
        qsq = qq @ qq
        ep_d = metric_distance(metric, jnp.sum(ep_vec * qq, axis=-1),
                               ep_sq, qsq)
        return qsq, ep_d
    return jax.vmap(one)(q)


@jax.jit
def _query_prep_pq(luts, ep_code):
    """ADC distance to the partition entry point, per query (dtype="pq").
    Same expression as `_greedy_upper`'s PQ entry distance, so the bits
    match the in-memory backends."""
    return jax.vmap(lambda lut: pq_lut_distances(lut, ep_code[None])[0])(luts)


@functools.partial(jax.jit, static_argnames=("metric",))
def _upper_step(improved, c, c_d, calcs, nbrs, valid, vecs, sqs, q, qsq,
                metric, lut=None):
    """One lockstep greedy hop in an upper layer (cf. _greedy_upper).

    With `lut` set (dtype="pq") `vecs` holds the gathered [M0, M] uint8
    code tiles and the distance is the LUT gather + sum; sqs/q/qsq ride
    along unused."""
    def one(improved, c, c_d, calcs, nbrs, valid, vecs, sqs, qq, qsq,
            *lut):
        if lut:
            d = pq_lut_distances(lut[0], vecs)
        else:
            d = metric_distance(metric, jnp.sum(vecs * qq, axis=-1), sqs,
                                qsq)
        d = jnp.where(valid, d, jnp.inf)
        safe = jnp.where(valid, nbrs, 0)
        j = jnp.argmin(d)
        best_d, best = d[j], safe[j]
        imp = best_d < c_d
        sel = lambda n, o: jnp.where(improved, n, o)
        return (sel(jnp.where(imp, best, c), c),
                sel(jnp.where(imp, best_d, c_d), c_d),
                improved & imp,
                sel(calcs + jnp.sum(valid), calcs))
    extra = () if lut is None else (lut,)
    return jax.vmap(one)(improved, c, c_d, calcs, nbrs, valid, vecs, sqs,
                         q, qsq, *extra)


@functools.partial(jax.jit, static_argnames=("metric",))
def _layer0_step(active, cand_d, cand_i, fin_d, fin_i, hops, calcs,
                 nbrs, act, vecs, sqs, q, qsq, metric, lut=None):
    """One lockstep beam hop at layer 0 (cf. _search_layer0's body).

    `act` = neighbor lanes that are valid AND unvisited — the visited
    bitmap is tested/updated on the host so only unvisited neighbors'
    vectors were read from the store (the paper's single-bit visited list
    as a flash-read filter). With `lut` set (dtype="pq"), `vecs` holds
    uint8 code tiles and the distance is the LUT gather + sum."""
    EF = fin_d.shape[-1]
    C = cand_d.shape[-1]

    def one(active, cand_d, cand_i, fin_d, fin_i, hops, calcs,
            nbrs, act, vecs, sqs, qq, qsq, *lut):
        ncand_d = jnp.roll(cand_d, -1).at[-1].set(jnp.inf)
        ncand_i = jnp.roll(cand_i, -1).at[-1].set(-1)
        # mul+sum matches core/search.py's _batch_distances bit for bit —
        # see the note there on matvec reduction-order instability; the PQ
        # branch is the one pq_lut_distances accumulation for the same
        # reason
        if lut:
            d = pq_lut_distances(lut[0], vecs)
        else:
            d = metric_distance(metric, jnp.sum(vecs * qq, axis=-1), sqs,
                                qsq)
        d = jnp.where(act, d, jnp.inf)
        ncalcs = calcs + jnp.sum(act)
        d = jnp.where(d < fin_d[-1], d, jnp.inf)
        safe = jnp.where(act, nbrs, 0)
        ids = jnp.where(jnp.isfinite(d), safe, -1)
        order = jnp.argsort(d, stable=True)
        bd, bi = d[order], ids[order]
        fd, fi = merge_sorted(fin_d, fin_i, bd, bi)
        cd, ci = merge_sorted(ncand_d, ncand_i, bd, bi)
        sel = lambda n, o: jnp.where(active, n, o)
        return (sel(cd[:C], cand_d), sel(ci[:C], cand_i),
                sel(fd[:EF], fin_d), sel(fi[:EF], fin_i),
                hops + active.astype(hops.dtype),
                sel(ncalcs, calcs))
    extra = () if lut is None else (lut,)
    return jax.vmap(one)(active, cand_d, cand_i, fin_d, fin_i, hops, calcs,
                         nbrs, act, vecs, sqs, q, qsq, *extra)


@functools.partial(jax.jit, static_argnames=("metric", "max_hops"))
def _layer0_superstep(cand_d, cand_i, fin_d, fin_i, hops, calcs,
                      spec, nbrs, act, vecs, sqs, q, qsq, metric,
                      max_hops, lut=None):
    """Replay up to H speculated beam hops in ONE dispatch (cf. the per-hop
    `_layer0_step`) — the csd half of the fused traversal (paper Fig. 6).

    The host plans a whole superstep ahead of time: it simulates the pop
    sequence in numpy, performs the visited test-and-set and the batched
    store reads for all H hops, and hands the kernel per-hop tiles
    (`spec[h]` = predicted pop, -1 where the simulation saw the lane
    terminate; `nbrs/act/vecs/sqs[h]` = that hop's neighbor row, unvisited
    mask, and gathered rows). The kernel *validates* each hop before
    applying it: hop h of a lane counts only while every prior hop
    matched, the lane is live by the device-state termination test, and
    the device candidate head equals the speculated pop. The visited
    evolution (hence `act` and the tiles) depends only on the pop
    sequence, never on distance values, so a validated hop is bit-exact —
    the arithmetic here is the same mul+sum / stable-argsort /
    `merge_sorted` as the hop-stepped kernel. Hop 0 is planned from synced
    device state, so every active lane advances at least one hop per
    superstep; the rare ulp-level mispredictions (numpy's reduction vs
    XLA's flipping a near-tie) stop the replay early and the host rolls
    the speculation back. Returns the per-lane count of applied hops so
    the host can do exactly that."""
    H = spec.shape[-1]
    EF = fin_d.shape[-1]
    C = cand_d.shape[-1]

    def one(cand_d, cand_i, fin_d, fin_i, hops, calcs,
            spec, nbrs, act, vecs, sqs, qq, qsq, *lut):
        ok = jnp.bool_(True)
        applied = jnp.int32(0)
        for h in range(H):                       # static unroll
            live = (cand_d[0] < fin_d[-1]) & (hops < max_hops)
            sim_live = spec[h] >= 0
            match = live & sim_live & (cand_i[0] == spec[h])
            app = ok & match
            # a terminated lane the simulation also saw terminate stays
            # valid (frozen); any live/spec disagreement ends the replay
            ok = ok & (match | (~live & ~sim_live))
            ncand_d = jnp.roll(cand_d, -1).at[-1].set(jnp.inf)
            ncand_i = jnp.roll(cand_i, -1).at[-1].set(-1)
            if lut:
                d = pq_lut_distances(lut[0], vecs[h])
            else:
                d = metric_distance(metric, jnp.sum(vecs[h] * qq, axis=-1),
                                    sqs[h], qsq)
            d = jnp.where(act[h], d, jnp.inf)
            ncalcs = calcs + jnp.sum(act[h])
            d = jnp.where(d < fin_d[-1], d, jnp.inf)
            safe = jnp.where(act[h], nbrs[h], 0)
            ids = jnp.where(jnp.isfinite(d), safe, -1)
            order = jnp.argsort(d, stable=True)
            bd, bi = d[order], ids[order]
            fd, fi = merge_sorted(fin_d, fin_i, bd, bi)
            cd, ci = merge_sorted(ncand_d, ncand_i, bd, bi)
            sel = lambda n, o: jnp.where(app, n, o)
            cand_d, cand_i = sel(cd[:C], cand_d), sel(ci[:C], cand_i)
            fin_d, fin_i = sel(fd[:EF], fin_d), sel(fi[:EF], fin_i)
            hops = hops + app.astype(hops.dtype)
            calcs = sel(ncalcs, calcs)
            applied = applied + app.astype(jnp.int32)
        return cand_d, cand_i, fin_d, fin_i, hops, calcs, applied
    extra = () if lut is None else (lut,)
    return jax.vmap(one)(cand_d, cand_i, fin_d, fin_i, hops, calcs,
                         spec, nbrs, act, vecs, sqs, q, qsq, *extra)


def _metric_dist_np(metric: str, dot, xsq, qsq):
    """numpy twin of metric_distance — only used to *predict* the pop
    sequence for superstep planning; every applied decision is re-made on
    device, so a last-ulp disagreement costs a shorter superstep, never a
    wrong result."""
    if metric == "l2":
        return np.maximum(xsq - 2.0 * dot + qsq, 0.0)
    if metric == "ip":
        return -dot
    if metric == "cosine":
        return 1.0 - dot
    raise ValueError(f"unknown metric {metric!r}")


def _adc_np(lut_h: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """numpy twin of pq_lut_distances over [B, M0, M] code tiles —
    prediction-only (superstep planning), same rollback safety as
    `_metric_dist_np`: a last-ulp disagreement with the device LUT sum
    costs a shorter superstep, never a wrong result."""
    b_ix = np.arange(lut_h.shape[0])[:, None, None]
    m_ix = np.arange(lut_h.shape[1])[None, None, :]
    return lut_h[b_ix, m_ix, codes.astype(np.int64)].sum(-1)


# ---------------------------------------------------------------------------
# Host-driven traversal over store reads
# ---------------------------------------------------------------------------


def _gather_vec_sq(reader: StoreReader, p: int, ids: np.ndarray,
                   mask: np.ndarray):
    """Vector + sqnorm tiles for masked neighbor lanes; zeros elsewhere
    (masked lanes are forced to +inf downstream, so zeros are inert).

    Neighbor ids repeat across lanes whenever two queries expand nodes
    that share a neighbor, so the store read is issued over the *unique*
    ids and the rows scattered back — the reader never sees (or pays row
    bookkeeping for) the duplicates, and the returned tiles are unchanged."""
    vecs = np.zeros(ids.shape + (reader.d_pad,), np.float32)
    sqs = np.zeros(ids.shape, np.float32)
    if mask.any():
        uniq, inv = np.unique(ids[mask], return_inverse=True)
        rows = reader.row("vectors", p, uniq)
        vecs[mask] = reader.read_rows("vectors", rows)[inv]
        sqs[mask] = reader.read_rows("sqnorms", rows)[inv, 0]
    return vecs, sqs


def _gather_codes(reader: StoreReader, p: int, ids: np.ndarray,
                  mask: np.ndarray) -> np.ndarray:
    """PQ variant of `_gather_vec_sq`: M-byte uint8 code tiles only
    (reader.d_pad == M for a PQ store). ADC needs no norms, so the sqnorm
    table is never read in stage 1 — code rows + graph rows are the whole
    per-hop flash traffic. Masked lanes stay zero (inert: forced to +inf
    downstream)."""
    codes = np.zeros(ids.shape + (reader.d_pad,), np.uint8)
    if mask.any():
        uniq, inv = np.unique(ids[mask], return_inverse=True)
        rows = reader.row("vectors", p, uniq)
        codes[mask] = reader.read_rows("vectors", rows)[inv]
    return codes


def _visited_test_and_set(bitmap: np.ndarray, ids: np.ndarray,
                          valid: np.ndarray) -> np.ndarray:
    """Host mirror of core.search.visited_test_and_set over [B, M] lanes.
    Returns `was` (visited-before OR invalid); sets bits for valid lanes."""
    B = bitmap.shape[0]
    safe = np.where(valid, ids, 0).astype(np.int64)
    w = safe >> 5
    b5 = (safe & 31).astype(np.uint32)
    rows = np.arange(B)[:, None]
    was = ((bitmap[rows, w] >> b5) & np.uint32(1)) > 0
    was |= ~valid
    bits = np.where(~was, np.left_shift(np.uint32(1), b5), np.uint32(0))
    np.bitwise_or.at(bitmap, (rows, w), bits)
    return was


def _layer0_supersteps(reader: StoreReader, p: int, q_pad, qsq, bitmap,
                       cand_d, cand_i, fin_d, fin_i, hops, calcs,
                       sp: SearchParams, luts=None, lut_h=None):
    """Speculative, PIPELINED H-hop supersteps over layer 0
    (`fused_hops > 1`).

    The host shadows the beam in numpy to *predict* the next H pops —
    reading neighbor rows and vector/sqnorm tiles as it goes, and applying
    the visited test-and-set for the whole superstep up front — then
    `_layer0_superstep` replays the hops on device, validating each
    against true device state. The two run as a software pipeline: while
    superstep k executes on device, the host plans superstep k+1 from the
    shadow (store reads overlap kernel compute, the paper's §5.3 overlap
    applied to whole supersteps), and only the tiny per-lane `applied`
    count is synced per superstep. Full beam state crosses the host
    boundary only at pipeline bubbles: the start, a misprediction (a
    last-ulp distance tie ordering differently in numpy than in XLA), or
    the shadow terminating while the device disagrees.

    The shadow only ever influences which hops get *planned* — every
    applied hop re-derives its pop, guard, and merge on device, so the
    result is bit-identical to the hop-stepped loop at any H. A lane
    whose speculation was rejected has its visited bits rolled back and
    its shadow resynced from device state, after which its next superstep
    is planned from truth and must apply ≥ 1 hop — no livelock. Returns
    the updated beam plus the number of supersteps (device dispatches ==
    host sync points) taken.

    dtype="pq": `luts` is the device [B, M, 256] ADC table (the kernel's
    distance operand) and `lut_h` its host copy — the shadow predicts
    with `_adc_np` over the same table values, so the only divergence
    source left is the accumulation order, exactly like the f32 path."""
    B = bitmap.shape[0]
    H = sp.fused_hops
    M0, D = reader.m0_pad, reader.d_pad
    C, EF = sp.cand_size, sp.ef
    metric = sp.metric
    qh = np.asarray(q_pad, np.float32)
    qsqh = np.asarray(qsq, np.float32)
    steps = 0

    # shadow of the device beam, advanced in place by plan(); resynced
    # from device arrays only at pipeline bubbles
    scand_d = np.array(cand_d)
    scand_i = np.array(cand_i)
    sfin_d = np.array(fin_d)
    shops = np.array(hops)

    def plan():
        """Plan up to H hops from shadow state (store reads + visited
        test-and-set happen here). Returns None if the shadow sees every
        lane terminated; otherwise the per-hop tiles for the kernel."""
        live0 = (scand_d[:, 0] < sfin_d[:, -1]) & (shops < sp.max_hops)
        if not live0.any():
            return None
        snap = bitmap.copy()
        spec = np.full((B, H), -1, np.int32)
        nbrs_t = np.full((B, H, M0), -1, np.int32)
        act_t = np.zeros((B, H, M0), bool)
        vecs_t = np.zeros((B, H, M0, D),
                          np.uint8 if lut_h is not None else np.float32)
        sqs_t = np.zeros((B, H, M0), np.float32)
        planned = np.zeros(B, np.int32)          # shadow-live hops per lane
        for h in range(H):
            live = (scand_d[:, 0] < sfin_d[:, -1]) & (shops < sp.max_hops)
            if not live.any():
                break
            pops = np.where(live, scand_i[:, 0], -1).astype(np.int32)
            spec[:, h] = pops
            planned += live
            lanes = np.flatnonzero(live)
            nbrs = nbrs_t[:, h]
            nbrs[lanes] = reader.read_rows(
                "l0_nbrs", reader.row("l0_nbrs", p, pops[lanes]))
            valid = (nbrs >= 0) & live[:, None]
            was = _visited_test_and_set(bitmap, nbrs, valid)
            act = valid & ~was
            act_t[:, h] = act
            if lut_h is not None:
                v = _gather_codes(reader, p, nbrs, act)
                vecs_t[:, h] = v
                d = _adc_np(lut_h, v)
            else:
                v, s = _gather_vec_sq(reader, p, nbrs, act)
                vecs_t[:, h], sqs_t[:, h] = v, s
                # shadow hop: the same pop/guard/merge, numpy arithmetic
                d = _metric_dist_np(metric,
                                    np.einsum("bmd,bd->bm", v, qh),
                                    s, qsqh[:, None])
            d = np.where(act, d, np.inf)
            d = np.where(d < sfin_d[:, -1:], d, np.inf)
            ids = np.where(np.isfinite(d), np.where(act, nbrs, 0), -1)
            o = np.argsort(d, axis=1, kind="stable")
            bd = np.take_along_axis(d, o, axis=1)
            bi = np.take_along_axis(ids, o, axis=1)
            pc_d = np.concatenate(
                [scand_d[:, 1:], np.full((B, 1), np.inf, np.float32)], 1)
            pc_i = np.concatenate(
                [scand_i[:, 1:], np.full((B, 1), -1, scand_i.dtype)], 1)
            o2 = np.argsort(np.concatenate([pc_d, bd], axis=1),
                            axis=1, kind="stable")
            sel = live[:, None]
            scand_d[:] = np.where(sel, np.take_along_axis(
                np.concatenate([pc_d, bd], 1), o2, 1)[:, :C], scand_d)
            scand_i[:] = np.where(sel, np.take_along_axis(
                np.concatenate([pc_i, bi], 1), o2, 1)[:, :C], scand_i)
            sfin_d[:] = np.where(sel, np.sort(
                np.concatenate([sfin_d, bd], 1), axis=1)[:, :EF], sfin_d)
            shops[:] = shops + live
        return dict(snap=snap, spec=spec, nbrs=nbrs_t, act=act_t,
                    vecs=vecs_t, sqs=sqs_t, planned=planned)

    def resync(lanes):
        """Pull true device beam state back into the shadow for `lanes`
        (boolean mask) — the only full-state host syncs in this driver."""
        scand_d[lanes] = np.asarray(cand_d)[lanes]
        scand_i[lanes] = np.asarray(cand_i)[lanes]
        sfin_d[lanes] = np.asarray(fin_d)[lanes]
        shops[lanes] = np.asarray(hops)[lanes]

    def settle(prev, applied_h, nxt):
        """Handle rejected speculation of the just-finished superstep
        `prev`: per bad lane, restore its visited bits to the pre-`prev`
        snapshot plus the applied prefix (this also wipes any bits the
        in-flight plan `nxt` set from that lane's diverged shadow),
        resync its shadow from device truth, and void its slots in
        `nxt` so the kernel skips it there."""
        bad = applied_h < prev["planned"]
        if not bad.any():
            return False
        for b in np.flatnonzero(bad):
            bitmap[b] = prev["snap"][b]
            for h in range(int(applied_h[b])):
                ib = prev["nbrs"][b, h][prev["act"][b, h]]
                np.bitwise_or.at(
                    bitmap[b], ib >> 5,
                    np.left_shift(np.uint32(1),
                                  (ib & 31).astype(np.uint32)))
        resync(bad)
        if nxt is not None:
            nxt["spec"][bad] = -1
            nxt["act"][bad] = False
            nxt["planned"][bad] = 0
        return True

    pending = None                   # (plan, applied) in flight on device
    while True:
        ps = plan()                  # overlaps the in-flight kernel
        if pending is not None:
            prev, applied = pending
            applied_h = np.asarray(applied)       # sync: superstep done
            pending = None
            if settle(prev, applied_h, ps) and ps is None:
                ps = plan()          # resynced lanes may still be live
        if ps is None:
            # shadow says done; the device has the final word (a last-ulp
            # tie can terminate the shadow while the device beam is live)
            live = ((np.asarray(cand_d)[:, 0] < np.asarray(fin_d)[:, -1])
                    & (np.asarray(hops) < sp.max_hops))
            if not live.any():
                break
            resync(live)
            ps = plan()
            if ps is None:           # cannot happen: resynced == live
                break
        with TRACER.child_span("hop_superstep", superstep=steps,
                               fused_hops=H,
                               active=int((ps["planned"] > 0).sum())):
            with TRACER.child_span("hop-kernel"):
                (cand_d, cand_i, fin_d, fin_i, hops, calcs,
                 applied) = _layer0_superstep(
                    cand_d, cand_i, fin_d, fin_i, hops, calcs,
                    jnp.asarray(ps["spec"]), jnp.asarray(ps["nbrs"]),
                    jnp.asarray(ps["act"]), jnp.asarray(ps["vecs"]),
                    jnp.asarray(ps["sqs"]), q_pad, qsq, metric, sp.max_hops,
                    lut=luts)
        pending = (ps, applied)
        steps += 1
    return cand_d, cand_i, fin_d, fin_i, hops, calcs, steps


def _search_one_partition(reader: StoreReader, p: int, q_pad: jnp.ndarray,
                          params: SearchParams, luts=None, lut_h=None):
    """Lockstep batched search of one sub-graph, all data via the store.

    Returns (gids [B,k], dists [B,k], hops [B], calcs [B], steps) —
    numerically identical to `batch_search` on the resident partition.
    `steps` counts host-sync'd traversal rounds: one per hop on the legacy
    path, one per `fused_hops`-hop superstep on the fused path.
    `luts`/`lut_h` are the device/host per-query ADC tables for dtype="pq"
    (store_search builds them once per batch; one code space per index,
    shared across partitions)."""
    B = int(q_pad.shape[0])
    pq = luts is not None
    sp = params.resolve(reader.m0_pad)
    C, EF, K = sp.cand_size, sp.ef, sp.k
    metric = sp.metric

    ep = int(reader.entry[p] if reader.entry.ndim else reader.entry)
    max_level = int(reader.max_level[p] if reader.max_level.ndim
                    else reader.max_level)
    ep_row = reader.row("vectors", p, [ep])
    if pq:
        ep_code = jnp.asarray(reader.read_rows("vectors", ep_row)[0])
        qsq = jnp.zeros((B,), jnp.float32)       # unused by ADC
        ep_d = _query_prep_pq(luts, ep_code)
    else:
        ep_vec = jnp.asarray(
            reader.read_rows("vectors", ep_row)[0].astype(np.float32))
        ep_sq = jnp.asarray(reader.read_rows("sqnorms", ep_row)[0, 0])
        qsq, ep_d = _query_prep(q_pad, ep_vec, ep_sq, metric)

    # -- upper layers: lockstep greedy descent (paper §5.2.2) ---------------
    cur = jnp.full((B,), ep, jnp.int32)
    cur_d = ep_d
    calcs = jnp.ones((B,), jnp.int32)
    n_layers = reader.n_layers
    for layer in range(min(n_layers, max_level), 0, -1):
        improved = jnp.ones((B,), bool)
        hop = 0
        while bool(np.asarray(improved).any()) and hop < sp.upper_hops:
            imp_h = np.asarray(improved)
            cur_h = np.asarray(cur)
            nbrs = np.full((B, reader.m_pad), -1, np.int32)
            if imp_h.any():
                ptr = reader.read_rows(
                    "up_ptr", reader.row("up_ptr", p, cur_h[imp_h]))[:, 0]
                has = ptr >= 0
                if has.any():
                    urows = reader.up_row(p, layer - 1, ptr[has])
                    lanes = np.flatnonzero(imp_h)[has]
                    nbrs[lanes] = reader.read_rows("up_nbrs", urows)
            valid = (nbrs >= 0) & imp_h[:, None]
            if pq:
                vecs = _gather_codes(reader, p, nbrs, valid)
                sqs = np.zeros(nbrs.shape, np.float32)
            else:
                vecs, sqs = _gather_vec_sq(reader, p, nbrs, valid)
            cur, cur_d, improved, calcs = _upper_step(
                improved, cur, cur_d, calcs,
                jnp.asarray(nbrs), jnp.asarray(valid),
                jnp.asarray(vecs), jnp.asarray(sqs), q_pad, qsq, metric,
                lut=luts)
            hop += 1

    # -- layer 0: lockstep beam search (paper §5.2.3) -----------------------
    n_words = bitmap_words(reader.n_pad)
    bitmap = np.zeros((B, n_words), np.uint32)
    ep_ids = np.asarray(cur)[:, None]
    _visited_test_and_set(bitmap, ep_ids, np.ones((B, 1), bool))
    cand_d = jnp.full((B, C), jnp.inf).at[:, 0].set(cur_d)
    cand_i = jnp.full((B, C), -1, jnp.int32).at[:, 0].set(cur)
    fin_d = jnp.full((B, EF), jnp.inf).at[:, 0].set(cur_d)
    fin_i = jnp.full((B, EF), -1, jnp.int32).at[:, 0].set(cur)
    hops = jnp.zeros((B,), jnp.int32)

    if sp.fused_hops > 1:
        # fused path: the superstep driver batches its own store reads per
        # H-hop plan, so the speculative next-hop prefetcher is redundant
        # traffic — it is deliberately not invoked here
        (cand_d, cand_i, fin_d, fin_i, hops, calcs,
         steps) = _layer0_supersteps(reader, p, q_pad, qsq, bitmap,
                                     cand_d, cand_i, fin_d, fin_i,
                                     hops, calcs, sp, luts=luts,
                                     lut_h=lut_h)
    else:
        hop_no = 0
        while True:
            cd_h, fd_h = np.asarray(cand_d), np.asarray(fin_d)
            hops_h = np.asarray(hops)
            active = (cd_h[:, 0] < fd_h[:, -1]) & (hops_h < sp.max_hops)
            if not active.any():
                break
            with TRACER.child_span("hop", hop=hop_no,
                                   active=int(active.sum())):
                pops = np.asarray(cand_i)[:, 0]
                nbrs = np.full((B, reader.m0_pad), -1, np.int32)
                if active.any():
                    lanes = np.flatnonzero(active)
                    nbrs[lanes] = reader.read_rows(
                        "l0_nbrs", reader.row("l0_nbrs", p, pops[lanes]))
                valid = (nbrs >= 0) & active[:, None]
                was = _visited_test_and_set(bitmap, nbrs, valid)
                act = valid & ~was
                if pq:
                    vecs = _gather_codes(reader, p, nbrs, act)
                    sqs = np.zeros(nbrs.shape, np.float32)
                else:
                    vecs, sqs = _gather_vec_sq(reader, p, nbrs, act)
                # hop-kernel covers only the jitted dispatch — the async
                # device compute itself overlaps the next hop's host work by
                # design, so the span is the submit cost, not the device time
                with TRACER.child_span("hop-kernel"):
                    cand_d, cand_i, fin_d, fin_i, hops, calcs = _layer0_step(
                        jnp.asarray(active), cand_d, cand_i, fin_d, fin_i,
                        hops, calcs, jnp.asarray(nbrs), jnp.asarray(act),
                        jnp.asarray(vecs), jnp.asarray(sqs), q_pad, qsq,
                        metric, lut=luts)
                # overlap the next hop's fetches with this round-trip
                reader.prefetch_next_hop(p, np.asarray(cand_i)[:, :2])
            hop_no += 1
        steps = hop_no

    k_i = np.asarray(fin_i)[:, :K]
    k_d = np.asarray(fin_d)[:, :K]
    k_g = np.full_like(k_i, -1)
    vmask = k_i >= 0
    if vmask.any():
        k_g[vmask] = reader.read_rows(
            "gids", reader.row("gids", p, k_i[vmask]))[:, 0]
    return k_g, k_d, np.asarray(hops), np.asarray(calcs), steps


def store_search(reader: StoreReader, queries, params: SearchParams,
                 merge: bool = True, pq_quant=None):
    """Two-stage search over every partition of the store.

    merge=True  -> (ids [B,k], dists [B,k], hops [B], calcs [B], supersteps)
    merge=False -> the unmerged [B, P*k] stage-1 pool (rerank consumes it).

    `supersteps` is the total host-sync'd traversal rounds across
    partitions — equal to total layer-0 hop rounds at fused_hops=1,
    roughly hops/fused_hops on the fused path.

    `pq_quant` is the index's fitted PQQuantizer for dtype="pq" stores:
    queries stay float32 (NOT padded to the store's d_pad, which is the
    code width M) and the per-query ADC LUT is built once here through
    the one shared jitted builder, then reused by every partition.
    """
    REGISTRY.gauge("traversal_fused_hops").set(float(params.fused_hops))
    q = np.asarray(queries, np.float32)
    luts = lut_h = None
    if pq_quant is not None:
        luts = build_pq_lut(jnp.asarray(q),
                            jnp.asarray(pq_quant.codebooks))
        lut_h = np.asarray(luts)      # shadow planner's prediction twin
    elif q.shape[-1] < reader.d_pad:
        q = np.pad(q, ((0, 0), (0, reader.d_pad - q.shape[-1])))
    q_pad = jnp.asarray(q)
    per_ids, per_ds = [], []
    hops = np.zeros(q.shape[0], np.int64)
    calcs = np.zeros(q.shape[0], np.int64)
    supersteps = 0
    for p in range(reader.num_partitions):
        with TRACER.child_span("traversal", partition=p):
            gi, gd, h, c, s = _search_one_partition(reader, p, q_pad, params,
                                                    luts=luts, lut_h=lut_h)
        per_ids.append(gi)
        per_ds.append(gd)
        hops += h
        calcs += c
        supersteps += s
    ids = np.stack(per_ids, axis=1)          # [B, P, k]
    ds = np.stack(per_ds, axis=1)
    if not merge:
        b = ids.shape[0]
        return ids.reshape(b, -1), ds.reshape(b, -1), hops, calcs, supersteps
    out_i, out_d = merge_topk(jnp.asarray(ids), jnp.asarray(ds), params.k)
    return out_i, out_d, hops, calcs, supersteps


# ---------------------------------------------------------------------------
# Backend
# ---------------------------------------------------------------------------


def _collect_csd(be: "CSDBackend"):
    """Snapshot-time metric samples per live csd backend (repro.obs).

    Publishes the per-query counters `QueryStats` carries (supersteps,
    dist_calcs, bytes_read) as cumulative REGISTRY series — the ADC and
    fused-superstep wins in the Prometheus export, not just per query —
    plus the store geometry gauges `repro.obs.calibrate` needs to price
    the workload (padded graph degree, vector row bytes, block size)."""
    r = be.reader
    labels = {"backend": be.uid}
    with be._tlock:
        q, hops, calcs, steps = (be._queries, be._hops, be._dist_calcs,
                                 be._supersteps)
    t = r.blockfile.tables["vectors"]
    row_bytes = int(t["cols"]) * np.dtype(t["dtype"]).itemsize
    return [
        ("counter", "csd_queries_total", labels, q),
        ("counter", "csd_hops_total", labels, hops),
        ("counter", "csd_supersteps_total", labels, steps),
        ("counter", "search_dist_calcs_total", labels, calcs),
        ("counter", "csd_bytes_read_total", labels,
         r.cache.snapshot()["bytes_read"]),
        ("gauge", "csd_graph_degree", labels, r.m0_pad),
        ("gauge", "csd_vector_row_bytes", labels, row_bytes),
        ("gauge", "csd_block_size", labels, r.block_size),
    ]


class CSDBackend:
    """Storage-resident two-stage engine (registered as `csd`).

    Build restructures the dataset into the block store at
    `spec.storage_path`; serving holds only the PageCache (`cache_bytes`)
    in memory. `rerank` needs no `keep_vectors` — stage 2 reads the raw
    vectors back from the store.
    """

    uses_graph = True

    def __init__(self, spec: IndexSpec, reader: StoreReader):
        self.spec = spec
        self.reader = reader
        self.quant = spec.quantizer()
        self.is_pq = spec.dtype == "pq"
        # cumulative engine counters behind the csd_*/search_* series
        self.uid = next_uid()
        self._tlock = threading.Lock()
        self._queries = 0
        self._hops = 0
        self._dist_calcs = 0
        self._supersteps = 0
        REGISTRY.register_collector(self, _collect_csd)

    @staticmethod
    def _storage_path(spec: IndexSpec) -> str:
        if not spec.storage_path:
            raise ValueError(
                "backend='csd' persists the database to a block store: set "
                "IndexSpec(storage_path=...) to its directory")
        return spec.storage_path

    @classmethod
    def build(cls, vectors: np.ndarray, spec: IndexSpec, mesh=None):
        path = cls._storage_path(spec)
        pdb = build_partitioned_db(vectors, spec.num_partitions, spec.hnsw)
        return cls._write(path, pdb, spec)

    @classmethod
    def from_partitioned(cls, pdb, spec: IndexSpec, raw=None):
        """Convert an already-built resident PartitionedDB into an
        out-of-core service (benchmarks reuse one graph build).

        For a dtype="pq" pdb whose vectors leaf already holds code rows
        (PartitionedBackend.build swaps them in), pass `raw` — the
        ORIGINAL [n, d] float32 rows — so the store still gets its
        `rerank_vectors` table."""
        return cls._write(cls._storage_path(spec), pdb, spec, raw=raw)

    @classmethod
    def _write(cls, path: str, pdb, spec: IndexSpec, raw=None):
        """Quantize the raw-data leaf and commit the block store.

        dtype="pq": the vectors leaf shrinks to M-byte code rows AND the
        TRUE float32 rows are persisted as an extra `rerank_vectors` table
        (same p * n_pad + i row addressing) — stage-2 rerank reads real
        vectors back from flash, because re-scoring decoded PQ rows would
        reproduce the ADC distances exactly and recover no recall."""
        extra = None
        if spec.dtype == "pq":
            quant = spec.quantizer()
            vecs = np.asarray(pdb.db.vectors)
            if vecs.dtype != np.uint8:     # true rows still in hand
                extra = {"rerank_vectors": np.ascontiguousarray(
                    vecs.reshape(-1, vecs.shape[-1]), np.float32)}
            elif raw is not None:          # scatter raw rows to pad layout
                raw = np.asarray(raw, np.float32)
                gids = np.asarray(pdb.db.gids)
                n_valid = np.atleast_1d(np.asarray(pdb.db.n_valid))
                n_pad = gids.shape[-1]
                p_ax = gids.shape[0] if gids.ndim == 2 else 1
                table = np.zeros((p_ax * n_pad, raw.shape[1]), np.float32)
                for pi in range(p_ax):
                    nv = int(n_valid[pi])
                    g = gids[pi, :nv] if gids.ndim == 2 else gids[:nv]
                    table[pi * n_pad: pi * n_pad + nv] = raw[g]
                extra = {"rerank_vectors": table}
            pdb = quantize_db_vectors(pdb, "pq", quant)
        else:
            # quantized spec: on-flash vector rows shrink to 1 byte/dim
            pdb = quantize_db_vectors(pdb, spec.dtype)
        write_store(path, pdb, block_size=spec.block_size,
                    extra_tables=extra)
        del pdb                     # from here on, the store is the database
        return cls(spec, open_store(path, spec.cache_bytes,
                                    prefetch=spec.prefetch))

    def params(self, k: int, ef: int) -> SearchParams:
        return SearchParams(ef=ef, k=k, metric=self.spec.metric,
                            fused_hops=self.spec.fused_hops)

    def search(self, queries, k: int, ef: int, rerank: bool,
               with_stats: bool):
        r = self.reader
        before = None
        if with_stats:
            if r.prefetcher is not None:
                r.prefetcher.drain()     # don't attribute a previous
            before = r.cache.snapshot()  # request's in-flight reads to us
        p = self.params(k, ef)
        pq_quant = self.quant if self.is_pq else None
        if rerank:
            cand, _, hops, calcs, steps = store_search(
                r, queries, p, merge=False, pq_quant=pq_quant)
            with TRACER.child_span("rerank", pool=int(cand.shape[1])):
                ids, dists = self._rerank_from_store(queries, cand, k)
        else:
            ids, dists, hops, calcs, steps = store_search(
                r, queries, p, pq_quant=pq_quant)
            if self.quant is not None and not self.is_pq:
                # code-space -> real-space (ADC is already real-space)
                dists = dists * jnp.float32(self.quant.dist_scale)
        with self._tlock:
            self._queries += int(np.asarray(queries).shape[0])
            self._hops += int(np.asarray(hops).sum())
            self._dist_calcs += int(np.asarray(calcs).sum())
            self._supersteps += int(steps)
        stats = None
        if with_stats:
            from repro.api.types import QueryStats
            if r.prefetcher is not None:
                r.prefetcher.drain()     # settle in-flight reads (counters)
            after = r.cache.snapshot()
            demand = ((after["hits"] - before["hits"])
                      + (after["misses"] - before["misses"]))
            hit_rate = ((after["hits"] - before["hits"]) / demand
                        if demand else 0.0)
            stats = QueryStats(
                hops=jnp.asarray(hops, jnp.int32),
                dist_calcs=jnp.asarray(calcs, jnp.int32),
                block_reads=after["block_reads"] - before["block_reads"],
                cache_hits=after["hits"] - before["hits"],
                cache_misses=after["misses"] - before["misses"],
                cache_hit_rate=hit_rate,
                bytes_read=after["bytes_read"] - before["bytes_read"],
                supersteps=steps,
            )
        return jnp.asarray(ids), jnp.asarray(dists), stats

    def _rerank_from_store(self, queries, cand: np.ndarray, k: int):
        """Stage-2 exact re-score from store reads (paper Fig. 4 stage 2).

        Candidates are remapped onto a compact, monotonically-ordered id
        space so `batched_rerank` behaves exactly as it does over the full
        resident vector table."""
        from repro.api.rerank import batched_rerank
        r = self.reader
        if r.partition_starts is None:
            raise ValueError(
                "rerank over this store is unsupported: partition global "
                "ids are not contiguous ranges")
        valid = cand >= 0
        uniq = np.unique(cand[valid])
        if uniq.size == 0:
            b = cand.shape[0]
            return (np.full((b, k), -1, np.int32),
                    np.full((b, k), np.inf, np.float32))
        part = np.searchsorted(r.partition_starts, uniq, side="right") - 1
        local = uniq - r.partition_starts[part]
        rows = part * r.n_pad + local
        if self.is_pq:
            # stage 2 over TRUE float32 rows from the extra table — the
            # code rows carry no information beyond their ADC distance
            if "rerank_vectors" not in r.blockfile.tables:
                raise ValueError(
                    "this PQ store has no 'rerank_vectors' table, so "
                    "stage-2 rerank cannot read true float32 rows: "
                    "rebuild it with CSDBackend.build/from_partitioned "
                    "over the original vectors")
            rows_f = r.read_rows("rerank_vectors", rows).astype(np.float32)
        else:
            rows_f = r.read_rows("vectors", rows)[:, :r.dim].astype(
                np.float32)
            if self.quant is not None:
                # stage 2 stays float32: dequantize the gathered code rows
                rows_f = self.quant.decode(rows_f)
        vecs = jnp.asarray(rows_f)
        sqs = jnp.einsum("nd,nd->n", vecs, vecs)
        compact = np.where(valid,
                           np.searchsorted(uniq, np.where(valid, cand, 0)),
                           -1).astype(np.int32)
        q = jnp.asarray(np.asarray(queries, np.float32))
        if self.quant is not None and not self.is_pq:
            q = self.quant.decode(q)     # code-valued queries -> f32 values
        ids_c, dists = batched_rerank(vecs, sqs, q, jnp.asarray(compact), k,
                                      self.spec.metric)
        ids_c = np.asarray(ids_c)
        ids = np.where(ids_c >= 0, uniq[np.maximum(ids_c, 0)], -1)
        return ids.astype(np.int32), dists

    # -- persistence ---------------------------------------------------------
    # The block store IS the database: state_tree carries only a format tag,
    # and the index manifest's spec points at the block files (storage_path)
    # instead of pickled arrays.

    def state_tree(self) -> dict:
        return {"meta": {"csd_store": np.int32(1),
                         "block_size": np.int32(self.spec.block_size)}}

    @classmethod
    def from_state(cls, spec: IndexSpec, leaves: dict, mesh=None):
        path = cls._storage_path(spec)
        return cls(spec, open_store(path, spec.cache_bytes,
                                    prefetch=spec.prefetch))
