"""Block-aligned storage file: the repo's stand-in for SmartSSD flash.

One store = one directory:

    <dir>/blocks.bin            all tables, each region block-aligned
    <dir>/store_manifest.json   block size, table directory, engine meta
    <dir>/_COMMITTED            written last — a partial write is never
                                readable (same contract as repro.checkpoint)

The unit of I/O is the *block* (default 4 KiB — the paper's flash page):
`BlockFile.read_block` returns exactly one block and is the only way data
leaves the file, so counting calls == counting flash reads / P2P-DMA
transfers. Tables are fixed-stride row arrays (paper Fig. 5); each table
region starts on a block boundary so a row's blocks are computable from its
index alone — the "one access per point" property carried to storage.
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np

__all__ = ["BlockFileWriter", "BlockFile", "StoreFormatError",
           "DATA_NAME", "MANIFEST_NAME", "COMMIT_NAME", "FORMAT"]

DATA_NAME = "blocks.bin"
MANIFEST_NAME = "store_manifest.json"
COMMIT_NAME = "_COMMITTED"
FORMAT = "repro-block-store-v1"


class StoreFormatError(RuntimeError):
    """Raised when a store directory is missing, uncommitted, or corrupt."""


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class BlockFileWriter:
    """Writes tables into a block-aligned data file, then commits.

    Usage:
        w = BlockFileWriter(path, block_size=4096)
        w.add_table("vectors", arr2d)          # row-major [R, C]
        w.finalize(meta)                       # manifest + commit marker
    """

    def __init__(self, path: str, block_size: int = 4096):
        if block_size <= 0 or block_size % 512:
            raise ValueError(f"block_size must be a positive multiple of "
                             f"512, got {block_size}")
        self.path = path
        self.block_size = block_size
        self._tables: dict[str, dict] = {}
        os.makedirs(path, exist_ok=True)
        # a re-written store must never look committed mid-write
        for name in (COMMIT_NAME, MANIFEST_NAME):
            p = os.path.join(path, name)
            if os.path.exists(p):
                os.remove(p)
        self._f = open(os.path.join(path, DATA_NAME), "wb")
        self._offset = 0

    def add_table(self, name: str, rows: np.ndarray) -> None:
        """Append one fixed-stride row table, padded to a block boundary."""
        if name in self._tables:
            raise ValueError(f"duplicate table {name!r}")
        rows = np.ascontiguousarray(rows)
        if rows.ndim != 2:
            raise ValueError(f"table {name!r} must be 2-D [rows, cols], "
                             f"got shape {rows.shape}")
        raw = rows.tobytes()
        self._tables[name] = {
            "offset": self._offset,
            "rows": int(rows.shape[0]),
            "cols": int(rows.shape[1]),
            "row_bytes": int(rows.strides[0]) if rows.shape[0] else
                         int(rows.shape[1] * rows.itemsize),
            "dtype": str(rows.dtype),
            "nbytes": len(raw),
        }
        self._f.write(raw)
        padded = _round_up(len(raw), self.block_size)
        self._f.write(b"\0" * (padded - len(raw)))
        self._offset += padded

    def finalize(self, meta: dict | None = None) -> None:
        """Flush data, write the manifest, then the commit marker (last)."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        manifest = {
            "format": FORMAT,
            "block_size": self.block_size,
            "num_blocks": self._offset // self.block_size,
            "tables": self._tables,
            "meta": meta or {},
        }
        with open(os.path.join(self.path, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(self.path, COMMIT_NAME), "w") as f:
            f.write("ok")

    def abort(self) -> None:
        self._f.close()
        shutil.rmtree(self.path, ignore_errors=True)


class BlockFile:
    """Read side: memory-mapped, strictly block-granular access.

    `read_block(i)` is one emulated flash read. Nothing else reads the data
    file, so callers (the PageCache) fully account the storage traffic.
    """

    def __init__(self, path: str):
        if not os.path.exists(os.path.join(path, COMMIT_NAME)):
            raise StoreFormatError(
                f"store at {path!r} has no commit marker — refusing to read "
                f"a partial or crashed write")
        with open(os.path.join(path, MANIFEST_NAME)) as f:
            self.manifest = json.load(f)
        if self.manifest.get("format") != FORMAT:
            raise StoreFormatError(
                f"store at {path!r} has format "
                f"{self.manifest.get('format')!r}; this build reads {FORMAT!r}")
        self.path = path
        self.block_size = int(self.manifest["block_size"])
        self.num_blocks = int(self.manifest["num_blocks"])
        self.tables = self.manifest["tables"]
        self.meta = self.manifest["meta"]
        data = os.path.join(path, DATA_NAME)
        expect = self.num_blocks * self.block_size
        if os.path.getsize(data) < expect:
            raise StoreFormatError(
                f"store at {path!r}: data file is "
                f"{os.path.getsize(data)} bytes, manifest expects {expect}")
        self._mm = np.memmap(data, dtype=np.uint8, mode="r")

    def read_block(self, idx: int) -> bytes:
        """One flash read: returns exactly one block."""
        if not 0 <= idx < self.num_blocks:
            raise IndexError(f"block {idx} out of range [0, {self.num_blocks})")
        lo = idx * self.block_size
        return self._mm[lo:lo + self.block_size].tobytes()

    def row_span(self, table: str, row: int) -> tuple[int, int]:
        """[start, end) byte span of one table row — the single source of
        row-addressing truth; every reader derives blocks and slices from
        it so layout changes cannot desynchronize fetch and decode."""
        t = self.tables[table]
        start = t["offset"] + row * t["row_bytes"]
        return start, start + t["row_bytes"]

    def blocks_of_row(self, table: str, row: int) -> range:
        """Block indices a given table row spans."""
        start, end = self.row_span(table, row)
        return range(start // self.block_size,
                     (end - 1) // self.block_size + 1)
