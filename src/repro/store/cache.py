"""LRU page cache in front of the block file (the SmartSSD DRAM tier).

Every demand access is a hit or a miss; every miss (and every prefetch) is
one `BlockFile.read_block` call — the emulated flash read. The counters are
the repo's stand-in for the paper's "number of vector reads" / P2P-DMA
traffic (Fig. 9):

    hits, misses      demand accesses served from / missing the cache
    prefetch_reads    blocks pulled in by the Prefetcher thread
    prefetch_hits     demand accesses that waited on an in-flight prefetch
                      (counted as hits — the flash read was the prefetch)
    block_reads       misses + prefetch_reads == total flash block transfers
    bytes_read        block_reads * block_size
    evictions         LRU evictions
    peak_bytes        high-water mark of resident cached bytes — the bound
                      the out-of-core guarantee is measured against

Thread safety: one lock around the LRU + counters; `get` waits outside the
lock on in-flight prefetches so the worker can complete them.
"""

from __future__ import annotations

import threading
from collections import OrderedDict

from repro.obs.metrics import REGISTRY, next_uid
from repro.store.blockfile import BlockFile

__all__ = ["PageCache"]


def _collect_cache(cache: "PageCache"):
    """Collector samples for the metrics registry (repro.obs): read at
    snapshot time under the cache's own lock — zero hot-path cost. Every
    live cache publishes one labeled series per counter; summing the
    `store_block_reads_total` series over `cache` labels is the paper's
    Fig. 9 P2P-DMA traffic."""
    snap = cache.snapshot()
    labels = {"cache": cache.uid}
    counters = ("hits", "misses", "prefetch_reads", "prefetch_hits",
                "evictions", "block_reads", "bytes_read")
    out = [("counter", f"store_cache_{c}_total" if not c.startswith("b")
            else f"store_{c}_total", labels, snap[c]) for c in counters]
    out.append(("gauge", "store_cache_resident_bytes", labels,
                snap["current_bytes"]))
    out.append(("gauge", "store_cache_peak_bytes", labels,
                snap["peak_bytes"]))
    out.append(("gauge", "store_cache_capacity_bytes", labels,
                cache.capacity_bytes))
    return out


class PageCache:
    def __init__(self, blockfile: BlockFile, capacity_bytes: int):
        if capacity_bytes < blockfile.block_size:
            raise ValueError(
                f"cache capacity {capacity_bytes} is smaller than one block "
                f"({blockfile.block_size}) — cannot hold a single read")
        self.blockfile = blockfile
        self.capacity_bytes = int(capacity_bytes)
        self.block_size = blockfile.block_size
        self.uid = next_uid()
        self._lru: OrderedDict[int, bytes] = OrderedDict()
        self._inflight: dict[int, threading.Event] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.prefetch_reads = 0
        self.prefetch_hits = 0
        self.evictions = 0
        self.current_bytes = 0
        self.peak_bytes = 0
        REGISTRY.register_collector(self, _collect_cache)

    # -- demand path ---------------------------------------------------------

    def get(self, idx: int) -> bytes:
        """Demand read of one block through the cache.

        The miss path claims the block in `_inflight` before reading, so a
        racing prefetch of the same block becomes a no-op — each block
        crosses the flash interface exactly once per residency."""
        while True:
            with self._lock:
                data = self._lru.get(idx)
                if data is not None:
                    self._lru.move_to_end(idx)
                    self.hits += 1
                    return data
                ev = self._inflight.get(idx)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[idx] = ev
                    break                      # we own this read
            # a prefetch (or another reader) owns it: wait, then re-check
            ev.wait()
            with self._lock:
                data = self._lru.get(idx)
                if data is not None:
                    self._lru.move_to_end(idx)
                    self.hits += 1
                    self.prefetch_hits += 1
                    return data
                # evicted before we woke (tiny cache): retry and own it
        try:
            data = self.blockfile.read_block(idx)
            with self._lock:
                self.misses += 1
                self._insert(idx, data)
        finally:
            with self._lock:
                self._inflight.pop(idx, None)
            ev.set()
        return data

    def get_many(self, idxs) -> dict[int, bytes]:
        """Demand-read a set of blocks; deduplicates within the request."""
        return {i: self.get(i) for i in dict.fromkeys(idxs)}

    # -- prefetch path (called from the Prefetcher worker) -------------------

    def prefetch(self, idx: int) -> None:
        """Pull one block into the cache ahead of demand; no-op if resident
        or already in flight."""
        with self._lock:
            if idx in self._lru or idx in self._inflight:
                return
            ev = threading.Event()
            self._inflight[idx] = ev
        try:
            data = self.blockfile.read_block(idx)
            with self._lock:
                self.prefetch_reads += 1
                self._insert(idx, data)
        finally:
            with self._lock:
                self._inflight.pop(idx, None)
            ev.set()

    def prefetch_get(self, idx: int) -> bytes:
        """Worker-side read: returns the block, counting any flash traffic
        as prefetch — never as a demand hit/miss (the chained prefetcher
        decodes neighbor rows without skewing the demand hit rate). Waits
        on in-flight reads like `get` does, preserving once-per-residency."""
        for _ in range(4):               # bounded retries under eviction races
            with self._lock:
                data = self._lru.get(idx)
                if data is not None:
                    return data
                ev = self._inflight.get(idx)
            if ev is not None:
                ev.wait()                # someone else is reading it
                continue
            self.prefetch(idx)           # claims _inflight or no-ops
            with self._lock:
                data = self._lru.get(idx)
                if data is not None:
                    return data
            # inserted and immediately evicted (tiny cache): try again
        with self._lock:                 # pathological thrash: counted read
            self.prefetch_reads += 1
        return self.blockfile.read_block(idx)

    # -- internals / stats ---------------------------------------------------

    def _insert(self, idx: int, data: bytes) -> None:
        # lock held. Evict before inserting so residency never exceeds the
        # configured capacity — the out-of-core memory bound.
        if idx in self._lru:
            return
        while self._lru and self.current_bytes + len(data) > self.capacity_bytes:
            _, old = self._lru.popitem(last=False)
            self.current_bytes -= len(old)
            self.evictions += 1
        self._lru[idx] = data
        self.current_bytes += len(data)
        self.peak_bytes = max(self.peak_bytes, self.current_bytes)

    def resize(self, capacity_bytes: int) -> None:
        """Shrink/grow the capacity in place, evicting LRU blocks down to
        the new bound. The ingest layer re-splits one `cache_bytes` budget
        across segment readers as segments appear, so the TOTAL resident
        cache stays bounded no matter how many segments are live. Clamped
        to one block (a cache that cannot hold a single read is useless)."""
        with self._lock:
            self.capacity_bytes = max(int(capacity_bytes), self.block_size)
            while self._lru and self.current_bytes > self.capacity_bytes:
                _, old = self._lru.popitem(last=False)
                self.current_bytes -= len(old)
                self.evictions += 1

    @property
    def block_reads(self) -> int:
        return self.misses + self.prefetch_reads

    @property
    def bytes_read(self) -> int:
        return self.block_reads * self.block_size

    @property
    def hit_rate(self) -> float:
        demand = self.hits + self.misses
        return self.hits / demand if demand else 0.0

    def snapshot(self) -> dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits, "misses": self.misses,
                "prefetch_reads": self.prefetch_reads,
                "prefetch_hits": self.prefetch_hits,
                "evictions": self.evictions,
                "block_reads": self.block_reads,
                "bytes_read": self.bytes_read,
                "current_bytes": self.current_bytes,
                "peak_bytes": self.peak_bytes,
            }
