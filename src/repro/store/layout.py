"""On-disk layout of the restructured DB (paper Fig. 5) + the row reader.

`write_store` persists a PartitionedDB as fixed-stride row tables inside
one block-aligned data file (see store/README.md for the byte-level
diagram); `StoreReader` is the serving-side object: manifest + BlockFile +
PageCache + optional Prefetcher, exposing `read_rows(table, rows)` — the
only way the search engine touches data, so all traffic is block-granular
and accounted.
"""

from __future__ import annotations

import numpy as np

from repro.core import hnsw_graph as hg
from repro.core.partitioned import PartitionedDB
from repro.obs.trace import TRACER
from repro.store.blockfile import BlockFile, BlockFileWriter
from repro.store.cache import PageCache
from repro.store.prefetch import Prefetcher

__all__ = ["write_store", "StoreReader", "open_store"]


def _partition_starts(db: hg.DeviceDB) -> list[int] | None:
    """First global id of each partition, when ids are contiguous ranges
    (build_partitioned_db always produces these). Enables the O(1)
    global-id -> (partition, local-row) mapping stage-2 rerank needs;
    None disables store-side rerank for exotic id layouts."""
    gids = np.asarray(db.gids)
    if gids.ndim == 1:
        gids = gids[None]
    n_valid = np.atleast_1d(np.asarray(db.n_valid))
    starts = []
    for p in range(gids.shape[0]):
        n = int(n_valid[p])
        g = gids[p, :n]
        if n == 0 or not np.array_equal(g, np.arange(g[0], g[0] + n)):
            return None
        starts.append(int(g[0]))
    return starts


def write_store(path: str, pdb: PartitionedDB, block_size: int = 4096,
                extra_tables: dict | None = None) -> None:
    """Persist the stacked DeviceDB as a committed block store.

    `extra_tables` appends additional fixed-stride row tables after the
    canonical set (e.g. the PQ store's `rerank_vectors` float32 table).
    `load_db` ignores them; they are only reachable through
    `StoreReader.read_rows`."""
    db = jax_to_host(pdb.db)
    tables, meta = hg.db_to_tables(db)
    meta.update({
        "dim": int(pdb.dim),
        "partition_starts": _partition_starts(db),
    })
    w = BlockFileWriter(path, block_size=block_size)
    try:
        for name in hg.TABLE_ORDER:
            w.add_table(name, tables[name])
        for name, rows in (extra_tables or {}).items():
            w.add_table(name, np.ascontiguousarray(rows))
    except BaseException:
        w.abort()
        raise
    w.finalize(meta)


def jax_to_host(db: hg.DeviceDB) -> hg.DeviceDB:
    return hg.DeviceDB(*(np.asarray(x) for x in db))


class StoreReader:
    """Row-granular reads over the block store, through the page cache.

    n_pad/d_pad/... mirror the DeviceDB geometry; `read_rows` returns host
    arrays assembled from cached blocks. All counters live on `self.cache`.
    """

    def __init__(self, path: str, cache_bytes: int, prefetch: bool = True):
        self.path = path
        self.blockfile = BlockFile(path)
        self.cache = PageCache(self.blockfile, cache_bytes)
        self.prefetcher = Prefetcher(self.cache) if prefetch else None
        self.meta = self.blockfile.meta
        self.block_size = self.blockfile.block_size
        for k in ("num_partitions", "n_pad", "d_pad", "m0_pad", "n_layers",
                  "up_pad", "m_pad", "dim"):
            setattr(self, k, int(self.meta[k]))
        self.entry = np.asarray(self.meta["entry"], np.int32)
        self.max_level = np.asarray(self.meta["max_level"], np.int32)
        self.n_valid = np.asarray(self.meta["n_valid"], np.int32)
        ps = self.meta.get("partition_starts")
        self.partition_starts = None if ps is None else np.asarray(ps, np.int64)

    # -- row addressing ------------------------------------------------------

    def row(self, table: str, p: int, i) -> np.ndarray:
        """Row index of point(s) i of partition p in a per-point table."""
        return np.asarray(i, np.int64) + p * self.n_pad

    def up_row(self, p: int, layer: int, r) -> np.ndarray:
        """Row index into the upper-list table for (partition, layer, slot)."""
        return np.asarray(r, np.int64) + (p * self.n_layers + layer) * self.up_pad

    def blocks_of_rows(self, table: str, rows) -> list[int]:
        out: dict[int, None] = {}
        for r in np.asarray(rows, np.int64).ravel():
            for b in self.blockfile.blocks_of_row(table, int(r)):
                out[b] = None
        return list(out)

    # -- reads ---------------------------------------------------------------

    def read_rows(self, table: str, rows, _get=None) -> np.ndarray:
        """Gather rows (any shape of indices) -> array [..., cols].

        Duplicate rows inside one request are fetched once (the engine
        batches a whole hop's gathers into one call — the paper's wide
        block read)."""
        t = self.blockfile.tables[table]
        idx = np.asarray(rows, np.int64)
        flat = idx.ravel()
        dtype = np.dtype(t["dtype"])
        cols, bs = t["cols"], self.block_size
        uniq, inv = np.unique(flat, return_inverse=True)
        need = self.blocks_of_rows(table, uniq)
        # child_span: only records under an already-sampled span on this
        # thread — prefetcher-worker calls (and untraced callers) stay free.
        with TRACER.child_span("store-read", table=table, rows=len(uniq),
                               blocks=len(need)):
            if _get is None:
                blocks = self.cache.get_many(need)
            else:
                blocks = {b: _get(b) for b in need}
        out = np.empty((len(uniq), cols), dtype)
        for j, r in enumerate(uniq):
            start, end = self.blockfile.row_span(table, int(r))
            b0, b1 = start // bs, (end - 1) // bs
            if b0 == b1:
                buf = blocks[b0][start - b0 * bs:end - b0 * bs]
            else:
                parts = []
                for b in range(b0, b1 + 1):
                    lo = max(start, b * bs) - b * bs
                    hi = min(end, (b + 1) * bs) - b * bs
                    parts.append(blocks[b][lo:hi])
                buf = b"".join(parts)
            out[j] = np.frombuffer(buf, dtype)
        return out[inv].reshape(idx.shape + (cols,))

    # -- prefetch hooks ------------------------------------------------------

    def prefetch_rows(self, table: str, rows) -> None:
        if self.prefetcher is not None:
            self.prefetcher.prefetch_blocks(self.blocks_of_rows(table, rows))

    def prefetch_next_hop(self, p: int, cand_ids: np.ndarray) -> None:
        """Chained next-hop prefetch: pull the l0 neighbor-list rows of the
        likely next pops, parse them on the worker, then pull the vector
        blocks those neighbors live in — all overlapped with device compute."""
        if self.prefetcher is None:
            return
        cand = [int(c) for c in np.asarray(cand_ids).ravel() if c >= 0]
        if not cand:
            return
        l0_blocks = self.blocks_of_rows("l0_nbrs", self.row("l0_nbrs", p, cand))

        def task():
            for b in l0_blocks:
                self.cache.prefetch(b)
            nbrs = self._parse_l0_rows(p, cand)
            if len(nbrs):
                vec_rows = self.row("vectors", p, nbrs)
                for b in self.blocks_of_rows("vectors", vec_rows):
                    self.cache.prefetch(b)

        self.prefetcher.submit(task)

    def _parse_l0_rows(self, p: int, ids) -> np.ndarray:
        """Worker-side decode of the just-prefetched l0 rows; traffic counts
        as prefetch, never as demand."""
        rows = self.read_rows("l0_nbrs", self.row("l0_nbrs", p, ids),
                              _get=self.cache.prefetch_get)
        flat = rows.ravel()
        return np.unique(flat[flat >= 0])

    # -- lifecycle / debug ---------------------------------------------------

    def load_db(self) -> hg.DeviceDB:
        """Materialize the full DeviceDB in host memory (tests and small
        stores only — this defeats the out-of-core purpose by design)."""
        tables = {}
        for name, t in self.blockfile.tables.items():
            tables[name] = self.read_rows(name, np.arange(t["rows"]))
        return hg.db_from_tables(tables, self.meta)

    def close(self) -> None:
        if self.prefetcher is not None:
            self.prefetcher.close()
            self.prefetcher = None


def open_store(path: str, cache_bytes: int, prefetch: bool = True) -> StoreReader:
    return StoreReader(path, cache_bytes, prefetch=prefetch)
