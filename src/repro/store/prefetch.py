"""Async next-hop prefetcher (paper §5.2: overlap flash reads with compute).

While the device evaluates hop t, the worker thread pulls the blocks hop
t+1 will touch: the layer-0 neighbor-list rows of the next candidates, and
— chained — the vector blocks of the neighbors those rows name. Blocks land
in the shared PageCache; the demand path then hits (or waits on the
in-flight read instead of issuing a second one), so every block still
crosses the "flash" interface exactly once per residency.

Best-effort by design: a failed or late prefetch degrades to a demand miss,
never to a wrong result.
"""

from __future__ import annotations

import queue
import threading

from repro.store.cache import PageCache

__all__ = ["Prefetcher"]

_STOP = object()


class Prefetcher:
    def __init__(self, cache: PageCache):
        self.cache = cache
        self._q: queue.Queue = queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            task = self._q.get()
            if task is _STOP:
                return
            try:
                task()
            except Exception:
                pass  # best-effort: the demand path re-reads on miss

    def submit(self, fn) -> None:
        """Queue an arbitrary prefetch task (used for chained next-hop
        fetches that must parse a neighbor row before knowing its blocks)."""
        self._q.put(fn)

    def prefetch_blocks(self, idxs) -> None:
        cache = self.cache
        blocks = list(dict.fromkeys(idxs))

        def task():
            for i in blocks:
                cache.prefetch(i)

        self._q.put(task)

    def drain(self) -> None:
        """Block until every queued task has run (tests / deterministic
        accounting)."""
        done = threading.Event()
        self._q.put(done.set)
        done.wait()

    def close(self) -> None:
        self._q.put(_STOP)
        self._thread.join(timeout=5)
