"""repro.store — block-aligned storage-resident vector/graph store.

The paper's database lives on SmartSSD flash and reaches the accelerator
as block-granular P2P-DMA reads; this package models that tier so datasets
larger than host memory are a supported scenario:

  blockfile : block-aligned data file + manifest + commit marker
  cache     : LRU PageCache with hit/miss/bytes-read counters (Fig. 9's
              "number of vector reads" for the storage tier)
  prefetch  : async next-hop prefetcher overlapping flash reads with compute
  layout    : paper Fig. 5 table layout + the row-granular StoreReader
  csd       : the out-of-core two-stage engine, registered as the `csd`
              backend of repro.api
  segments  : segment directory of a mutable store (repro.ingest): one
              committed block store per sealed segment + an atomically
              swapped segments.json — appends never rewrite existing blocks
"""

from repro.store.blockfile import (
    BlockFile,
    BlockFileWriter,
    StoreFormatError,
)
from repro.store.cache import PageCache
from repro.store.csd import CSDBackend, store_search
from repro.store.layout import StoreReader, open_store, write_store
from repro.store.prefetch import Prefetcher
from repro.store.segments import (
    append_segment,
    list_segments,
    replace_segments,
    segment_dir,
)

__all__ = [
    "append_segment",
    "list_segments",
    "replace_segments",
    "segment_dir",
    "BlockFile",
    "BlockFileWriter",
    "StoreFormatError",
    "PageCache",
    "Prefetcher",
    "StoreReader",
    "open_store",
    "write_store",
    "CSDBackend",
    "store_search",
]
