"""Compaction: merge live segments + tombstones into one rebuilt segment.

The LSM-style maintenance step of the mutable index. Searches fan out over
every live segment, so cost grows with segment count and with tombstone
debt (dead rows still burn traversal hops and over-fetch slots until they
are reclaimed). `compact()` restores both: it gathers every *surviving*
row (local-order reads through each segment's own backend — page-cache
reads for csd), rebuilds one segment with the spec's full partition count
via `SearchService.build`, and swaps it in.

Because the rebuild goes through the exact same build path as a
from-scratch index, a compacted csd segment is bit-identical to an
in-memory `partitioned` build over the same merged rows — the parity
tests pin that.

Write amplification: one compaction rewrites `survivors * row_bytes`
while ingestion appended `inserted * row_bytes` — the
`launch/costmodel.compaction_cost` term models this tradeoff at SIFT1B
scale and `ann_dryrun` reports it.
"""

from __future__ import annotations

import numpy as np

from repro.ingest.segments import Segment, build_segment, segment_vectors
from repro.ingest.tombstones import TombstoneSet

__all__ = ["merge_survivors", "compact_segments", "CompactionResult"]


class CompactionResult:
    """What one compaction did (sizes in rows; bytes derived by callers)."""

    def __init__(self, merged: Segment | None, old_names: list[str],
                 rows_read: int, rows_written: int, rows_reclaimed: int):
        self.merged = merged
        self.old_names = old_names
        self.rows_read = rows_read
        self.rows_written = rows_written
        self.rows_reclaimed = rows_reclaimed


def merge_survivors(segments: list[Segment], tombstones: TombstoneSet
                    ) -> tuple[np.ndarray, np.ndarray, int]:
    """Gather (vectors, gids) of every non-tombstoned row, sorted by gid.

    Returns (vectors [n, D], gids [n], rows_read)."""
    vecs, gids, rows_read = [], [], 0
    for seg in segments:
        rows_read += seg.n
        live = ~tombstones.contains(seg.gid_map)
        if not live.any():
            continue
        v = segment_vectors(seg)
        vecs.append(v[live])
        gids.append(seg.gid_map[live])
    if not vecs:
        return (np.zeros((0, 0), np.float32), np.zeros(0, np.int64),
                rows_read)
    v = np.concatenate(vecs)
    g = np.concatenate(gids)
    order = np.argsort(g, kind="stable")
    return v[order], g[order], rows_read


def compact_segments(spec, segments: list[Segment],
                     tombstones: TombstoneSet, name: str, *,
                     storage_path: str | None = None,
                     cache_bytes: int | None = None) -> CompactionResult:
    """Rebuild `segments` minus tombstones into one segment named `name`.

    Pure build step — the caller owns publication (store segment-manifest
    swap, in-memory list swap, tombstone retirement), so a failed build
    leaves the index untouched."""
    old_names = [s.name for s in segments]
    vectors, gids, rows_read = merge_survivors(segments, tombstones)
    if gids.size == 0:
        return CompactionResult(None, old_names, rows_read, 0, rows_read)
    seg = build_segment(spec, name, vectors, gids,
                        storage_path=storage_path, cache_bytes=cache_bytes)
    return CompactionResult(seg, old_names, rows_read, int(gids.size),
                            rows_read - int(gids.size))
