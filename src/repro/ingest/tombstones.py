"""Tombstone set: deleted global ids as a growable packed bitmap.

`delete(ids)` in the mutable index never touches segment data — it only
sets bits here (the same single-bit-per-point trick as the search kernel's
visited list, paper §5.1.1). The bitmap is consulted at result-merge time,
so a deleted id can never surface, and at seal/compaction time, when the
space is actually reclaimed. One bit per assigned global id: 1 GB of
tombstones covers 8G inserts, so the bitmap itself never needs segmenting.
"""

from __future__ import annotations

import numpy as np

__all__ = ["TombstoneSet"]


class TombstoneSet:
    """Packed uint32 bitmap over the global-id space, grown on demand."""

    def __init__(self, words: np.ndarray | None = None):
        self._words = (np.zeros(4, np.uint32) if words is None
                       else np.ascontiguousarray(words, np.uint32).copy())
        self.count = int(np.unpackbits(self._words.view(np.uint8)).sum())

    def _grow(self, max_id: int) -> None:
        need = (max_id >> 5) + 1
        if need > self._words.size:
            grown = np.zeros(max(need, 2 * self._words.size), np.uint32)
            grown[: self._words.size] = self._words
            self._words = grown

    def add(self, ids) -> int:
        """Mark ids deleted; returns how many were newly dead."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size == 0:
            return 0
        if (ids < 0).any():
            raise ValueError("tombstones take non-negative global ids")
        self._grow(int(ids.max()))
        ids = np.unique(ids)
        fresh = ~self.contains(ids)
        w, b = ids >> 5, (ids & 31).astype(np.uint32)
        np.bitwise_or.at(self._words, w[fresh],
                         np.left_shift(np.uint32(1), b[fresh]))
        self.count += int(fresh.sum())
        return int(fresh.sum())

    def discard(self, ids) -> None:
        """Clear bits (compaction: the merged segment no longer holds the
        dead rows, so their ids stop counting toward the live-debt)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size == 0:
            return
        ids = np.unique(ids[ids < self._words.size * 32])
        dead = self.contains(ids)
        w, b = ids >> 5, (ids & 31).astype(np.uint32)
        np.bitwise_and.at(self._words, w[dead],
                          ~np.left_shift(np.uint32(1), b[dead]))
        self.count -= int(dead.sum())

    def contains(self, ids) -> np.ndarray:
        """Boolean mask over `ids` (any shape); negative ids are False."""
        ids = np.asarray(ids, np.int64)
        safe = np.clip(ids, 0, self._words.size * 32 - 1)
        out = ((self._words[safe >> 5]
                >> (safe & 31).astype(np.uint32)) & np.uint32(1)) > 0
        return out & (ids >= 0) & (ids < self._words.size * 32)

    def copy(self) -> "TombstoneSet":
        return TombstoneSet(self._words)

    # -- persistence ---------------------------------------------------------

    def words(self) -> np.ndarray:
        return self._words.copy()

    @classmethod
    def from_words(cls, words: np.ndarray) -> "TombstoneSet":
        return cls(words)

    def __len__(self) -> int:
        return self.count
