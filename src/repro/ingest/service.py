"""MutableSearchService: streaming inserts + tombstone deletes over
`repro.api`, with LSM-style sealed segments and background-able compaction.

    from repro.api import IndexSpec, MutableSearchService, SearchRequest

    svc = MutableSearchService(IndexSpec(backend="partitioned"),
                               seal_threshold=1024)
    gids = svc.insert(vectors)          # global ids, assigned monotonically
    svc.delete(gids[:100])              # tombstoned; never surfaces again
    resp = svc.search(SearchRequest(queries, k=10, ef=40))
    svc.flush()                         # seal the memtable explicitly
    svc.compact()                       # merge segments + reclaim space
    svc.save(path); MutableSearchService.load(path)   # manifest v2

Search fans out over the memtable (exact scan) and every sealed segment
(each one is a normal `SearchService` — partitioned/csd hop kernels
unchanged: a segment is just one more partition), filters tombstones, and
rank-merges the per-source top-k — the same stage-2 reduction as the
two-stage engine; `rerank=True` re-scores inside each segment first, so
the merged distances are exact.

Consistency: one lock guards all mutations; `search` snapshots (segment
list, tombstone bitmap, memtable rows) under that lock and then runs
lock-free, so a query batch always sees one atomic state — the snapshot
semantics `repro.serve` relies on to interleave writes with batched reads.

Memory (csd backend): segment PageCaches share ONE `spec.cache_bytes`
budget — the budget is re-split (`PageCache.resize`) whenever the live
segment set changes — so peak resident store memory stays
`max(cache_bytes, n_segments * block_size)` + the memtable buffer no
matter how many rows stream in. `peak_resident_bytes` tracks the
high-water mark and the ingest CI job asserts the bound.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading

import numpy as np

from repro.api import metrics as _metrics
from repro.api.service import SearchService
from repro.api.types import (IndexSpec, QueryStats, SearchRequest,
                             SearchResponse)
from repro.core.merge import mask_dead_lanes, rank_merge
from repro.ingest.compactor import compact_segments
from repro.ingest.memtable import Memtable
from repro.ingest.segments import Segment, seal_memtable
from repro.ingest.tombstones import TombstoneSet
from repro.obs.metrics import REGISTRY, next_uid
from repro.obs.trace import TRACER

__all__ = ["MutableSearchService", "MUTABLE_FORMAT_VERSION",
           "MUTABLE_MANIFEST_NAME"]

# v1 is the immutable SearchService manifest; v2 adds the segment list,
# tombstones, and the memtable — a half-compacted index round-trips.
MUTABLE_FORMAT_VERSION = 2
MUTABLE_MANIFEST_NAME = "index_manifest.json"

_SUPPORTED = ("exact", "hnsw", "partitioned", "csd")
# Per-source over-fetch ceiling: k + tombstone-debt is clamped here so a
# pathological pile of deletes degrades recall instead of blowing up the
# scan kernels (compact() is the actual fix for that much debt).
_MAX_FETCH = 256


def _collect_ingest(svc: "MutableSearchService"):
    """Snapshot-time metric samples (repro.obs registry collector)."""
    labels = {"index": svc.uid}
    return [
        ("counter", "ingest_rows_inserted_total", labels, svc._next_gid),
        ("counter", "ingest_rows_deleted_total", labels, svc._deleted_total),
        ("counter", "ingest_compactions_total", labels, svc._compactions),
        ("gauge", "ingest_segments", labels, svc.num_segments),
        ("gauge", "ingest_live_rows", labels, svc.size),
        ("gauge", "ingest_resident_bytes", labels, svc.resident_bytes()),
        ("gauge", "ingest_peak_resident_bytes", labels,
         svc.peak_resident_bytes),
    ]


class MutableSearchService:
    """A segmented, mutable index over one immutable-backend spec."""

    def __init__(self, spec: IndexSpec | None = None, *,
                 seal_threshold: int = 1024):
        spec = spec or IndexSpec()
        if spec.backend not in _SUPPORTED:
            raise ValueError(
                f"mutable indexes support backends {_SUPPORTED}; got "
                f"{spec.backend!r} (distributed segments would need a "
                f"mesh-wide seal — build those immutably)")
        if spec.dtype != "float32":
            raise ValueError(
                "mutable indexes are float32-only for now: per-segment "
                "quantizer fitting would make distances drift across "
                "segments as the data churns")
        metric = _metrics.get_metric(spec.metric)
        if spec.backend != "exact" and not metric.graph_safe:
            raise ValueError(
                f"metric {spec.metric!r} is not graph-safe: use "
                f"backend='exact' (same rule as SearchService.build)")
        if seal_threshold < 1:
            raise ValueError(f"seal_threshold must be >= 1, "
                             f"got {seal_threshold}")
        if spec.backend == "csd" and not spec.storage_path:
            raise ValueError(
                "backend='csd' needs IndexSpec(storage_path=...): the "
                "segment block stores live there")
        self.spec = spec
        self.metric = metric
        self.seal_threshold = int(seal_threshold)
        self.backend = None               # duck-typing for serve stats
        self._lock = threading.RLock()
        self._compact_lock = threading.Lock()   # serializes compactions
        self._segments: list[Segment] = []
        self._tombstones = TombstoneSet()
        self._memtable: Memtable | None = None     # created on first insert
        self._dim: int | None = None
        self._next_gid = 0
        self._next_seg = 0
        self.peak_resident_bytes = 0
        self.peak_storage_resident_bytes = 0
        self._deleted_total = 0            # monotonic (tombstones shrink)
        self._compactions = 0
        self.uid = next_uid()
        REGISTRY.register_collector(self, _collect_ingest)

    # -- introspection -------------------------------------------------------

    @property
    def num_segments(self) -> int:
        with self._lock:
            return len(self._segments)

    @property
    def size(self) -> int:
        """Live (non-tombstoned) row count."""
        with self._lock:
            total = sum(s.n - s.n_deleted for s in self._segments)
            if self._memtable is not None and len(self._memtable):
                _, gids = self._memtable.snapshot()
                total += int((~self._tombstones.contains(gids)).sum())
            return total

    def storage_resident_bytes(self) -> int:
        """Bytes currently held by segment page caches. Structurally
        bounded by max(cache_bytes, n_segments * block_size): the one
        budget is re-split across readers as the segment set changes."""
        with self._lock:
            total = 0
            for seg in self._segments:
                reader = getattr(seg.service.backend, "reader", None)
                if reader is not None:
                    total += reader.cache.current_bytes
            return total

    def resident_bytes(self) -> int:
        """Current resident bytes: segment page caches + memtable buffer."""
        with self._lock:
            total = self.storage_resident_bytes()
            if self._memtable is not None:
                total += self._memtable.nbytes
            return total

    def _note_resident(self) -> None:
        self.peak_storage_resident_bytes = max(
            self.peak_storage_resident_bytes, self.storage_resident_bytes())
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes())

    # -- mutations -----------------------------------------------------------

    def insert(self, vectors) -> np.ndarray:
        """Add rows; returns their newly-assigned global ids [n]. Seals the
        memtable into a segment whenever it reaches `seal_threshold`."""
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        prepared = self.metric.prepare_data(vectors)
        with self._lock:
            if self._dim is None:
                self._dim = int(prepared.shape[1])
            elif prepared.shape[1] != self._dim:
                raise ValueError(f"expected dim {self._dim}, "
                                 f"got {prepared.shape[1]}")
            gids = np.arange(self._next_gid,
                             self._next_gid + len(prepared), dtype=np.int64)
            self._next_gid += len(prepared)
            if self._memtable is None:
                self._memtable = Memtable(self._dim, self.spec.hnsw,
                                          build_graph=self.spec.backend
                                          != "exact")
            # seal in threshold-sized waves so one huge insert cannot grow
            # the memtable unboundedly past the threshold
            off = 0
            while off < len(prepared):
                room = self.seal_threshold - len(self._memtable)
                take = min(room, len(prepared) - off)
                self._memtable.insert(prepared[off: off + take],
                                      gids[off: off + take])
                off += take
                if len(self._memtable) >= self.seal_threshold:
                    self._seal_locked()
            self._note_resident()
        return gids

    def delete(self, gids) -> int:
        """Tombstone global ids; returns how many were newly deleted.
        Deleted ids never surface again (asserted in tests, including
        through rerank); space comes back at seal/compaction time."""
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        with self._lock:
            known = np.unique(gids[(gids >= 0) & (gids < self._next_gid)])
            fresh_mask = ~self._tombstones.contains(known)
            fresh = known[fresh_mask]
            self._tombstones.add(known)
            for seg in self._segments:
                seg.n_deleted += int(seg.contains(fresh).sum())
            self._deleted_total += int(fresh.size)
            return int(fresh.size)

    def flush(self) -> None:
        """Seal the memtable into a segment now (no-op when empty)."""
        with self._lock:
            self._seal_locked()
            self._note_resident()

    def compact(self) -> dict:
        """Merge every live segment (memtable flushed first) plus the
        tombstones into one rebuilt segment; returns a summary dict. Space
        is reclaimed and per-query fan-out drops back to one segment.

        Concurrent compactions serialize on their own lock (two racing
        rebuilds over the same snapshot would publish every row twice);
        searches and mutations are NOT blocked by a running rebuild.

        csd note: compaction deletes the merged-away segment stores, so a
        `save()` taken earlier — whose manifests reference those stores
        without copying them, the block store's standing no-copy contract
        — is superseded; re-`save()` after compacting to keep a loadable
        snapshot."""
        with self._compact_lock:
            with self._lock:
                self._seal_locked()
                segments = list(self._segments)
                tomb = self._tombstones.copy()
                name = self._seg_name()
            # the expensive rebuild runs outside the service lock: searches
            # keep serving from the old segment list, mutations queue on
            # the lock only for the final swap below
            result = compact_segments(
                self.spec, segments, tomb, name,
                storage_path=self._seg_storage(name),
                cache_bytes=self._cache_budget(1))
            with self._lock:
                if self.spec.backend == "csd" and segments:
                    from repro.store.segments import replace_segments
                    replace_segments(self.spec.storage_path,
                                     [s.name for s in segments],
                                     [result.merged.name]
                                     if result.merged else [])
                # retire only the tombstones this rebuild actually dropped
                # — a delete() that raced the lock-free rebuild keeps its
                # bit set and keeps filtering the merged segment's rows
                for s in segments:
                    was_dead = tomb.contains(s.gid_map)
                    self._tombstones.discard(s.gid_map[was_dead])
                merged = []
                if result.merged is not None:
                    result.merged.n_deleted = int(self._tombstones.contains(
                        result.merged.gid_map).sum())
                    merged = [result.merged]
                old_ids = set(map(id, segments))
                self._segments = merged + [s for s in self._segments
                                           if id(s) not in old_ids]
                self._rebalance_caches_locked()
                self._note_resident()
                self._compactions += 1
            return {"merged_segments": len(segments),
                    "rows_read": result.rows_read,
                    "rows_written": result.rows_written,
                    "rows_reclaimed": result.rows_reclaimed,
                    "live_segments": self.num_segments}

    def close(self) -> None:
        """Close segment store readers (csd); in-memory backends are GC'd."""
        with self._lock:
            for seg in self._segments:
                reader = getattr(seg.service.backend, "reader", None)
                if reader is not None:
                    reader.close()

    # -- sealing internals ---------------------------------------------------

    def _seg_name(self) -> str:
        name = f"seg_{self._next_seg:08d}"
        self._next_seg += 1
        return name

    def _seg_storage(self, name: str) -> str | None:
        if self.spec.backend != "csd":
            return None
        return os.path.join(self.spec.storage_path, name)

    def _cache_budget(self, n_segments: int) -> int | None:
        if self.spec.backend != "csd":
            return None
        return max(self.spec.block_size,
                   self.spec.cache_bytes // max(1, n_segments))

    def _rebalance_caches_locked(self) -> None:
        """Re-split the one cache_bytes budget over the live csd readers."""
        if self.spec.backend != "csd":
            return
        budget = self._cache_budget(len(self._segments))
        for seg in self._segments:
            reader = getattr(seg.service.backend, "reader", None)
            if reader is not None:
                reader.cache.resize(budget)

    def _seal_locked(self) -> None:
        mem = self._memtable
        if mem is None or len(mem) == 0:
            return
        vectors, gids = mem.snapshot()
        dead = self._tombstones.contains(gids)
        if dead.any():
            # dead rows never reach a segment: drop them now and retire
            # their tombstones (the space debt is settled at the source);
            # the incremental graph contains them, so rebuild the survivors
            self._tombstones.discard(gids[dead])
            vectors, gids = vectors[~dead], gids[~dead]
            graph = None
        else:
            graph = mem.graph() if mem.build_graph else None
        self._memtable = Memtable(self._dim, self.spec.hnsw,
                                  build_graph=mem.build_graph)
        if gids.size == 0:
            return
        name = self._seg_name()
        seg = seal_memtable(
            self.spec, name, vectors, gids, graph,
            storage_path=self._seg_storage(name),
            cache_bytes=self._cache_budget(len(self._segments) + 1))
        if self.spec.backend == "csd":
            from repro.store.segments import append_segment
            append_segment(self.spec.storage_path, name)
        self._segments.append(seg)
        self._rebalance_caches_locked()

    # -- search --------------------------------------------------------------

    def search(self, request: SearchRequest) -> SearchResponse:
        """Snapshot-consistent fan-out over memtable + live segments."""
        if not isinstance(request, SearchRequest):
            request = SearchRequest(queries=request)
        with self._lock:                       # one atomic snapshot
            segments = list(self._segments)
            tomb = self._tombstones.copy()
            mem = (self._memtable.snapshot() if self._memtable is not None
                   else None)
        queries = np.atleast_2d(np.asarray(request.queries, np.float32))
        b, k = queries.shape[0], request.k

        all_ids, all_ds = [], []
        seg_stats: list[dict] = []
        agg = {"hops": None, "dist_calcs": None, "block_reads": 0,
               "cache_hits": 0, "cache_misses": 0, "bytes_read": 0,
               "saw_cache": False}

        def _acc(stats, name: str, n: int):
            if stats is None:
                return
            row = {"segment": name, "n": n}
            for f in ("hops", "dist_calcs"):
                v = getattr(stats, f)
                if v is not None:
                    v = np.asarray(v)
                    row[f] = float(v.mean())
                    agg[f] = v if agg[f] is None else agg[f] + v
            for f in ("block_reads", "cache_hits", "cache_misses",
                      "bytes_read"):
                v = getattr(stats, f)
                if v is not None:
                    row[f] = int(v)
                    agg[f] += int(v)
                    if f in ("cache_hits", "cache_misses"):
                        agg["saw_cache"] = True
            seg_stats.append(row)

        # the fan-out span: ambient nesting wins (replica dispatch span);
        # the batcher-stamped request ctx only parents on a cold thread
        if request.trace is not None and TRACER.current_ctx() is None:
            span = TRACER.span("search", parent=request.trace,
                               backend="mutable", k=request.k)
        else:
            span = TRACER.span("search", backend="mutable", k=request.k)
        with span:
            for seg in segments:
                # the clamp bounds tombstone OVER-fetch only — never k itself
                k_fetch = max(k, min(k + seg.n_deleted, _MAX_FETCH))
                with TRACER.child_span("segment", segment=seg.name):
                    gids, ds, stats = seg.search(
                        queries, k=k_fetch, ef=request.ef,
                        rerank=request.rerank,
                        with_stats=request.with_stats)
                gids, ds = mask_dead_lanes(gids, ds, tomb.contains(gids))
                all_ids.append(gids)
                all_ds.append(ds)
                if request.with_stats:
                    _acc(stats, seg.name, seg.n)

            if mem is not None and mem[1].size:
                mem_dead = int(tomb.contains(mem[1]).sum())
                k_fetch = max(k, min(k + mem_dead, _MAX_FETCH))
                mq = self.metric.prepare_queries(queries)
                with TRACER.child_span("memtable", rows=int(mem[1].size)):
                    ids, ds = Memtable.scan(mem[0], mem[1], mq, k_fetch,
                                            self.spec.metric)
                ids, ds = mask_dead_lanes(ids, ds, tomb.contains(ids))
                all_ids.append(ids)
                all_ds.append(ds)
                if request.with_stats:
                    calcs = np.full((b,), mem[1].size, np.int64)
                    _acc(QueryStats(dist_calcs=calcs), "memtable",
                         mem[1].size)

            if not all_ids:
                return SearchResponse(
                    ids=np.full((b, k), -1, np.int64),
                    dists=np.full((b, k), np.inf, np.float32))
            # stage-2 rank merge across sources (core.merge.rank_merge — the
            # same reduction the cluster router uses): tombstoned lanes carry
            # +inf so they can never displace a live id
            out_i, out_d = rank_merge(all_ids, all_ds, k)
        stats = None
        if request.with_stats:
            self._note_resident()
            # demand-weighted hit rate over all csd segments — the same
            # formula as one cache (hits / (hits + misses)), computed from
            # the summed counters, never by averaging per-segment rates
            demand = agg["cache_hits"] + agg["cache_misses"]
            hit_rate = ((agg["cache_hits"] / demand if demand else 0.0)
                        if agg["saw_cache"] else None)
            stats = QueryStats(
                hops=agg["hops"], dist_calcs=agg["dist_calcs"],
                block_reads=agg["block_reads"] or None,
                cache_hits=agg["cache_hits"] or None,
                cache_misses=agg["cache_misses"] or None,
                cache_hit_rate=hit_rate,
                bytes_read=agg["bytes_read"] or None,
                segments=seg_stats)
        return SearchResponse(ids=out_i, dists=out_d, stats=stats)

    # -- persistence (manifest v2) -------------------------------------------

    def save(self, path: str) -> str:
        """Persist the whole mutable state — segments, tombstones, and the
        un-sealed memtable — so a half-compacted index round-trips."""
        with self._lock:
            os.makedirs(path, exist_ok=True)
            seg_root = os.path.join(path, "segments")
            os.makedirs(seg_root, exist_ok=True)
            live = {s.name for s in self._segments}
            for stale in os.listdir(seg_root):        # dropped by compaction
                if stale not in live:
                    shutil.rmtree(os.path.join(seg_root, stale),
                                  ignore_errors=True)
            entries = []
            for seg in self._segments:
                d = os.path.join(seg_root, seg.name)
                seg.service.save(d)
                np.save(os.path.join(d, "gid_map.npy"), seg.gid_map)
                entries.append({"name": seg.name, "n": seg.n,
                                "n_deleted": int(seg.n_deleted)})
            np.save(os.path.join(path, "tombstones.npy"),
                    self._tombstones.words())
            if self._memtable is not None and len(self._memtable):
                mv, mg = self._memtable.snapshot()
            else:
                mv = np.zeros((0, self._dim or 0), np.float32)
                mg = np.zeros(0, np.int64)
            np.save(os.path.join(path, "memtable_vectors.npy"), mv)
            np.save(os.path.join(path, "memtable_gids.npy"), mg)
            manifest = {
                "format_version": MUTABLE_FORMAT_VERSION,
                "kind": "mutable-segmented-index",
                "spec": self.spec.to_json(),
                "seal_threshold": self.seal_threshold,
                "next_gid": int(self._next_gid),
                "next_seg": int(self._next_seg),
                "dim": self._dim,
                "segments": entries,
            }
            tmp = os.path.join(path, MUTABLE_MANIFEST_NAME + ".tmp")
            with open(tmp, "w") as f:
                json.dump(manifest, f, indent=1)
            os.replace(tmp, os.path.join(path, MUTABLE_MANIFEST_NAME))
            return path

    @classmethod
    def load(cls, path: str) -> "MutableSearchService":
        with open(os.path.join(path, MUTABLE_MANIFEST_NAME)) as f:
            manifest = json.load(f)
        version = manifest.get("format_version")
        if version != MUTABLE_FORMAT_VERSION:
            raise ValueError(
                f"index at {path!r} has format_version={version}; mutable "
                f"indexes are version {MUTABLE_FORMAT_VERSION} "
                f"(SearchService.load reads version 1, and version 3 — "
                f"a product-quantized immutable index)")
        spec = IndexSpec.from_json(manifest["spec"])
        svc = cls(spec, seal_threshold=int(manifest["seal_threshold"]))
        svc._dim = manifest["dim"]
        svc._next_gid = int(manifest["next_gid"])
        svc._next_seg = int(manifest["next_seg"])
        budget = svc._cache_budget(max(1, len(manifest["segments"])))
        for e in manifest["segments"]:
            d = os.path.join(path, "segments", e["name"])
            sub = SearchService.load(d)
            if budget is not None:
                reader = getattr(sub.backend, "reader", None)
                if reader is not None:
                    reader.cache.resize(budget)
            gid_map = np.load(os.path.join(d, "gid_map.npy"))
            svc._segments.append(Segment(e["name"], sub, gid_map,
                                         n_deleted=int(e["n_deleted"])))
        svc._tombstones = TombstoneSet.from_words(
            np.load(os.path.join(path, "tombstones.npy")))
        mv = np.load(os.path.join(path, "memtable_vectors.npy"))
        mg = np.load(os.path.join(path, "memtable_gids.npy"))
        if len(mg):
            svc._memtable = Memtable(svc._dim, spec.hnsw,
                                     build_graph=spec.backend != "exact")
            svc._memtable.insert(mv, mg)   # replays the incremental graph
        return svc
