"""Memtable: the small mutable head of a segmented index.

Absorbs `insert()` calls two ways at once, both below a seal threshold:

  * the vectors land in a growable host array that is **exact-scanned** at
    query time with the same blocked brute-force kernel the `exact`
    backend uses (`core.bruteforce.bruteforce_topk`, identical CHUNK
    padding) — so a memtable answer is bit-identical to an `exact`-backend
    segment over the same rows;
  * every insert is also fed through `core.hnsw_graph.GraphBuilder.
    insert_point` — the insertion routine factored out of `build_hnsw` —
    so by the time the memtable seals, its HNSW graph already exists and
    sealing is a pure `restructure()` (no O(n²·log n) rebuild pause).

Deletes are NOT applied here (tombstones filter at merge time); sealing
drops dead rows, so a tombstoned memtable row never reaches a segment.
"""

from __future__ import annotations

import numpy as np

from repro.core import hnsw_graph as hg
from repro.core.bruteforce import bruteforce_topk

__all__ = ["Memtable"]

_CHUNK = 512        # ExactBackend.CHUNK — keep the scan bit-identical


class Memtable:
    """Growable (vectors, global-ids) buffer + incremental HNSW graph."""

    def __init__(self, dim: int, cfg: hg.HNSWConfig, build_graph: bool = True):
        self.dim = int(dim)
        self.cfg = cfg
        self.build_graph = build_graph
        self._gids = np.full(64, -1, np.int64)
        self.n = 0
        # graph memtables read their vectors out of the builder's own
        # table — one resident copy, not two (the memory bound counts it)
        self._builder = (hg.GraphBuilder(self.dim, cfg) if build_graph
                         else None)
        self._vectors = (None if build_graph
                         else np.zeros((64, self.dim), np.float32))

    def __len__(self) -> int:
        return self.n

    @property
    def nbytes(self) -> int:
        """Resident bytes (vector table + id map + builder link tables)."""
        total = self._gids.nbytes
        if self._builder is not None:
            b = self._builder
            total += (b._vectors.nbytes + b._levels.nbytes + b._l0.nbytes
                      + b._up_ptr.nbytes + b._up.nbytes)
        else:
            total += self._vectors.nbytes
        return total

    # -- writes --------------------------------------------------------------

    def insert(self, vectors: np.ndarray, gids: np.ndarray) -> None:
        vectors = np.ascontiguousarray(vectors, np.float32)
        gids = np.asarray(gids, np.int64)
        assert vectors.shape == (len(gids), self.dim)
        need = self.n + len(gids)
        if need > self._gids.shape[0]:
            cap = max(need, 2 * self._gids.shape[0])
            gg = np.full(cap, -1, np.int64)
            gg[: self.n] = self._gids[: self.n]
            self._gids = gg
        if self._vectors is not None and need > self._vectors.shape[0]:
            cap = max(need, 2 * self._vectors.shape[0])
            vg = np.zeros((cap, self.dim), np.float32)
            vg[: self.n] = self._vectors[: self.n]
            self._vectors = vg
        if self._vectors is not None:
            self._vectors[self.n: need] = vectors
        self._gids[self.n: need] = gids
        self.n = need
        if self._builder is not None:
            for row in vectors:
                self._builder.insert_point(row)

    # -- reads ---------------------------------------------------------------

    def snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """(vectors[n, D], gids[n]) copies — the search-time view."""
        vecs = (self._builder._vectors if self._builder is not None
                else self._vectors)
        return (vecs[: self.n].copy(), self._gids[: self.n].copy())

    @staticmethod
    def scan(vectors: np.ndarray, gids: np.ndarray, queries: np.ndarray,
             k: int, metric: str) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-k over a (vectors, gids) snapshot; ids are GLOBAL.
        Pads to the same CHUNK multiples as the exact backend so a sealed
        exact segment answers bit-identically to the memtable it came
        from. Static so searches run on lock-free snapshots."""
        b = np.asarray(queries, np.float32).shape[0]
        n = vectors.shape[0]
        if n == 0:
            return (np.full((b, k), -1, np.int64),
                    np.full((b, k), np.inf, np.float32))
        n_pad = ((n + _CHUNK - 1) // _CHUNK) * _CHUNK
        vp = np.zeros((n_pad, vectors.shape[1]), np.float32)
        vp[:n] = vectors
        sq = np.full(n_pad, np.inf, np.float32)
        sq[:n] = np.einsum("nd,nd->n", vectors, vectors)
        k_eff = min(k, n, _CHUNK)
        ids, dists = bruteforce_topk(vp, sq, np.asarray(queries, np.float32),
                                     k=k_eff, chunk=_CHUNK, metric=metric)
        ids, dists = np.asarray(ids), np.asarray(dists)
        out_i = np.full((b, k), -1, np.int64)
        out_d = np.full((b, k), np.inf, np.float32)
        valid = ids >= 0
        out_i[:, :k_eff] = np.where(valid, np.asarray(gids, np.int64)[
            np.maximum(ids, 0)], -1)
        out_d[:, :k_eff] = dists
        return out_i, out_d

    def search(self, queries: np.ndarray, k: int, metric: str
               ) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-k over the current rows (convenience wrapper)."""
        vecs, gids = self.snapshot()
        return self.scan(vecs, gids, queries, k, metric)

    def graph(self) -> hg.HostGraph:
        """The incrementally-built HNSW graph over the current rows."""
        if self._builder is None:
            raise ValueError("memtable was created with build_graph=False")
        return self._builder.graph()
