"""repro.ingest — mutable segmented index over the immutable engines.

The paper serves a static SIFT1B index; this package opens the dynamic-
workload scenario class (databases that grow and churn while serving) as
an LSM-style composition of the pieces the repo already has:

  memtable   : small mutable head — exact-scanned, incrementally graphed
               via the `insert_point` routine factored out of `build_hnsw`
  segments   : sealed immutable segments — each one a normal SearchService
               ("a segment is just one more partition"); csd segments are
               appended to the block store, never rewriting existing blocks
  tombstones : deletes as a packed bitmap consulted at result-merge time
  compactor  : merge small segments + tombstones into one rebuilt segment
  service    : MutableSearchService — insert/delete/flush/compact/search,
               manifest v2 save/load (also exported from repro.api)

See ingest/README.md for the segment lifecycle.
"""

from repro.ingest.compactor import compact_segments, merge_survivors
from repro.ingest.memtable import Memtable
from repro.ingest.segments import Segment, build_segment, seal_memtable
from repro.ingest.service import (
    MUTABLE_FORMAT_VERSION,
    MutableSearchService,
)
from repro.ingest.tombstones import TombstoneSet

__all__ = [
    "MUTABLE_FORMAT_VERSION",
    "MutableSearchService",
    "Memtable",
    "Segment",
    "TombstoneSet",
    "build_segment",
    "seal_memtable",
    "compact_segments",
    "merge_survivors",
]
