"""Sealed immutable segments: one `SearchService` + a local→global id map.

A sealed segment is *exactly* one more partition of the two-stage engine
(paper §4.1): internally it searches in a compact local id space
[0, n) — which keeps the block store's contiguous-gid rerank path and the
hop kernels untouched — and the ingest layer remaps local ids to global
ids through `gid_map` at merge time. `gid_map` is always sorted ascending
(ids are assigned monotonically and compaction merges in id order), so
membership tests and local-row lookups are one `searchsorted`.

Two ways a segment is born:

  seal_memtable : the memtable's incrementally-built graph (GraphBuilder)
                  is `restructure`d into a DeviceDB — no rebuild. If the
                  memtable carries tombstoned rows they are dropped here
                  and the graph is rebuilt over the survivors instead
                  (dead rows must never reach a segment).
  build_segment : full `SearchService.build` over gathered survivor
                  vectors — the compactor's path, which is also what makes
                  `compact()` on the csd backend bit-identical to an
                  in-memory `partitioned` build over the same rows.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.service import SearchService
from repro.api.types import IndexSpec, SearchRequest
from repro.core import hnsw_graph as hg
from repro.core.partitioned import PartitionedDB

__all__ = ["Segment", "seal_memtable", "build_segment", "segment_vectors"]


@dataclasses.dataclass(eq=False)
class Segment:
    """One immutable sealed segment of a mutable index (identity eq: the
    compactor swaps segment LISTS, never compares array contents)."""

    name: str
    service: SearchService
    gid_map: np.ndarray            # [n] int64, sorted: local id -> global id
    n_deleted: int = 0             # live tombstone debt (over-fetch sizing)

    @property
    def n(self) -> int:
        return int(self.gid_map.size)

    def contains(self, gids: np.ndarray) -> np.ndarray:
        """Membership mask of global ids in this segment (searchsorted)."""
        gids = np.asarray(gids, np.int64)
        pos = np.searchsorted(self.gid_map, gids)
        pos = np.minimum(pos, self.gid_map.size - 1)
        return self.gid_map[pos] == gids

    def search(self, queries, k: int, ef: int, rerank: bool,
               with_stats: bool):
        """One segment's stage-1 answer, remapped to GLOBAL ids."""
        resp = self.service.search(SearchRequest(
            queries=queries, k=k, ef=ef, rerank=rerank,
            with_stats=with_stats))
        ids = np.asarray(resp.ids)
        gids = np.where(ids >= 0, self.gid_map[np.maximum(ids, 0)],
                        np.int64(-1))
        return gids, np.asarray(resp.dists), resp.stats


def _segment_spec(spec: IndexSpec, *, num_partitions: int,
                  storage_path: str | None,
                  cache_bytes: int | None) -> IndexSpec:
    backend = "partitioned" if spec.backend == "hnsw" else spec.backend
    kw = dict(backend=backend, num_partitions=num_partitions)
    if storage_path is not None:
        kw["storage_path"] = storage_path
    if cache_bytes is not None:
        kw["cache_bytes"] = cache_bytes
    return dataclasses.replace(spec, **kw)


def _stack_single(db: hg.DeviceDB) -> hg.DeviceDB:
    """[...] -> [1, ...]: one sealed graph as a P=1 stacked DeviceDB."""
    return hg.DeviceDB(*(np.stack([np.asarray(getattr(db, f))])
                         for f in hg.DeviceDB._fields))


def seal_memtable(spec: IndexSpec, name: str, vectors: np.ndarray,
                  gids: np.ndarray, graph: hg.HostGraph | None, *,
                  storage_path: str | None = None,
                  cache_bytes: int | None = None) -> Segment:
    """Restructure a memtable into a sealed segment (paper Fig. 5 tables).

    `vectors`/`gids` are the SURVIVING rows (tombstones already dropped);
    `graph` is the memtable's incremental graph when no row was dropped
    (then sealing is restructure-only), else None to force a rebuild.
    """
    gids = np.asarray(gids, np.int64)
    seg_spec = _segment_spec(spec, num_partitions=1,
                             storage_path=storage_path,
                             cache_bytes=cache_bytes)
    if seg_spec.backend == "exact":
        from repro.api.backends import ExactBackend
        return Segment(name, SearchService(
            seg_spec, ExactBackend(seg_spec, vectors)), gids)
    if graph is None:
        return build_segment(spec, name, vectors, gids,
                             storage_path=storage_path,
                             cache_bytes=cache_bytes, num_partitions=1)
    db = hg.restructure(graph)             # local arange gids inside
    pdb = PartitionedDB(db=_stack_single(db), num_partitions=1,
                        dim=vectors.shape[1])
    if seg_spec.backend == "csd":
        from repro.store.csd import CSDBackend
        from repro.store.layout import open_store, write_store
        write_store(seg_spec.storage_path, pdb,
                    block_size=seg_spec.block_size)
        backend = CSDBackend(seg_spec, open_store(
            seg_spec.storage_path, seg_spec.cache_bytes,
            prefetch=seg_spec.prefetch))
        return Segment(name, SearchService(seg_spec, backend), gids)
    from repro.api.backends import PartitionedBackend
    pdb = PartitionedDB(db=jax.tree.map(jnp.asarray, pdb.db),
                        num_partitions=1, dim=pdb.dim)
    backend = PartitionedBackend(
        seg_spec, pdb, raw=vectors if seg_spec.keep_vectors else None)
    return Segment(name, SearchService(seg_spec, backend), gids)


def build_segment(spec: IndexSpec, name: str, vectors: np.ndarray,
                  gids: np.ndarray, *, storage_path: str | None = None,
                  cache_bytes: int | None = None,
                  num_partitions: int | None = None) -> Segment:
    """Full from-scratch build over survivor rows (the compactor's path)."""
    seg_spec = _segment_spec(
        spec,
        num_partitions=(spec.num_partitions if num_partitions is None
                        else num_partitions),
        storage_path=storage_path, cache_bytes=cache_bytes)
    svc = SearchService.build(vectors, seg_spec)
    return Segment(name, svc, np.asarray(gids, np.int64))


def segment_vectors(segment: Segment) -> np.ndarray:
    """All rows of a segment as float32 [n, dim], in local-id order — the
    compactor's gather. Reads through the page cache for csd segments (no
    full-DB materialization beyond the merge buffer itself)."""
    backend = segment.service.backend
    if hasattr(backend, "reader"):                       # csd
        r = backend.reader
        parts = []
        for p in range(r.num_partitions):
            n = int(np.atleast_1d(r.n_valid)[p])
            rows = r.row("vectors", p, np.arange(n))
            parts.append(r.read_rows("vectors", rows)[:, : r.dim]
                         .astype(np.float32))
        return np.concatenate(parts) if parts else np.zeros(
            (0, r.dim), np.float32)
    if hasattr(backend, "pdb"):                          # partitioned/hnsw
        db = backend.pdb
        vec = np.asarray(db.db.vectors)
        n_valid = np.atleast_1d(np.asarray(db.db.n_valid))
        return np.concatenate([vec[p, : int(n_valid[p]), : db.dim]
                               for p in range(vec.shape[0])])
    return np.asarray(backend.raw, np.float32)           # exact
