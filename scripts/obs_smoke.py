#!/usr/bin/env python
"""CI obs-smoke: tracing + metrics over the real serving stack, end to end.

Serves a tiny query stream through SearchServer -> batcher -> replica pool
-> csd SearchService with tracing ON, then ASSERTS the observability
acceptance bounds:

  * every layer of the paper's request path shows up in the trace at least
    once — queue, batch, dispatch, search, traversal, store-read, hop —
    and the spans form one well-parented tree (no orphans);
  * the Chrome/Perfetto trace-event export is valid JSON with 'X' events
    whose args carry the span identity (loads in ui.perfetto.dev);
  * the Prometheus text exposition parses line by line (TYPE'd families,
    histogram bucket monotonicity, _count == +Inf bucket) and carries the
    serve/store/api series the docs promise;
  * results are bit-identical with tracing on vs off (observability must
    never steer the search).

  PYTHONPATH=src python scripts/obs_smoke.py
"""

import json
import os
import re
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.api import IndexSpec, SearchRequest, SearchService  # noqa: E402
from repro.core.hnsw_graph import HNSWConfig  # noqa: E402
from repro.data import clustered_vectors  # noqa: E402
from repro.obs import TRACER, write_snapshot  # noqa: E402
from repro.serve import SearchServer  # noqa: E402

N, DIM, K, EF = 1200, 32, 10, 40
NQ = 24

# the layers the trace must witness (ISSUE 7 acceptance list)
REQUIRED_SPANS = {"request", "queue", "batch", "dispatch", "search",
                  "traversal", "store-read", "hop", "hop-kernel", "rerank"}

PROM_LINE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$')


def check_prometheus(text: str) -> dict:
    """Parse the exposition the way a scraper would; return {name: value}
    for scalar samples."""
    samples, families = {}, {}
    for ln in text.strip().splitlines():
        if ln.startswith("# TYPE"):
            _, _, name, kind = ln.split()
            assert name not in families, f"duplicate TYPE for {name}"
            families[name] = kind
            continue
        assert not ln.startswith("#"), f"unexpected comment line: {ln!r}"
        assert PROM_LINE.match(ln), f"unparseable sample line: {ln!r}"
        name, value = ln.rsplit(" ", 1)
        samples[name] = float(value) if value != "+Inf" else float("inf")
    # histogram invariants: buckets cumulative-monotone, count == +Inf
    for fam, kind in families.items():
        if kind != "histogram":
            continue
        series = [(n, v) for n, v in samples.items()
                  if n.startswith(fam + "_bucket")]
        assert series, f"histogram {fam} has no buckets"
        by_labels: dict = {}
        for n, v in series:
            base = re.sub(r'le="[^"]*",?', "", n)
            by_labels.setdefault(base, []).append(v)
        # exposition order is ascending le, so each group must be monotone
        for base, vs in by_labels.items():
            assert vs == sorted(vs), f"non-monotone buckets in {base}"
    return samples


def main():
    root = tempfile.mkdtemp(prefix="obs-smoke-")
    vecs = clustered_vectors(N, DIM, k=10, seed=0)
    rng = np.random.default_rng(1)
    queries = (vecs[rng.integers(0, N, NQ)]
               + rng.normal(scale=1.0, size=(NQ, DIM))).astype(np.float32)
    spec = IndexSpec(backend="csd", num_partitions=2,
                     hnsw=HNSWConfig(M=8, ef_construction=50, seed=0),
                     block_size=512, cache_bytes=1 << 20, prefetch=False,
                     storage_path=os.path.join(root, "store"))
    svc = SearchService.build(vecs, spec)

    # -- golden run, tracing OFF --------------------------------------------
    req = SearchRequest(queries=queries, k=K, ef=EF, rerank=True)
    want = np.asarray(svc.search(req).ids)

    # -- traced run through the full serving stack --------------------------
    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    with SearchServer(svc, replicas=2, max_batch=8, max_wait_ms=1.0) as srv:
        futs = [srv.submit(q, k=K, ef=EF, rerank=True) for q in queries]
        got = np.stack([np.asarray(f.result(timeout=120).ids)
                        for f in futs])
        srv.drain()
        prom = srv.metrics()
        trace_doc = TRACER.export()
    TRACER.configure(enabled=False)

    assert np.array_equal(got, want), \
        "tracing changed search results (must be bit-identical)"

    # -- span coverage + tree shape -----------------------------------------
    spans = TRACER.spans()
    names = {s["name"] for s in spans}
    missing = REQUIRED_SPANS - names
    assert not missing, f"layers missing from the trace: {sorted(missing)}"
    by_id = {s["id"]: s for s in spans}
    n_req = 0
    for s in spans:
        if s["parent"] == 0:
            assert s["name"] == "request", \
                f"unexpected root span {s['name']!r}"
            n_req += 1
        else:
            parent = by_id.get(s["parent"])
            assert parent is not None, f"orphan span {s['name']!r}"
            assert parent["trace"] == s["trace"], \
                f"span {s['name']!r} crosses trace ids"
    assert n_req == NQ, f"expected {NQ} request roots, got {n_req}"

    # -- Perfetto JSON loads -------------------------------------------------
    trace_path = os.path.join(root, "trace.json")
    TRACER.write(trace_path)
    with open(trace_path) as f:
        doc = json.load(f)
    assert doc == json.loads(json.dumps(trace_doc))
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert len(events) == len(spans)
    for e in events:
        assert e["dur"] >= 0 and "span_id" in e["args"]
    assert any(e.get("ph") == "M" and e["name"] == "thread_name"
               for e in doc["traceEvents"])
    assert doc["otherData"]["dropped_events"] == 0

    # -- Prometheus exposition parses + promised series exist ---------------
    samples = check_prometheus(prom)
    assert samples['api_searches_total{backend="csd"}'] >= 1
    assert samples["serve_requests_total"] == NQ
    assert any(n.startswith("store_block_reads_total") for n in samples)
    assert any(n.startswith("serve_e2e_ms_bucket") for n in samples)
    assert any(n.startswith("serve_replica_queries_total") for n in samples)
    # the one-shot file writer round-trips both formats
    jpath = write_snapshot(os.path.join(root, "metrics.json"))
    with open(jpath) as f:
        jdoc = json.load(f)
    assert jdoc["ts_unix"] > 0 and jdoc["counters"]
    check_prometheus(open(write_snapshot(
        os.path.join(root, "metrics.prom"))).read())

    stage_names = sorted(names & REQUIRED_SPANS)
    print(f"[obs-smoke] OK: {len(spans)} spans over {NQ} requests, layers "
          f"{stage_names} all present; results bit-identical traced vs "
          f"untraced; Prometheus exposition ({len(samples)} samples) and "
          f"Perfetto JSON ({len(events)} events) both parse")


if __name__ == "__main__":
    main()
