#!/usr/bin/env python
"""CI ingest-smoke: tiny streaming workload on the csd backend.

Exercises the whole mutable-index lifecycle out-of-core with a deliberately
tiny (8 KiB) cache — insert waves, deletes, explicit flush, searches while
segments accumulate, then compact — and ASSERTS the acceptance bounds:

  * peak resident store memory stays inside the re-split cache budget
    (max(cache_bytes, n_segments * block_size)) the whole time, and the
    total including the memtable stays inside budget + memtable buffer;
  * deleted ids never surface, before or after compaction;
  * compaction leaves one segment, non-empty results, space reclaimed on
    disk (dead segment stores deleted, store manifest swapped).

  PYTHONPATH=src python scripts/ingest_smoke.py
"""

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.api import IndexSpec, MutableSearchService, SearchRequest  # noqa: E402
from repro.core.hnsw_graph import HNSWConfig  # noqa: E402
from repro.data import clustered_vectors  # noqa: E402
from repro.store.segments import list_segments  # noqa: E402

CACHE_BYTES = 8192
BLOCK_SIZE = 512
SEAL = 120
N, DIM = 900, 32


def main():
    store = tempfile.mkdtemp(prefix="ingest-smoke-")
    vecs = clustered_vectors(N, DIM, k=10, seed=0)
    rng = np.random.default_rng(1)
    queries = (vecs[rng.integers(0, N, 8)]
               + rng.normal(scale=1.0, size=(8, DIM))).astype(np.float32)
    spec = IndexSpec(backend="csd", num_partitions=1,
                     hnsw=HNSWConfig(M=8, ef_construction=50, seed=0),
                     storage_path=store, block_size=BLOCK_SIZE,
                     cache_bytes=CACHE_BYTES, prefetch=False)
    svc = MutableSearchService(spec, seal_threshold=SEAL)

    deleted = []
    mem_peak = 0
    for lo in range(0, N, 75):
        gids = svc.insert(vecs[lo: lo + 75])
        deleted.extend(gids[::5][:5].tolist())
        svc.delete(gids[::5][:5])
        resp = svc.search(SearchRequest(queries=queries, k=10, ef=40,
                                        with_stats=True))
        ids = np.asarray(resp.ids)
        assert not np.isin(ids, np.asarray(deleted)).any(), \
            "deleted id surfaced during streaming"
        mem_peak = max(mem_peak,
                       svc.resident_bytes() - svc.storage_resident_bytes())
        cache_bound = max(CACHE_BYTES, svc.num_segments * BLOCK_SIZE)
        assert svc.peak_storage_resident_bytes <= cache_bound, (
            f"cache residency {svc.peak_storage_resident_bytes} B exceeds "
            f"bound {cache_bound} B")
    svc.flush()
    n_seg_pre = svc.num_segments
    assert n_seg_pre >= 5, f"expected several segments, got {n_seg_pre}"
    cache_bound = max(CACHE_BYTES, n_seg_pre * BLOCK_SIZE)
    assert svc.peak_resident_bytes <= cache_bound + mem_peak, (
        f"peak resident {svc.peak_resident_bytes} B exceeds "
        f"{cache_bound} + {mem_peak} B")

    out = svc.compact()
    assert svc.num_segments == 1
    # every deleted row is physically gone: some were dropped at seal time
    # (deleted while still in the memtable), the rest just now by compact
    assert out["rows_reclaimed"] <= len(set(deleted))
    assert svc.size == N - len(set(deleted))
    assert list_segments(store) == [s.name for s in svc._segments]
    resp = svc.search(SearchRequest(queries=queries, k=10, ef=40,
                                    with_stats=True))
    ids = np.asarray(resp.ids)
    assert (ids[:, 0] >= 0).all(), "empty results after compaction"
    assert not np.isin(ids, np.asarray(deleted)).any()
    assert resp.stats.block_reads > 0

    print(f"[ingest-smoke] OK: {N} inserts, {len(set(deleted))} deletes, "
          f"{n_seg_pre} segments -> 1 after compact; "
          f"peak cache {svc.peak_storage_resident_bytes} B "
          f"(bound {cache_bound} B), peak memtable {mem_peak} B, "
          f"block_reads={resp.stats.block_reads}")
    svc.close()


if __name__ == "__main__":
    main()
