#!/usr/bin/env python
"""Docs CI: keep the documentation from rotting.

Two checks (stdlib only — no extra dependencies):

  links       validate every markdown link in README.md, docs/, and the
              package READMEs: relative links must point at files/dirs
              that exist (with #anchors checked against the target's
              headings); absolute URLs are only syntax-checked (CI has no
              network).

  quickstart  extract the bash block(s) between the
              `<!-- ci-quickstart:start -->` / `<!-- ci-quickstart:end -->`
              markers in README.md and EXECUTE every command. The README
              quickstart is therefore the executable spec — editing the
              docs without keeping the commands green fails CI.

  python scripts/check_docs.py links
  python scripts/check_docs.py quickstart
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_GLOBS = [
    "README.md",
    "docs/*.md",
    "src/repro/*/README.md",
]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _anchor(text: str) -> str:
    """GitHub-style heading -> anchor slug."""
    text = re.sub(r"[`*_]", "", text.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors_of(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return {_anchor(h) for h in HEADING_RE.findall(f.read())}


def doc_files() -> list[str]:
    out = []
    for pat in DOC_GLOBS:
        out.extend(sorted(glob.glob(os.path.join(ROOT, pat))))
    return out


def check_links() -> int:
    errors = []
    for doc in doc_files():
        rel_doc = os.path.relpath(doc, ROOT)
        with open(doc, encoding="utf-8") as f:
            body = f.read()
        for target in LINK_RE.findall(body):
            if target.startswith(("http://", "https://", "mailto:")):
                continue                      # offline CI: syntax-only
            target, _, frag = target.partition("#")
            if not target:                    # pure in-page anchor
                if frag and _anchor(frag) not in _anchors_of(doc):
                    errors.append(f"{rel_doc}: missing anchor #{frag}")
                continue
            dest = os.path.normpath(os.path.join(os.path.dirname(doc),
                                                 target))
            if not os.path.exists(dest):
                errors.append(f"{rel_doc}: broken link -> {target}")
                continue
            if frag and dest.endswith(".md") and \
                    _anchor(frag) not in _anchors_of(dest):
                errors.append(f"{rel_doc}: {target}#{frag} — no such "
                              f"heading in target")
    for e in errors:
        print(f"LINK ERROR  {e}")
    print(f"checked {len(doc_files())} docs: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


def _quickstart_commands() -> list[str]:
    readme = os.path.join(ROOT, "README.md")
    with open(readme, encoding="utf-8") as f:
        body = f.read()
    blocks = re.findall(
        r"<!-- ci-quickstart:start -->\s*```bash\n(.*?)```\s*"
        r"<!-- ci-quickstart:end -->",
        body, re.DOTALL)
    if not blocks:
        print("README.md has no ci-quickstart block — the quickstart is "
              "no longer executable-by-CI")
        sys.exit(1)
    commands, cont = [], ""
    for block in blocks:
        for line in block.splitlines():
            line = line.rstrip()
            if not line or (line.lstrip().startswith("#") and not cont):
                continue
            if line.endswith("\\"):
                cont += line[:-1] + " "
                continue
            commands.append((cont + line).strip())
            cont = ""
    return commands


def run_quickstart() -> int:
    cmds = _quickstart_commands()
    env = dict(os.environ)
    for cmd in cmds:
        print(f"$ {cmd}", flush=True)
        proc = subprocess.run(cmd, shell=True, cwd=ROOT, env=env)
        if proc.returncode != 0:
            print(f"QUICKSTART FAIL ({proc.returncode}): {cmd}")
            return proc.returncode
    print(f"quickstart ok ({len(cmds)} commands)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("check", choices=["links", "quickstart"])
    args = ap.parse_args()
    return check_links() if args.check == "links" else run_quickstart()


if __name__ == "__main__":
    sys.exit(main())
