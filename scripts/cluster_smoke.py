#!/usr/bin/env python
"""CI cluster-smoke: 3 shards x 2 replicas out-of-core, failover mid-stream.

Stands up a `repro.cluster` router over csd shards on tiny data and
ASSERTS the acceptance bounds end to end:

  * merge parity — the cluster's top-k ids AND dists are bit-identical to
    one SearchService built over the same rows (with and without rerank);
  * failover — one replica of every shard is killed WHILE a stream of
    in-flight queries is running; every query completes with the correct
    answer (nothing lost, nothing duplicated, no error surfaces);
  * the health sweep reports the killed replicas down and the survivors
    up, and the published `cluster.json` matches the live topology.

  PYTHONPATH=src python scripts/cluster_smoke.py
"""

import dataclasses
import os
import sys
import tempfile
import threading

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.api import IndexSpec, SearchRequest, SearchService  # noqa: E402
from repro.cluster import HealthMonitor, build_cluster, read_topology  # noqa: E402
from repro.core.hnsw_graph import HNSWConfig  # noqa: E402
from repro.data import clustered_vectors  # noqa: E402

N, DIM, NSHARDS, REPLICAS = 900, 32, 3, 2
K, EF = 10, 40


def main():
    root = tempfile.mkdtemp(prefix="cluster-smoke-")
    vecs = clustered_vectors(N, DIM, k=10, seed=0)
    rng = np.random.default_rng(1)
    queries = (vecs[rng.integers(0, N, 8)]
               + rng.normal(scale=1.0, size=(8, DIM))).astype(np.float32)
    spec = IndexSpec(backend="csd", num_partitions=1,
                     hnsw=HNSWConfig(M=8, ef_construction=50, seed=0),
                     block_size=512, cache_bytes=1 << 20, prefetch=False)

    single = SearchService.build(vecs, dataclasses.replace(
        spec, num_partitions=NSHARDS,
        storage_path=os.path.join(root, "single")))
    cluster = build_cluster(vecs, spec, NSHARDS, replicas=REPLICAS,
                            path=root)

    # -- merge parity: bit-identical to the single index --------------------
    for rerank in (False, True):
        req = SearchRequest(queries=queries, k=K, ef=EF, rerank=rerank)
        want, got = single.search(req), cluster.search(req)
        assert np.array_equal(np.asarray(want.ids), np.asarray(got.ids)), \
            f"id mismatch (rerank={rerank})"
        assert np.array_equal(np.asarray(want.dists),
                              np.asarray(got.dists)), \
            f"dist mismatch (rerank={rerank})"
    req = SearchRequest(queries=queries, k=K, ef=EF)
    want_ids = np.asarray(single.search(req).ids)

    # -- kill one replica of EVERY shard while queries are in flight --------
    results, errors = [], []
    started = threading.Event()

    def stream():
        for i in range(40):
            if i == 4:
                started.set()
            try:
                results.append(np.asarray(cluster.search(req).ids))
            except Exception as exc:     # no query may see the failure
                errors.append(repr(exc))

    t = threading.Thread(target=stream)
    t.start()
    started.wait(timeout=60)
    for client in cluster.shards:
        client.replicas[0].kill()
    t.join()
    assert not errors, f"queries failed during failover: {errors[:3]}"
    assert len(results) == 40, "queries were lost during failover"
    for ids in results:
        assert np.array_equal(ids, want_ids), \
            "failover produced a wrong answer"
    per_shard = [sum(rep.queries for rep in c.replicas)
                 for c in cluster.shards]
    expected = (2 + 40) * len(queries)       # parity x2 + stream
    assert all(q == expected for q in per_shard), (
        f"lost/duplicated shard requests: {per_shard} != {expected}")

    # -- health + topology ----------------------------------------------------
    mon = HealthMonitor(cluster, interval_s=30.0, timeout_s=60.0)
    states = mon.probe_now()
    assert all(v == [False, True] for v in states.values()), states
    topo = read_topology(root)
    assert topo.version == cluster.version
    assert [s.name for s in topo.shards] == \
        [c.name for c in cluster.shards]

    failovers = sum(c.failovers for c in cluster.shards)
    print(f"[cluster-smoke] OK: {NSHARDS} shards x {REPLICAS} replicas "
          f"(csd), parity bit-identical (+rerank), 40 in-flight queries "
          f"correct across kill-one-replica-per-shard "
          f"({failovers} failovers), manifest v{topo.version}")
    cluster.close()


if __name__ == "__main__":
    main()
