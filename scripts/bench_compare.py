#!/usr/bin/env python
"""Perf-regression gate over the BENCH_*.json trajectory (ISSUE 10).

Diffs a fresh benchmark record against a committed baseline with
noise-aware thresholds:

  * QPS: any `qps` leaf that drops more than --qps-drop-pct (default 15 %,
    well above the fig_obs run-to-run noise floor) fails the gate;
  * recall: thresholds are ABSOLUTE floors, not diffs — recall on these
    seeded workloads is deterministic, so the gate only fires when a
    fresh value lands below the pinned floor for its artifact (a baseline
    that itself regressed can never grandfather a bad recall in);
  * provenance: records must carry the same `bench_meta.schema_version`
    and the same variant (tiny vs full) — a tiny baseline is never
    diffed against a full run, their wall-times differ by shape, not by
    regression. Hosts are reported but not enforced (recall comparisons
    are host-independent; QPS across hosts prints a warning).

Usage (two positional files, or directory mode):

  python scripts/bench_compare.py BENCH_cluster.json fresh/BENCH_cluster.json
  python scripts/bench_compare.py --baseline-dir . --fresh-dir /tmp/fresh \
      --names cluster,traversal,pq

Exit status: 0 clean, 1 on any regression (CI gate), 2 on usage errors.
Stdlib only — runs before any environment setup.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

QPS_DROP_PCT = 15.0

# absolute recall floors per artifact, keyed by BENCH file stem then by
# leaf key: a floor applies to EVERY leaf with that key in the record.
# Measured 2026-08: cluster/traversal tiny and full shapes sit at
# recall 1.0, so 0.90 leaves generous determinism margin. pq's sweep
# spans M in {4,8,16} and the M=4 point is intentionally lossy
# (recall 0.125 full / 0.2125 tiny), so the per-sweep floors are low;
# the headline (M=16 + rerank) and uint8 reference get real floors.
RECALL_FLOORS = {
    "cluster": {"recall": 0.90},
    "traversal": {"recall": 0.90},
    "pq": {"recall_rerank": 0.10, "recall_raw": 0.10,
           "recall_pq": 0.90, "recall_uint8": 0.90},
    "obs": {},
}


def _walk(node, path=""):
    """Yield (dotted_path, leaf_key, value) for every numeric leaf."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, f"{path}.{k}" if path else k)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, f"{path}[{i}]")
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield path, path.rsplit(".", 1)[-1], float(node)


def _load(path):
    with open(path) as f:
        return json.load(f)


def _meta(rec):
    m = rec.get("bench_meta", {})
    return (m.get("schema_version"), m.get("variant"),
            m.get("host", {}).get("platform"))


def compare(name, base, fresh, qps_drop_pct=QPS_DROP_PCT):
    """Returns (problems, warnings) comparing one artifact pair."""
    problems, warnings = [], []
    b_ver, b_var, b_host = _meta(base)
    f_ver, f_var, f_host = _meta(fresh)
    if b_ver != f_ver:
        problems.append(
            f"{name}: schema_version mismatch baseline={b_ver} "
            f"fresh={f_ver} — regenerate the baseline")
        return problems, warnings
    if b_var != f_var:
        problems.append(
            f"{name}: variant mismatch baseline={b_var!r} fresh={f_var!r} "
            f"— tiny and full runs are not comparable")
        return problems, warnings
    qps_comparable = True
    if b_host and f_host and b_host != f_host:
        warnings.append(
            f"{name}: hosts differ ({b_host} vs {f_host}) — QPS skipped, "
            f"recall floors still enforced")
        qps_comparable = False

    base_leaves = {p: v for p, _k, v in _walk(base)}
    for path, key, v in _walk(fresh):
        floor = RECALL_FLOORS.get(name, {}).get(key)
        if floor is not None:
            if v < floor:
                problems.append(
                    f"{name}: {path} = {v:.4f} below pinned floor {floor}")
            continue
        if key == "qps" and qps_comparable:
            bv = base_leaves.get(path)
            if bv is None:
                warnings.append(f"{name}: {path} has no baseline (new leaf)")
            elif bv > 0 and v < bv * (1.0 - qps_drop_pct / 100.0):
                problems.append(
                    f"{name}: {path} dropped {100 * (1 - v / bv):.1f}% "
                    f"({bv:.1f} -> {v:.1f} QPS, threshold "
                    f"{qps_drop_pct:.0f}%)")
    return problems, warnings


def _pairs_from_dirs(baseline_dir, fresh_dir, names):
    pairs = []
    for name in names:
        fn = f"BENCH_{name}.json"
        b, f = os.path.join(baseline_dir, fn), os.path.join(fresh_dir, fn)
        if not os.path.exists(b):
            print(f"[bench-compare] no baseline {b}; skipping {name}")
            continue
        if not os.path.exists(f):
            print(f"[bench-compare] ERROR: fresh run missing {f}")
            sys.exit(2)
        pairs.append((name, b, f))
    return pairs


def _stem(path):
    base = os.path.basename(path)
    if base.startswith("BENCH_") and base.endswith(".json"):
        return base[len("BENCH_"):-len(".json")]
    return os.path.splitext(base)[0]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("fresh", nargs="?", help="fresh BENCH_*.json")
    ap.add_argument("--baseline-dir", default=None)
    ap.add_argument("--fresh-dir", default=None)
    ap.add_argument("--names", default="cluster,traversal,pq",
                    help="comma-separated artifact stems for directory mode")
    ap.add_argument("--qps-drop-pct", type=float, default=QPS_DROP_PCT)
    args = ap.parse_args(argv)

    if args.baseline_dir and args.fresh_dir:
        names = [n.strip() for n in args.names.split(",") if n.strip()]
        pairs = _pairs_from_dirs(args.baseline_dir, args.fresh_dir, names)
    elif args.baseline and args.fresh:
        pairs = [(_stem(args.fresh), args.baseline, args.fresh)]
    else:
        ap.error("give BASELINE FRESH files, or --baseline-dir/--fresh-dir")

    any_problem = False
    for name, bpath, fpath in pairs:
        problems, warnings = compare(name, _load(bpath), _load(fpath),
                                     qps_drop_pct=args.qps_drop_pct)
        for w in warnings:
            print(f"[bench-compare] warn: {w}")
        if problems:
            any_problem = True
            for p in problems:
                print(f"[bench-compare] REGRESSION: {p}")
        else:
            print(f"[bench-compare] {name}: OK "
                  f"({bpath} vs {fpath})")
    if any_problem:
        print("[bench-compare] FAILED")
        return 1
    print("[bench-compare] all artifacts clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
