#!/usr/bin/env python
"""CI pq-smoke: the product-quantized path end to end on tiny data.

Builds one PQ (M=8) partitioned index, restructures it onto a tiny csd
block store (M-byte code rows + the float32 `rerank_vectors` table), and
ASSERTS the acceptance bounds in-process:

  * csd == partitioned BIT-IDENTICALLY (ids, dists, hops, dist_calcs),
    with and without the true-float32 rerank, at fused_hops 1 and 4;
  * the stored vector table is pq_m bytes/row — 16x under the uint8
    store's lane-padded rows here — and a cold-cache search moves fewer
    `bytes_read` than the same search on the uint8 store;
  * the manifest round-trips as format_version 3.

  PYTHONPATH=src python scripts/pq_smoke.py
"""

import dataclasses
import json
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.api import IndexSpec, SearchRequest, SearchService  # noqa: E402
from repro.core.hnsw_graph import HNSWConfig  # noqa: E402
from repro.data import clustered_vectors  # noqa: E402
from repro.store.csd import CSDBackend  # noqa: E402
from repro.store.layout import open_store  # noqa: E402

N, DIM, NQ, K, EF = 1500, 64, 12, 10, 40
PQ_M = 8


def _build_csd(part, tag):
    store = tempfile.mkdtemp(prefix=f"pq-smoke-{tag}-") + "/store"
    spec = dataclasses.replace(part.spec, backend="csd",
                               keep_vectors=False, storage_path=store,
                               prefetch=False)
    raw = part.backend.raw if part.spec.dtype == "pq" else None
    return SearchService(spec, CSDBackend.from_partitioned(
        part.backend.pdb, spec, raw=raw))


def _respond(svc, queries, rerank, fused_hops):
    svc.backend.spec = dataclasses.replace(svc.backend.spec,
                                           fused_hops=fused_hops)
    r = svc.search(SearchRequest(queries=queries, k=K, ef=EF, rerank=rerank,
                                 with_stats=True))
    return (np.asarray(r.ids), np.asarray(r.dists),
            np.asarray(r.stats.hops), np.asarray(r.stats.dist_calcs))


def _cold_bytes(svc, queries):
    reader = open_store(svc.backend.reader.path, svc.spec.cache_bytes,
                        prefetch=False)
    try:
        cold = SearchService(svc.spec, CSDBackend(svc.spec, reader))
        r = cold.search(SearchRequest(queries=queries, k=K, ef=EF,
                                      with_stats=True))
        return float(r.stats.bytes_read)
    finally:
        reader.close()


def main():
    vecs = clustered_vectors(N, DIM, k=16, seed=0)
    rng = np.random.default_rng(1)
    queries = (vecs[rng.integers(0, N, NQ)]
               + rng.normal(scale=1.5, size=(NQ, DIM))).astype(np.float32)
    cfg = HNSWConfig(M=12, ef_construction=80, seed=0)

    pq = SearchService.build(vecs, IndexSpec(
        backend="partitioned", dtype="pq", pq_m=PQ_M, num_partitions=2,
        hnsw=cfg, keep_vectors=True))
    pq_csd = _build_csd(pq, "pq")
    u8 = SearchService.build(vecs, IndexSpec(
        backend="partitioned", dtype="uint8", num_partitions=2, hnsw=cfg,
        keep_vectors=True))
    u8_csd = _build_csd(u8, "u8")

    # 1) bit-parity: csd == partitioned on every counter, every mode
    for fh in (1, 4):
        for rerank in (False, True):
            want = _respond(pq, queries, rerank, fh)
            got = _respond(pq_csd, queries, rerank, fh)
            for g, w, what in zip(got, want,
                                  ("ids", "dists", "hops", "dist_calcs")):
                assert np.array_equal(g, w), (
                    f"pq csd != partitioned on {what} "
                    f"(fused_hops={fh}, rerank={rerank})")

    # 2) storage: M-byte rows, strictly fewer cold bytes than uint8
    t_pq = pq_csd.backend.reader.blockfile.tables["vectors"]
    t_u8 = u8_csd.backend.reader.blockfile.tables["vectors"]
    assert t_pq["dtype"] == "uint8" and t_pq["row_bytes"] == PQ_M, t_pq
    assert t_u8["row_bytes"] == 16 * t_pq["row_bytes"], (t_u8, t_pq)
    assert "rerank_vectors" in pq_csd.backend.reader.blockfile.tables
    b_pq, b_u8 = _cold_bytes(pq_csd, queries), _cold_bytes(u8_csd, queries)
    assert b_pq < b_u8, (
        f"pq store read MORE than uint8: {b_pq:.0f} vs {b_u8:.0f} B")

    # 3) manifest v3 round-trip
    path = tempfile.mkdtemp(prefix="pq-smoke-manifest-")
    pq.save(path)
    with open(os.path.join(path, "index_manifest.json")) as f:
        assert json.load(f)["format_version"] == 3
    back = SearchService.load(path)
    r1 = pq.search(SearchRequest(queries=queries, k=K, ef=EF))
    r2 = back.search(SearchRequest(queries=queries, k=K, ef=EF))
    assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    assert np.array_equal(np.asarray(r1.dists), np.asarray(r2.dists))

    print(f"[pq-smoke] OK: n={N} d={DIM} M={PQ_M} — csd==partitioned "
          f"bitwise (fused_hops 1/4, rerank on/off); rows "
          f"{t_u8['row_bytes']}B->{t_pq['row_bytes']}B; cold bytes_read "
          f"{b_u8:.0f}->{b_pq:.0f} ({b_u8 / b_pq:.2f}x); manifest v3 ok")


if __name__ == "__main__":
    main()
