#!/usr/bin/env python
"""CI slo-smoke: SLO burn-rate accounting, continuous profiling, and
cost-model calibration over the real serving stack, end to end (ISSUE 10).

Serves a tiny query stream through SearchServer -> batcher -> replica pool
-> csd SearchService with an impossible latency SLO attached, then ASSERTS
the phase-2 observability acceptance bounds:

  * breach accounting is EXACT: every request misses a 0.001 ms p99
    target, so the latency SLO must show samples == NQ, bad == NQ, burn
    100x over budget on both windows, exactly one edge-triggered breach
    event, and `slo_breaches_total` == 1 in the snapshot — while the
    error-rate SLO (no failures injected) stays clean;
  * the continuous profiler's live `profile_report()` covers every
    request and telescopes to the measured e2e latency (queue + exec ==
    e2e; traversal net of store reads; residue in dispatch_other);
  * `calibrate()` on the emitted metrics snapshot fits >= 3 cost-model
    terms (storage / fanout / dispatch) with finite values, and the
    calibrated storage seconds/query lands within 2x of measured;
  * `ann_dryrun --calibrated <snapshot>` surfaces the same table from a
    fresh process (capacity planning on observed numbers, ROADMAP 5).

  PYTHONPATH=src python scripts/slo_smoke.py [--skip-dryrun]
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.api import IndexSpec, SearchService  # noqa: E402
from repro.core.hnsw_graph import HNSWConfig  # noqa: E402
from repro.data import clustered_vectors  # noqa: E402
from repro.obs import (PROFILER, SLOTracker, default_slos,  # noqa: E402
                       load_calibration, compare_terms, profile_report,
                       write_snapshot)
from repro.serve import SearchServer  # noqa: E402

N, DIM, K, EF = 1200, 32, 10, 40
NQ = 64


def check_slo_accounting(slo) -> None:
    rows = {r["slo"]: r for r in slo.evaluate()}
    lat, err = rows["latency_p99"], rows["error_rate"]
    assert lat["samples"] == NQ, \
        f"latency SLO saw {lat['samples']} samples, served {NQ}"
    assert lat["bad"] == NQ, \
        f"every request must miss a 0.001ms target; bad={lat['bad']}"
    # bad_frac 1.0 over a 0.01 budget: burn 100x on both windows
    assert lat["burn_long"] == 100.0 and lat["burn_short"] == 100.0, lat
    assert lat["breaching"], "latency SLO must be breaching"
    assert err["samples"] == NQ and err["bad"] == 0, err
    assert not err["breaching"], "no errors injected, yet error SLO fired"
    events = slo.breaches()
    assert len(events) == 1 and events[0]["slo"] == "latency_p99", \
        f"expected exactly one edge-triggered breach event, got {events}"
    # re-evaluating while still breaching must NOT re-fire the edge
    slo.evaluate()
    assert len(slo.breaches()) == 1, "breach event re-fired on re-evaluate"


def check_profile(rep: dict) -> None:
    assert rep["requests"] == NQ, \
        f"profiler saw {rep['requests']} requests, served {NQ}"
    assert rep["sum_matches_e2e"], \
        f"stage attribution does not telescope to e2e: {rep}"
    assert abs(rep["stage_sum_ms"] - rep["e2e_ms"]) \
        <= 0.02 * max(1.0, rep["e2e_ms"]), rep
    stages = rep["stage_ms"]
    assert stages["store_read"] > 0.0, \
        "csd traffic must attribute store-read time"
    assert stages["traversal"] >= 0.0 and stages["queue"] >= 0.0, stages


def check_calibration(snap_path: str) -> dict:
    cal = load_calibration(snap_path)
    assert cal.queries and cal.queries >= NQ, cal.queries
    terms = compare_terms(cal)
    available = [k for k, t in terms.items() if not t.get("unavailable")]
    assert set(available) >= {"storage", "fanout", "dispatch"}, \
        f"expected >=3 fitted terms, got {available}"
    st = terms["storage"]
    ratio = st["calibrated"] / st["measured"]
    assert 0.5 <= ratio <= 2.0, \
        f"calibrated storage {st['calibrated']:.3e}s/q is {ratio:.2f}x " \
        f"measured {st['measured']:.3e}s/q (must be within 2x)"
    fo = terms["fanout"]
    assert fo["calibrated_rel_error"] == 0.0, \
        "fanout fit must reproduce the measured blocks/query exactly"
    assert terms["dispatch"]["measured"] >= 0.0
    return terms


def check_dryrun(snap_path: str) -> None:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    t0 = time.time()
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.ann_dryrun",
         "--calibrated", snap_path],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert out.returncode == 0, \
        f"ann_dryrun --calibrated failed:\n{out.stderr[-2000:]}"
    rec = json.loads(out.stdout)
    calib = rec["calibration"]
    assert calib["source"] == snap_path
    available = [k for k, t in calib["terms"].items()
                 if not t.get("unavailable")]
    assert set(available) >= {"storage", "fanout", "dispatch"}, available
    st = calib["terms"]["storage"]
    ratio = st["calibrated"] / st["measured"]
    assert 0.5 <= ratio <= 2.0, st
    assert calib["fitted"]["effective_ssd_bw"] > 0
    mw = calib.get("measured_workload")
    assert mw and mw["calibrated_qps_per_device"] > 0, mw
    print(f"[slo-smoke] ann_dryrun --calibrated OK in {time.time()-t0:.0f}s "
          f"(storage calibrated/measured = {ratio:.2f}x, "
          f"calibrated {mw['calibrated_qps_per_device']} QPS/device)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skip-dryrun", action="store_true",
                    help="skip the ann_dryrun subprocess (compiles the "
                         "full distributed search; minutes on CPU)")
    args = ap.parse_args()

    root = tempfile.mkdtemp(prefix="slo-smoke-")
    vecs = clustered_vectors(N, DIM, k=10, seed=0)
    rng = np.random.default_rng(1)
    queries = (vecs[rng.integers(0, N, NQ)]
               + rng.normal(scale=1.0, size=(NQ, DIM))).astype(np.float32)
    spec = IndexSpec(backend="csd", num_partitions=2,
                     hnsw=HNSWConfig(M=8, ef_construction=50, seed=0),
                     block_size=512, cache_bytes=1 << 20, prefetch=False,
                     storage_path=os.path.join(root, "store"))
    svc = SearchService.build(vecs, spec)

    # impossible latency target -> every request is a bad sample; stock
    # error-rate SLO rides along and must stay clean
    slo = SLOTracker(default_slos(p99_ms=0.001, error_rate=0.01))
    PROFILER.configure(enabled=True)
    PROFILER.reset()
    with SearchServer(svc, replicas=2, max_batch=8, max_wait_ms=1.0,
                      slo=slo) as srv:
        futs = [srv.submit(q, k=K, ef=EF, rerank=True) for q in queries]
        [f.result(timeout=120) for f in futs]
        srv.drain()
        assert srv.slo is slo

    check_slo_accounting(slo)
    rep = profile_report()
    check_profile(rep)

    snap_path = write_snapshot(os.path.join(root, "metrics.json"))
    with open(snap_path) as f:
        snap = json.load(f)
    breach_counters = [c for c in snap["counters"]
                       if c["name"] == "slo_breaches_total"]
    by_slo = {c["labels"]["slo"]: c["value"] for c in breach_counters}
    assert by_slo.get("latency_p99") == 1, by_slo
    assert by_slo.get("error_rate") == 0, by_slo
    assert any(c["name"] == "profile_requests_total" and c["value"] >= NQ
               for c in snap["counters"])

    terms = check_calibration(snap_path)
    st = terms["storage"]
    print(f"[slo-smoke] slo accounting exact ({NQ}/{NQ} bad, burn 100x, "
          f"1 breach event); profiler attribution sums to "
          f"{rep['e2e_ms']}ms e2e over {rep['requests']} requests; "
          f"storage term calibrated within "
          f"{st['calibrated'] / st['measured']:.2f}x of measured")

    if args.skip_dryrun:
        print("[slo-smoke] OK (dryrun skipped)")
        return
    check_dryrun(snap_path)
    print("[slo-smoke] OK")


if __name__ == "__main__":
    main()
