"""Fused-traversal sweep (paper Fig. 6 pipeline): QPS at EQUAL recall vs
`fused_hops`, the hops-per-dispatch knob of the fused traversal kernel.

Two sweeps over the same graph, fused_hops in {1, 2, 4, 8}:

  * csd (the headline): the superstep driver amortizes the per-hop host
    round-trip — sync + store reads + jitted dispatch drop from one per
    hop to one per H-hop superstep. QPS is measured with the same
    concurrent-lane harness as fig_cluster; `supersteps` (host syncs) and
    `bytes_read` come from QueryStats and must fall with H.
  * in-memory (partitioned backend): the persistent Pallas kernel runs H
    hops per invocation. NOTE: this container executes Pallas in
    interpret mode (CPU), where the kernel pays a python interpreter per
    hop — wall-clock here measures dispatch-count scaling only; on real
    hardware the fused kernel removes the per-hop launch + HBM beam
    round-trip (see kernels/README.md).

"Equal recall" is not sampled — it is asserted: every sweep point's ids
must be bit-identical to the fused_hops=1 golden (the fused traversal's
core contract), so recall is equal by construction and reported once.

Emits schema-validated `BENCH_traversal.json` at the repo root.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import bench_stamp, recall_of
from benchmarks.fig_cluster import _throughput
from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset

K, EF = 10, 40
SWEEP = (1, 2, 4, 8)
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_traversal.json")


def _shapes(tiny: bool):
    if tiny:    # CI smoke: same code path, minutes -> seconds
        return dict(n=2000, dim=64, nq=32,
                    cfg=HNSWConfig(M=12, ef_construction=80, seed=0),
                    partitions=2, lanes=2, rounds=2, nq_mem=16)
    # fig1 / table2 workload (benchmarks/common.py shapes)
    return dict(n=8000, dim=128, nq=256,
                cfg=HNSWConfig(M=16, ef_construction=100, seed=0),
                partitions=4, lanes=2, rounds=3, nq_mem=64)


def _build(tmp: str, s: dict):
    """One graph, served two ways (zoo-style: csd restructures the
    partitioned backend's own DB, so both answer bit-identically)."""
    from repro.store.csd import CSDBackend

    ds = VectorDataset(s["n"], s["dim"], n_clusters=64, seed=0)
    vectors = ds.vectors()
    queries = ds.queries(s["nq"])
    d2 = (np.einsum("nd,nd->n", vectors, vectors)[None]
          - 2 * queries @ vectors.T
          + np.einsum("qd,qd->q", queries, queries)[:, None])
    gt = np.argsort(d2, axis=1, kind="stable")[:, :K]
    part = SearchService.build(
        vectors, IndexSpec(backend="partitioned",
                           num_partitions=s["partitions"], hnsw=s["cfg"],
                           keep_vectors=False))
    import dataclasses
    spec = dataclasses.replace(part.spec, backend="csd",
                               storage_path=os.path.join(tmp, "store"),
                               cache_bytes=64 << 20, prefetch=True)
    csd = SearchService(spec, CSDBackend.from_partitioned(
        part.backend.pdb, spec))
    return part, csd, queries, gt


def _at_fused_hops(svc, h: int):
    """Re-tune an already-built service: backend.params reads the spec."""
    import dataclasses
    svc.backend.spec = dataclasses.replace(svc.backend.spec, fused_hops=h)
    return svc


def _cold_bytes(svc, queries, h: int) -> int:
    """Store traffic of one batch from a COLD PageCache (the warm shared
    cache would report ~0 for every sweep point after the first)."""
    from repro.core.search import SearchParams
    from repro.store.csd import store_search
    from repro.store.layout import open_store

    spec = svc.backend.spec
    reader = open_store(spec.storage_path, spec.cache_bytes,
                        prefetch=spec.prefetch)
    try:
        store_search(reader, queries,
                     SearchParams(ef=EF, k=K, metric=spec.metric,
                                  fused_hops=h))
        if reader.prefetcher is not None:
            reader.prefetcher.drain()
        return int(reader.cache.snapshot()["bytes_read"])
    finally:
        reader.close()


def _sweep_csd(svc, queries, gt, s: dict) -> list[dict]:
    out = []
    golden = None
    for h in SWEEP:
        _at_fused_hops(svc, h)
        resp = svc.search(SearchRequest(queries=queries, k=K, ef=EF,
                                        with_stats=True))
        ids = np.asarray(resp.ids)
        if golden is None:
            golden = ids
        np.testing.assert_array_equal(ids, golden)   # equal recall, proven
        thr = _throughput(svc.search, queries, lanes=s["lanes"],
                          rounds=s["rounds"])
        st = resp.stats
        out.append({
            "fused_hops": h,
            "qps": round(thr["qps"], 1),
            "p50_ms": thr["p50_ms"],
            "us_per_query": thr["us_per_query"],
            "recall": round(recall_of(ids, gt), 4),
            "ids_bit_identical_to_h1": True,
            "hops_mean": round(float(np.mean(np.asarray(st.hops))), 2),
            "supersteps": int(st.supersteps),
            "bytes_read_cold": _cold_bytes(svc, queries, h),
        })
    _at_fused_hops(svc, 1)
    h1 = out[0]
    for row in out:
        row["speedup_vs_h1"] = round(row["qps"] / h1["qps"], 2)
        row["host_syncs_vs_h1"] = round(row["supersteps"]
                                        / h1["supersteps"], 3)
    return out


def _sweep_memory(svc, queries, gt, s: dict) -> list[dict]:
    from benchmarks.common import timeit
    q = queries[:s["nq_mem"]]
    out = []
    golden = None
    for h in SWEEP:
        _at_fused_hops(svc, h)
        resp = svc.search(SearchRequest(queries=q, k=K, ef=EF,
                                        with_stats=True))
        ids = np.asarray(resp.ids)
        if golden is None:
            golden = ids
        np.testing.assert_array_equal(ids, golden)
        us = timeit(lambda: svc.search(
            SearchRequest(queries=q, k=K, ef=EF)).ids, iters=2)
        out.append({
            "fused_hops": h,
            "qps": round(len(q) / (us / 1e6), 1),
            "us_per_query": round(us / len(q), 1),
            "recall": round(recall_of(ids, gt[:len(q)]), 4),
            "ids_bit_identical_to_h1": True,
            "hops_mean": round(float(np.mean(np.asarray(st.hops))), 2)
            if (st := resp.stats) and st.hops is not None else None,
        })
    _at_fused_hops(svc, 1)
    return out


def _validate(record: dict) -> None:
    """Fail loudly before writing a malformed artifact."""
    for key in ("n", "dim", "nq", "k", "ef"):
        assert isinstance(record[key], int), f"{key} must be int"
    for name in ("csd", "in_memory"):
        sweep = record["sweeps"][name]
        assert [r["fused_hops"] for r in sweep] == list(SWEEP), \
            f"{name} sweep must cover fused_hops {SWEEP}"
        for r in sweep:
            assert r["qps"] > 0 and r["us_per_query"] > 0
            assert 0.0 <= r["recall"] <= 1.0
            assert r["ids_bit_identical_to_h1"] is True
        recalls = {r["recall"] for r in sweep}
        assert len(recalls) == 1, f"{name}: recall drifted across H: {recalls}"
    csd = {r["fused_hops"]: r for r in record["sweeps"]["csd"]}
    assert csd[4]["supersteps"] < csd[1]["supersteps"], \
        "H=4 must cut host syncs vs the per-hop loop"
    assert csd[4]["bytes_read_cold"] <= csd[1]["bytes_read_cold"], \
        "superstep mode must not read more than hop-stepped + prefetch"
    assert csd[4]["qps"] > csd[1]["qps"], \
        f"no QPS win at fused_hops=4: {csd[4]['qps']} vs {csd[1]['qps']}"


def run(tiny: bool = False):
    import tempfile

    s = _shapes(tiny)
    tmp = tempfile.mkdtemp(prefix="fig-traversal-")
    part, csd, queries, gt = _build(tmp, s)
    record = {"n": s["n"], "dim": s["dim"], "nq": s["nq"], "k": K, "ef": EF,
              "tiny": tiny, "sweep": list(SWEEP),
              "bench_meta": bench_stamp("tiny" if tiny else "full"),
              "note": ("in_memory runs Pallas in interpret mode on CPU — "
                       "dispatch-count scaling only; csd QPS is the "
                       "host-round-trip amortization the paper targets"),
              "sweeps": {}}
    record["sweeps"]["csd"] = _sweep_csd(csd, queries, gt, s)
    record["sweeps"]["in_memory"] = _sweep_memory(part, queries, gt, s)

    _validate(record)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)

    rows = []
    for r in record["sweeps"]["csd"]:
        rows.append((f"fig_traversal_csd_h{r['fused_hops']}",
                     r["us_per_query"],
                     f"qps={r['qps']};speedup={r['speedup_vs_h1']};"
                     f"recall={r['recall']};supersteps={r['supersteps']};"
                     f"bytes_read_cold={r['bytes_read_cold']}"))
    for r in record["sweeps"]["in_memory"]:
        rows.append((f"fig_traversal_mem_h{r['fused_hops']}",
                     r["us_per_query"],
                     f"qps={r['qps']};recall={r['recall']}"))
    rows.append(("fig_traversal_json", 0.0, f"wrote={BENCH_JSON}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds, same code path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, extra in run(tiny=args.tiny):
        print(f"{name},{us:.1f},{extra}")


if __name__ == "__main__":
    main()
