"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (CPU wall for relative numbers,
`derived` carries recall / modeled-TPU quantities / paper references).

  PYTHONPATH=src python -m benchmarks.run [--only fig9,...]
"""

from __future__ import annotations

import argparse
import inspect
import sys
import traceback

MODULES = [
    "fig1_recall_qps",
    "fig8_engines",
    "fig9_bruteforce",
    "fig11_parallelism",
    "fig12_platforms",
    "fig_ingest",
    "fig_cluster",
    "fig_obs",
    "fig_pq",
    "fig_traversal",
    "table2_kernels",
    "lm_substrate",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--backend", default=None,
                    help="extra backend rows for modules that support it "
                         "(fig9: 'csd' adds out-of-core block-read rows)")
    ap.add_argument("--serve", action="store_true",
                    help="extra serving rows for modules that support it "
                         "(fig11: repro.serve replicas x max_batch sweep)")
    ap.add_argument("--dtype", default=None,
                    help="extra quantized-path rows for modules that "
                         "support it (fig9: 'uint8' adds the paper's "
                         "SIFT1B operating point — recall delta + "
                         "storage-byte ratio vs float32)")
    args = ap.parse_args()
    mods = MODULES
    if args.only:
        want = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(w) for w in want)]
    print("name,us_per_call,derived")
    failed = 0
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            kwargs = {}
            if (args.backend and
                    "backend" in inspect.signature(mod.run).parameters):
                kwargs["backend"] = args.backend
            if (args.serve and
                    "serve" in inspect.signature(mod.run).parameters):
                kwargs["serve"] = True
            if (args.dtype and
                    "dtype" in inspect.signature(mod.run).parameters):
                kwargs["dtype"] = args.dtype
            for row in mod.run(**kwargs):
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            sys.stdout.flush()
        except Exception:
            failed += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
