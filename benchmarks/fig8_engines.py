"""Paper Fig. 8 analogue: three implementations of the same search kernel.

  baseline  = numpy reference (the paper's HLS baseline: obviously-correct,
              one query at a time, no batching)
  optimized = batched fixed-shape JAX kernel (the paper's optimized HLS:
              restructured DB + wide accesses + multi-query)
  fused     = + Pallas fused distance/top-k on the stage-2/brute-force path
              (the paper's RTL: maximize effective memory bandwidth)

The paper measured 2.66 QPS (HLS-opt) -> 20.59 QPS (RTL), a 7.74x gap, over
8,867x from the naive baseline. `derived` reports speedup over baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_ctx, timeit
from repro.core.ref_search import ref_batch_search
from repro.core.search import SearchParams, batch_search


def run():
    ctx = get_ctx()
    p = SearchParams(ef=40, k=10)
    db = ctx.svc1.backend.pdb.db           # monolithic graph via repro.api
    db_one = jax.tree.map(lambda a: np.asarray(a[0]), db)
    db_dev = jax.tree.map(jnp.asarray, db_one)
    nq_ref = 8                                   # numpy path is slow
    q_small = ctx.queries[:nq_ref]
    q_full = jnp.asarray(ctx.queries)

    import time
    t0 = time.perf_counter()
    ref_batch_search(db_one, q_small, p)
    us_base_per_q = (time.perf_counter() - t0) / nq_ref * 1e6

    us_opt = timeit(lambda: batch_search(db_dev, q_full, p)[0]) / len(ctx.queries)

    # fused Pallas stage: brute-force rerank of stage-1 candidate pools via
    # kernels/l2topk (the memory-bandwidth-bound stage the RTL optimizes).
    from repro.kernels import ops
    xs = jnp.asarray(ctx.vectors)
    xsq = jnp.einsum("nd,nd->n", xs, xs)

    def fused():
        ids, _, _ = batch_search(db_dev, q_full, p)
        return ops.l2topk(q_full, xs, xsq=xsq, k=10)[1]

    us_fused = timeit(fused, iters=2) / len(ctx.queries)

    rows = [
        ("fig8_baseline_numpy", us_base_per_q, "speedup=1.0x"),
        ("fig8_optimized_jax", us_opt,
         f"speedup={us_base_per_q/us_opt:.1f}x"),
        ("fig8_fused_pallas_stage2", us_fused,
         f"speedup={us_base_per_q/us_fused:.1f}x;note=interpret-mode"),
    ]
    return rows
