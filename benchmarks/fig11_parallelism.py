"""Paper Fig. 11: query parallelism vs graph parallelism scale-up (1-4).

The paper measured: query parallelism 1.56x at 4 devices (bottleneck:
every device reloads the whole DB), graph parallelism 3.67x (near-linear).

This container has ONE physical core, so wall-clock over fake devices is
meaningless; the benchmark instead reproduces the MECHANISM: per-device
work (distance calculations) and per-device database bytes moved, and
derives the modeled speedup on v5e constants (819 GB/s HBM; the paper's
per-query compute measured from the single-device run). Correctness of the
distributed execution itself is covered by tests/test_distributed.py on 8
fake devices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_ctx
from repro.core.partitioned import search_partitioned
from repro.launch.roofline import HW


def run():
    ctx = get_ctx()
    q = ctx.queries
    # per-partition [P, B] counters: drive the api backend's engine directly
    # (the service-level QueryStats are already reduced over partitions)
    backend = ctx.svc.backend
    _, _, stats = search_partitioned(backend.pdb, jnp.asarray(q),
                                     backend.params(10, 40))
    calcs = np.asarray(stats.dist_calcs)           # [P, B]
    per_part = calcs.sum(axis=1)                   # work per partition
    total_work = float(per_part.sum())
    db_bytes = sum(a.nbytes for a in jax.tree.leaves(backend.pdb.db))
    hw = HW()
    dim = ctx.vectors.shape[1]
    nq = len(q)

    # per-query compute seconds on one device (modeled: reads dominate —
    # each distance calc touches one d-dim vector from HBM).
    t_read_per_calc = dim * 4 / hw.hbm_bw
    rows = []
    for ndev in (1, 2, 4):
        # graph parallelism: each device holds P/ndev partitions; work and
        # DB load both shrink by ndev. One DB load per batch window.
        work_dev = total_work / ndev
        t_g = work_dev * t_read_per_calc + (db_bytes / ndev) / hw.hbm_bw
        # query parallelism: full DB per device, queries split.
        t_q = (total_work / ndev) * t_read_per_calc + db_bytes / hw.hbm_bw
        if ndev == 1:
            t1 = t_g
        rows.append((f"fig11_graph_par_{ndev}dev", t_g / nq * 1e6,
                     f"modeled_speedup={t1/t_g:.2f}x"))
        rows.append((f"fig11_query_par_{ndev}dev", t_q / nq * 1e6,
                     f"modeled_speedup={t1/t_q:.2f}x"))
    rows.append(("fig11_paper_reference", 0.0,
                 "paper: graph 3.67x@4dev, query 1.56x@4dev"))
    return rows
