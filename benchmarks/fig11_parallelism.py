"""Paper Fig. 11: query parallelism vs graph parallelism scale-up (1-4).

The paper measured: query parallelism 1.56x at 4 devices (bottleneck:
every device reloads the whole DB), graph parallelism 3.67x (near-linear).

This container has ONE physical core, so wall-clock over fake devices is
meaningless; the benchmark instead reproduces the MECHANISM: per-device
work (distance calculations) and per-device database bytes moved, and
derives the modeled speedup on v5e constants (819 GB/s HBM; the paper's
per-query compute measured from the single-device run). Correctness of the
distributed execution itself is covered by tests/test_distributed.py on 8
fake devices.

`--serve` adds the deployment-pipeline rows: the repro.serve dynamic
batcher + replica pool swept over replicas x max_batch. Wall QPS on one
core is contention-bound, so the scaling column is `modeled_qps` =
(uncontended 1-replica QPS) x (dispatch balance = nq / max per-replica
queries) — measured from the dispatcher's actual per-replica assignment,
so a load-balancing regression shows up as a flattened curve.

  PYTHONPATH=src python -m benchmarks.fig11_parallelism --serve
  PYTHONPATH=src python -m benchmarks.run --only fig11 --serve
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_ctx
from repro.core.partitioned import search_partitioned
from repro.launch.roofline import HW


def run(serve: bool = False):
    ctx = get_ctx()
    q = ctx.queries
    # per-partition [P, B] counters: drive the api backend's engine directly
    # (the service-level QueryStats are already reduced over partitions)
    backend = ctx.svc.backend
    _, _, stats = search_partitioned(backend.pdb, jnp.asarray(q),
                                     backend.params(10, 40))
    calcs = np.asarray(stats.dist_calcs)           # [P, B]
    per_part = calcs.sum(axis=1)                   # work per partition
    total_work = float(per_part.sum())
    db_bytes = sum(a.nbytes for a in jax.tree.leaves(backend.pdb.db))
    hw = HW()
    dim = ctx.vectors.shape[1]
    nq = len(q)

    # per-query compute seconds on one device (modeled: reads dominate —
    # each distance calc touches one d-dim vector from HBM).
    t_read_per_calc = dim * 4 / hw.hbm_bw
    rows = []
    for ndev in (1, 2, 4):
        # graph parallelism: each device holds P/ndev partitions; work and
        # DB load both shrink by ndev. One DB load per batch window.
        work_dev = total_work / ndev
        t_g = work_dev * t_read_per_calc + (db_bytes / ndev) / hw.hbm_bw
        # query parallelism: full DB per device, queries split.
        t_q = (total_work / ndev) * t_read_per_calc + db_bytes / hw.hbm_bw
        if ndev == 1:
            t1 = t_g
        rows.append((f"fig11_graph_par_{ndev}dev", t_g / nq * 1e6,
                     f"modeled_speedup={t1/t_g:.2f}x"))
        rows.append((f"fig11_query_par_{ndev}dev", t_q / nq * 1e6,
                     f"modeled_speedup={t1/t_q:.2f}x"))
    rows.append(("fig11_paper_reference", 0.0,
                 "paper: graph 3.67x@4dev, query 1.56x@4dev"))
    if serve:
        rows.extend(serve_rows())
    return rows


# ---------------------------------------------------------------------------
# --serve: replicas x max_batch sweep through the async serving subsystem
# ---------------------------------------------------------------------------


def _serve_window(svc, queries, n_replicas: int, max_batch: int):
    """One measured serving window; returns (wall_s, ServeStats)."""
    from repro.serve import SearchServer

    srv = SearchServer(svc, replicas=n_replicas, max_batch=max_batch,
                       max_wait_ms=1.0)
    try:
        t0 = time.perf_counter()
        for f in srv.submit_many(queries, k=10, ef=40):
            f.result()
        wall = time.perf_counter() - t0
        return wall, srv.stats()
    finally:
        srv.shutdown()


def serve_rows():
    ctx = get_ctx()
    q = ctx.queries
    nq = len(q)
    # warm the jit cache for every batch bucket the sweep will produce
    # (powers of two up to the largest max_batch), so measured windows
    # time serving, not compilation
    from repro.api import SearchRequest
    b = 1
    while b <= 64:
        ctx.svc.search(SearchRequest(queries=q[:b], k=10, ef=40))
        b *= 2
    rows = []
    for max_batch in (16, 64):
        base_qps = None
        for nrep in (1, 2, 4):
            wall, st = _serve_window(ctx.svc, q, nrep, max_batch)
            qps = nq / wall
            per_rep = [r["queries"] for r in st.replicas]
            balance = nq / max(per_rep)          # == nrep when balanced
            if base_qps is None:
                base_qps = qps                   # uncontended single replica
            modeled = base_qps * balance
            rows.append((
                f"fig11_serve_{nrep}rep_batch{max_batch}",
                wall / nq * 1e6,
                f"qps={qps:.1f};modeled_qps={modeled:.1f};"
                f"modeled_speedup={modeled / base_qps:.2f}x;"
                f"mean_batch={st.mean_batch:.1f};"
                f"queue_p50_ms={st.queue_ms['p50']:.2f};"
                f"e2e_p99_ms={st.e2e_ms['p99']:.1f};"
                f"per_replica_q={'/'.join(map(str, per_rep))}"))
    rows.append(("fig11_serve_paper_reference", 0.0,
                 "paper graph parallelism 3.67x@4dev; modeled_speedup = "
                 "dispatch balance x 1-replica QPS (1 CPU core: wall QPS "
                 "is contention-bound, balance is the measured mechanism)"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--serve", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(serve=args.serve):
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
