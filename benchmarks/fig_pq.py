"""Product-quantization sweep: recall / QPS / cold `bytes_read` vs the
number of PQ subspaces M in {4, 8, 16}, against the paper's uint8 store.

The paper's SIFT1B configuration fits the platform because rows are ~1
byte/dim; `dtype="pq"` compresses further to M bytes/row (d/M dims per
byte). This benchmark measures what that buys on flash: the same graph is
served from two csd block stores — uint8 rows (the paper's operating
point) vs M-byte PQ code rows + the float32 `rerank_vectors` stage-2
table — and every point reports recall@10, warm-cache QPS, and the
cold-PageCache `bytes_read` of one batch.

Dataset note (and the honesty caveat that goes with it): PQ's recall
depends on the per-subspace entropy of the data, not its raw
dimensionality. Real embedding spaces are low-rank / cluster-structured
(which is why PQ works on SIFT); i.i.d. Gaussian data is adversarial for
any 256-centroid codebook. We generate block-structured vectors — each
d/16-dim block drawn from 64 per-block patterns plus small noise — so
the M=16 subspaces align with the generating blocks and the codebook can
capture them (the SIFT-like regime), while M=4/8 span several blocks
(support 64^2..64^4 patterns >> 256 centroids) and show the classic PQ
fidelity cliff. The headline comparison is therefore the M=16 row:
recall@10 (rerank on) matched to uint8 within `recall_eps`, at >=
`min_bytes_ratio` fewer cold bytes — both ASSERTED before the artifact
is written, not just reported.

Emits schema-validated `BENCH_pq.json` at the repo root.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from benchmarks.common import bench_stamp, recall_of, timeit
from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.store.csd import CSDBackend
from repro.store.layout import open_store

K = 10
EF = 120
SWEEP_M = (4, 8, 16)
HEADLINE_M = 16
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_pq.json")


def _shapes(tiny: bool):
    if tiny:    # CI smoke: same code path, same asserts at a lower bar
        return dict(n=1000, dim=1024, nq=8, nblocks=16, patterns=64,
                    cfg=HNSWConfig(M=12, ef_construction=80, seed=0),
                    block_size=512, min_bytes_ratio=2.0, recall_eps=0.05)
    return dict(n=2000, dim=2048, nq=8, nblocks=16, patterns=64,
                cfg=HNSWConfig(M=12, ef_construction=80, seed=0),
                block_size=512, min_bytes_ratio=4.0, recall_eps=0.05)


def _block_structured(s: dict, seed: int = 0):
    """Vectors whose d/nblocks-dim blocks are drawn from `patterns`
    per-block prototypes (+ small noise): low per-subspace entropy, the
    structure PQ codebooks exist to capture."""
    rng = np.random.default_rng(seed)
    dsub = s["dim"] // s["nblocks"]
    protos = rng.normal(
        size=(s["nblocks"], s["patterns"], dsub)).astype(np.float32)

    def draw(count):
        codes = rng.integers(0, s["patterns"], size=(count, s["nblocks"]))
        out = np.concatenate(
            [protos[j, codes[:, j]] for j in range(s["nblocks"])], axis=1)
        return (out + rng.normal(scale=0.01, size=(count, s["dim"]))
                ).astype(np.float32)

    return draw(s["n"]), draw(s["nq"])


def _build_csd(tmp: str, vectors, s: dict, dtype: str, pq_m=None):
    """One graph on a csd block store (single partition: the out-of-core
    operating point where row bytes, not merge width, set the traffic)."""
    kw = dict(pq_m=pq_m) if dtype == "pq" else {}
    part = SearchService.build(vectors, IndexSpec(
        backend="partitioned", dtype=dtype, num_partitions=1, hnsw=s["cfg"],
        keep_vectors=True, block_size=s["block_size"], **kw))
    spec = dataclasses.replace(
        part.spec, backend="csd", keep_vectors=False,
        storage_path=os.path.join(tmp, f"{dtype}{pq_m or ''}"),
        prefetch=False)
    raw = part.backend.raw if dtype == "pq" else None
    return SearchService(spec, CSDBackend.from_partitioned(
        part.backend.pdb, spec, raw=raw))


def _cold_bytes(svc, queries, rerank: bool) -> int:
    """Store traffic of one batch from a COLD PageCache (the service's
    own warm cache would report ~0 after the first measurement)."""
    spec = svc.backend.spec
    reader = open_store(spec.storage_path, spec.cache_bytes, prefetch=False)
    try:
        cold = SearchService(spec, CSDBackend(spec, reader))
        resp = cold.search(SearchRequest(queries=queries, k=K, ef=EF,
                                         rerank=rerank, with_stats=True))
        return int(resp.stats.bytes_read)
    finally:
        reader.close()


def _measure(svc, queries, gt) -> dict:
    resp = svc.search(SearchRequest(queries=queries, k=K, ef=EF,
                                    rerank=True, with_stats=True))
    us = timeit(lambda: svc.search(SearchRequest(
        queries=queries, k=K, ef=EF, rerank=True)).ids, iters=2)
    raw = svc.search(SearchRequest(queries=queries, k=K, ef=EF,
                                   rerank=False))
    table = svc.backend.reader.blockfile.tables["vectors"]
    return {
        "recall_rerank": round(recall_of(np.asarray(resp.ids), gt), 4),
        "recall_raw": round(recall_of(np.asarray(raw.ids), gt), 4),
        "qps": round(len(queries) / (us / 1e6), 1),
        "us_per_query": round(us / len(queries), 1),
        "row_bytes": int(table["row_bytes"]),
        "bytes_read_cold": _cold_bytes(svc, queries, rerank=True),
        "bytes_read_cold_stage1": _cold_bytes(svc, queries, rerank=False),
    }


def _validate(record: dict, s: dict) -> None:
    """Fail loudly before writing a malformed artifact."""
    u8 = record["uint8"]
    assert [p["pq_m"] for p in record["sweep"]] == list(SWEEP_M)
    for p in record["sweep"]:
        assert p["qps"] > 0 and p["us_per_query"] > 0
        assert 0.0 <= p["recall_raw"] <= p["recall_rerank"] <= 1.0, \
            f"M={p['pq_m']}: rerank must not lose recall: {p}"
        assert p["row_bytes"] == p["pq_m"], \
            f"PQ store row must be M bytes: {p}"
        assert p["bytes_read_cold"] < u8["bytes_read_cold"], \
            f"M={p['pq_m']} read more than uint8"
    by_m = {p["pq_m"]: p for p in record["sweep"]}
    assert (by_m[4]["recall_rerank"] < by_m[8]["recall_rerank"]
            < by_m[16]["recall_rerank"]), \
        "recall must rise with M (codebook fidelity)"
    h = record["headline"]
    assert h["recall_gap"] <= s["recall_eps"], \
        (f"recall not matched: pq={h['recall_pq']} "
         f"uint8={h['recall_uint8']} (eps={s['recall_eps']})")
    assert h["bytes_ratio_vs_uint8"] >= s["min_bytes_ratio"], \
        (f"bytes_read ratio {h['bytes_ratio_vs_uint8']} < "
         f"{s['min_bytes_ratio']}x at matched recall")


def run(tiny: bool = False):
    import tempfile

    s = _shapes(tiny)
    tmp = tempfile.mkdtemp(prefix="fig-pq-")
    vectors, queries = _block_structured(s)
    d2 = (np.einsum("nd,nd->n", vectors, vectors)[None]
          - 2 * queries @ vectors.T
          + np.einsum("qd,qd->q", queries, queries)[:, None])
    gt = np.argsort(d2, axis=1, kind="stable")[:, :K]

    u8 = _measure(_build_csd(tmp, vectors, s, "uint8"), queries, gt)
    sweep = []
    for m in SWEEP_M:
        svc = _build_csd(tmp, vectors, s, "pq", pq_m=m)
        sweep.append({"pq_m": m, **_measure(svc, queries, gt)})

    head = next(p for p in sweep if p["pq_m"] == HEADLINE_M)
    record = {
        "n": s["n"], "dim": s["dim"], "nq": s["nq"], "k": K, "ef": EF,
        "tiny": tiny, "sweep_m": list(SWEEP_M),
        "bench_meta": bench_stamp("tiny" if tiny else "full"),
        "note": ("block-structured data (d/16-dim blocks, 64 patterns "
                 "each): M=16 subspaces align with the generating blocks "
                 "(codebook-capturable, the SIFT-like regime); M=4/8 "
                 "span several blocks and show the PQ fidelity cliff. "
                 "bytes_read_cold includes stage-2 float32 rerank reads; "
                 "_stage1 is the same batch with rerank off."),
        "uint8": u8,
        "sweep": sweep,
        "headline": {
            "pq_m": HEADLINE_M,
            "recall_pq": head["recall_rerank"],
            "recall_uint8": u8["recall_rerank"],
            "recall_gap": round(abs(u8["recall_rerank"]
                                    - head["recall_rerank"]), 4),
            "bytes_ratio_vs_uint8": round(u8["bytes_read_cold"]
                                          / head["bytes_read_cold"], 2),
            "row_bytes_ratio_vs_uint8": round(u8["row_bytes"]
                                              / head["row_bytes"], 2),
        },
    }
    _validate(record, s)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)

    rows = [("fig_pq_uint8", u8["us_per_query"],
             f"qps={u8['qps']};recall={u8['recall_rerank']};"
             f"row_bytes={u8['row_bytes']};"
             f"bytes_read_cold={u8['bytes_read_cold']}")]
    for p in sweep:
        rows.append((f"fig_pq_m{p['pq_m']}", p["us_per_query"],
                     f"qps={p['qps']};recall={p['recall_rerank']};"
                     f"recall_raw={p['recall_raw']};"
                     f"row_bytes={p['row_bytes']};"
                     f"bytes_read_cold={p['bytes_read_cold']}"))
    h = record["headline"]
    rows.append(("fig_pq_json", 0.0,
                 f"wrote={BENCH_JSON};headline_m={h['pq_m']};"
                 f"bytes_ratio={h['bytes_ratio_vs_uint8']};"
                 f"recall_gap={h['recall_gap']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds, same code path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, extra in run(tiny=args.tiny):
        print(f"{name},{us:.1f},{extra}")


if __name__ == "__main__":
    main()
