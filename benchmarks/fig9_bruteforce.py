"""Paper Fig. 9: brute-force vs HNSW — QPS and number of vector reads.

The paper: HNSW reads 0.03% of the vectors (338,739x fewer) and wins 6.86x
in QPS despite the brute-force design being perfectly compute-efficient.

With `--backend csd` (benchmarks/run.py) the same comparison is extended to
the out-of-core engine: the graph is served from the block store and the
derived column reports *block reads* (flash / P2P-DMA transfers, the
paper's storage-side unit) next to the in-memory vector-read counts.
"""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from benchmarks.common import get_ctx, timeit
from repro.api import SearchRequest


def _csd_rows(ctx, reads_hnsw: float):
    """Serve the already-built partitioned graph out-of-core and count the
    storage traffic the same search costs."""
    import dataclasses

    import jax

    from repro.api import SearchService
    from repro.api.backends import CSDBackend

    q = ctx.queries[:32]      # host-driven block reads; keep the run short
    tmp = tempfile.mkdtemp(prefix="fig9_csd_")
    svc = None
    try:
        spec = dataclasses.replace(
            ctx.svc.spec, backend="csd", keep_vectors=False,
            storage_path=os.path.join(tmp, "store"),
            cache_bytes=8 << 20)
        pdb_host = ctx.svc.backend.pdb._replace(
            db=jax.tree.map(np.asarray, ctx.svc.backend.pdb.db))
        svc = SearchService(spec, CSDBackend.from_partitioned(pdb_host, spec))
        resp = svc.search(SearchRequest(queries=q, k=10, ef=40,
                                        with_stats=True))
        blocks = int(resp.stats.block_reads)
        us = timeit(
            lambda: svc.search(SearchRequest(queries=q, k=10, ef=40)).ids,
            warmup=1, iters=2) / len(q)
        return [
            ("fig9_csd_store", us,
             f"block_reads={blocks};blocks_per_query={blocks/len(q):.1f};"
             f"vector_reads_mem={reads_hnsw:.0f};"
             f"cache_hit_rate={resp.stats.cache_hit_rate:.2f};"
             f"bytes_from_flash={int(resp.stats.bytes_read)}"),
        ]
    finally:
        if svc is not None:
            svc.backend.reader.close()
        shutil.rmtree(tmp, ignore_errors=True)


def run(backend: str | None = None):
    ctx = get_ctx()
    n = ctx.vectors.shape[0]
    q = ctx.queries

    resp = ctx.svc.search(SearchRequest(queries=q, k=10, ef=40,
                                        with_stats=True))
    reads_hnsw = float(np.mean(np.asarray(resp.stats.dist_calcs)))
    us_hnsw = timeit(
        lambda: ctx.svc.search(SearchRequest(queries=q, k=10, ef=40)).ids
    ) / len(q)

    us_bf = timeit(
        lambda: ctx.svc_exact.search(SearchRequest(queries=q, k=10)).ids
    ) / len(q)

    # scale extrapolation: HNSW reads grow ~a*ln(n) (hierarchical graph),
    # brute force reads grow ~n. At the paper's n = 1e9 the measured
    # coefficient puts the read ratio in the paper's regime (they measured
    # 338,739x; see derived). At n = 8e3 the crossover has not happened and
    # brute force wins wall-clock — report both honestly.
    a = reads_hnsw / np.log(n)
    reads_1b = a * np.log(1e9)
    ratio_1b = 1e9 / reads_1b
    rows = [
        ("fig9_hnsw", us_hnsw,
         f"vector_reads={reads_hnsw:.0f};frac={reads_hnsw/n:.4f}"),
        ("fig9_bruteforce", us_bf,
         f"vector_reads={n};read_ratio={n/reads_hnsw:.1f}x"),
        ("fig9_qps_ratio", 0.0,
         f"hnsw_over_bf_cpu_n8k={us_bf/us_hnsw:.2f}x;"
         f"extrapolated_read_ratio_1B={ratio_1b:.0f}x;"
         f"paper_1B=338739x"),
    ]
    if backend == "csd":
        rows += _csd_rows(ctx, reads_hnsw)
    return rows
