"""Paper Fig. 9: brute-force vs HNSW — QPS and number of vector reads.

The paper: HNSW reads 0.03% of the vectors (338,739x fewer) and wins 6.86x
in QPS despite the brute-force design being perfectly compute-efficient.

With `--backend csd` (benchmarks/run.py) the same comparison is extended to
the out-of-core engine: the graph is served from the block store and the
derived column reports *block reads* (flash / P2P-DMA transfers, the
paper's storage-side unit) next to the in-memory vector-read counts.

With `--dtype uint8` the sweep adds the paper's actual SIFT1B operating
point — uint8 vectors (IndexSpec.dtype): the quantized graph is built,
served both in-memory and out-of-core, and the derived columns report the
recall cost of quantization next to the storage-bandwidth win (uint8
vector rows are 4x smaller, so `bytes_read` drops; neighbor-table traffic
is unchanged, which is why the measured end-to-end ratio sits between
2.5x and 4x at this scale).
"""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from benchmarks.common import get_ctx, timeit
from repro.api import SearchRequest


def _csd_service(svc_src, tag: str, tmp: str, cache_bytes: int = 8 << 20):
    """One shared recipe for serving an already-built (possibly quantized)
    partitioned service out-of-core — the --backend csd and --dtype uint8
    rows must measure identically-configured stores."""
    import dataclasses

    import jax

    from repro.api import SearchService
    from repro.api.backends import CSDBackend

    spec = dataclasses.replace(
        svc_src.spec, backend="csd", keep_vectors=False,
        storage_path=os.path.join(tmp, f"store_{tag}"),
        cache_bytes=cache_bytes)
    pdb_host = svc_src.backend.pdb._replace(
        db=jax.tree.map(np.asarray, svc_src.backend.pdb.db))
    return SearchService(spec, CSDBackend.from_partitioned(pdb_host, spec))


def _csd_rows(ctx, reads_hnsw: float):
    """Serve the already-built partitioned graph out-of-core and count the
    storage traffic the same search costs."""
    q = ctx.queries[:32]      # host-driven block reads; keep the run short
    tmp = tempfile.mkdtemp(prefix="fig9_csd_")
    svc = None
    try:
        svc = _csd_service(ctx.svc, "f32", tmp)
        resp = svc.search(SearchRequest(queries=q, k=10, ef=40,
                                        with_stats=True))
        blocks = int(resp.stats.block_reads)
        us = timeit(
            lambda: svc.search(SearchRequest(queries=q, k=10, ef=40)).ids,
            warmup=1, iters=2) / len(q)
        return [
            ("fig9_csd_store", us,
             f"block_reads={blocks};blocks_per_query={blocks/len(q):.1f};"
             f"vector_reads_mem={reads_hnsw:.0f};"
             f"cache_hit_rate={resp.stats.cache_hit_rate:.2f};"
             f"bytes_from_flash={int(resp.stats.bytes_read)}"),
        ]
    finally:
        if svc is not None:
            svc.backend.reader.close()
        shutil.rmtree(tmp, ignore_errors=True)


def _csd_bytes(svc_src, tag: str, q, tmp: str):
    """Measure the per-request storage traffic of one out-of-core serve."""
    svc = _csd_service(svc_src, tag, tmp)
    try:
        resp = svc.search(SearchRequest(queries=q, k=10, ef=40,
                                        with_stats=True))
        return int(resp.stats.bytes_read), int(resp.stats.block_reads)
    finally:
        svc.backend.reader.close()


def _uint8_rows(ctx):
    """The quantized operating point: recall delta + storage-byte ratio."""
    import dataclasses

    from repro.api import SearchService
    from benchmarks.common import recall_of

    q = ctx.queries[:32]
    spec_u8 = dataclasses.replace(ctx.svc.spec, dtype="uint8",
                                  qscale=None, qzero=None)
    svc_u8 = SearchService.build(ctx.vectors, spec_u8)
    r_f32 = recall_of(np.asarray(ctx.svc.search(
        SearchRequest(queries=ctx.queries, k=10, ef=40)).ids), ctx.gt)
    r_u8 = recall_of(np.asarray(svc_u8.search(
        SearchRequest(queries=ctx.queries, k=10, ef=40)).ids), ctx.gt)
    us_u8 = timeit(
        lambda: svc_u8.search(SearchRequest(queries=ctx.queries, k=10,
                                            ef=40)).ids,
        warmup=1, iters=2) / len(ctx.queries)
    tmp = tempfile.mkdtemp(prefix="fig9_u8_")
    try:
        by_u8, bl_u8 = _csd_bytes(svc_u8, "u8", q, tmp)
        by_f32, bl_f32 = _csd_bytes(ctx.svc, "f32", q, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return [
        ("fig9_uint8_graph", us_u8,
         f"recall_u8={r_u8:.3f};recall_f32={r_f32:.3f};"
         f"delta={r_f32 - r_u8:+.3f};qscale={svc_u8.spec.qscale:.4g}"),
        ("fig9_uint8_csd_bytes", 0.0,
         f"bytes_read_u8={by_u8};bytes_read_f32={by_f32};"
         f"ratio={by_f32 / max(by_u8, 1):.2f}x;"
         f"block_reads_u8={bl_u8};block_reads_f32={bl_f32};"
         f"vector_row_shrink=4.00x"),
    ]


def run(backend: str | None = None, dtype: str | None = None):
    ctx = get_ctx()
    n = ctx.vectors.shape[0]
    q = ctx.queries

    resp = ctx.svc.search(SearchRequest(queries=q, k=10, ef=40,
                                        with_stats=True))
    reads_hnsw = float(np.mean(np.asarray(resp.stats.dist_calcs)))
    us_hnsw = timeit(
        lambda: ctx.svc.search(SearchRequest(queries=q, k=10, ef=40)).ids
    ) / len(q)

    us_bf = timeit(
        lambda: ctx.svc_exact.search(SearchRequest(queries=q, k=10)).ids
    ) / len(q)

    # scale extrapolation: HNSW reads grow ~a*ln(n) (hierarchical graph),
    # brute force reads grow ~n. At the paper's n = 1e9 the measured
    # coefficient puts the read ratio in the paper's regime (they measured
    # 338,739x; see derived). At n = 8e3 the crossover has not happened and
    # brute force wins wall-clock — report both honestly.
    a = reads_hnsw / np.log(n)
    reads_1b = a * np.log(1e9)
    ratio_1b = 1e9 / reads_1b
    rows = [
        ("fig9_hnsw", us_hnsw,
         f"vector_reads={reads_hnsw:.0f};frac={reads_hnsw/n:.4f}"),
        ("fig9_bruteforce", us_bf,
         f"vector_reads={n};read_ratio={n/reads_hnsw:.1f}x"),
        ("fig9_qps_ratio", 0.0,
         f"hnsw_over_bf_cpu_n8k={us_bf/us_hnsw:.2f}x;"
         f"extrapolated_read_ratio_1B={ratio_1b:.0f}x;"
         f"paper_1B=338739x"),
    ]
    if backend == "csd":
        rows += _csd_rows(ctx, reads_hnsw)
    if dtype == "uint8":
        rows += _uint8_rows(ctx)
    return rows
