"""Cluster scaling sweep (repro.cluster): QPS/recall vs shards x replicas,
plus the failover-under-load latency spike.

Not a paper figure — the paper stops at 4 SmartSSDs in one server
(Fig. 11's graph parallelism); this is the cross-node layer's cost
surface:

  * QPS and recall@10 vs SHARD COUNT (the merge is bit-identical to one
    index, so recall is flat by construction — the QPS column prices the
    scatter-gather tax of full-ef traversal on every shard; NOTE on this
    single-box harness all shards share one CPU, so the sweep shows the
    tax only — the aggregate-flash-bandwidth win that pays for it needs
    real nodes and is priced by `costmodel.cluster_fanout_cost`);
  * QPS vs REPLICAS per shard under concurrent load (replicas are the
    throughput lever: each serves from its own executor);
  * p50/p99 latency with all replicas up vs after killing one replica of
    every shard mid-stream (failover keeps answers identical; the spike
    is the price).

Emits `BENCH_cluster.json` at the repo root (per-PR perf trajectory,
ROADMAP item 2) in addition to the usual CSV rows.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from benchmarks.common import bench_stamp, recall_of
from repro.api import IndexSpec, SearchRequest, SearchService
from repro.cluster import build_cluster
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset

K, EF = 10, 40
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_cluster.json")


def _shapes(tiny: bool):
    if tiny:    # CI smoke: same code path, minutes -> seconds
        return {"n": 1200, "dim": 64, "nq": 32, "rounds": 3,
                "cfg": HNSWConfig(M=8, ef_construction=60, seed=0),
                "shards": (1, 2), "replicas": (1, 2),
                "failover": (2, 2)}
    return {"n": 4000, "dim": 64, "nq": 64, "rounds": 6,
            "cfg": HNSWConfig(M=12, ef_construction=80, seed=0),
            "shards": (1, 2, 3, 4), "replicas": (1, 2),
            "failover": (3, 2)}


def _workload(s):
    ds = VectorDataset(s["n"], s["dim"], n_clusters=32, seed=0)
    vectors = ds.vectors()
    queries = ds.queries(s["nq"])
    d2 = (np.einsum("nd,nd->n", vectors, vectors)[None]
          - 2 * queries @ vectors.T
          + np.einsum("qd,qd->q", queries, queries)[:, None])
    return vectors, queries, np.argsort(d2, axis=1, kind="stable")[:, :K]


def _throughput(search, queries, *, lanes: int = 4, rounds: int = 6):
    """Concurrent-lane QPS + latency percentiles (router work overlaps
    across lanes the way repro.serve drives it)."""
    import jax

    req = SearchRequest(queries=queries, k=K, ef=EF)
    jax.block_until_ready(search(req).ids)          # warmup / compile
    lat = []

    def lane():
        out = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            jax.block_until_ready(search(req).ids)  # numpy: no-op
            out.append(time.perf_counter() - t0)
        return out

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=lanes) as ex:
        for fut in [ex.submit(lane) for _ in range(lanes)]:
            lat.extend(fut.result())
    wall = time.perf_counter() - t0
    lat_ms = np.asarray(lat) * 1e3
    n_queries = lanes * rounds * len(queries)
    return {"qps": n_queries / wall,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "us_per_query": wall / n_queries * 1e6}


def run(tiny: bool = False):
    s = _shapes(tiny)
    cfg, rounds = s["cfg"], s["rounds"]
    vectors, queries, gt = _workload(s)
    spec = IndexSpec(backend="partitioned", num_partitions=1, hnsw=cfg,
                     keep_vectors=True)
    rows = []
    record = {"n": s["n"], "dim": s["dim"], "k": K, "ef": EF,
              "tiny": tiny,
              "bench_meta": bench_stamp("tiny" if tiny else "full"),
              "sweeps": {}}

    # single-index baseline: what shards==1 must tie with
    single = SearchService.build(
        vectors, IndexSpec(backend="partitioned", num_partitions=1,
                           hnsw=cfg, keep_vectors=True))
    base = _throughput(single.search, queries, rounds=rounds)
    base_ids = np.asarray(single.search(
        SearchRequest(queries=queries, k=K, ef=EF)).ids)
    rec0 = recall_of(base_ids, gt)
    rows.append(("fig_cluster_single_index", base["us_per_query"],
                 f"recall={rec0:.3f};qps={base['qps']:.0f}"))
    record["sweeps"]["single_index"] = {**base, "recall": round(rec0, 4)}

    # -- sweep: shards x replicas --------------------------------------------
    for n_shards in s["shards"]:
        for replicas in s["replicas"]:
            cluster = build_cluster(vectors, spec, n_shards,
                                    replicas=replicas)
            ids = np.asarray(cluster.search(
                SearchRequest(queries=queries, k=K, ef=EF)).ids)
            rec = recall_of(ids, gt)
            m = _throughput(cluster.search, queries, rounds=rounds)
            cluster.close()
            rows.append((f"fig_cluster_{n_shards}shards_x{replicas}",
                         m["us_per_query"],
                         f"recall={rec:.3f};qps={m['qps']:.0f};"
                         f"p50_ms={m['p50_ms']:.1f};"
                         f"p99_ms={m['p99_ms']:.1f}"))
            record["sweeps"][f"shards_{n_shards}x{replicas}"] = {
                **m, "recall": round(rec, 4)}

    # -- failover under load: kill one replica of every shard mid-stream ----
    fo_shards, fo_reps = s["failover"]
    cluster = build_cluster(vectors, spec, fo_shards, replicas=fo_reps)
    want = np.asarray(cluster.search(
        SearchRequest(queries=queries, k=K, ef=EF)).ids)
    healthy = _throughput(cluster.search, queries, rounds=rounds)
    for client in cluster.shards:
        client.replicas[0].kill()
    degraded = _throughput(cluster.search, queries, rounds=rounds)
    got = np.asarray(cluster.search(
        SearchRequest(queries=queries, k=K, ef=EF)).ids)
    correct = bool(np.array_equal(want, got))
    cluster.close()
    rows.append(("fig_cluster_failover", degraded["us_per_query"],
                 f"answers_identical={correct};"
                 f"qps_healthy={healthy['qps']:.0f};"
                 f"qps_degraded={degraded['qps']:.0f};"
                 f"p99_healthy_ms={healthy['p99_ms']:.1f};"
                 f"p99_degraded_ms={degraded['p99_ms']:.1f}"))
    record["sweeps"][f"failover_{fo_shards}x{fo_reps}_kill_one_each"] = {
        "healthy": healthy, "degraded": degraded,
        "answers_identical": correct}

    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
    rows.append(("fig_cluster_json", 0.0, f"wrote={BENCH_JSON}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds, same code path)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, extra in run(tiny=args.tiny):
        print(f"{name},{us:.1f},{extra}")


if __name__ == "__main__":
    main()
