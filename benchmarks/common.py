"""Shared benchmark context: one dataset + engines, built once.

CPU wall-times are for RELATIVE comparisons (this container has no TPU);
each row's `derived` column carries the paper-relevant quantity (recall,
modeled-TPU QPS, vector reads, scaling factor...). Modeled numbers use the
v5e constants from launch/roofline.py and are labeled `modeled_*`.
"""

from __future__ import annotations

import dataclasses
import os
import platform
import time

import jax
import numpy as np

from repro.api import IndexSpec, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset

N, DIM, NQ = 8000, 128, 256
K, EF = 10, 40

# bump when the shape of any BENCH_*.json record changes incompatibly;
# scripts/bench_compare.py refuses to diff records from different versions
BENCH_SCHEMA_VERSION = 2


def bench_stamp(variant: str = "full") -> dict:
    """Provenance block every BENCH_*.json emitter embeds as `bench_meta`.

    `variant` distinguishes full-shape runs from `--tiny` CI smoke runs so
    bench_compare never diffs a tiny baseline against a full fresh run (the
    numbers differ by orders of magnitude, not by regressions). The host
    block records what the wall-times were measured ON — two snapshots from
    different machines are comparable in recall but not in QPS."""
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "variant": variant,
        "generated_unix": int(time.time()),
        "host": {
            "platform": platform.platform(),
            "machine": platform.machine(),
            "python": platform.python_version(),
            "cpu_count": os.cpu_count(),
            "jax": jax.__version__,
            "default_backend": jax.default_backend(),
        },
    }


@dataclasses.dataclass
class BenchCtx:
    vectors: np.ndarray
    queries: np.ndarray
    gt: np.ndarray
    cfg: HNSWConfig
    svc: SearchService           # partitioned backend, 4 sub-graphs
    svc1: SearchService          # hnsw backend (one graph)
    svc_exact: SearchService     # exact brute-force backend


_CTX = None


def get_ctx() -> BenchCtx:
    global _CTX
    if _CTX is not None:
        return _CTX
    t0 = time.time()
    ds = VectorDataset(N, DIM, n_clusters=64, seed=0)
    vectors = ds.vectors()
    queries = ds.queries(NQ)
    d2 = (np.einsum("nd,nd->n", vectors, vectors)[None]
          - 2 * queries @ vectors.T
          + np.einsum("qd,qd->q", queries, queries)[:, None])
    gt = np.argsort(d2, axis=1, kind="stable")[:, :K]
    cfg = HNSWConfig(M=16, ef_construction=100, seed=0)
    svc = SearchService.build(
        vectors, IndexSpec(backend="partitioned", num_partitions=4,
                           hnsw=cfg, keep_vectors=True))
    svc1 = SearchService.build(
        vectors, IndexSpec(backend="hnsw", hnsw=cfg, keep_vectors=False))
    svc_exact = SearchService.build(vectors, IndexSpec(backend="exact"))
    print(f"# bench context: n={N} built in {time.time()-t0:.1f}s")
    _CTX = BenchCtx(vectors, queries, gt, cfg, svc, svc1, svc_exact)
    return _CTX


def recall_of(ids: np.ndarray, gt: np.ndarray) -> float:
    k = gt.shape[1]
    return float(np.mean(
        [len(set(ids[b, :k]) & set(gt[b])) / k for b in range(len(gt))]))


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall microseconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
