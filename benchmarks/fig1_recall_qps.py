"""Paper Fig. 1 analogue: recall/QPS trade-off of the multi-layer graph
search, swept over ef (the paper's quality knob; SIFT1B point: ef=40 ->
recall 0.94). Runs through the repro.api service layer."""

from __future__ import annotations

import numpy as np

from benchmarks.common import get_ctx, recall_of, timeit
from repro.api import SearchRequest


def run():
    ctx = get_ctx()
    rows = []
    for ef in (10, 20, 40, 80, 160):
        resp = ctx.svc.search(SearchRequest(queries=ctx.queries, k=10, ef=ef))
        rec = recall_of(np.asarray(resp.ids), ctx.gt)
        us = timeit(lambda ef=ef: ctx.svc.search(
            SearchRequest(queries=ctx.queries, k=10, ef=ef)).ids)
        qps = len(ctx.queries) / (us / 1e6)
        rows.append((f"fig1_ef{ef}", us, f"recall={rec:.3f};qps_cpu={qps:.1f}"))
    return rows
