"""Paper Fig. 1 analogue: recall/QPS trade-off of the multi-layer graph
search, swept over ef (the paper's quality knob; SIFT1B point: ef=40 ->
recall 0.94)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_ctx, recall_of, timeit


def run():
    ctx = get_ctx()
    rows = []
    q = jnp.asarray(ctx.queries)
    for ef in (10, 20, 40, 80, 160):
        ids, _ = ctx.engine.search(ctx.queries, k=10, ef=ef)
        rec = recall_of(np.asarray(ids), ctx.gt)
        us = timeit(lambda ef=ef: ctx.engine.search(ctx.queries, k=10, ef=ef)[0])
        qps = len(ctx.queries) / (us / 1e6)
        rows.append((f"fig1_ef{ef}", us, f"recall={rec:.3f};qps_cpu={qps:.1f}"))
    return rows
