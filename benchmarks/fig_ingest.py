"""Streaming-ingest sweep (repro.ingest): QPS and recall@10 under churn.

Not a paper figure — the paper serves a static SIFT1B index — this is the
dynamic-workload extension's cost surface:

  * recall@10 and QPS vs FRACTION DELETED (tombstone debt burns over-fetch
    slots and traversal work until compaction reclaims it);
  * QPS vs SEGMENT COUNT (searches fan out over every live segment — the
    LSM read-amplification curve);
  * both, before and after `compact()` (one rebuilt segment restores the
    static-index cost).

`derived` also carries the modeled write-amplification of the same
workload (launch/costmodel.compaction_cost), tying the measured read cost
to the SSD-write cost the compactor pays to fix it.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import recall_of, timeit
from repro.api import IndexSpec, MutableSearchService, SearchRequest
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset

N, DIM, NQ = 4000, 64, 64
K, EF = 10, 40
CFG = HNSWConfig(M=12, ef_construction=80, seed=0)


def _workload():
    ds = VectorDataset(N, DIM, n_clusters=32, seed=0)
    return ds.vectors(), ds.queries(NQ)


def _gt(vectors, mask, queries):
    surv = vectors[mask]
    gids = np.flatnonzero(mask)
    d2 = (np.einsum("nd,nd->n", surv, surv)[None]
          - 2 * queries @ surv.T
          + np.einsum("qd,qd->q", queries, queries)[:, None])
    return gids[np.argsort(d2, axis=1, kind="stable")[:, :K]]


def _measure(svc, queries, gt):
    req = SearchRequest(queries=queries, k=K, ef=EF)
    ids = np.asarray(svc.search(req).ids)
    us = timeit(lambda: svc.search(req).ids, warmup=1, iters=2)
    return recall_of(ids, gt), us / len(queries), 1e6 / (us / len(queries))


def run():
    vectors, queries = _workload()
    rows = []

    # -- sweep 1: fraction deleted (fixed segment count) ---------------------
    for frac in (0.0, 0.25, 0.5):
        svc = MutableSearchService(
            IndexSpec(backend="partitioned", num_partitions=2, hnsw=CFG),
            seal_threshold=N // 4)
        gids = svc.insert(vectors)
        n_del = int(frac * N)
        dele = gids[:: max(1, N // max(n_del, 1))][:n_del]
        if len(dele):
            svc.delete(dele)
        mask = ~np.isin(np.arange(N), dele)
        gt = _gt(vectors, mask, queries)
        n_seg_pre = svc.num_segments
        r0, us0, qps0 = _measure(svc, queries, gt)
        svc.compact()
        r1, us1, qps1 = _measure(svc, queries, gt)
        rows.append((f"fig_ingest_deleted_{int(frac*100):02d}pct", us0,
                     f"recall_pre={r0:.3f};qps_pre={qps0:.0f};"
                     f"recall_post_compact={r1:.3f};qps_post={qps1:.0f};"
                     f"segments_pre={n_seg_pre};"
                     f"deleted={len(dele)}"))

    # -- sweep 2: segment count (no deletes) ---------------------------------
    mask = np.ones(N, bool)
    gt = _gt(vectors, mask, queries)
    for n_seg in (1, 2, 4, 8):
        svc = MutableSearchService(
            IndexSpec(backend="partitioned", num_partitions=2, hnsw=CFG),
            seal_threshold=N // n_seg)
        svc.insert(vectors)
        svc.flush()
        r0, us0, qps0 = _measure(svc, queries, gt)
        rows.append((f"fig_ingest_segments_{n_seg}", us0,
                     f"recall={r0:.3f};qps={qps0:.0f};"
                     f"live_segments={svc.num_segments}"))

    # modeled write amplification of the same cadence (costmodel tie-in)
    from repro.launch.costmodel import compaction_cost, vector_row_bytes
    cc = compaction_cost(N, vector_row_bytes(DIM), seal_threshold=N // 4,
                         compact_every=4, delete_frac=0.25)
    rows.append(("fig_ingest_write_amp", 0.0,
                 f"write_amp={cc.write_amp:.2f};"
                 f"bytes_ingested={int(cc.bytes_ingested)};"
                 f"bytes_rewritten={int(cc.bytes_rewritten)};"
                 f"compactions={cc.compactions}"))
    return rows
