"""LM substrate microbench: reduced-arch train-step throughput on CPU.

Not a paper figure — the observability hook for the serving/training side
of the framework (tokens/s on this host; roofline cells in EXPERIMENTS.md
carry the TPU-modeled numbers).
"""

from __future__ import annotations

import jax

from benchmarks.common import timeit
from repro.configs import reduced_config
from repro.data.pipeline import make_batch
from repro.models.model import make_train_state, train_step
from repro.optim.adamw import AdamWConfig

ARCHS = ["granite_3_8b", "deepseek_v2_lite_16b", "jamba_v01_52b"]


def run():
    rows = []
    opt = AdamWConfig(total_steps=100, warmup_steps=5)
    B, T = 2, 64
    for arch in ARCHS:
        cfg = reduced_config(arch)
        holder = {"state": make_train_state(jax.random.PRNGKey(0), cfg)}
        batch = jax.tree.map(jax.numpy.asarray,
                             make_batch(cfg, "train", T, B, step=0))

        def step(holder=holder, batch=batch, cfg=cfg):
            # train_step donates its state: thread it through.
            holder["state"], m = train_step(holder["state"], batch, cfg, opt)
            return m["loss"]

        us = timeit(step, warmup=1, iters=2)
        rows.append((f"lm_train_step_{arch}", us,
                     f"tokens_per_s_cpu={B*T/(us/1e6):.0f}"))
    return rows
