"""Paper Table 2 analogue: per-kernel accounting.

The FPGA table reports LUT/FF/BRAM/DSP; the TPU equivalents are the
roofline-relevant per-kernel numbers: FLOPs, HBM bytes, arithmetic
intensity, and the modeled v5e time for each Pallas kernel at a production
tile (derived column). Wall column is the CPU jnp-reference execution (the
oracle path), NOT TPU time.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_ctx, timeit
from repro.kernels.ref import l2dist_ref, l2topk_q_ref, l2topk_ref
from repro.launch.roofline import HW


def run():
    hw = HW()
    rng = np.random.default_rng(0)
    BQ, BX, D, K = 1024, 131072, 128, 10
    q = jnp.asarray(rng.normal(size=(BQ, D)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(BX, D)).astype(np.float32))

    rows = []
    # l2dist: flops = 2*BQ*BX*D; unfused writes the D2 matrix to HBM.
    fl = 2 * BQ * BX * D
    bytes_unfused = (BQ * D + BX * D + BQ * BX) * 4 + BQ * BX * 4  # +re-read
    t_c = fl / hw.peak_flops
    t_m = bytes_unfused / hw.hbm_bw
    us = timeit(lambda: l2dist_ref(q[:256], x[:8192]), iters=2)
    rows.append(("table2_l2dist_unfused", us,
                 f"modeled_v5e_us={max(t_c,t_m)*1e6:.0f};"
                 f"ai={fl/bytes_unfused:.1f}flop/B;bound="
                 f"{'mem' if t_m>t_c else 'compute'}"))
    # fused l2topk: only streams X once, result is [BQ, K].
    bytes_fused = (BQ * D + BX * D + BQ * K * 2) * 4
    t_m_f = bytes_fused / hw.hbm_bw
    us_f = timeit(lambda: l2topk_ref(q[:256], x[:8192], k=K), iters=2)
    rows.append(("table2_l2topk_fused", us_f,
                 f"modeled_v5e_us={max(t_c,t_m_f)*1e6:.0f};"
                 f"ai={fl/bytes_fused:.1f}flop/B;"
                 f"traffic_saved={bytes_unfused/bytes_fused:.1f}x"))
    # integer fused l2topk (paper's uint8 regime): X streams at 1 byte/dim.
    qc = jnp.asarray(rng.integers(0, 256, size=(BQ, D)).astype(np.uint8))
    xc = jnp.asarray(rng.integers(0, 256, size=(BX, D)).astype(np.uint8))
    bytes_fused_q = (BQ * D + BX * D) * 1 + BQ * K * 2 * 4
    t_m_q = bytes_fused_q / hw.hbm_bw
    us_q = timeit(lambda: l2topk_q_ref(qc[:256], xc[:8192], k=K), iters=2)
    rows.append(("table2_l2topk_q_uint8", us_q,
                 f"modeled_v5e_us={max(t_c,t_m_q)*1e6:.0f};"
                 f"ai={fl/bytes_fused_q:.1f}flop/B;"
                 f"traffic_vs_f32_fused={bytes_fused/bytes_fused_q:.1f}x"))
    # HNSW hop: gather maxM0 vectors + matvec per query (f32 and uint8 rows).
    ctx = get_ctx()
    m0 = ctx.svc.backend.pdb.db.l0_nbrs.shape[-1]
    d_pad = ctx.svc.backend.pdb.db.vectors.shape[-1]
    hop_flops = 2 * m0 * d_pad
    for tag, vb in (("", 4), ("_uint8", 1)):
        hop_bytes = m0 * (d_pad * vb + 4) + 64
        rows.append((f"table2_hnsw_hop{tag}", 0.0,
                     f"modeled_v5e_us={max(hop_flops/hw.peak_flops, hop_bytes/hw.hbm_bw)*1e6:.2f};"
                     f"ai={hop_flops/hop_bytes:.2f}flop/B;bound=mem"))
    return rows
