"""Observability budget: tracing overhead sweep + per-stage latency split.

Two questions, both acceptance bounds of the obs subsystem (ISSUE 7):

  * what does tracing COST? The same concurrent-lane QPS harness as
    fig_cluster drives the csd backend with tracing disabled (twice —
    the second run measures run-to-run noise, which is the bar "disabled
    is unmeasurable" must clear), with ONLY the continuous profiler on
    (the always-on production posture, budgeted < 2 % — ISSUE 10),
    fully sampled (target < 5 % QPS loss), and at 10 % sampling;
  * where does a request's time GO? A traced run through the full async
    serving stack (SearchServer -> batcher -> replica pool -> csd) is
    decomposed from its own spans into queue / traversal / store-read /
    rerank / dispatch-other, attributed per request (batch stages are
    weighted by batch size). The stages sum to the measured end-to-end
    latency exactly — queue+exec == e2e by construction, and the exec
    residue is reported as `dispatch_other`, not dropped.

Emits `BENCH_obs.json` at the repo root next to the other BENCH files.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict

import numpy as np

from benchmarks.common import bench_stamp
from benchmarks.fig_cluster import _throughput
from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset
from repro.obs import PROFILER, TRACER, profile_report

N, DIM, NQ = 4000, 64, 64
K, EF = 10, 40
CFG = HNSWConfig(M=12, ef_construction=80, seed=0)
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_obs.json")


def _build(tmp: str):
    ds = VectorDataset(N, DIM, n_clusters=32, seed=0)
    spec = IndexSpec(backend="csd", num_partitions=2, hnsw=CFG,
                     storage_path=os.path.join(tmp, "store"),
                     cache_bytes=32 << 20)
    return SearchService.build(ds.vectors(), spec), ds.queries(NQ)


def _overhead_sweep(svc, queries) -> dict:
    """QPS under the fig_cluster lane harness at each tracing state.

    `profiled` is the continuous profiler ALONE (tracing off — the hot
    path takes the disabled-tracer branch, which hands spans to the
    profiler instead of the no-op): the always-on production posture,
    budgeted at < 2 % QPS loss."""
    out = {}
    states = [
        ("baseline", dict(enabled=False), False),
        ("disabled", dict(enabled=False), False),   # re-run: noise floor
        ("profiled", dict(enabled=False), True),
        ("sampled_1.0", dict(enabled=True, sample_rate=1.0), False),
        ("sampled_0.1", dict(enabled=True, sample_rate=0.1), False),
    ]
    for name, cfg, prof in states:
        TRACER.configure(**cfg)
        TRACER.clear()
        PROFILER.configure(enabled=prof)
        PROFILER.reset()
        out[name] = _throughput(svc.search, queries)
    TRACER.configure(enabled=False)
    TRACER.clear()
    PROFILER.configure(enabled=True)                # production default
    PROFILER.reset()
    base = out["baseline"]["qps"]
    for name in ("disabled", "profiled", "sampled_1.0", "sampled_0.1"):
        out[name]["overhead_pct"] = round(
            (base - out[name]["qps"]) / base * 100.0, 2)
    out["targets"] = {
        "sampled_1.0_max_pct": 5.0,
        "sampled_1.0_met": out["sampled_1.0"]["overhead_pct"] < 5.0,
        "disabled_max_pct": 1.0,
        "disabled_met": out["disabled"]["overhead_pct"] <= 1.0,
        "profiled_max_pct": 2.0,
        "profiled_met": out["profiled"]["overhead_pct"] < 2.0,
    }
    return out


def _stage_breakdown(svc, queries) -> dict:
    """Serve traced traffic, then attribute each request's e2e latency to
    stages from the recorded spans. Batch-shared stages (traversal,
    store-read, rerank) are weighted by batch size: every co-rider of a
    batch experiences that batch's whole stage time."""
    from repro.serve import SearchServer

    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    PROFILER.configure(enabled=True)
    PROFILER.reset()
    with SearchServer(svc, replicas=2, max_batch=16,
                      max_wait_ms=1.0) as srv:
        for _ in range(2):                       # second pass runs warm
            futs = [srv.submit(q, k=K, ef=EF, rerank=True)
                    for q in queries]
            [f.result(timeout=300) for f in futs]
        srv.drain()
    spans = TRACER.spans()
    # the continuous profiler saw the same traffic through its Tracer hook;
    # its live attribution must agree with the post-hoc span analysis below
    live = profile_report(reset=True)
    TRACER.configure(enabled=False)
    TRACER.clear()

    def _dur(s):
        return (s["t1"] - s["t0"]) * 1e3

    per_name = defaultdict(list)
    for s in spans:
        per_name[s["name"]].append(s)
    n_req = len(per_name["request"])
    e2e = float(np.mean([_dur(s) for s in per_name["request"]]))
    queue = float(np.mean([_dur(s) for s in per_name["queue"]]))
    execm = float(np.mean([_dur(s) for s in per_name["exec"]]))

    # batch-shared stage totals, grouped by the batch's trace id and
    # weighted by the batch's size attr
    by_trace = defaultdict(lambda: defaultdict(float))
    size_of = {}
    for s in spans:
        if s["name"] == "batch":
            size_of[s["trace"]] = s["attrs"]["size"]
        elif s["name"] in ("traversal", "store-read", "rerank"):
            by_trace[s["trace"]][s["name"]] += _dur(s)
    stage_mean = defaultdict(float)
    for trace, stages in by_trace.items():
        w = size_of.get(trace, 1) / n_req
        for name, total in stages.items():
            stage_mean[name] += total * w

    trav = stage_mean["traversal"]               # includes store-read
    store = stage_mean["store-read"]
    rerank = stage_mean["rerank"]
    breakdown = {
        "queue": round(queue, 3),
        "traversal": round(trav - store, 3),
        "store_read": round(store, 3),
        "rerank": round(rerank, 3),
        # replica wait + batch pack/pad + scatter — everything in the
        # exec window the search stages do not account for
        "dispatch_other": round(execm - trav - rerank, 3),
    }
    return {
        "requests": n_req,
        "e2e_ms": round(e2e, 3),
        "stage_ms": breakdown,
        "stage_sum_ms": round(sum(breakdown.values()), 3),
        # queue+exec == e2e by construction; this is the proof the stages
        # neither drop nor double-count time
        "sum_matches_e2e": bool(
            abs(queue + execm - e2e) < 1e-6 * max(1.0, e2e)),
        "search_coverage_of_exec": round((trav + rerank) / execm, 3)
        if execm else None,
        "spans_recorded": len(spans),
        # same traffic, attributed live by repro.obs.profile (no spans
        # retained): what `profile_report()` serves in production
        "profiler_live": live,
    }


def run():
    import tempfile

    tmp = tempfile.mkdtemp(prefix="fig-obs-")
    svc, queries = _build(tmp)
    record = {"n": N, "dim": DIM, "nq": NQ, "k": K, "ef": EF,
              "backend": "csd", "bench_meta": bench_stamp("full")}

    record["overhead"] = _overhead_sweep(svc, queries)
    record["stages"] = _stage_breakdown(svc, queries)

    # acceptance bound (ISSUE 10): the always-on profiler must cost < 2 %
    # QPS — checked BEFORE the record is written so a blown budget can
    # never land in BENCH_obs.json as a quiet regression
    prof_pct = record["overhead"]["profiled"]["overhead_pct"]
    assert prof_pct < 2.0, \
        f"continuous profiler costs {prof_pct}% QPS (budget: < 2%)"
    live = record["stages"]["profiler_live"]
    assert live["sum_matches_e2e"], \
        f"profiler live attribution does not telescope to e2e: {live}"

    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)

    ov, st = record["overhead"], record["stages"]
    rows = []
    for name in ("baseline", "disabled", "profiled", "sampled_1.0",
                 "sampled_0.1"):
        m = ov[name]
        extra = (f"qps={m['qps']:.0f};p50_ms={m['p50_ms']:.1f}"
                 + (f";overhead_pct={m['overhead_pct']}"
                    if "overhead_pct" in m else ""))
        rows.append((f"fig_obs_{name}", m["us_per_query"], extra))
    stage_str = ";".join(f"{k}_ms={v}" for k, v in st["stage_ms"].items())
    rows.append(("fig_obs_stages", st["e2e_ms"] * 1e3,
                 f"e2e_ms={st['e2e_ms']};{stage_str};"
                 f"sum_matches_e2e={st['sum_matches_e2e']}"))
    rows.append(("fig_obs_json", 0.0, f"wrote={BENCH_JSON}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for _name, _us, _extra in run():
        print(f"{_name},{_us:.1f},{_extra}")
