"""Paper Fig. 12: platform comparison — QPS, power, energy efficiency.

Platforms here:
  cpu_numpy    = hnswlib-equivalent reference on the host CPU (the paper's
                 CPU server baseline)
  jax_cpu      = this framework on the container CPU
  tpu_modeled  = this framework on v5e, QPS derived from the ANN roofline
                 (memory term dominates: reads/query x bytes/read / HBM bw)

Power is MODELED from nameplate numbers (no power meter in a container):
EPYC server 225W, v5e chip ~200W board power incl. host share — labeled
modeled_* accordingly. The paper's numbers: 75.59 QPS @ 258.66W (4 cards).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import get_ctx, timeit
from repro.api import SearchRequest
from repro.core.ref_search import ref_batch_search
from repro.core.search import SearchParams
from repro.launch.roofline import HW

CPU_W = 225.0          # modeled host CPU package power
TPU_W = 200.0          # modeled v5e chip+share board power


def run():
    ctx = get_ctx()
    p = SearchParams(ef=40, k=10)
    db_one = jax.tree.map(lambda a: np.asarray(a[0]), ctx.svc1.backend.pdb.db)

    nq_ref = 8
    t0 = time.perf_counter()
    ref_batch_search(db_one, ctx.queries[:nq_ref], p)
    qps_numpy = nq_ref / (time.perf_counter() - t0)

    us = timeit(lambda: ctx.svc.search(
        SearchRequest(queries=ctx.queries, k=10, ef=40)).ids)
    qps_jax = len(ctx.queries) / (us / 1e6)

    # modeled TPU QPS: per-query HBM traffic from measured vector reads.
    resp = ctx.svc.search(SearchRequest(queries=ctx.queries, k=10, ef=40,
                                        with_stats=True))
    reads = float(np.mean(np.asarray(resp.stats.dist_calcs)))
    dim_pad = ctx.svc.backend.pdb.db.vectors.shape[-1]
    bytes_per_q = reads * (dim_pad * 4 + 64)       # vector + index/list rows
    hw = HW()
    qps_tpu = 1.0 / (bytes_per_q / hw.hbm_bw)      # one chip, memory-bound
    rows = [
        ("fig12_cpu_numpy", 1e6 / qps_numpy,
         f"qps={qps_numpy:.2f};modeled_w={CPU_W};qps_per_w={qps_numpy/CPU_W:.4f}"),
        ("fig12_jax_cpu", 1e6 / qps_jax,
         f"qps={qps_jax:.2f};modeled_w={CPU_W};qps_per_w={qps_jax/CPU_W:.4f}"),
        ("fig12_tpu_modeled_1chip", 1e6 / qps_tpu,
         f"modeled_qps={qps_tpu:.0f};modeled_w={TPU_W};"
         f"qps_per_w={qps_tpu/TPU_W:.2f}"),
        ("fig12_paper_reference", 0.0,
         "paper(4xSmartSSD,SSD-bound): 75.59qps@258.66W=0.29qps_per_w; "
         "paper DRAM-resident upper bound (sec6.5): 4118qps/device — our "
         "modeled HBM-resident chip scales that by the ~200x bandwidth gap"),
    ]
    return rows
