"""Cross-backend x cross-metric parity matrix over shared golden fixtures.

One parametrized suite replaces the ad-hoc parity checks that were
duplicated across test_api.py (cosine vs l2-on-normalized), test_store.py
(csd cosine vs partitioned), and test_partitioned.py (rerank vs stage 2):

  * every backend sharing the canonical 2-partition graph (partitioned,
    distributed, csd) must return IDENTICAL top-k ids — the BackendZoo
    builds them from one graph (csd restructures the partitioned DB,
    distributed rebuilds deterministically from the same seed);
  * `exact` must match the numpy ground truth under every metric;
  * cosine over raw data must rank exactly like l2 over pre-normalized
    data, for every backend family — the metric registry's contract;
  * graph-unsafe combos (ip on an L2-built graph) are skipped via
    `Metric.graph_safe`, mirroring the build-time rejection;
  * rerank (stage 2) re-scores exactly, so it must preserve the top-k set.
"""

import numpy as np
import pytest

from repro.api import IndexSpec, SearchService, exact_topk_np, get_metric

BACKENDS = ["exact", "hnsw", "partitioned", "distributed", "csd"]
METRICS = ["l2", "ip", "cosine"]
GRAPH_BACKENDS = [b for b in BACKENDS if b != "exact"]
K, EF = 10, 40


def _skip_graph_unsafe(backend: str, metric: str) -> None:
    if backend != "exact" and not get_metric(metric).graph_safe:
        pytest.skip(f"metric {metric!r} is not graph-safe "
                    f"(Metric.graph_safe=False); backend {backend!r} "
                    f"rejects it at build time")


# ---------------------------------------------------------------------------
# the matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", ["partitioned", "distributed", "csd"])
def test_shared_graph_backends_answer_identically(backend, metric,
                                                  backend_zoo):
    """partitioned / distributed / csd serve ONE graph -> one answer."""
    _skip_graph_unsafe(backend, metric)
    golden = backend_zoo.ids("partitioned", metric, k=K, ef=EF)
    got = backend_zoo.ids(backend, metric, k=K, ef=EF)
    np.testing.assert_array_equal(got, golden)


@pytest.mark.parametrize("metric", METRICS)
def test_exact_matches_numpy_golden(metric, backend_zoo):
    golden = exact_topk_np(metric, backend_zoo.data["vectors"],
                           backend_zoo.queries(), K)
    np.testing.assert_array_equal(backend_zoo.ids("exact", metric, k=K),
                                  golden)


@pytest.mark.parametrize("backend", ["exact", "hnsw", "partitioned", "csd"])
def test_cosine_equals_l2_over_normalized(backend, backend_zoo):
    """The registry's normalization contract, per backend family: cosine
    over raw vectors ranks exactly like l2 over pre-normalized vectors.
    (distributed is covered transitively via the shared-graph test.)"""
    ids_cos = backend_zoo.ids(backend, "cosine", k=K, ef=EF)
    ids_l2n = backend_zoo.ids(backend, "l2", k=K, ef=EF, normalized=True)
    np.testing.assert_array_equal(ids_cos, ids_l2n)


@pytest.mark.parametrize("rerank", [False, True])
def test_pq_backends_answer_identically(rerank, backend_zoo):
    """The PQ column of the matrix: the in-memory and the csd PQ engine
    serve ONE graph and ONE code space (the csd store is written from the
    partitioned backend's own DB and codebooks), so they must return
    identical ids with and without the true-float32 rerank. l2 only — PQ
    rejects other metrics at build time, mirroring the uint8 column."""
    golden = backend_zoo.ids("pq", "l2", k=K, ef=EF, rerank=rerank)
    got = backend_zoo.ids("pq_csd", "l2", k=K, ef=EF, rerank=rerank)
    np.testing.assert_array_equal(got, golden)


def test_hnsw_is_partitioned_with_one_partition(backend_zoo):
    np.testing.assert_array_equal(
        backend_zoo.ids("hnsw", "l2", k=K, ef=EF),
        backend_zoo.ids("partitioned1", "l2", k=K, ef=EF))


@pytest.mark.parametrize("backend", ["hnsw", "partitioned"])
def test_rerank_preserves_topk_set(backend, backend_zoo):
    """Paper stage 2: distances are already exact, so the exact re-score
    must not change the top-k membership (replaces the ad-hoc check that
    lived in test_partitioned.py)."""
    ids = backend_zoo.ids(backend, "l2", k=K, ef=EF)
    ids_r = backend_zoo.ids(backend, "l2", k=K, ef=EF, rerank=True)
    for a, b in zip(ids, ids_r):
        assert set(a[a >= 0]) == set(b[b >= 0])


def test_graph_unsafe_metric_rejected_at_build(backend_zoo, tmp_path):
    """The skip condition above mirrors a real build-time rejection."""
    for backend in GRAPH_BACKENDS:
        with pytest.raises(ValueError, match="not graph-safe"):
            SearchService.build(
                backend_zoo.data["vectors"],
                IndexSpec(metric="ip", backend=backend,
                          storage_path=str(tmp_path / "ip-store")))
