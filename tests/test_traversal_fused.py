"""Fused multi-hop traversal: bit-parity matrix, bitmap-sizing regression,
and store-read accounting.

The contract under test (ISSUE: fused traversal): `fused_hops` is a pure
scheduling knob — H hops per kernel invocation (in-memory backends) or per
host superstep (csd) — and must NEVER change results. Every cell of the
matrix asserts ids, dists, hops, and dist_calcs are bit-identical to the
hop-stepped `fused_hops=1` golden; the oracle property extends that to the
numpy reference. The regression half pins the visited-bitmap ceil-division
fix: a graph whose padded row count is NOT a multiple of 32 must still
visit every row at most once (ids/calcs match the oracle exactly), on both
the in-memory and the store-driven path.
"""

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SearchRequest
from repro.core import hnsw_graph as hg
from repro.core.partitioned import PartitionedDB
from repro.core.ref_search import ref_batch_search
from repro.core.search import SearchParams, batch_search, bitmap_words
from repro.store.csd import _gather_vec_sq, _visited_test_and_set, store_search
from repro.store.layout import open_store, write_store

K, EF = 10, 40
CACHE = 32 << 20


@contextlib.contextmanager
def fused(svc, h):
    """Temporarily serve `svc` at fused_hops=h (backend.params reads the
    backend's spec, so swapping it re-tunes an already-built service)."""
    be = svc.backend
    old = be.spec
    be.spec = dataclasses.replace(old, fused_hops=h)
    try:
        yield svc
    finally:
        be.spec = old


def _respond(svc, q, rerank=False):
    r = svc.search(SearchRequest(queries=q, k=K, ef=EF, rerank=rerank,
                                 with_stats=True))
    return (np.asarray(r.ids), np.asarray(r.dists),
            np.asarray(r.stats.hops), np.asarray(r.stats.dist_calcs),
            r.stats.supersteps)


# ---------------------------------------------------------------------------
# the parity matrix: fused == lockstep, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused_hops", [2, 4])
@pytest.mark.parametrize("rerank", [False, True])
@pytest.mark.parametrize("metric", ["l2", "cosine"])
@pytest.mark.parametrize("backend", ["hnsw", "partitioned", "csd"])
def test_fused_matches_lockstep_bitwise(backend, metric, rerank, fused_hops,
                                        backend_zoo):
    svc = backend_zoo.service(backend, metric)
    q = backend_zoo.queries()
    with fused(svc, 1):
        golden = _respond(svc, q, rerank)
    with fused(svc, fused_hops):
        got = _respond(svc, q, rerank)
    np.testing.assert_array_equal(got[0], golden[0])   # ids
    np.testing.assert_array_equal(got[1], golden[1])   # dists, bit-exact
    np.testing.assert_array_equal(got[2], golden[2])   # hops
    np.testing.assert_array_equal(got[3], golden[3])   # dist_calcs


def test_csd_supersteps_amortize_host_syncs(backend_zoo):
    """The point of the superstep: host round-trips drop ~1/H while every
    per-query counter stays identical."""
    svc = backend_zoo.service("csd", "l2")
    q = backend_zoo.queries()
    with fused(svc, 1):
        *_, hops1, calcs1, s1 = _respond(svc, q)
    with fused(svc, 4):
        *_, hops4, calcs4, s4 = _respond(svc, q)
    np.testing.assert_array_equal(hops4, hops1)
    np.testing.assert_array_equal(calcs4, calcs1)
    assert s1 > 0 and s4 > 0
    assert s4 < s1, f"superstep count did not drop: {s4} !< {s1}"
    assert s4 <= s1 // 2, (f"H=4 should at least halve host syncs: "
                           f"{s4} vs {s1}")


def test_any_fused_hops_matches_numpy_oracle(built_graph, small_dataset):
    """Property over the knob: every H agrees with core/ref_search.py."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need the hypothesis package")
    from hypothesis import given, settings, strategies as st

    g, _ = built_graph
    db_np = hg.restructure(g)
    db = jax.tree.map(jnp.asarray, db_np)
    q = small_dataset["queries"]
    p0 = SearchParams(ef=EF, k=K)
    rids, rds, rhops, _ = ref_batch_search(db_np, q, p0)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=1, max_value=8))
    def prop(h):
        ids, ds, stats = batch_search(
            db, jnp.asarray(q), dataclasses.replace(p0, fused_hops=h))
        np.testing.assert_array_equal(np.asarray(ids), rids)
        # same tolerance as test_search: the oracle's numpy matvec and the
        # kernel's mul+sum may part in the last ulp; ids/hops stay exact
        np.testing.assert_allclose(np.asarray(ds), rds, rtol=1e-3, atol=2.0)
        np.testing.assert_array_equal(np.asarray(stats.hops), rhops)

    prop()


# ---------------------------------------------------------------------------
# visited-bitmap sizing regression (n_pad % 32 != 0)
# ---------------------------------------------------------------------------


def test_bitmap_words_is_ceil_division():
    assert bitmap_words(32) == 1
    assert bitmap_words(33) == 2          # the old n // 32 said 1
    assert bitmap_words(2040) == 64


def test_visited_mirror_covers_partial_last_word():
    """Rows in the final partial word must be trackable — the floor-division
    bug truncated the bitmap so they could be expanded twice."""
    n_pad = 48                            # 48 % 32 != 0 -> 2 words
    bitmap = np.zeros((1, bitmap_words(n_pad)), np.uint32)
    ids = np.array([[47]], np.int32)      # lives in the partial word
    valid = np.ones((1, 1), bool)
    assert not _visited_test_and_set(bitmap, ids, valid)[0, 0]
    assert _visited_test_and_set(bitmap, ids, valid)[0, 0], \
        "row in the partial bitmap word was not remembered as visited"


@pytest.fixture(scope="module")
def odd_pad_db(built_graph):
    """The 2k graph padded to 2040 rows — 2040 % 32 == 24, the shape the
    floor-division bug silently corrupted (normal builds round to 32)."""
    g, _ = built_graph
    db_np = hg.restructure(g, n_pad=2040)
    assert db_np.vectors.shape[0] % 32 != 0
    return db_np, jax.tree.map(jnp.asarray, db_np)


@pytest.mark.parametrize("fused_hops", [1, 4])
def test_odd_pad_in_memory_matches_oracle(odd_pad_db, small_dataset,
                                          fused_hops):
    """ids AND dist_calcs exact vs the oracle == no row expanded twice
    (a truncated bitmap cannot mark the tail rows, so their re-expansion
    would inflate dist_calcs before anything else)."""
    db_np, db = odd_pad_db
    q = small_dataset["queries"]
    p = SearchParams(ef=EF, k=K, fused_hops=fused_hops)
    ids, _, stats = batch_search(db, jnp.asarray(q), p)
    rids, _, rhops, rcalcs = ref_batch_search(db_np, q, p)
    np.testing.assert_array_equal(np.asarray(ids), rids)
    np.testing.assert_array_equal(np.asarray(stats.hops), rhops)
    np.testing.assert_array_equal(np.asarray(stats.dist_calcs), rcalcs)


def test_odd_pad_csd_matches_partitioned_bitwise(odd_pad_db, small_dataset,
                                                 tmp_path):
    """Same odd-padded table served from the block store: csd must stay
    bit-identical to the in-memory path at every fused_hops."""
    db_np, db = odd_pad_db
    pdb = PartitionedDB(
        db=hg.DeviceDB(*(np.stack([getattr(db_np, f)])
                         for f in hg.DeviceDB._fields)),
        num_partitions=1, dim=small_dataset["vectors"].shape[1])
    write_store(str(tmp_path / "store"), pdb, block_size=4096)
    reader = open_store(str(tmp_path / "store"), CACHE, prefetch=False)
    try:
        assert reader.n_pad % 32 != 0
        q = small_dataset["queries"]
        for h in (1, 4):
            p = SearchParams(ef=EF, k=K, fused_hops=h)
            ids, ds, stats = batch_search(db, jnp.asarray(q), p)
            sids, sds, shops, scalcs, _ = store_search(reader, q, p)
            np.testing.assert_array_equal(np.asarray(sids), np.asarray(ids))
            np.testing.assert_array_equal(np.asarray(sds), np.asarray(ds))
            np.testing.assert_array_equal(shops, np.asarray(stats.hops))
            np.testing.assert_array_equal(scalcs,
                                          np.asarray(stats.dist_calcs))
    finally:
        reader.close()


# ---------------------------------------------------------------------------
# store-read accounting: dedup'd gathers + superstep traffic
# ---------------------------------------------------------------------------


def test_gather_vec_sq_reads_each_row_once(backend_zoo, monkeypatch):
    """Duplicate neighbor ids across lanes must reach the reader as ONE
    row each; the scattered-back tiles stay element-for-element right."""
    reader = backend_zoo.service("csd", "l2").backend.reader
    seen = []
    orig = reader.read_rows

    def spy(table, rows):
        seen.append((table, np.asarray(rows).copy()))
        return orig(table, rows)

    monkeypatch.setattr(reader, "read_rows", spy)
    ids = np.array([[5, 7, 5, -1],
                    [7, 9, 9, 3]], np.int32)
    mask = ids >= 0
    vecs, sqs = _gather_vec_sq(reader, 0, ids, mask)
    for table, rows in seen:
        assert len(rows) == len(np.unique(rows)) == 4, \
            f"{table} read {len(rows)} rows for 4 unique ids"
    monkeypatch.undo()
    for b in range(ids.shape[0]):
        for m in range(ids.shape[1]):
            if not mask[b, m]:
                assert not vecs[b, m].any() and sqs[b, m] == 0
                continue
            row = reader.row("vectors", 0, np.array([ids[b, m]]))
            np.testing.assert_array_equal(
                vecs[b, m], reader.read_rows("vectors", row)[0])
            assert sqs[b, m] == reader.read_rows("sqnorms", row)[0, 0]


def test_superstep_spans_and_gauge(backend_zoo):
    """Fused csd traffic must trace as `hop_superstep` children of
    `traversal` (one per host sync, replacing the per-hop `hop` spans)
    and publish the `traversal_fused_hops` gauge."""
    from repro.obs import TRACER
    from repro.obs.metrics import REGISTRY

    svc = backend_zoo.service("csd", "l2")
    q = backend_zoo.queries()[:4]
    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    try:
        with fused(svc, 4):
            svc.search(SearchRequest(queries=q, k=K, ef=EF))
        spans = TRACER.spans()
    finally:
        TRACER.configure(enabled=False)
        TRACER.clear()
    ss = [s for s in spans if s["name"] == "hop_superstep"]
    trav = {s["id"] for s in spans if s["name"] == "traversal"}
    assert ss, "fused csd search recorded no hop_superstep spans"
    assert all(s["parent"] in trav for s in ss)
    assert all(s["attrs"]["fused_hops"] == 4 and "superstep" in s["attrs"]
               and "active" in s["attrs"] for s in ss)
    assert not any(s["name"] == "hop" for s in spans), \
        "fused mode must replace per-hop spans, not add to them"
    gauges = [m for m in REGISTRY.snapshot()["gauges"]
              if m["name"] == "traversal_fused_hops"]
    assert gauges and gauges[0]["value"] == 4.0


def test_superstep_mode_strictly_reduces_bytes_read(backend_zoo):
    """With the speculative next-hop prefetcher on (prefetch reads count in
    bytes_read), the superstep driver's exact hop-batched reads must move
    strictly fewer bytes than the hop-stepped loop — same answers.

    A narrow workload (2 queries, ef=10) keeps the demanded block set well
    below the whole store, so the legacy path's speculative blocks — those
    prefetched for runner-up candidates that never get popped — are real
    extra traffic rather than reads the traversal would have made anyway."""
    path = backend_zoo.service("csd", "l2").spec.storage_path
    q = backend_zoo.queries()[:2]

    def run(h):
        reader = open_store(path, CACHE, prefetch=True)
        try:
            out = store_search(reader, q,
                               SearchParams(ef=10, k=K, fused_hops=h))
            if reader.prefetcher is not None:
                reader.prefetcher.drain()
            snap = reader.cache.snapshot()
        finally:
            reader.close()
        return out, snap

    (ids1, ds1, *_), snap1 = run(1)
    (ids4, ds4, *_), snap4 = run(4)
    np.testing.assert_array_equal(np.asarray(ids4), np.asarray(ids1))
    np.testing.assert_array_equal(np.asarray(ds4), np.asarray(ds1))
    assert snap4["bytes_read"] < snap1["bytes_read"], (
        f"superstep mode should read strictly less: "
        f"{snap4['bytes_read']} !< {snap1['bytes_read']}")
