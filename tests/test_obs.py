"""repro.obs: traces, metrics registry, exporters, shared stats helpers.

The acceptance bars (ISSUE 7):

  * search results are BIT-IDENTICAL with tracing enabled vs disabled —
    observability reads the hot path, it never steers it;
  * `latency_summary` reproduces the retired `serve._pct` /
    `cluster.shard` inline-percentile outputs bit-for-bit, and fixes the
    empty-sample edge exactly once;
  * the registry's counters/histograms are exact under N-thread hammering
    (no lost increments);
  * spans nest correctly across the batcher's thread handoff
    (request -> batch -> dispatch -> search on different threads);
  * the ingest rollup's cache_hit_rate is demand-weighted (the serve/
    dispatch formula), not an average of per-segment rates;
  * exporters emit parseable Prometheus text and Chrome/Perfetto JSON.
"""

import json
import threading

import numpy as np
import pytest

from repro.obs import (
    DEFAULT_MS_BUCKETS,
    MetricsRegistry,
    PeriodicExporter,
    REGISTRY,
    TRACER,
    Tracer,
    latency_summary,
    to_json,
    to_prometheus,
    write_snapshot,
)
from repro.obs.trace import SpanCtx


@pytest.fixture
def tracer():
    """The global TRACER, enabled for one test and always reset after."""
    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    yield TRACER
    TRACER.configure(enabled=False, sample_rate=1.0, max_events=1_000_000)
    TRACER.clear()


# ---------------------------------------------------------------------------
# latency_summary (satellite: the one percentile helper)
# ---------------------------------------------------------------------------


def _old_serve_pct(xs):
    """The retired serve/server.py `_pct` — the bit-parity golden."""
    if not xs:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(xs)
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


def test_latency_summary_matches_old_serve_pct():
    rng = np.random.default_rng(7)
    xs = list(rng.gamma(2.0, 3.0, size=257))
    old = _old_serve_pct(xs)
    new = latency_summary(xs)
    for key in ("p50", "p99", "mean"):
        assert new[key] == old[key]          # bit-identical, not approx
    assert new["count"] == len(xs)


def test_latency_summary_matches_old_shard_percentiles():
    """cluster/shard.py used to compute np.percentile(lat, 50/99) on a
    float64 array of its latency deque — same numbers, exactly."""
    rng = np.random.default_rng(8)
    lat = rng.gamma(1.5, 2.0, size=512)
    arr = np.asarray(lat, np.float64)
    new = latency_summary(lat)
    assert new["p50"] == float(np.percentile(arr, 50))
    assert new["p99"] == float(np.percentile(arr, 99))


def test_latency_summary_empty_is_zeros_not_raise():
    """The once-duplicated edge case: np.percentile raises on empty input;
    both old call sites guarded it separately, now it is fixed here."""
    out = latency_summary([])
    assert out == {"p50": 0.0, "p99": 0.0, "p999": 0.0, "mean": 0.0,
                   "count": 0}
    out = latency_summary(np.zeros(0))
    assert out["count"] == 0


def test_latency_summary_accepts_any_arraylike():
    from collections import deque
    assert latency_summary(deque([3.0, 1.0, 2.0]))["p50"] == 2.0
    assert latency_summary((5.0,))["p999"] == 5.0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_exact_under_concurrency():
    reg = MetricsRegistry()
    c = reg.counter("test_hammer_total")
    n_threads, per = 8, 5000

    def hammer():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per      # not one increment lost


def test_histogram_exact_under_concurrency():
    reg = MetricsRegistry()
    h = reg.histogram("test_lat_ms")
    n_threads, per = 8, 2000
    values = [0.2, 3.0, 40.0, 9000.0]      # spread over distinct buckets

    def hammer(seed):
        rng = np.random.default_rng(seed)
        for _ in range(per):
            h.observe(values[rng.integers(0, len(values))])

    ts = [threading.Thread(target=hammer, args=(i,)) for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = h.snapshot()
    assert snap["count"] == n_threads * per
    # cumulative buckets are monotone and top out at the total count
    cums = [c for _, c in snap["buckets"]]
    assert cums == sorted(cums)
    assert cums[-1] == n_threads * per
    assert snap["buckets"][-1][0] == float("inf")


def test_registry_get_or_create_by_name_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("x_total", shard="a")
    b = reg.counter("x_total", shard="b")
    assert a is reg.counter("x_total", shard="a")
    assert a is not b
    a.inc(3)
    snap = reg.snapshot()
    series = {tuple(sorted(s["labels"].items())): s["value"]
              for s in snap["counters"]}
    assert series[(("shard", "a"),)] == 3
    assert series[(("shard", "b"),)] == 0


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="cannot decrease"):
        reg.counter("y_total").inc(-1)


def test_collector_weakref_lifecycle():
    """A registered collector publishes while its owner lives and silently
    drops from the snapshot when the owner is garbage collected."""
    import gc

    class Owner:
        hits = 42

    reg = MetricsRegistry()
    o = Owner()
    reg.register_collector(
        o, lambda x: [("counter", "owner_hits_total", {}, x.hits)])
    names = [s["name"] for s in reg.snapshot()["counters"]]
    assert "owner_hits_total" in names
    del o
    gc.collect()
    names = [s["name"] for s in reg.snapshot()["counters"]]
    assert "owner_hits_total" not in names


def test_pagecache_publishes_into_registry(tmp_path):
    """Every live PageCache is one labeled series set in the global
    REGISTRY snapshot — its counters match `snapshot()` exactly."""
    from repro.store.blockfile import BlockFileWriter
    from repro.store.layout import open_store

    path = str(tmp_path / "store")
    w = BlockFileWriter(path, block_size=512)
    w.add_table("vectors", np.arange(64 * 8, dtype=np.float32).reshape(64, 8))
    w.finalize({"num_partitions": 1, "n_pad": 64, "d_pad": 8, "m0_pad": 4,
                "n_layers": 1, "up_pad": 4, "m_pad": 4, "dim": 8,
                "entry": 0, "max_level": 0, "n_valid": 64,
                "partition_starts": [0]})
    reader = open_store(path, cache_bytes=4096, prefetch=False)
    reader.read_rows("vectors", np.arange(32))
    snap = reader.cache.snapshot()
    uid = reader.cache.uid
    series = {s["name"]: s["value"]
              for s in REGISTRY.snapshot()["counters"]
              if s["labels"].get("cache") == uid}
    assert series["store_block_reads_total"] == snap["block_reads"]
    assert series["store_cache_hits_total"] == snap["hits"]
    assert series["store_cache_misses_total"] == snap["misses"]
    assert series["store_bytes_read_total"] == snap["bytes_read"]
    reader.close()


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------


def test_disabled_tracer_records_nothing():
    t = Tracer(enabled=False)
    with t.span("a"):
        with t.child_span("b"):
            pass
    assert t.spans() == []
    assert t.current_ctx() is None
    assert t.sample_request() is None


def test_disabled_span_is_shared_noop():
    """span() with tracing off returns one shared object — no per-call
    allocation on the disabled hot path."""
    t = Tracer(enabled=False)
    assert t.span("a") is t.span("b") is t.child_span("c")


def test_sample_rate_zero_records_nothing():
    t = Tracer(enabled=True, sample_rate=0.0)
    with t.span("root"):
        with t.span("child"):
            pass
    assert t.spans() == []
    ctx = t.sample_request()
    assert ctx is not None and not ctx.sampled


def test_nesting_same_thread(tracer):
    with tracer.span("root") as r:
        with tracer.span("mid") as m:
            with tracer.child_span("leaf"):
                pass
    spans = {s["name"]: s for s in tracer.spans()}
    assert spans["mid"]["parent"] == r.span_id
    assert spans["leaf"]["parent"] == m.span_id
    assert spans["leaf"]["trace"] == spans["root"]["trace"]
    assert spans["root"]["parent"] == 0


def test_child_span_never_roots(tracer):
    """child_span on a thread with no open span is a no-op — background
    workers (prefetcher, health probes) cannot create stray traces."""
    with tracer.child_span("orphan"):
        pass
    assert tracer.spans() == []


def test_explicit_parent_across_threads(tracer):
    """The batcher handoff pattern: a ctx minted on one thread parents a
    span entered on another."""
    with tracer.span("root") as r:
        ctx = r.ctx
    out = {}

    def worker():
        with tracer.span("remote", parent=ctx) as sp:
            out["id"] = sp.span_id

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    spans = {s["name"]: s for s in tracer.spans()}
    assert spans["remote"]["parent"] == r.span_id
    assert spans["remote"]["trace"] == spans["root"]["trace"]


def test_ctx_wire_roundtrip():
    ctx = SpanCtx(5, 9, 2, True)
    w = json.loads(json.dumps(ctx.wire()))    # must be JSON-encodable
    back = SpanCtx.from_wire(w)
    assert (back.trace_id, back.span_id, back.sampled) == (5, 9, True)


def test_retroactive_record_span(tracer):
    root = tracer.sample_request()
    tracer.record_span("request", 1.0, 3.0, ctx=root, tid="lane")
    tracer.record_span("queue", 1.0, 2.0, parent=root, tid="lane")
    spans = {s["name"]: s for s in tracer.spans()}
    assert spans["queue"]["parent"] == root.span_id
    assert spans["request"]["t1"] == 3.0
    assert spans["request"]["tid"] == "lane"


def test_max_events_bounds_memory(tracer):
    tracer.configure(max_events=5)
    for i in range(9):
        with tracer.span(f"s{i}"):
            pass
    assert len(tracer.spans()) == 5
    assert tracer.dropped == 4
    tracer.configure(max_events=1_000_000)


def test_chrome_export_loads(tracer):
    with tracer.span("a", key="v"):
        with tracer.child_span("b"):
            pass
    doc = json.loads(json.dumps(tracer.export()))
    events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in events} == {"a", "b"}
    assert any(m["name"] == "thread_name" for m in metas)
    for e in events:
        assert e["dur"] >= 0 and "span_id" in e["args"]
    a = next(e for e in events if e["name"] == "a")
    assert a["args"]["key"] == "v"


# ---------------------------------------------------------------------------
# spans across the serve stack (batcher thread handoff)
# ---------------------------------------------------------------------------


def test_spans_nest_across_batcher_handoff(tracer, backend_zoo):
    """request -> queue/exec (queue thread, retroactive), batch (batcher
    thread), dispatch (replica thread), search (same) — all one tree."""
    from repro.serve import SearchServer

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=2, max_batch=4, max_wait_ms=1.0) as srv:
        futs = [srv.submit(x, k=5, ef=40) for x in q[:8]]
        [f.result(timeout=60) for f in futs]
        srv.drain()
    spans = tracer.spans()
    by_id = {s["id"]: s for s in spans}
    names = {s["name"] for s in spans}
    assert {"request", "queue", "exec", "batch", "dispatch",
            "search"} <= names
    n_request = 0
    for s in spans:
        parent = by_id.get(s["parent"])
        if s["name"] == "request":
            n_request += 1
            assert s["parent"] == 0                      # a root
        elif s["name"] in ("queue", "exec"):
            assert parent["name"] == "request"
        elif s["name"] == "batch":
            assert parent["name"] == "request"
        elif s["name"] == "dispatch":
            assert parent["name"] == "batch"
        elif s["name"] == "search":
            assert parent["name"] == "dispatch"
        if s["name"] != "request" and parent is not None:
            assert s["trace"] == parent["trace"]         # one trace id
    assert n_request == 8                                # every request


def test_csd_results_bit_identical_traced_vs_untraced(backend_zoo):
    """Tracing must not change a single output bit (csd backend: spans
    wrap store reads, hops, kernels — the full Fig. 4 dataflow)."""
    from repro.api import SearchRequest

    svc = backend_zoo.service("csd", "l2")
    q = backend_zoo.queries()
    req = SearchRequest(queries=q, k=10, ef=40)
    TRACER.configure(enabled=False)
    base = svc.search(req)
    try:
        TRACER.configure(enabled=True, sample_rate=1.0)
        TRACER.clear()
        traced = svc.search(req)
        assert len(TRACER.spans()) > 0          # it really traced
    finally:
        TRACER.configure(enabled=False)
        TRACER.clear()
    np.testing.assert_array_equal(np.asarray(base.ids),
                                  np.asarray(traced.ids))
    np.testing.assert_array_equal(np.asarray(base.dists),
                                  np.asarray(traced.dists))


# ---------------------------------------------------------------------------
# ingest demand-weighted hit rate (satellite regression test)
# ---------------------------------------------------------------------------


def test_ingest_cache_hit_rate_demand_weighted(tmp_path):
    """The rollup must be hits/(hits+misses) over SUMMED counters — the
    serve/dispatch formula — not a per-segment average. Regression: the
    pre-obs rollup never set cache_hit_rate at all."""
    from repro.api import IndexSpec, MutableSearchService, SearchRequest

    spec = IndexSpec(backend="csd", num_partitions=1,
                     storage_path=str(tmp_path / "store"),
                     cache_bytes=1 << 20)
    svc = MutableSearchService(spec, seal_threshold=400)
    rng = np.random.default_rng(3)
    svc.insert(rng.normal(size=(800, 32)).astype(np.float32))
    svc.flush()                                  # two sealed segments
    assert svc.num_segments == 2
    q = rng.normal(size=(4, 32)).astype(np.float32)
    stats = svc.search(SearchRequest(queries=q, k=5, ef=40,
                                     with_stats=True)).stats
    assert stats.cache_hits is not None and stats.cache_misses is not None
    demand = stats.cache_hits + stats.cache_misses
    assert demand > 0
    assert stats.cache_hit_rate == stats.cache_hits / demand
    # the per-segment rows carry both counters, so the aggregate above is
    # exactly reconstructible from them
    seg_rows = [s for s in stats.segments if s["segment"] != "memtable"]
    assert sum(s["cache_hits"] for s in seg_rows) == stats.cache_hits
    assert sum(s["cache_misses"] for s in seg_rows) == stats.cache_misses
    svc.close()


def test_cluster_roll_stats_demand_weighted():
    """Router-side aggregation uses the same summed-counter formula."""
    from repro.cluster.router import ClusterRouter

    resps = [{"cache_hits": 90, "cache_misses": 10},
             {"cache_hits": 0, "cache_misses": 100}]
    stats = ClusterRouter._roll_stats(None, resps)
    # 90 hits of 200 demand accesses = 0.45; a rate average would say 0.45
    # only by luck of equal demand — here demand differs: mean of rates
    # would be (0.9 + 0.0)/2 = 0.45 too, so pick asymmetric demand:
    resps = [{"cache_hits": 9, "cache_misses": 1},       # 10 demand, 0.9
             {"cache_hits": 0, "cache_misses": 990}]     # 990 demand, 0.0
    stats = ClusterRouter._roll_stats(None, resps)
    assert stats.cache_hit_rate == 9 / 1000              # not (0.9+0)/2
    assert stats.cache_hits == 9 and stats.cache_misses == 991


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _tiny_registry():
    reg = MetricsRegistry()
    reg.counter("reads_total", table="vectors").inc(7)
    reg.gauge("resident_bytes").set(123.0)
    h = reg.histogram("lat_ms", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    return reg


def test_prometheus_exposition_parses():
    text = to_prometheus(_tiny_registry().snapshot())
    lines = [ln for ln in text.strip().splitlines()]
    types = {ln.split()[2]: ln.split()[3]
             for ln in lines if ln.startswith("# TYPE")}
    assert types == {"reads_total": "counter", "resident_bytes": "gauge",
                     "lat_ms": "histogram"}
    samples = {}
    for ln in lines:
        if ln.startswith("#"):
            continue
        name, value = ln.rsplit(" ", 1)
        samples[name] = value
    assert samples['reads_total{table="vectors"}'] == "7"
    assert samples["resident_bytes"] == "123"
    assert samples['lat_ms_bucket{le="1"}'] == "1"
    assert samples['lat_ms_bucket{le="10"}'] == "2"
    assert samples['lat_ms_bucket{le="+Inf"}'] == "3"
    assert samples["lat_ms_count"] == "3"
    assert float(samples["lat_ms_sum"]) == 55.5


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("c_total", path='a"b\\c\nd').inc()
    text = to_prometheus(reg.snapshot())
    assert '\\"' in text and "\\\\" in text and "\\n" in text


def test_json_snapshot_roundtrips():
    doc = json.loads(to_json(_tiny_registry().snapshot()))
    assert doc["ts_unix"] > 0
    assert doc["counters"][0]["value"] == 7
    assert doc["histograms"][0]["count"] == 3


def test_write_snapshot_format_by_extension(tmp_path):
    reg = _tiny_registry()
    jp = write_snapshot(str(tmp_path / "m.json"), reg)
    with open(jp) as f:
        assert json.load(f)["gauges"][0]["value"] == 123.0
    pp = write_snapshot(str(tmp_path / "m.prom"), reg)
    with open(pp) as f:
        assert "# TYPE reads_total counter" in f.read()


def test_periodic_exporter_emits_and_final_snapshot(tmp_path):
    reg = _tiny_registry()
    path = str(tmp_path / "metrics.prom")
    with PeriodicExporter(path, interval_s=0.05, registry=reg) as ex:
        reg.counter("reads_total", table="vectors").inc(100)
        deadline = 100
        while ex.emits < 2 and deadline:
            threading.Event().wait(0.02)
            deadline -= 1
    with open(path) as f:
        text = f.read()
    assert 'reads_total{table="vectors"} 107' in text   # final emit on stop
    assert ex.emits >= 2


def test_periodic_exporter_stop_is_idempotent(tmp_path):
    """Exactly ONE final emission: a second stop() must not rewrite the
    file (callers treat it as complete at first return)."""
    reg = _tiny_registry()
    path = str(tmp_path / "metrics.prom")
    ex = PeriodicExporter(path, interval_s=60.0, registry=reg).start()
    ex.stop()
    emits_after_stop = ex.emits
    reg.counter("reads_total", table="vectors").inc(1000)
    ex.stop()                                   # no thread, no re-emit
    assert ex.emits == emits_after_stop
    with open(path) as f:
        assert 'reads_total{table="vectors"} 7' in f.read()  # pre-inc


def test_periodic_exporter_stop_without_start_emits_once(tmp_path):
    """stop() on a never-started exporter still leaves one complete
    snapshot behind (the serve CLI's finally-block contract)."""
    reg = _tiny_registry()
    path = str(tmp_path / "metrics.prom")
    ex = PeriodicExporter(path, interval_s=60.0, registry=reg)
    ex.stop()
    assert ex.emits == 1
    with open(path) as f:
        assert "reads_total" in f.read()
    ex.stop()
    assert ex.emits == 1                        # still exactly one


def test_periodic_exporter_restarts_after_stop(tmp_path):
    reg = _tiny_registry()
    path = str(tmp_path / "metrics.prom")
    ex = PeriodicExporter(path, interval_s=60.0, registry=reg)
    ex.start()
    ex.stop()
    first_round = ex.emits
    reg.counter("reads_total", table="vectors").inc(3)
    ex.start()                                  # must arm a fresh thread
    ex.stop()
    assert ex.emits == first_round + 2          # start-emit + final emit
    with open(path) as f:
        assert 'reads_total{table="vectors"} 10' in f.read()


def test_concurrent_clients_trace_export_consistent(tmp_path, backend_zoo):
    """N client threads against a 2-replica server while a PeriodicExporter
    re-emits metrics + trace on a hot interval: the final trace is
    parseable, no span is double-emitted, and every client's results are
    bit-identical to the untraced direct path (csd backend — profiler
    hooks active on every span close)."""
    from repro.api import SearchRequest
    from repro.obs import PROFILER
    from repro.serve import SearchServer

    svc = backend_zoo.service("csd", "l2")
    q = backend_zoo.queries()
    n_clients, per_client = 4, 6

    TRACER.configure(enabled=False)
    want = np.asarray(svc.search(
        SearchRequest(queries=q[:per_client], k=10, ef=40)).ids)

    PROFILER.configure(enabled=True)
    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    trace_path = str(tmp_path / "trace.json")
    metrics_path = str(tmp_path / "metrics.json")
    got: dict[int, np.ndarray] = {}
    try:
        with PeriodicExporter(metrics_path, interval_s=0.02,
                              tracer=TRACER, trace_path=trace_path):
            with SearchServer(svc, replicas=2, max_batch=4,
                              max_wait_ms=1.0) as srv:

                def client(cid):
                    futs = [srv.submit(x, k=10, ef=40)
                            for x in q[:per_client]]
                    got[cid] = np.stack(
                        [np.asarray(f.result(timeout=120).ids)
                         for f in futs])

                ts = [threading.Thread(target=client, args=(i,))
                      for i in range(n_clients)]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join()
                srv.drain()
    finally:
        TRACER.configure(enabled=False)
        TRACER.clear()

    # every client bit-identical to the untraced direct path
    assert len(got) == n_clients
    for cid, ids in got.items():
        np.testing.assert_array_equal(ids, want)

    # the exporter's final emission (stop() after the server closed) is
    # complete and parseable; spans are unique — re-emitting on a hot
    # interval never double-records
    with open(trace_path) as f:
        doc = json.load(f)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    keys = [(e["args"]["trace_id"], e["args"]["span_id"]) for e in events]
    assert len(keys) == len(set(keys)), "double-emitted spans in export"
    n_requests = sum(1 for e in events if e["name"] == "request")
    assert n_requests == n_clients * per_client
    with open(metrics_path) as f:
        snap = json.load(f)
    assert any(c["name"] == "serve_requests_total" for c in snap["counters"])


def test_server_metrics_endpoint(backend_zoo):
    from repro.serve import SearchServer

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=1.0) as srv:
        [f.result(timeout=60) for f in
         [srv.submit(x, k=5, ef=40) for x in q[:4]]]
        prom = srv.metrics()
        js = srv.metrics("json")
    assert "# TYPE serve_requests_total counter" in prom
    assert "serve_e2e_ms_bucket" in prom
    doc = json.loads(js)
    assert any(s["name"] == "serve_batch_size" for s in doc["histograms"])
    with pytest.raises(ValueError, match="unknown metrics format"):
        srv.metrics("xml")
