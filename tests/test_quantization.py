"""The quantized uint8/int8 vector path (IndexSpec.dtype).

Four contracts, mirroring the paper's SIFT1B operating point (uint8 rows,
integer distance units, float32 stage-2):

  * quantizer: round-trip error bounded by scale/2; SIFT-style integer
    byte data round-trips exactly.
  * kernels: the Pallas integer distance / fused top-k kernels equal the
    numpy/jnp references EXACTLY (f32 accumulation over 8-bit codes is
    exact below 2^24).
  * engines: quantized `csd` == quantized `partitioned` bit-identically
    (ids and dists), stage-1 distances are `scale**2 *` code-space, and
    stage-2 rerank re-scores in dequantized float32.
  * storage: the quantized store's raw-data table is exactly 4x smaller
    and measured `QueryStats.bytes_read` drops accordingly (neighbor-table
    traffic is unchanged, so the end-to-end ratio sits between 2x and 4x
    at test scale).
"""

import contextlib
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.api import SearchRequest
from repro.optim.compression import CODE_DTYPES, VectorQuantizer

K, EF = 10, 40


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", sorted(CODE_DTYPES))
@pytest.mark.parametrize("signed_data", [False, True])
def test_roundtrip_error_bounded_by_half_scale(dtype, signed_data):
    rng = np.random.default_rng(0)
    x = rng.normal(scale=20.0, size=(512, 32)).astype(np.float32)
    if not signed_data:
        x = np.abs(x)
    q = VectorQuantizer.fit(x, dtype)
    err = np.abs(x - q.decode(q.encode(x)))
    assert float(err.max()) <= q.scale / 2 + 1e-5, (
        f"round-trip error {err.max():.4g} exceeds scale/2 = "
        f"{q.scale / 2:.4g} ({dtype}, signed={signed_data})")


def test_sift_style_bytes_roundtrip_exactly():
    """Integer-valued data in [0, 255] (SIFT's native format) quantizes to
    uint8 with scale 1 / zero-point 0 and is reconstructed bit-exactly."""
    rng = np.random.default_rng(1)
    x = rng.integers(0, 256, size=(256, 128)).astype(np.float32)
    x[0, 0] = 255.0                          # pin the range
    q = VectorQuantizer.fit(x, "uint8")
    assert q.scale == 1.0 and q.zero_point == 0
    np.testing.assert_array_equal(q.decode(q.encode(x)), x)


def test_code_space_l2_is_scaled_real_l2():
    """The quantizer's core geometric property: squared L2 over codes *
    scale**2 == squared L2 over dequantized values (zero-point cancels)."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(64, 16)).astype(np.float32)   # signed -> zp=128
    q = VectorQuantizer.fit(x, "uint8")
    assert q.zero_point == 128
    a, b = q.encode(x[:32]).astype(np.float64), q.encode(x[32:]).astype(np.float64)
    code_d2 = ((a - b) ** 2).sum(1) * q.dist_scale
    da, db = q.decode(q.encode(x[:32])), q.decode(q.encode(x[32:]))
    real_d2 = ((da.astype(np.float64) - db.astype(np.float64)) ** 2).sum(1)
    np.testing.assert_allclose(code_d2, real_d2, rtol=1e-6)


def test_fit_rejects_unknown_dtype():
    with pytest.raises((KeyError, ValueError)):
        VectorQuantizer.fit(np.zeros((4, 4), np.float32), "int4")


# ---------------------------------------------------------------------------
# Pallas integer kernels vs numpy references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("np_dtype,lo,hi", [(np.uint8, 0, 256),
                                            (np.int8, -127, 128)])
@pytest.mark.parametrize("bq,bx,d", [(7, 100, 17), (33, 600, 128),
                                     (1, 1024, 96)])
def test_l2dist_q_matches_ref_exactly(np_dtype, lo, hi, bq, bx, d):
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.ref import l2dist_q_ref

    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.integers(lo, hi, size=(bq, d)).astype(np_dtype))
    x = jnp.asarray(rng.integers(lo, hi, size=(bx, d)).astype(np_dtype))
    got = ops.l2dist_q(q, x, out_scale=0.25)
    want = l2dist_q_ref(q, x, out_scale=0.25)
    # f32 accumulation over 8-bit codes is exact -> bitwise equality
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("np_dtype,lo,hi", [(np.uint8, 0, 256),
                                            (np.int8, -127, 128)])
def test_l2topk_q_fused_matches_ref(np_dtype, lo, hi):
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.ref import l2topk_q_ref

    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.integers(lo, hi, size=(5, 64)).astype(np_dtype))
    x = jnp.asarray(rng.integers(lo, hi, size=(1500, 64)).astype(np_dtype))
    gv, gi = ops.l2topk_q(q, x, k=K, out_scale=0.5)
    wv, wi = l2topk_q_ref(q, x, k=K, out_scale=0.5)
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    # integer distances tie often; values must agree, ids mostly
    assert (np.asarray(gi) == np.asarray(wi)).mean() > 0.9


def test_l2topk_q_padding_rows_excluded():
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.integers(0, 256, size=(4, 32)).astype(np.uint8))
    x = jnp.asarray(rng.integers(0, 256, size=(700, 32)).astype(np.uint8))
    xf = x.astype(jnp.float32)
    xsq = jnp.einsum("bd,bd->b", xf, xf).at[100:].set(jnp.inf)
    _, gi = ops.l2topk_q(q, x, xsq=xsq, k=K)
    assert np.asarray(gi).max() < 100


# ---------------------------------------------------------------------------
# engines: quantized csd == quantized partitioned; distance semantics
# ---------------------------------------------------------------------------


def _resp(zoo, backend, **kw):
    svc = zoo.service(backend, "l2")
    return svc.search(SearchRequest(queries=zoo.queries(), k=K, ef=EF, **kw))


def test_quantized_csd_bit_identical_to_partitioned(backend_zoo):
    """Acceptance: backend in {partitioned, csd} with dtype=uint8 returns
    bit-identical ids (and dists) — one edge quantization, one kernel."""
    rp = _resp(backend_zoo, "uint8")
    rc = _resp(backend_zoo, "uint8_csd")
    np.testing.assert_array_equal(np.asarray(rc.ids), np.asarray(rp.ids))
    np.testing.assert_array_equal(np.asarray(rc.dists), np.asarray(rp.dists))


def test_quantized_rerank_parity_and_float32_semantics(backend_zoo):
    """Stage 2 stays float32: both engines re-score the candidate pool over
    DEQUANTIZED rows, so (a) they agree bit-for-bit and (b) the distances
    equal a numpy recompute in dequantized space."""
    rp = _resp(backend_zoo, "uint8", rerank=True)
    rc = _resp(backend_zoo, "uint8_csd", rerank=True)
    np.testing.assert_array_equal(np.asarray(rc.ids), np.asarray(rp.ids))

    svc = backend_zoo.service("uint8", "l2")
    quant = svc.quantizer
    deq_x = quant.decode(quant.encode(backend_zoo.data["vectors"]))
    deq_q = quant.decode(quant.encode(backend_zoo.queries()))
    ids = np.asarray(rp.ids)
    want = np.einsum("bkd,bkd->bk", deq_x[ids] - deq_q[:, None],
                     deq_x[ids] - deq_q[:, None])
    # the engine evaluates the dot-product form (xsq - 2 x.q + qsq) in f32;
    # the direct-difference recompute differs by f32 cancellation noise
    np.testing.assert_allclose(np.asarray(rp.dists), want, rtol=1e-3,
                               atol=0.1)


def test_quantized_stage1_dists_are_scaled_code_space(backend_zoo):
    """Non-rerank distances == dist_scale * code-space squared L2."""
    svc = backend_zoo.service("uint8", "l2")
    quant = svc.quantizer
    resp = _resp(backend_zoo, "uint8")
    codes_x = quant.encode(backend_zoo.data["vectors"]).astype(np.float32)
    codes_q = quant.encode(backend_zoo.queries()).astype(np.float32)
    ids = np.asarray(resp.ids)
    code_d2 = np.einsum("bkd,bkd->bk", codes_x[ids] - codes_q[:, None],
                        codes_x[ids] - codes_q[:, None])
    np.testing.assert_allclose(np.asarray(resp.dists),
                               code_d2 * quant.dist_scale, rtol=1e-5)


def test_quantized_spec_in_manifest_and_load_roundtrip(backend_zoo,
                                                       tmp_path):
    """scale/zero-point land in index_manifest.json; load reproduces the
    exact same answers."""
    from repro.api import SearchService
    from repro.api.service import MANIFEST_NAME

    svc = backend_zoo.service("uint8", "l2")
    path = str(tmp_path / "u8-index")
    svc.save(path)
    with open(os.path.join(path, MANIFEST_NAME)) as f:
        spec_json = json.load(f)["spec"]
    assert spec_json["dtype"] == "uint8"
    assert spec_json["qscale"] == svc.spec.qscale
    assert spec_json["qzero"] == svc.spec.qzero

    svc2 = SearchService.load(path)
    assert np.asarray(svc2.backend.pdb.db.vectors).dtype == np.uint8
    r1 = svc.search(SearchRequest(queries=backend_zoo.queries(), k=K, ef=EF))
    r2 = svc2.search(SearchRequest(queries=backend_zoo.queries(), k=K, ef=EF))
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))


def test_quantized_rejects_non_l2_metrics(backend_zoo):
    from repro.api import IndexSpec, SearchService

    with pytest.raises(ValueError, match="metric='l2' only"):
        SearchService.build(backend_zoo.data["vectors"],
                            IndexSpec(metric="cosine", dtype="uint8",
                                      backend="partitioned"))


# ---------------------------------------------------------------------------
# fused traversal: quantized backends were missing from the fused parity
# matrix (test_traversal_fused covers float32 only) — pin uint8 and pq here
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _fused(svc, h):
    be = svc.backend
    old = be.spec
    be.spec = dataclasses.replace(old, fused_hops=h)
    try:
        yield svc
    finally:
        be.spec = old


@pytest.mark.parametrize("fused_hops", [2, 4])
@pytest.mark.parametrize("rerank", [False, True])
@pytest.mark.parametrize("backend", ["uint8", "uint8_csd", "pq", "pq_csd"])
def test_quantized_fused_matches_lockstep_bitwise(backend, rerank,
                                                  fused_hops, backend_zoo):
    """fused_hops is a pure batching knob on the quantized paths too: the
    integer-distance kernels and the PQ LUT supersteps replay the exact
    hop-stepped visit order, so ids/dists/hops/dist_calcs all match the
    fused_hops=1 golden bit for bit."""
    svc = backend_zoo.service(backend, "l2")
    q = backend_zoo.queries()

    def respond():
        r = svc.search(SearchRequest(queries=q, k=K, ef=EF, rerank=rerank,
                                     with_stats=True))
        return (np.asarray(r.ids), np.asarray(r.dists),
                np.asarray(r.stats.hops), np.asarray(r.stats.dist_calcs))

    with _fused(svc, 1):
        golden = respond()
    with _fused(svc, fused_hops):
        got = respond()
    for g, w, what in zip(got, golden, ("ids", "dists", "hops",
                                        "dist_calcs")):
        np.testing.assert_array_equal(g, w, err_msg=(
            f"{backend} fused_hops={fused_hops} diverges on {what}"))


# ---------------------------------------------------------------------------
# storage: 4x smaller rows, fewer bytes over the "flash" link
# ---------------------------------------------------------------------------


def test_uint8_store_reads_fewer_bytes(backend_zoo):
    """The raw-data table shrinks exactly 4x; measured bytes_read drops.

    The end-to-end ratio is < 4x because neighbor-table traffic (int32
    ids) is precision-independent — at this scale vectors are ~80% of the
    traffic, so anything >= 2x means the vector rows really shrank (see
    launch/ann_dryrun.py for the SIFT1B-scale 4x projection)."""
    svc_u8 = backend_zoo.service("uint8_csd", "l2")
    svc_f32 = backend_zoo.service("csd", "l2")

    t_u8 = svc_u8.backend.reader.blockfile.tables["vectors"]
    t_f32 = svc_f32.backend.reader.blockfile.tables["vectors"]
    assert t_u8["dtype"] == "uint8" and t_f32["dtype"] == "float32"
    assert t_f32["nbytes"] == 4 * t_u8["nbytes"]
    assert t_f32["row_bytes"] == 4 * t_u8["row_bytes"]

    # cold-cache measurement: fresh readers over the same stores (the
    # zoo services' shared PageCaches are warm from earlier tests)
    from repro.api import SearchService
    from repro.store.csd import CSDBackend
    from repro.store.layout import open_store

    def cold_bytes(svc):
        reader = open_store(svc.backend.reader.path,
                            svc.spec.cache_bytes, prefetch=False)
        try:
            cold = SearchService(svc.spec, CSDBackend(svc.spec, reader))
            resp = cold.search(SearchRequest(queries=backend_zoo.queries(),
                                             k=K, ef=EF, with_stats=True))
            return float(resp.stats.bytes_read)
        finally:
            reader.close()

    b_f32, b_u8 = cold_bytes(svc_f32), cold_bytes(svc_u8)
    ratio = b_f32 / b_u8
    assert ratio >= 2.0, (
        f"uint8 store should cut storage bytes ~4x (vectors) — measured "
        f"total ratio {ratio:.2f}x ({int(b_f32)} vs {int(b_u8)})")
