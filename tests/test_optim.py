"""Optimizer + schedule + compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.compression import compress_grads, decompress_grads


def test_adamw_matches_reference_formulas():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                      clip_norm=1e9, warmup_steps=0, total_steps=1,
                      min_lr_frac=1.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    opt = adamw_init(p)
    new_p, new_opt, _ = adamw_update(cfg, p, g, opt)

    m = 0.1 * np.array([0.5, 0.25])
    v = 0.01 * np.array([0.25, 0.0625])
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = np.array([1.0, -2.0]) - 1e-2 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.array([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_opt["step"]) == 1


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=1,
                      weight_decay=0.0, min_lr_frac=1.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}           # norm 200 >> 1
    opt = adamw_init(p)
    _, _, metrics = adamw_update(cfg, p, g, opt)
    assert float(metrics["grad_norm"]) > 100


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.05
    assert abs(lrs[-1] - 0.1) < 0.02
    assert all(b <= a + 1e-6 for a, b in zip(lrs[2:], lrs[3:]))


def test_compression_roundtrip_small_error():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=64).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32) * 100)}
    q, s, err = compress_grads(g)
    back = decompress_grads(q, s)
    for k in g:
        scale = float(jnp.abs(g[k]).max())
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(g[k]),
                                   atol=scale / 100)
    # int8 payload is 4x smaller
    assert jax.tree.leaves(q)[0].dtype == jnp.int8
