"""repro.cluster: sharded serving == single index, bit for bit.

The load-bearing invariants:

  * parity     — `ClusterRouter.search` over N shards returns bit-identical
                 ids AND dists to one `SearchService` over the union of
                 rows (exact/partitioned/csd, with and without rerank)
  * failover   — killing a replica degrades latency, never correctness;
                 no request is lost or served twice
  * elasticity — shards join under live traffic; in-flight searches keep
                 their snapshot
  * durability — `cluster.json` swaps atomically and refuses to regress
  * merge      — `core.merge.rank_merge` is bit-identical to the inline
                 reduction `ingest/service.py` shipped before the factor-out
"""

import dataclasses
import json
import os
import threading

import numpy as np
import pytest

from repro.api.service import SearchService
from repro.api.types import IndexSpec, SearchRequest
from repro.cluster import (ClusterRouter, ClusterTopology, HealthMonitor,
                           ShardFault, ShardInfo, build_cluster, from_wire,
                           make_shard, read_topology, shard_bounds,
                           shard_spec, to_wire, write_topology)
from repro.core.hnsw_graph import HNSWConfig
from repro.core.merge import mask_dead_lanes, rank_merge

CFG = HNSWConfig(M=8, ef_construction=50, seed=0)
N, DIM, NSHARDS = 900, 32, 3


def _data():
    rng = np.random.default_rng(7)
    return (rng.standard_normal((N, DIM), dtype=np.float32),
            rng.standard_normal((10, DIM), dtype=np.float32))


def _spec(backend, storage=None):
    return IndexSpec(metric="l2", backend=backend, num_partitions=1,
                     hnsw=CFG, keep_vectors=backend != "csd",
                     storage_path=storage, cache_bytes=1 << 20)


@pytest.fixture(scope="module", params=["exact", "partitioned", "csd"])
def zoo(request, tmp_path_factory):
    """(backend, single-index reference, 3-shard x 2-replica cluster)."""
    backend = request.param
    vecs, queries = _data()
    td = tmp_path_factory.mktemp(f"cluster-{backend}")
    spec = _spec(backend, storage=str(td / "shards")
                 if backend == "csd" else None)
    ref_spec = spec if backend == "exact" else dataclasses.replace(
        spec, num_partitions=NSHARDS,
        storage_path=str(td / "single") if backend == "csd" else None)
    ref = SearchService.build(vecs, ref_spec)
    cluster = build_cluster(vecs, spec, NSHARDS, replicas=2, path=str(td))
    yield backend, ref, cluster, queries
    cluster.close()


# ---------------------------------------------------------------------------
# parity: cluster == single index, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rerank", [False, True])
def test_cluster_parity_bit_identical(zoo, rerank):
    backend, ref, cluster, queries = zoo
    req = SearchRequest(queries=queries, k=10, ef=40, rerank=rerank)
    want = ref.search(req)
    got = cluster.search(req)
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(want.dists),
                                  np.asarray(got.dists))


def test_cluster_stats_rollup(zoo):
    backend, ref, cluster, queries = zoo
    cluster.search(SearchRequest(queries=queries, k=5, ef=40,
                                 with_stats=True))
    s = cluster.stats()
    assert s.n_shards == NSHARDS
    assert s.queries > 0
    assert set(s.qps) == {c.name for c in cluster.shards}
    assert s.row_skew >= 1.0 and s.query_skew >= 1.0
    if backend == "csd":
        assert s.block_reads > 0 and s.bytes_read > 0
        assert s.cache_hit_rate is not None


def test_cluster_query_stats_aggregate(zoo):
    backend, ref, cluster, queries = zoo
    resp = cluster.search(SearchRequest(queries=queries, k=5, ef=40,
                                        with_stats=True))
    if backend == "exact":
        return                      # exact tracks no traversal counters
    assert resp.stats is not None
    assert np.asarray(resp.stats.hops).shape == (queries.shape[0],)
    if backend == "csd":
        # the shared module cache may be fully warm: demand accesses must
        # show up either as flash reads or as hits, never vanish
        assert resp.stats.block_reads + resp.stats.cache_hits > 0


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------


def test_failover_correctness_no_lost_or_duplicated(zoo):
    backend, ref, cluster, queries = zoo
    req = SearchRequest(queries=queries, k=10, ef=40)
    want = ref.search(req)
    shard = cluster.shards[0]
    before = [rep.queries for rep in shard.replicas]
    shard.replicas[0].kill()
    rounds = 6
    for _ in range(rounds):
        got = cluster.search(req)
        np.testing.assert_array_equal(np.asarray(want.ids),
                                      np.asarray(got.ids))
        np.testing.assert_array_equal(np.asarray(want.dists),
                                      np.asarray(got.dists))
    # exactly one replica served each request: nothing lost, nothing double
    served = sum(rep.queries for rep in shard.replicas) - sum(before)
    assert served == rounds * queries.shape[0]
    shard.replicas[0].revive()
    shard.mark(0, True)


def test_transient_fault_fails_over(zoo):
    backend, ref, cluster, queries = zoo
    req = SearchRequest(queries=queries, k=10, ef=40)
    want = ref.search(req)
    shard = cluster.shards[1]
    failovers = shard.failovers
    shard.replicas[0].inject_faults(1)
    for _ in range(4):              # round-robin guarantees a hit
        got = cluster.search(req)
        np.testing.assert_array_equal(np.asarray(want.ids),
                                      np.asarray(got.ids))
    assert shard.failovers > failovers
    for i in range(len(shard.replicas)):
        shard.mark(i, True)


def test_all_replicas_down_raises(tmp_path):
    vecs, queries = _data()
    cluster = build_cluster(vecs[:300], _spec("exact"), 2, replicas=1)
    try:
        for rep in cluster.shards[0].replicas:
            rep.kill()
        with pytest.raises(ShardFault, match="no live replicas"):
            cluster.search(SearchRequest(queries=queries, k=5, ef=40))
    finally:
        cluster.close()


def test_health_monitor_detects_and_revives(zoo):
    backend, ref, cluster, queries = zoo
    mon = HealthMonitor(cluster, interval_s=30.0, timeout_s=60.0)
    shard = cluster.shards[2]
    shard.replicas[1].kill()
    states = mon.probe_now()
    assert states[shard.name] == [True, False]
    assert shard.live() == 1
    shard.replicas[1].revive()
    assert mon.probe_now()[shard.name] == [True, True]
    assert shard.live() == 2


# ---------------------------------------------------------------------------
# elasticity under live traffic
# ---------------------------------------------------------------------------


def test_elastic_add_shard_under_live_traffic(tmp_path):
    vecs, queries = _data()
    spec = _spec("exact")
    cluster = build_cluster(vecs[:600], spec, 2, path=str(tmp_path))
    errors, stop = [], threading.Event()

    def hammer():
        req = SearchRequest(queries=queries, k=5, ef=40)
        while not stop.is_set():
            try:
                r = cluster.search(req)
                if np.asarray(r.ids).shape != (queries.shape[0], 5):
                    errors.append("bad shape")
            except Exception as exc:   # traffic must never see the swap
                errors.append(repr(exc))

    threads = [threading.Thread(target=hammer) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        newbie = make_shard(vecs[600:], spec, name="shard-new",
                            gid_map=np.arange(600, N), shard_index=2)
        cluster.add_shard(newbie)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    assert cluster.topology().n_shards == 3
    assert read_topology(str(tmp_path)).version == cluster.version
    # the new shard's rows are served now
    r = cluster.search(SearchRequest(queries=vecs[700:701], k=1, ef=40))
    assert int(np.asarray(r.ids)[0, 0]) == 700
    assert float(np.asarray(r.dists)[0, 0]) == 0.0
    cluster.close()


def test_add_remove_replica_publishes(tmp_path):
    vecs, _ = _data()
    spec = _spec("exact")
    cluster = build_cluster(vecs[:300], spec, 2, path=str(tmp_path))
    v0 = cluster.version
    from repro.cluster import ShardWorker
    name = cluster.shards[0].name
    svc = cluster.shards[0].replicas[0].service
    cluster.add_replica(name, ShardWorker(
        name, svc, cluster.shards[0].replicas[0].gid_map, rid=1))
    assert len(cluster._client(name).replicas) == 2
    assert read_topology(str(tmp_path)).version == v0 + 1
    cluster.remove_replica(name, 1)
    assert len(cluster._client(name).replicas) == 1
    with pytest.raises(ValueError, match="last replica"):
        cluster.remove_replica(name, 0)
    with pytest.raises(KeyError):
        cluster.remove_shard("no-such-shard")
    cluster.close()


# ---------------------------------------------------------------------------
# cluster.json durability
# ---------------------------------------------------------------------------


def test_manifest_crash_safety(tmp_path):
    td = str(tmp_path)
    topo = ClusterTopology(shards=(ShardInfo("s0", replicas=2, rows=100),),
                           version=1)
    write_topology(td, topo)
    # a crash mid-write leaves a torn tmp file; the committed manifest wins
    with open(os.path.join(td, "cluster.json.tmp"), "w") as f:
        f.write('{"torn": tru')
    got = read_topology(td)
    assert got == topo
    # stale writers are refused
    with pytest.raises(ValueError, match="stale topology"):
        write_topology(td, ClusterTopology(
            shards=(ShardInfo("s0"),), version=1))
    # a fresh version replaces the torn tmp and commits
    write_topology(td, ClusterTopology(shards=(ShardInfo("s0"),),
                                       version=2))
    assert read_topology(td).version == 2


def test_manifest_format_check(tmp_path):
    with open(tmp_path / "cluster.json", "w") as f:
        json.dump({"format": "something-else", "version": 1}, f)
    with pytest.raises(ValueError, match="format"):
        read_topology(str(tmp_path))


def test_read_topology_empty_dir(tmp_path):
    topo = read_topology(str(tmp_path))
    assert topo.n_shards == 0 and topo.version == 0


# ---------------------------------------------------------------------------
# topology math
# ---------------------------------------------------------------------------


def test_shard_bounds_match_partition_split():
    for n, p in [(900, 3), (1000, 7), (5, 5), (64, 1)]:
        want = np.linspace(0, n, p + 1).astype(np.int64)
        np.testing.assert_array_equal(shard_bounds(n, p), want)
    with pytest.raises(ValueError):
        shard_bounds(100, 0)


def test_shard_spec_seed_schedule():
    spec = _spec("partitioned")
    spec2 = dataclasses.replace(spec, num_partitions=2)
    # shard i, q partitions/shard -> seeds [i*q, i*q+q) == global schedule
    assert shard_spec(spec2, 0).hnsw.seed == CFG.seed
    assert shard_spec(spec2, 3).hnsw.seed == CFG.seed + 6
    assert shard_spec(spec2, 3).num_partitions == 2
    s = shard_spec(spec, 1, storage_path="/x/y")
    assert s.storage_path == "/x/y" and s.hnsw.seed == CFG.seed + 1


def test_cluster_rejects_quantized_spec():
    spec = dataclasses.replace(_spec("partitioned"), dtype="uint8")
    with pytest.raises(ValueError, match="float32 or pq only"):
        ClusterRouter(spec, [])


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------


def test_wire_roundtrip():
    msg = {"op": "search", "k": 10, "frac": 0.5, "flag": True,
           "name": "shard-000", "nothing": None,
           "queries": np.arange(12, dtype=np.float32).reshape(3, 4),
           "ids": np.array([[1, -1], [5, 9]], dtype=np.int64),
           "empty": np.zeros((0, 4), dtype=np.int32)}
    got = from_wire(to_wire(msg))
    for k in ("op", "k", "frac", "flag", "name", "nothing"):
        assert got[k] == msg[k]
    for k in ("queries", "ids", "empty"):
        assert got[k].dtype == msg[k].dtype
        np.testing.assert_array_equal(got[k], msg[k])


def test_wire_rejects_bad_magic():
    with pytest.raises(ValueError, match="magic"):
        from_wire(b"XXXX" + b"\x00" * 16)


# ---------------------------------------------------------------------------
# core.merge: the factored-out reduction is the one ingest shipped
# ---------------------------------------------------------------------------


def _legacy_inline_merge(all_ids, all_ds, k):
    """ingest/service.py's merge block before the core.merge factor-out."""
    cat_ids = np.concatenate(all_ids, axis=1)
    cat_ds = np.concatenate(all_ds, axis=1)
    order = np.argsort(cat_ds, axis=1, kind="stable")[:, :k]
    out_i = np.take_along_axis(cat_ids, order, axis=1)
    out_d = np.take_along_axis(cat_ds, order, axis=1)
    out_i = np.where(np.isfinite(out_d), out_i, -1)
    if out_i.shape[1] < k:
        pad = k - out_i.shape[1]
        out_i = np.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
        out_d = np.pad(out_d, ((0, 0), (0, pad)), constant_values=np.inf)
    return out_i, out_d


def test_rank_merge_bit_identical_to_legacy_inline():
    rng = np.random.default_rng(3)
    for trial in range(20):
        b, k = int(rng.integers(1, 5)), int(rng.integers(1, 12))
        ids_list, ds_list = [], []
        for _ in range(int(rng.integers(1, 4))):
            w = int(rng.integers(1, 9))
            d = np.sort(rng.choice(  # ties on purpose: stable order matters
                np.float32([0.5, 1.0, 1.0, 2.0, 3.0, np.inf]),
                size=(b, w)), axis=1)
            i = np.where(np.isfinite(d),
                         rng.integers(0, 1000, (b, w)), -1).astype(np.int64)
            ids_list.append(i)
            ds_list.append(np.float32(d))
        want = _legacy_inline_merge(ids_list, ds_list, k)
        got = rank_merge(ids_list, ds_list, k)
        np.testing.assert_array_equal(want[0], got[0])
        np.testing.assert_array_equal(want[1], got[1])


def test_mask_dead_lanes():
    ids = np.array([[3, 7, 9]], dtype=np.int64)
    ds = np.array([[0.5, 1.5, 2.5]], dtype=np.float32)
    mi, md = mask_dead_lanes(ids, ds, np.array([[False, True, False]]))
    np.testing.assert_array_equal(mi, [[3, -1, 9]])
    np.testing.assert_array_equal(md, np.float32([[0.5, np.inf, 2.5]]))
    assert mi.dtype == np.int64 and md.dtype == np.float32


# ---------------------------------------------------------------------------
# serving integration: a cluster is just another dispatch target
# ---------------------------------------------------------------------------


def test_search_server_over_cluster(zoo):
    from repro.serve import SearchServer

    backend, ref, cluster, queries = zoo
    want = np.asarray(ref.search(
        SearchRequest(queries=queries, k=5, ef=40)).ids)
    with SearchServer(cluster, replicas=2, max_batch=4,
                      max_wait_ms=1.0) as srv:
        futs = srv.submit_many(queries, k=5, ef=40)
        got = np.stack([np.asarray(f.result().ids) for f in futs])
        srv.drain()
    np.testing.assert_array_equal(want, got)
