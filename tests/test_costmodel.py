"""Calibrate the analytic cost model against XLA's cost_analysis.

XLA counts while-loop bodies once, so calibration uses configs where every
loop has trip count 1: num_periods=1, attention blocks >= T, loss chunks =
T, SSM chunk >= T. On such configs cost_analysis is complete and must agree
with launch/costmodel.py within tolerance.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.costmodel import _count_params, cell_costs, storage_cost
from repro.models.model import prefill_step
from repro.models.transformer import init_cache, init_params

T, B = 64, 4


def _single_trip(cfg):
    kw = dict(num_periods=1, prefix_pattern=(), block_q=T, block_k=T,
              loss_chunk=T, param_dtype=jnp.float32)
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, chunk=T)
    if cfg.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=T)
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize("arch,tol", [
    ("granite_3_8b", 0.30),
    ("qwen3_14b", 0.30),
    ("deepseek_v2_lite_16b", 0.45),   # scatter/gather flops are fuzzier
])
def test_prefill_flops_match_xla(arch, tol):
    cfg = _single_trip(reduced_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, B, T)
    if cfg.embed_inputs:
        inputs = jnp.zeros((B, T), jnp.int32)
    else:
        inputs = jnp.zeros((B, T, cfg.d_model), jnp.float32)
    lowered = prefill_step.lower(params, {"inputs": inputs}, cache, cfg)
    ca = lowered.compile().cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0]
    got = ca["flops"]
    want = cell_costs(cfg, "prefill", T, B, n_devices=1, model_ax=1,
                      dp_ax=1, fsdp=False).flops_per_dev
    # analytic excludes elementwise ops XLA counts (norms, rope, softmax),
    # so allow an asymmetric band.
    ratio = got / want
    assert (1 - tol) < ratio < (1 + 2 * tol), (
        f"{arch}: XLA {got/1e6:.1f}MF vs analytic {want/1e6:.1f}MF "
        f"(ratio {ratio:.2f})")


def test_storage_cost_term():
    """The csd storage-bandwidth term: blocks * block_size / SSD-BW,
    cache-hit-adjusted; hits scale the flash traffic linearly."""
    from repro.launch.roofline import HW
    hw = HW()
    cold = storage_cost(1000, 4096, cache_hit_rate=0.0, ssd_bw=hw.ssd_bw)
    assert cold.blocks_from_flash == 1000
    assert cold.bytes_from_flash == 1000 * 4096
    assert cold.storage_s == pytest.approx(1000 * 4096 / hw.ssd_bw)
    warm = storage_cost(1000, 4096, cache_hit_rate=0.9, ssd_bw=hw.ssd_bw)
    assert warm.bytes_from_flash == pytest.approx(0.1 * cold.bytes_from_flash)
    assert warm.storage_s == pytest.approx(0.1 * cold.storage_s)
    # the paper's regime: the storage term dwarfs the HBM term for the
    # same traffic (SmartSSD ~3 GB/s vs HBM ~819 GB/s)
    assert cold.storage_s > (1000 * 4096 / hw.hbm_bw) * 100
    with pytest.raises(ValueError, match="cache_hit_rate"):
        storage_cost(1, 4096, cache_hit_rate=1.5)


@pytest.mark.parametrize("arch", ["granite_3_8b", "qwen3_14b",
                                  "deepseek_v2_lite_16b", "jamba_v01_52b",
                                  "xlstm_350m", "musicgen_large"])
def test_param_count_matches_init(arch):
    cfg = reduced_config(arch)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    actual = sum(l.size for l in jax.tree.leaves(shapes))
    analytic = _count_params(cfg)
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)
