"""Calibrate the analytic cost model against XLA's cost_analysis.

XLA counts while-loop bodies once, so calibration uses configs where every
loop has trip count 1: num_periods=1, attention blocks >= T, loss chunks =
T, SSM chunk >= T. On such configs cost_analysis is complete and must agree
with launch/costmodel.py within tolerance.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch.costmodel import (_count_params, cell_costs,
                                    compaction_cost, storage_cost)
from repro.models.model import prefill_step
from repro.models.transformer import init_cache, init_params

T, B = 64, 4


def _single_trip(cfg):
    kw = dict(num_periods=1, prefix_pattern=(), block_q=T, block_k=T,
              loss_chunk=T, param_dtype=jnp.float32)
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, chunk=T)
    if cfg.xlstm is not None:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=T)
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize("arch,tol", [
    ("granite_3_8b", 0.30),
    ("qwen3_14b", 0.30),
    ("deepseek_v2_lite_16b", 0.45),   # scatter/gather flops are fuzzier
])
def test_prefill_flops_match_xla(arch, tol):
    cfg = _single_trip(reduced_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, B, T)
    if cfg.embed_inputs:
        inputs = jnp.zeros((B, T), jnp.int32)
    else:
        inputs = jnp.zeros((B, T, cfg.d_model), jnp.float32)
    lowered = prefill_step.lower(params, {"inputs": inputs}, cache, cfg)
    ca = lowered.compile().cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0]
    got = ca["flops"]
    want = cell_costs(cfg, "prefill", T, B, n_devices=1, model_ax=1,
                      dp_ax=1, fsdp=False).flops_per_dev
    # analytic excludes elementwise ops XLA counts (norms, rope, softmax),
    # so allow an asymmetric band.
    ratio = got / want
    assert (1 - tol) < ratio < (1 + 2 * tol), (
        f"{arch}: XLA {got/1e6:.1f}MF vs analytic {want/1e6:.1f}MF "
        f"(ratio {ratio:.2f})")


def test_storage_cost_term():
    """The csd storage-bandwidth term: blocks * block_size / SSD-BW,
    cache-hit-adjusted; hits scale the flash traffic linearly."""
    from repro.launch.roofline import HW
    hw = HW()
    cold = storage_cost(1000, 4096, cache_hit_rate=0.0, ssd_bw=hw.ssd_bw)
    assert cold.blocks_from_flash == 1000
    assert cold.bytes_from_flash == 1000 * 4096
    assert cold.storage_s == pytest.approx(1000 * 4096 / hw.ssd_bw)
    warm = storage_cost(1000, 4096, cache_hit_rate=0.9, ssd_bw=hw.ssd_bw)
    assert warm.bytes_from_flash == pytest.approx(0.1 * cold.bytes_from_flash)
    assert warm.storage_s == pytest.approx(0.1 * cold.storage_s)
    # the paper's regime: the storage term dwarfs the HBM term for the
    # same traffic (SmartSSD ~3 GB/s vs HBM ~819 GB/s)
    assert cold.storage_s > (1000 * 4096 / hw.hbm_bw) * 100
    with pytest.raises(ValueError, match="cache_hit_rate"):
        storage_cost(1, 4096, cache_hit_rate=1.5)


def test_compaction_cost_write_amplification():
    """The ingest write-amp term: no compaction -> amp 1; the
    merge-everything policy compounds; deletes shrink later rewrites;
    uint8 rows cut the absolute bytes 4x at identical amplification."""
    from repro.launch.costmodel import vector_row_bytes
    from repro.launch.roofline import HW

    hw = HW()
    none = compaction_cost(10_000, 400, seal_threshold=100,
                           compact_every=10**9, ssd_bw=hw.ssd_bw)
    assert none.compactions == 0 and none.write_amp == 1.0
    cc = compaction_cost(10_000, 400, seal_threshold=100, compact_every=10,
                         ssd_bw=hw.ssd_bw)
    assert cc.seals == 100 and cc.compactions == 10
    # merge-everything: rewrite_j = j * 10 * 100 rows -> amp = 1 + 5.5
    assert cc.write_amp == pytest.approx(6.5)
    assert cc.rewrite_s == pytest.approx(cc.bytes_rewritten / hw.ssd_bw)
    # more frequent compaction rewrites strictly more
    eager = compaction_cost(10_000, 400, seal_threshold=100,
                            compact_every=2, ssd_bw=hw.ssd_bw)
    assert eager.write_amp > cc.write_amp
    # churn shrinks the live set and therefore later rewrites
    churn = compaction_cost(10_000, 400, seal_threshold=100,
                            compact_every=10, delete_frac=0.3,
                            ssd_bw=hw.ssd_bw)
    assert churn.bytes_rewritten < cc.bytes_rewritten
    # quantized rows: 4x fewer bytes, same amplification factor
    u8 = compaction_cost(10_000, vector_row_bytes(128, "uint8"),
                         seal_threshold=100, compact_every=10,
                         ssd_bw=hw.ssd_bw)
    f32 = compaction_cost(10_000, vector_row_bytes(128, "float32"),
                          seal_threshold=100, compact_every=10,
                          ssd_bw=hw.ssd_bw)
    assert f32.bytes_rewritten == pytest.approx(4 * u8.bytes_rewritten)
    assert f32.write_amp == pytest.approx(u8.write_amp)
    with pytest.raises(ValueError, match="delete_frac"):
        compaction_cost(100, 4, 10, 1, delete_frac=1.0)


def test_cluster_fanout_cost_term():
    """The repro.cluster fan-out term: replicas scale storage QPS linearly;
    shards duplicate full-ef traversal so storage QPS does NOT scale with
    shard count alone; the router link binds once fan-out bytes beat it."""
    from repro.launch.costmodel import cluster_fanout_cost
    from repro.launch.roofline import HW

    hw = HW()
    base = cluster_fanout_cost(1, 1, dim=128, k=10, blocks_per_query=100,
                               block_size=4096, ssd_bw=hw.ssd_bw)
    # router bytes: N * (query scatter + top-k gather)
    assert base.router_bytes_q == 128 * 4 + 10 * 12
    assert base.flash_bytes_q == 100 * 4096
    assert base.storage_qps == pytest.approx(
        hw.ssd_bw / (100 * 4096))
    assert base.modeled_qps == min(base.router_qps, base.storage_qps)

    # replicas: aggregate SSDs grow, per-query flash work does not
    rep2 = cluster_fanout_cost(1, 2, dim=128, k=10, blocks_per_query=100,
                               block_size=4096, ssd_bw=hw.ssd_bw)
    assert rep2.storage_qps == pytest.approx(2 * base.storage_qps)
    assert rep2.router_bytes_q == base.router_bytes_q

    # shards: N SSDs but N full-ef traversals — storage QPS unchanged,
    # router bytes grow with N (the fan-out tax)
    sh4 = cluster_fanout_cost(4, 1, dim=128, k=10, blocks_per_query=100,
                              block_size=4096, ssd_bw=hw.ssd_bw)
    assert sh4.aggregate_ssd_bw == pytest.approx(4 * hw.ssd_bw)
    assert sh4.flash_bytes_q == pytest.approx(4 * base.flash_bytes_q)
    assert sh4.storage_qps == pytest.approx(base.storage_qps)
    assert sh4.router_bytes_q == pytest.approx(4 * base.router_bytes_q)

    # cache hits shrink flash traffic, raising the storage ceiling
    warm = cluster_fanout_cost(4, 1, dim=128, k=10, blocks_per_query=100,
                               block_size=4096, cache_hit_rate=0.9,
                               ssd_bw=hw.ssd_bw)
    assert warm.storage_qps == pytest.approx(10 * sh4.storage_qps)

    # a slow router link eventually binds
    bound = cluster_fanout_cost(64, 8, dim=128, k=10, blocks_per_query=1,
                                block_size=4096, cache_hit_rate=0.99,
                                ssd_bw=hw.ssd_bw, link_bw=1e6)
    assert bound.bound == "router"
    assert bound.modeled_qps == pytest.approx(bound.router_qps)

    with pytest.raises(ValueError, match="n_shards"):
        cluster_fanout_cost(0, 1, dim=128, k=10, blocks_per_query=1,
                            block_size=4096)
    with pytest.raises(ValueError, match="cache_hit_rate"):
        cluster_fanout_cost(1, 1, dim=128, k=10, blocks_per_query=1,
                            block_size=4096, cache_hit_rate=-0.1)


@pytest.mark.parametrize("arch", ["granite_3_8b", "qwen3_14b",
                                  "deepseek_v2_lite_16b", "jamba_v01_52b",
                                  "xlstm_350m", "musicgen_large"])
def test_param_count_matches_init(arch):
    cfg = reduced_config(arch)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    actual = sum(l.size for l in jax.tree.leaves(shapes))
    analytic = _count_params(cfg)
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)
