"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps per the deliverable."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import l2dist_ref, l2topk_ref, topk_ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("bq,bx,d", [
    (7, 100, 17), (128, 512, 128), (33, 1000, 96), (1, 2048, 128), (64, 64, 64),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_l2dist_matches_ref(bq, bx, d, dtype):
    q = jnp.asarray(RNG.normal(size=(bq, d)).astype(np.float32)).astype(dtype)
    x = jnp.asarray(RNG.normal(size=(bx, d)).astype(np.float32)).astype(dtype)
    got = ops.l2dist(q, x)
    want = l2dist_ref(q, x)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("b,n,k", [
    (4, 100, 5), (16, 3000, 10), (3, 1024, 32), (8, 4096, 1),
])
def test_topk_matches_ref(b, n, k):
    x = jnp.asarray(RNG.normal(size=(b, n)).astype(np.float32))
    gv, gi = ops.topk(x, k)
    wv, wi = topk_ref(x, k)
    np.testing.assert_allclose(gv, wv, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


@pytest.mark.parametrize("bq,bx,d,k", [
    (5, 1500, 64, 10), (64, 2048, 128, 20), (1, 999, 32, 8),
])
def test_l2topk_fused_matches_ref(bq, bx, d, k):
    q = jnp.asarray(RNG.normal(size=(bq, d)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(bx, d)).astype(np.float32))
    gv, gi = ops.l2topk(q, x, k=k)
    wv, wi = l2topk_ref(q, x, k=k)
    np.testing.assert_allclose(gv, wv, rtol=1e-3, atol=1e-3)
    # float ties can reorder ids at equal distance; values must agree.
    match = (np.asarray(gi) == np.asarray(wi)).mean()
    assert match > 0.97, match


def test_l2topk_handles_padding_rows():
    """+inf sqnorm padding rows must never appear in the top-k."""
    q = jnp.asarray(RNG.normal(size=(4, 32)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(700, 32)).astype(np.float32))
    xsq = jnp.einsum("nd,nd->n", x, x)
    xsq = xsq.at[100:].set(jnp.inf)                 # only first 100 valid
    _, gi = ops.l2topk(q, x, xsq=xsq, k=10)
    assert np.asarray(gi).max() < 100


def test_topk_values_sorted_ascending():
    x = jnp.asarray(RNG.normal(size=(6, 512)).astype(np.float32))
    gv, _ = ops.topk(x, 16)
    assert np.all(np.diff(np.asarray(gv), axis=1) >= -1e-7)


@pytest.mark.parametrize("bh,t,hd,causal", [
    (4, 128, 64, True), (2, 100, 32, True), (3, 257, 128, False),
    (1, 31, 16, False), (8, 300, 64, True),
])
def test_flash_attention_matches_ref(bh, t, hd, causal):
    from repro.kernels.ref import flash_attention_ref
    q = jnp.asarray(RNG.normal(size=(bh, t, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(bh, t, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(bh, t, hd)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=causal)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.ref import flash_attention_ref
    q = jnp.asarray(RNG.normal(size=(2, 64, 32)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(2, 64, 32)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(2, 64, 32)).astype(np.float32)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
