"""End-to-end behaviour tests for the paper's system."""

import numpy as np

from repro.core.engine import ANNEngine
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset


def test_end_to_end_serving_pipeline():
    """Build -> load -> serve batched queries (paper Fig. 4 dataflow)."""
    from repro.launch.serve import serve_loop

    ds = VectorDataset(1200, 32, n_clusters=12, seed=3)
    eng = ANNEngine.build(ds.vectors(), num_partitions=2,
                          cfg=HNSWConfig(M=8, ef_construction=50))
    queries = ds.queries(64)
    ids, stats = serve_loop(eng, queries, batch=16, k=5, ef=24,
                            log=lambda *a: None)
    assert stats["qps"] > 0 and stats["batches"] == 4
    assert ids.shape == (64, 5)
    assert (ids >= 0).mean() > 0.99


def test_engine_recall_beats_random_baseline():
    ds = VectorDataset(1000, 24, n_clusters=10, seed=4)
    vecs = ds.vectors()
    eng = ANNEngine.build(vecs, num_partitions=2,
                          cfg=HNSWConfig(M=8, ef_construction=50))
    q = ds.queries(8)
    ids, dists = eng.search(q, k=5, ef=24)
    ids = np.asarray(ids)
    d2 = (np.einsum("nd,nd->n", vecs, vecs)[None]
          - 2 * q @ vecs.T + np.einsum("qd,qd->q", q, q)[:, None])
    gt = np.argsort(d2, 1)[:, :5]
    recall = np.mean([len(set(ids[b]) & set(gt[b])) / 5 for b in range(8)])
    assert recall > 0.8, recall


def test_engine_save_load_roundtrip(tmp_path):
    """Fig. 4 step 1-2: persist the restructured DB, reload, same results."""
    import numpy as np

    from repro.data import VectorDataset

    ds = VectorDataset(800, 24, n_clusters=8, seed=7)
    eng = ANNEngine.build(ds.vectors(), num_partitions=2,
                          cfg=HNSWConfig(M=8, ef_construction=40))
    q = ds.queries(8)
    ids0, ds0 = eng.search(q, k=5, ef=24)
    eng.save(str(tmp_path / "db"))
    eng2 = ANNEngine.load(str(tmp_path / "db"))
    ids1, ds1 = eng2.search(q, k=5, ef=24)
    np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
    np.testing.assert_allclose(np.asarray(ds0), np.asarray(ds1), rtol=1e-6)
