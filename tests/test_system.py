"""End-to-end behaviour tests for the paper's system (repro.api surface)."""

import numpy as np

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig
from repro.data import VectorDataset


def test_end_to_end_serving_pipeline():
    """Build -> load -> serve batched queries (paper Fig. 4 dataflow)."""
    from repro.launch.serve import serve_loop

    ds = VectorDataset(1200, 32, n_clusters=12, seed=3)
    svc = SearchService.build(ds.vectors(), IndexSpec(
        backend="partitioned", num_partitions=2,
        hnsw=HNSWConfig(M=8, ef_construction=50)))
    queries = ds.queries(64)
    ids, stats = serve_loop(svc, queries, batch=16, k=5, ef=24,
                            log=lambda *a: None)
    assert stats["qps"] > 0 and stats["batches"] == 4
    assert ids.shape == (64, 5)
    assert (ids >= 0).mean() > 0.99


def test_engine_recall_beats_random_baseline():
    ds = VectorDataset(1000, 24, n_clusters=10, seed=4)
    vecs = ds.vectors()
    svc = SearchService.build(vecs, IndexSpec(
        backend="partitioned", num_partitions=2,
        hnsw=HNSWConfig(M=8, ef_construction=50)))
    q = ds.queries(8)
    ids = np.asarray(svc.search(SearchRequest(queries=q, k=5, ef=24)).ids)
    d2 = (np.einsum("nd,nd->n", vecs, vecs)[None]
          - 2 * q @ vecs.T + np.einsum("qd,qd->q", q, q)[:, None])
    gt = np.argsort(d2, 1)[:, :5]
    recall = np.mean([len(set(ids[b]) & set(gt[b])) / 5 for b in range(8)])
    assert recall > 0.8, recall


def test_engine_save_load_roundtrip(tmp_path):
    """Fig. 4 step 1-2: persist the restructured DB, reload, same results."""
    ds = VectorDataset(800, 24, n_clusters=8, seed=7)
    svc = SearchService.build(ds.vectors(), IndexSpec(
        backend="partitioned", num_partitions=2,
        hnsw=HNSWConfig(M=8, ef_construction=40)))
    q = ds.queries(8)
    req = SearchRequest(queries=q, k=5, ef=24)
    resp0 = svc.search(req)
    svc.save(str(tmp_path / "db"))
    svc2 = SearchService.load(str(tmp_path / "db"))
    resp1 = svc2.search(req)
    np.testing.assert_array_equal(np.asarray(resp0.ids),
                                  np.asarray(resp1.ids))
    np.testing.assert_allclose(np.asarray(resp0.dists),
                               np.asarray(resp1.dists), rtol=1e-6)
