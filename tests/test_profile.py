"""repro.obs.profile: the continuous per-stage profiler (obs phase 2).

Acceptance bars (ISSUE 10):

  * always-on stage timings flow with tracing DISABLED (the tracer's
    disabled path hands out profiler spans) and with tracing enabled
    (Tracer._record feeds the same observe());
  * `report()` reproduces fig_obs's batch-weighted attribution — queue /
    traversal / store_read / rerank / dispatch_other — and telescopes to
    the measured e2e latency exactly;
  * disabled, the profiler hands back one shared no-op object (no
    per-span allocation), and private Tracer() instances stay unlinked
    (their disabled path still returns the shared tracer no-op);
  * REGISTRY publication: `profile_stage_ms` histograms plus the
    weighted totals the report is derived from.
"""

import threading

import pytest

from repro.obs import PROFILER, TRACER, Tracer, profile_report
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import Profiler


@pytest.fixture
def prof():
    """A private profiler wired to a private registry."""
    return Profiler(enabled=True, registry=MetricsRegistry())


@pytest.fixture
def global_prof():
    """The global PROFILER, reset before and after one test."""
    PROFILER.configure(enabled=True)
    PROFILER.reset()
    yield PROFILER
    PROFILER.configure(enabled=True)
    PROFILER.reset()


def test_span_times_and_aggregates(prof):
    with prof.span("traversal"):
        pass
    with prof.span("traversal"):
        pass
    rep = prof.report()
    assert rep["spans"]["traversal"]["count"] == 2
    assert rep["spans"]["traversal"]["total_ms"] >= 0.0


def test_disabled_span_is_shared_noop(prof):
    prof.configure(enabled=False)
    a, b = prof.span("x"), prof.span("y")
    assert a is b                       # one shared object, no allocation
    with a:
        pass
    assert prof.report()["spans"] == {}


def test_observe_feeds_registry_histogram():
    reg = MetricsRegistry()
    p = Profiler(enabled=True, registry=reg)
    p.observe("store-read", 2.5)
    p.observe("store-read", 7.5)
    snap = reg.snapshot()
    h = next(h for h in snap["histograms"]
             if h["name"] == "profile_stage_ms"
             and h["labels"]["stage"] == "store-read")
    assert h["count"] == 2 and h["sum"] == 10.0


def test_registry_collector_publishes_weighted_totals():
    reg = MetricsRegistry()
    p = Profiler(enabled=True, registry=reg)
    with p.weighted(4):
        p.observe("traversal", 10.0)
    p.request(1.0, 2.0, 3.0)
    counters = {(s["name"], s["labels"].get("stage")): s["value"]
                for s in reg.snapshot()["counters"]}
    assert counters[("profile_requests_total", None)] == 1
    assert counters[("profile_stage_weighted_ms_total", "traversal")] == 40.0


def test_reset_zeroes_report_but_not_histograms():
    reg = MetricsRegistry()
    p = Profiler(enabled=True, registry=reg)
    p.observe("hop", 1.0)
    p.request(1.0, 2.0, 3.0)
    p.reset()
    assert p.report() == {"requests": 0, "spans": {}}
    h = next(h for h in reg.snapshot()["histograms"]
             if h["labels"].get("stage") == "hop")
    assert h["count"] == 1              # Prometheus series never reset


def test_report_attribution_telescopes_exactly(prof):
    """Synthetic two-request window: queue+exec == e2e, traversal net of
    store reads, residue in dispatch_other — all exact."""
    # one batch of 2 requests: traversal 10ms (6 of it store reads),
    # rerank 2ms, each weighted by batch size 2
    with prof.weighted(2):
        prof.observe("store-read", 6.0)
        prof.observe("traversal", 10.0)
        prof.observe("rerank", 2.0)
    prof.request(queue_ms=1.0, exec_ms=15.0, e2e_ms=16.0)
    prof.request(queue_ms=3.0, exec_ms=15.0, e2e_ms=18.0)
    rep = prof.report()
    assert rep["requests"] == 2
    assert rep["e2e_ms"] == 17.0
    st = rep["stage_ms"]
    assert st["queue"] == 2.0
    assert st["traversal"] == 4.0       # (10-6) * weight 2 / 2 requests
    assert st["store_read"] == 6.0
    assert st["rerank"] == 2.0
    assert st["dispatch_other"] == 3.0  # exec 15 - traversal 10 - rerank 2
    assert rep["stage_sum_ms"] == rep["e2e_ms"]
    assert rep["sum_matches_e2e"]


def test_weighted_is_thread_local(prof):
    """A prefetcher-style background thread must not inherit the serving
    thread's batch weight."""
    done = threading.Event()

    def background():
        prof.observe("store-read", 5.0)     # no weight on this thread
        done.set()

    with prof.weighted(8):
        th = threading.Thread(target=background)
        th.start()
        done.wait(5)
        th.join()
        prof.observe("traversal", 1.0)
    prof.request(0.0, 1.0, 1.0)
    rep = prof.report()
    # traversal weighted x8; the background store-read contributed to the
    # histograms but NOT to the weighted attribution
    assert rep["stage_ms"]["traversal"] == 8.0
    assert rep["stage_ms"]["store_read"] == 0.0
    assert rep["spans"]["store-read"]["count"] == 1


def test_tracer_disabled_path_feeds_profiler(global_prof):
    """With tracing off (production default), TRACER.span() returns a
    profiler span — stage timings still flow."""
    TRACER.configure(enabled=False)
    with TRACER.span("traversal"):
        pass
    with TRACER.child_span("store-read"):
        pass
    rep = profile_report(reset=True)
    assert rep["spans"]["traversal"]["count"] == 1
    assert rep["spans"]["store-read"]["count"] == 1


def test_tracer_enabled_path_feeds_profiler(global_prof):
    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    try:
        with TRACER.span("traversal"):
            pass
    finally:
        TRACER.configure(enabled=False)
        TRACER.clear()
    assert profile_report(reset=True)["spans"]["traversal"]["count"] == 1


def test_profiler_disabled_tracer_disabled_is_shared_noop(global_prof):
    """Both tiers off: the original zero-cost contract still holds."""
    global_prof.configure(enabled=False)
    TRACER.configure(enabled=False)
    assert TRACER.span("a") is TRACER.span("b") is TRACER.child_span("c")


def test_private_tracers_stay_unlinked():
    """Only the global TRACER carries the global PROFILER; private
    instances keep the shared-noop disabled path (test isolation)."""
    t = Tracer(enabled=False)
    assert t.profiler is None
    assert t.span("a") is t.span("b")


def test_empty_report_shape(prof):
    assert prof.report() == {"requests": 0, "spans": {}}
