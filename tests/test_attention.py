"""Blockwise (flash-style) attention vs naive-softmax oracle.

This caught a real block-order transpose bug — keep the sweep broad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import apply_rope, blockwise_attn

B, T, H, HD = 2, 35, 4, 8
RNG = np.random.default_rng(0)


def _qkv(kv_heads=H):
    q = jnp.asarray(RNG.normal(size=(B, T, H, HD)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, T, kv_heads, HD)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, T, kv_heads, HD)).astype(np.float32))
    return q, k, v


def _naive(q, k, v, *, window=0, prefix=None):
    G = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q, kf) / np.sqrt(HD)
    row, col = np.arange(T)[:, None], np.arange(T)[None, :]
    mask = col <= row
    if prefix is not None:
        mask = mask | (col < prefix)
    if window:
        mask &= col > row - window
    s = jnp.where(jnp.asarray(mask)[None, None], s, -jnp.inf)
    return jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(s, -1), vf)


@pytest.mark.parametrize("bq,bk", [(16, 32), (8, 16), (512, 1024), (16, 8)])
def test_causal_matches_naive(bq, bk):
    q, k, v = _qkv()
    out = blockwise_attn(q, k, v, causal=True, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive(q, k, v)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [1, 5, 7, 40])
def test_sliding_window(window):
    q, k, v = _qkv()
    out = blockwise_attn(q, k, v, causal=True, window=window,
                         block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive(q, k, v, window=window)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("prefix", [1, 9, 35])
def test_prefix_lm(prefix):
    q, k, v = _qkv()
    out = blockwise_attn(q, k, v, causal=True, prefix_len=jnp.int32(prefix),
                         block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive(q, k, v, prefix=prefix)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kv", [1, 2])
def test_gqa(kv):
    q, k, v = _qkv(kv_heads=kv)
    out = blockwise_attn(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive(q, k, v)),
                               rtol=1e-5, atol=1e-5)


def test_causality_under_perturbation():
    q, k, v = _qkv()
    o1 = blockwise_attn(q, k, v, causal=True, block_q=16, block_k=16)
    k2, v2 = k.at[:, -1].add(10.0), v.at[:, -1].add(10.0)
    o2 = blockwise_attn(q, k2, v2, causal=True, block_q=16, block_k=16)
    leak = np.abs(np.asarray(o1 - o2))[:, :-1]
    assert leak.max() < 1e-6, "future token leaked into the past"


def test_rope_positions_shift_invariance():
    """RoPE: scores depend on relative positions only."""
    q, k, _ = _qkv()
    q1 = apply_rope(q, jnp.arange(T))
    k1 = apply_rope(k, jnp.arange(T))
    q2 = apply_rope(q, 100 + jnp.arange(T))
    k2 = apply_rope(k, 100 + jnp.arange(T))
    s1 = jnp.einsum("bthd,bshd->bhts", q1, k1)
    s2 = jnp.einsum("bthd,bshd->bhts", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-3)
