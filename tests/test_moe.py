"""MoE routing + grouped dispatch correctness."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, moe_apply, moe_init


def _dense_reference(p, x, mc: MoEConfig, act=jax.nn.silu):
    """O(S*E) reference: every token through every expert, weighted by the
    (renormalized) top-k gates — equals the dispatch path when no token is
    dropped."""
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, idx = jax.lax.top_k(probs, mc.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(mc.num_experts):
        h = jnp.einsum("sd,dgf->sgf", xf, p["w_in"][e])
        h = act(h[:, 0]) * h[:, 1]
        out_e = h @ p["w_out"][e]
        w = jnp.where(idx == e, gate, 0.0).sum(-1)
        y = y + out_e * w[:, None].astype(out_e.dtype)
    if "shared_w_in" in p:
        sh = jnp.einsum("sd,dgf->sgf", xf, p["shared_w_in"])
        sh = act(sh[:, 0]) * sh[:, 1]
        y = y + sh @ p["shared_w_out"]
    return y.reshape(B, T, d)


def test_grouped_dispatch_matches_dense_reference():
    mc = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=4.0)
    p = moe_init(jax.random.PRNGKey(0), 8, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    y, _ = moe_apply(p, x, mc)
    want = _dense_reference(p, x, mc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_shared_experts_always_contribute():
    mc = MoEConfig(num_experts=4, top_k=1, d_ff=8, n_shared=1, shared_d_ff=8,
                   capacity_factor=4.0)
    p = moe_init(jax.random.PRNGKey(0), 8, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8))
    y, _ = moe_apply(p, x, mc)
    want = _dense_reference(p, x, mc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_capacity_drops_tokens_not_correctness():
    """With capacity_factor near 0 most tokens drop: output stays finite and
    dropped tokens produce ~0 routed contribution."""
    mc = MoEConfig(num_experts=2, top_k=1, d_ff=8, capacity_factor=0.01)
    p = moe_init(jax.random.PRNGKey(0), 8, mc)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    y, _ = moe_apply(p, x, mc)
    assert np.isfinite(np.asarray(y)).all()
    # capacity is max(ceil(...), 4) per group: at most 4*2 rows survive
    nonzero = (np.abs(np.asarray(y)).sum(-1) > 1e-7).sum()
    assert nonzero <= 2 * 4 * 64  # loose sanity


def test_aux_loss_balanced_router_is_one():
    """A perfectly uniform router gives aux ~= 1 (Switch normalization)."""
    mc = MoEConfig(num_experts=8, top_k=2, d_ff=8, capacity_factor=2.0)
    p = moe_init(jax.random.PRNGKey(0), 16, mc)
    p = dict(p, router=jnp.zeros_like(p["router"]))     # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
    _, aux = moe_apply(p, x, mc, train=True)
    assert 0.9 < float(aux) < 1.1, float(aux)


def test_router_kernel_path_matches_lax():
    mc_a = MoEConfig(num_experts=8, top_k=2, d_ff=8, capacity_factor=4.0)
    mc_b = MoEConfig(num_experts=8, top_k=2, d_ff=8, capacity_factor=4.0,
                     router_use_kernel=True)
    p = moe_init(jax.random.PRNGKey(0), 8, mc_a)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    ya, _ = moe_apply(p, x, mc_a)
    yb, _ = moe_apply(p, x, mc_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=1e-4, atol=1e-5)
