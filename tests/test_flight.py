"""repro.obs.flight: the slow-query flight recorder (obs phase 2).

Acceptance bars (ISSUE 10):

  * the recorder keeps exactly the N slowest completed requests (min-heap
    semantics: a new request only displaces the fastest capture) plus
    every errored request in a bounded ring;
  * an injected slow query is captured END TO END through the real stack
    (SearchServer -> batcher -> replica pool), with its latency split and
    — when traced — its span tree in the Perfetto dump;
  * `debug_dump()` emits valid Perfetto/Chrome trace JSON whose events
    are filtered to the captured trace ids, with the capture records
    under `otherData.flight`.
"""

import json
import time

import numpy as np
import pytest

from repro.obs import FlightRecorder, TRACER
from repro.obs.metrics import MetricsRegistry


def make(capacity=4):
    return FlightRecorder(capacity=capacity, registry=MetricsRegistry())


# ---------------------------------------------------------------------------
# recorder mechanics
# ---------------------------------------------------------------------------


def test_keeps_n_slowest():
    fr = make(capacity=3)
    for seq, ms in enumerate([10.0, 50.0, 5.0, 30.0, 40.0, 1.0]):
        fr.record(seq=seq, e2e_ms=ms)
    snap = fr.snapshot()
    assert [r["e2e_ms"] for r in snap["slowest"]] == [50.0, 40.0, 30.0]
    assert snap["captured_total"] == 5          # 1.0 never made the cut
    assert snap["capacity"] == 3


def test_fast_request_rejected_cheaply():
    fr = make(capacity=2)
    assert fr.record(seq=0, e2e_ms=10.0)
    assert fr.record(seq=1, e2e_ms=20.0)
    assert not fr.record(seq=2, e2e_ms=5.0)     # below the heap floor
    assert fr.record(seq=3, e2e_ms=15.0)        # displaces the 10ms one
    assert [r["e2e_ms"] for r in fr.snapshot()["slowest"]] == [20.0, 15.0]


def test_capacity_validated():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0, registry=MetricsRegistry())


def test_errors_always_kept_newest():
    fr = make(capacity=2)
    for i in range(5):
        fr.record_error(seq=i, error=f"boom-{i}")
    snap = fr.snapshot()
    assert [e["seq"] for e in snap["errored"]] == [3, 4]
    assert snap["errors_total"] == 5


def test_record_payload_is_json_safe():
    """QueryStats-style payloads with numpy arrays/scalars must survive
    json.dumps round-trip."""
    fr = make()
    fr.record(seq=0, e2e_ms=12.0, queue_ms=2.0, exec_ms=10.0, k=10, ef=40,
              stats={"hops": np.int64(7),
                     "dist_calcs": np.array([3, 4]),
                     "nested": {"rate": np.float32(0.5)}})
    doc = json.loads(json.dumps(fr.export()))
    rec = doc["otherData"]["flight"]["slowest"][0]
    assert rec["stats"]["hops"] == 7
    assert rec["stats"]["dist_calcs"] == [3, 4]
    assert rec["k"] == 10 and rec["queue_ms"] == 2.0


def test_trace_id_kept_only_when_sampled():
    from repro.obs.trace import SpanCtx

    fr = make()
    fr.record(seq=0, e2e_ms=10.0, trace=SpanCtx(7, 1, 0, True))
    fr.record(seq=1, e2e_ms=20.0, trace=SpanCtx(8, 1, 0, False))
    fr.record(seq=2, e2e_ms=30.0, trace=None)
    by_seq = {r["seq"]: r for r in fr.snapshot()["slowest"]}
    assert by_seq[0]["trace_id"] == 7
    assert by_seq[1]["trace_id"] is None        # unsampled: no id to replay
    assert by_seq[2]["trace_id"] is None
    assert fr.trace_ids() == {7}


def test_export_without_tracer_is_valid_trace_json():
    fr = make()
    fr.record(seq=0, e2e_ms=10.0)
    doc = json.loads(json.dumps(fr.export()))
    assert doc["traceEvents"] == []
    assert doc["otherData"]["flight"]["slowest"][0]["seq"] == 0


def test_export_filters_tracer_to_captured_ids(tmp_path):
    """Only the captured requests' span trees land in the dump — the
    point of the recorder is NOT keeping everything."""
    from repro.obs import Tracer

    t = Tracer(enabled=True, sample_rate=1.0)
    ctxs = []
    for name in ("fast", "slow"):
        with t.span(name) as sp:
            ctxs.append(sp.ctx)
    fr = make()
    fr.record(seq=0, e2e_ms=99.0, trace=ctxs[1])     # capture only "slow"
    path = str(tmp_path / "flight.json")
    fr.write(path, tracer=t)
    with open(path) as f:
        doc = json.load(f)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in events} == {"slow"}
    assert doc["otherData"]["flight"]["captured_total"] == 1


def test_registry_series():
    reg = MetricsRegistry()
    fr = FlightRecorder(capacity=2, registry=reg)
    fr.record(seq=0, e2e_ms=10.0)
    fr.record(seq=1, e2e_ms=30.0)
    fr.record_error(seq=2, error="x")
    snap = reg.snapshot()
    counters = {c["name"]: c["value"] for c in snap["counters"]}
    gauges = {g["name"]: g["value"] for g in snap["gauges"]}
    assert counters["flight_captured_total"] == 2
    assert counters["flight_errors_total"] == 1
    assert gauges["flight_slowest_ms"] == 10.0  # heap floor once full


# ---------------------------------------------------------------------------
# end-to-end through the serving stack
# ---------------------------------------------------------------------------


class SlowOnce:
    """Service delegate that injects one slow search (the tail outlier
    the recorder exists to catch)."""

    def __init__(self, service, sleep_s=0.08):
        self._service = service
        self._sleep_s = sleep_s
        self._fired = False
        self.spec = service.spec
        self.backend = service.backend

    def search(self, request):
        if not self._fired:
            self._fired = True
            time.sleep(self._sleep_s)
        return self._service.search(request)


def test_injected_slow_query_captured_end_to_end(backend_zoo):
    from repro.serve import SearchServer

    svc = SlowOnce(backend_zoo.service("partitioned", "l2"), sleep_s=0.08)
    q = backend_zoo.queries()
    TRACER.configure(enabled=True, sample_rate=1.0)
    TRACER.clear()
    try:
        with SearchServer(svc, replicas=1, max_batch=1, max_wait_ms=0.1,
                          flight=4) as srv:
            futs = [srv.submit(x, k=5, ef=40) for x in q[:8]]
            [f.result(timeout=60) for f in futs]
            srv.drain()
            doc = srv.debug_dump()
            path_doc = None
    finally:
        TRACER.configure(enabled=False)
        TRACER.clear()

    flight = doc["otherData"]["flight"]
    slowest = flight["slowest"]
    assert 1 <= len(slowest) <= 4
    # the injected outlier leads, with its full latency split
    head = slowest[0]
    assert head["e2e_ms"] >= 80.0, \
        f"injected 80ms query not at the head of the captures: {slowest}"
    assert head["e2e_ms"] >= head["exec_ms"] >= 80.0 * 0.9
    assert head["trace_id"] is not None         # fully sampled run
    # its span tree is in the dump: every layer of the request path
    doc2 = json.loads(json.dumps(doc))          # valid JSON end to end
    names = {e["name"] for e in doc2["traceEvents"] if e.get("ph") == "X"}
    assert {"request", "queue", "exec", "batch", "dispatch",
            "search"} <= names
    captured_ids = {r["trace_id"] for r in slowest
                    if r["trace_id"] is not None}
    event_traces = {e["args"]["trace_id"]
                    for e in doc2["traceEvents"] if e.get("ph") == "X"}
    assert event_traces == captured_ids         # filtered, not everything


def test_debug_dump_untraced_still_has_records(backend_zoo):
    """Tracing off (production default): no span trees, but the capture
    records — latency split, params, stats — are all there."""
    from repro.serve import SearchServer

    svc = SlowOnce(backend_zoo.service("partitioned", "l2"), sleep_s=0.05)
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=0.5,
                      flight=2) as srv:
        futs = [srv.submit(x, k=5, ef=40) for x in q[:6]]
        [f.result(timeout=60) for f in futs]
        srv.drain()
        doc = srv.debug_dump()
    flight = doc["otherData"]["flight"]
    assert flight["slowest"][0]["e2e_ms"] >= 50.0
    assert flight["slowest"][0]["trace_id"] is None
    assert doc["traceEvents"] == []


def test_debug_dump_writes_file(backend_zoo, tmp_path):
    from repro.serve import SearchServer

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=0.5,
                      flight=2) as srv:
        [f.result(timeout=60) for f in
         [srv.submit(x, k=5, ef=40) for x in q[:4]]]
        srv.drain()
        path = srv.debug_dump(str(tmp_path / "flight.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["otherData"]["flight"]["captured_total"] >= 1


def test_flight_disabled(backend_zoo):
    from repro.serve import SearchServer

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=0.5,
                      flight=None) as srv:
        [f.result(timeout=60) for f in
         [srv.submit(x, k=5, ef=40) for x in q[:4]]]
        srv.drain()
        assert srv.flight is None
        with pytest.raises(RuntimeError, match="flight recorder disabled"):
            srv.debug_dump()


def test_batcher_failure_lands_in_flight_and_error_counters(backend_zoo):
    """A dispatch exception fails the futures AND records every rider in
    the flight recorder's error ring + serve_errors_total."""
    from repro.serve import SearchServer

    class Exploding:
        def __init__(self, service):
            self.spec = service.spec
            self.backend = service.backend

        def search(self, request):
            raise RuntimeError("injected engine failure")

    svc = Exploding(backend_zoo.service("partitioned", "l2"))
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=0.5,
                      flight=4) as srv:
        futs = [srv.submit(x, k=5, ef=40) for x in q[:4]]
        for f in futs:
            with pytest.raises(RuntimeError, match="injected engine"):
                f.result(timeout=60)
        srv.drain()
        snap = srv.flight.snapshot()
        rows = {r["slo"]: r for r in srv.slo_status()} \
            if srv.slo is not None else {}
    assert snap["errors_total"] == 4
    assert all("injected engine failure" in e["error"]
               for e in snap["errored"])
    assert snap["slowest"] == []               # nothing completed
