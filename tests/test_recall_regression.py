"""Seeded recall-regression floors: graph quality failures fail tier-1.

The pinned-seed dataset (conftest.small_dataset, seeds 0/1) and the pinned
build config (conftest.ZOO_CFG) make recall@10 deterministic, so a floor
turns graph-quality regressions (construction bugs, traversal bugs, merge
bugs) into red tests instead of silently drifting benchmark numbers.

Floors sit below the observed values (~0.95-0.99 at ef=40) by a small
safety margin, but above anything a broken graph could reach; the paper's
own operating point is recall 0.94 at ef=40/K=10 (SIFT1B, §6.2).
"""

import numpy as np
import pytest

# floor per backend: observed ~0.95+ on the pinned seed; a real graph
# regression drops recall far below 0.90 (a broken merge halves it).
# "uint8" is the quantized partitioned engine (IndexSpec.dtype="uint8",
# the paper's SIFT1B precision): observed 0.956 on the pinned seed — the
# quantization cost must stay a few points, not tens.
RECALL_FLOORS = {"hnsw": 0.90, "partitioned": 0.90, "csd": 0.90,
                 "uint8": 0.90}
K, EF = 10, 40
# max recall@10 the uint8 path may lose vs the float32 engine on the
# pinned seed (observed delta: ~0.04)
UINT8_MAX_RECALL_DROP = 0.08


def _recall(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    return float(np.mean(
        [len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))]))


@pytest.mark.parametrize("backend", sorted(RECALL_FLOORS))
def test_recall_floor_vs_bruteforce(backend, backend_zoo):
    ids = backend_zoo.ids(backend, "l2", k=K, ef=EF)
    r = _recall(ids, backend_zoo.data["gt"], K)
    floor = RECALL_FLOORS[backend]
    assert r >= floor, (
        f"{backend} recall@{K} regressed: {r:.3f} < floor {floor} "
        f"(pinned seed, ef={EF})")


def test_bruteforce_baseline_is_exact(backend_zoo):
    """The floor's reference point: the exact backend IS the ground truth."""
    ids = backend_zoo.ids("exact", "l2", k=K)
    assert _recall(ids, backend_zoo.data["gt"], K) == 1.0


def test_uint8_recall_within_floor_of_float32(backend_zoo):
    """The quantized path's recall cost vs the float32 engine stays
    bounded on the pinned seed (ISSUE: uint8 vs float32 floor)."""
    gt = backend_zoo.data["gt"]
    r_f32 = _recall(backend_zoo.ids("partitioned", "l2", k=K, ef=EF), gt, K)
    r_u8 = _recall(backend_zoo.ids("uint8", "l2", k=K, ef=EF), gt, K)
    assert r_u8 >= r_f32 - UINT8_MAX_RECALL_DROP, (
        f"uint8 recall@{K} fell {r_f32 - r_u8:.3f} below float32 "
        f"(allowed {UINT8_MAX_RECALL_DROP}): {r_u8:.3f} vs {r_f32:.3f}")
