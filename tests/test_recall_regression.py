"""Seeded recall-regression floors: graph quality failures fail tier-1.

The pinned-seed dataset (conftest.small_dataset, seeds 0/1) and the pinned
build config (conftest.ZOO_CFG) make recall@10 deterministic, so a floor
turns graph-quality regressions (construction bugs, traversal bugs, merge
bugs) into red tests instead of silently drifting benchmark numbers.

Floors sit below the observed values (~0.95-0.99 at ef=40) by a small
safety margin, but above anything a broken graph could reach; the paper's
own operating point is recall 0.94 at ef=40/K=10 (SIFT1B, §6.2).
"""

import numpy as np
import pytest

# floor per backend: observed ~0.95+ on the pinned seed; a real graph
# regression drops recall far below 0.90 (a broken merge halves it).
# "uint8" is the quantized partitioned engine (IndexSpec.dtype="uint8",
# the paper's SIFT1B precision): observed 0.956 on the pinned seed — the
# quantization cost must stay a few points, not tens.
RECALL_FLOORS = {"hnsw": 0.90, "partitioned": 0.90, "csd": 0.90,
                 "uint8": 0.90}
K, EF = 10, 40
# max recall@10 the uint8 path may lose vs the float32 engine on the
# pinned seed (observed delta: ~0.04)
UINT8_MAX_RECALL_DROP = 0.08
# dtype="pq" floors, RERANK ON: 8-byte code rows are deliberately lossy
# (observed stage-1 recall ~0.48), and the true-float32 stage-2 rerank is
# part of the PQ operating point — observed 0.719 on the pinned seed for
# both the in-memory and the csd engine (they are bit-identical).
PQ_RECALL_FLOORS = {"pq": 0.65, "pq_csd": 0.65}


def _recall(ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    return float(np.mean(
        [len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))]))


@pytest.mark.parametrize("backend", sorted(RECALL_FLOORS))
def test_recall_floor_vs_bruteforce(backend, backend_zoo):
    ids = backend_zoo.ids(backend, "l2", k=K, ef=EF)
    r = _recall(ids, backend_zoo.data["gt"], K)
    floor = RECALL_FLOORS[backend]
    assert r >= floor, (
        f"{backend} recall@{K} regressed: {r:.3f} < floor {floor} "
        f"(pinned seed, ef={EF})")


def test_bruteforce_baseline_is_exact(backend_zoo):
    """The floor's reference point: the exact backend IS the ground truth."""
    ids = backend_zoo.ids("exact", "l2", k=K)
    assert _recall(ids, backend_zoo.data["gt"], K) == 1.0


@pytest.mark.parametrize("backend", sorted(PQ_RECALL_FLOORS))
def test_pq_recall_floor_with_rerank(backend, backend_zoo):
    """The PQ operating point: ADC stage 1 over 8-byte code rows + exact
    float32 stage 2. Rerank ON is the contract here — without it PQ
    recall is bounded by the reconstruction error by design."""
    ids = backend_zoo.ids(backend, "l2", k=K, ef=EF, rerank=True)
    r = _recall(ids, backend_zoo.data["gt"], K)
    floor = PQ_RECALL_FLOORS[backend]
    assert r >= floor, (
        f"{backend} recall@{K} (rerank on) regressed: {r:.3f} < floor "
        f"{floor} (pinned seed, ef={EF})")


def test_pq_rerank_recovers_recall(backend_zoo):
    """Stage-2 rerank must actually recover recall lost to the 8-byte
    codes (observed: 0.48 -> 0.72 on the pinned seed); if rerank stops
    helping, the true-row table is probably being bypassed."""
    gt = backend_zoo.data["gt"]
    r_raw = _recall(backend_zoo.ids("pq", "l2", k=K, ef=EF), gt, K)
    r_rr = _recall(backend_zoo.ids("pq", "l2", k=K, ef=EF, rerank=True),
                   gt, K)
    assert r_rr >= r_raw + 0.10, (
        f"rerank recovered only {r_rr - r_raw:.3f} recall@{K} "
        f"({r_raw:.3f} -> {r_rr:.3f})")


def test_uint8_recall_within_floor_of_float32(backend_zoo):
    """The quantized path's recall cost vs the float32 engine stays
    bounded on the pinned seed (ISSUE: uint8 vs float32 floor)."""
    gt = backend_zoo.data["gt"]
    r_f32 = _recall(backend_zoo.ids("partitioned", "l2", k=K, ef=EF), gt, K)
    r_u8 = _recall(backend_zoo.ids("uint8", "l2", k=K, ef=EF), gt, K)
    assert r_u8 >= r_f32 - UINT8_MAX_RECALL_DROP, (
        f"uint8 recall@{K} fell {r_f32 - r_u8:.3f} below float32 "
        f"(allowed {UINT8_MAX_RECALL_DROP}): {r_u8:.3f} vs {r_f32:.3f}")
