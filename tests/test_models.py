"""Per-arch smoke tests (reduced configs, CPU): one train step + prefill +
decode, asserting shapes and finiteness — the assignment's smoke deliverable.
Plus prefill/decode consistency for every layer family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.data.pipeline import make_batch
from repro.models.model import decode_step, prefill_step, train_step
from repro.models.transformer import (
    compute_logits, forward, init_cache, init_params)
from repro.optim.adamw import AdamWConfig

B, T = 2, 32
OPT = AdamWConfig(total_steps=50, warmup_steps=2)


def _batch(cfg):
    b = make_batch(cfg, "train", T, B, step=0)
    return jax.tree.map(jnp.asarray, b)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    from repro.optim.adamw import adamw_init
    state = {"params": jax.tree.map(jnp.copy, params),
             "opt": adamw_init(params)}
    before = [np.asarray(x) for x in jax.tree.leaves(state["params"])]
    batch = _batch(cfg)
    state2, metrics = train_step(state, batch, cfg, OPT)  # donates state
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0
    # params changed
    delta = sum(
        float(np.abs(a - np.asarray(b)).sum())
        for a, b in zip(before, jax.tree.leaves(state2["params"])))
    assert delta > 0, f"{arch}: optimizer did not update"

    # prefill -> decode one token
    cache = init_cache(cfg, B, T + 4)
    pf = {"inputs": batch["inputs"]}
    if cfg.prefix_lm:
        pf["prefix_len"] = batch["prefix_len"]
    logits, cache = prefill_step(params, pf, cache, cfg)
    v = cfg.padded_vocab
    want_shape = (B, 1, v) if cfg.num_output_heads == 1 else (B, 1, cfg.num_output_heads, v)
    assert logits.shape == want_shape, (arch, logits.shape)
    nxt = (jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
           if cfg.num_output_heads == 1
           else batch["inputs"][:, -1:])
    tok = nxt if cfg.embed_inputs else batch["inputs"][:, -1:, :]
    logits2, cache = decode_step(params, tok, cache, jnp.int32(T), cfg)
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["h2o_danube3_4b", "deepseek_v2_lite_16b",
                                  "xlstm_350m", "jamba_v01_52b",
                                  "musicgen_large"])
def test_prefill_decode_consistency(arch):
    """Strong invariant: prefill(T) then decode(T..T+2) must equal the
    full forward over T+3 tokens at those positions — validates every
    cache type (KV full/ring, MLA compressed, conv/ssm/mlstm/slstm)."""
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    total = T + 3
    batch = make_batch(cfg, "train", total, B, step=1)
    inputs = jnp.asarray(batch["inputs"])

    hidden_full, _, _ = forward(params, cfg, inputs, mode="prefill",
                                prefix_len=batch.get("prefix_len"))
    logits_full = compute_logits(params, cfg, hidden_full)

    cache = init_cache(cfg, B, total)
    pre = inputs[:, :T] if cfg.embed_inputs else inputs[:, :T, :]
    pf = {"inputs": pre}
    if cfg.prefix_lm:
        pf["prefix_len"] = jnp.asarray(batch["prefix_len"])
    logits_p, cache = prefill_step(params, pf, cache, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(logits_full[:, T - 1]),
        rtol=2e-3, atol=2e-3)

    for t in range(T, total):
        tok = inputs[:, t : t + 1] if cfg.embed_inputs else inputs[:, t : t + 1, :]
        logits_d, cache = decode_step(params, tok, cache, jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} step {t}")


def test_swa_ring_buffer_matches_full_window():
    """Sliding-window decode with a ring buffer must equal decoding with
    a full-length cache when the context fits in the window."""
    cfg = reduced_config("h2o_danube3_4b")          # window=8 reduced
    params = init_params(jax.random.PRNGKey(2), cfg)
    total = 12
    toks = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, total)),
        jnp.int32)
    hidden, _, _ = forward(params, cfg, toks, mode="prefill")
    logits_full = compute_logits(params, cfg, hidden)
    cache = init_cache(cfg, 1, total)               # ring: S = window = 8
    _, cache = prefill_step(params, {"inputs": toks[:, :8]}, cache, cfg)
    for t in range(8, total):
        logits_d, cache = decode_step(params, toks[:, t:t+1], cache,
                                      jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"ring step {t}")


def test_loss_decreases_on_tiny_run():
    cfg = reduced_config("granite_3_8b")
    from repro.models.model import make_train_state
    state = make_train_state(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=1e-2, total_steps=30, warmup_steps=1, weight_decay=0.0)
    first = last = None
    batch = _batch(cfg)                              # overfit one batch
    for step in range(12):
        state, m = train_step(state, batch, cfg, opt)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)


def test_kv_quant_decode_close_to_exact():
    """int8 KV cache (decode cells' memory lever) stays close to bf16."""
    import dataclasses
    cfg = reduced_config("musicgen_large")
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(jax.random.PRNGKey(3), cfg)
    batch = make_batch(cfg, "train", T, B, step=5)
    inputs = jnp.asarray(batch["inputs"])
    outs = {}
    for name, c in (("exact", cfg), ("quant", cfg_q)):
        cache = init_cache(c, B, T + 2)
        _, cache = prefill_step(params, {"inputs": inputs}, cache, c)
        logits, _ = decode_step(params, inputs[:, -1:, :], cache,
                                jnp.int32(T), c)
        outs[name] = np.asarray(logits)
    err = np.abs(outs["exact"] - outs["quant"]).max()
    scale = np.abs(outs["exact"]).max()
    assert err < 0.05 * scale + 0.1, (err, scale)


def test_skip_masked_blocks_is_exact():
    """Causal block skipping (§Perf) must be bit-equivalent."""
    import dataclasses
    cfg = reduced_config("granite_3_8b")
    cfg_s = dataclasses.replace(cfg, skip_masked_blocks=True)
    params = init_params(jax.random.PRNGKey(4), cfg)
    batch = make_batch(cfg, "train", T, B, step=6)
    inputs = jnp.asarray(batch["inputs"])
    h0, _, _ = forward(params, cfg, inputs, mode="prefill")
    h1, _, _ = forward(params, cfg_s, inputs, mode="prefill")
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                               rtol=1e-5, atol=1e-5)
