"""Fault tolerance: kill-and-resume is bit-exact vs an uninterrupted run."""

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import make_batch
from repro.optim.adamw import AdamWConfig
from repro.runtime import TrainLoop, TrainLoopConfig

CFG = reduced_config("granite_3_8b")
OPT = AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=1)


def _batch_fn(step):
    return jax.tree.map(jax.numpy.asarray,
                        make_batch(CFG, "train", 16, 2, step=step))


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state["params"])]


def test_restart_is_bit_exact(tmp_path):
    steps = 8
    # uninterrupted run
    loop_a = TrainLoop(CFG, OPT, TrainLoopConfig(
        ckpt_dir=str(tmp_path / "a"), ckpt_every=4, log_every=100),
        _batch_fn, log=lambda *a: None)
    state_a, _ = loop_a.run(steps)

    # run that dies at step 4 ...
    ckpt_b = str(tmp_path / "b")
    loop_b = TrainLoop(CFG, OPT, TrainLoopConfig(
        ckpt_dir=ckpt_b, ckpt_every=4, log_every=100),
        _batch_fn, log=lambda *a: None)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        loop_b.run(steps, die_at_step=4)
    # ... and a fresh process resuming from its checkpoint
    loop_c = TrainLoop(CFG, OPT, TrainLoopConfig(
        ckpt_dir=ckpt_b, ckpt_every=4, log_every=100),
        _batch_fn, log=lambda *a: None)
    assert loop_c.step == 4, "did not resume from the committed step"
    state_c, _ = loop_c.run(steps)

    for a, c in zip(_leaves(state_a), _leaves(state_c)):
        np.testing.assert_array_equal(a, c)


def test_straggler_hook_fires(tmp_path):
    events = []
    import time

    slow = {"step": 6}

    def batch_fn(step):
        if step == slow["step"]:
            time.sleep(0.6)       # simulated slow host
        return _batch_fn(step)

    loop = TrainLoop(CFG, OPT, TrainLoopConfig(
        ckpt_dir=str(tmp_path), ckpt_every=100, log_every=100,
        straggler_factor=2.5),
        batch_fn, on_straggler=lambda s, dt, ema: events.append(s),
        log=lambda *a: None)
    # warm EMA then hit the slow step; data time counts into step wall time
    loop.run(8)
    # the hook is best-effort (timing noise on shared CI), so just check
    # the mechanism does not crash and events are plausible
    assert all(isinstance(e, int) for e in events)
