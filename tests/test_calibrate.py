"""repro.obs.calibrate: cost-model calibration from measured telemetry.

Acceptance bars (ISSUE 10):

  * `calibrate()` fits the HW parameters (cache hit rate, effective SSD
    bandwidth, per-superstep dispatch overhead) from a REGISTRY snapshot
    exactly — verified against a synthetic snapshot with known answers;
  * missing inputs degrade to None fields / unavailable terms, never
    exceptions (a snapshot from a non-csd workload is a valid input);
  * live end-to-end: csd traffic -> snapshot -> calibrate ->
    `compare_terms` yields >= 3 fitted terms with the storage term's
    calibrated prediction within 2x of measured;
  * `DispatchCost` prices the superstep overhead the prior model omits.
"""

import json

import numpy as np
import pytest

from repro.launch.costmodel import DispatchCost, dispatch_cost
from repro.obs import PROFILER, calibrate, compare_terms, load_calibration
from repro.obs.metrics import REGISTRY


def synthetic_snapshot():
    """A snapshot with hand-picked numbers: 100 queries, 80% hit rate,
    1000 demand accesses, 200 misses x 4096B from flash in 0.8s of
    store-read time, 500 hops over 125 supersteps with 2ms/superstep of
    host overhead on top of 1ms/superstep of kernel time."""
    return {
        "counters": [
            {"name": "store_cache_hits_total", "labels": {}, "value": 800},
            {"name": "store_cache_misses_total", "labels": {}, "value": 200},
            {"name": "store_bytes_read_total", "labels": {},
             "value": 200 * 4096},
            {"name": "csd_queries_total", "labels": {}, "value": 100},
            {"name": "csd_hops_total", "labels": {}, "value": 500},
            {"name": "csd_supersteps_total", "labels": {}, "value": 125},
        ],
        "gauges": [
            {"name": "csd_graph_degree", "labels": {}, "value": 24},
            {"name": "csd_vector_row_bytes", "labels": {}, "value": 512},
            {"name": "csd_block_size", "labels": {}, "value": 4096},
        ],
        "histograms": [
            {"name": "profile_stage_ms", "labels": {"stage": "store-read"},
             "buckets": [], "sum": 800.0, "count": 400},
            {"name": "profile_stage_ms",
             "labels": {"stage": "hop_superstep"},
             "buckets": [], "sum": 375.0, "count": 125},
            {"name": "profile_stage_ms", "labels": {"stage": "hop-kernel"},
             "buckets": [], "sum": 125.0, "count": 125},
        ],
    }


def test_calibrate_fits_known_answers():
    cal = calibrate(synthetic_snapshot())
    assert cal.queries == 100
    assert cal.cache_hit_rate == pytest.approx(0.8)
    # 200 misses x 4096B over 0.8s of store-read wall time
    assert cal.effective_ssd_bw == pytest.approx(200 * 4096 / 0.8)
    assert cal.blocks_per_query == pytest.approx(10.0)
    assert cal.bytes_per_query == pytest.approx(200 * 4096 / 100)
    assert cal.hops_per_query == pytest.approx(5.0)
    assert cal.supersteps_per_query == pytest.approx(1.25)
    # (375ms superstep - 125ms kernel) / 125 supersteps = 2ms each
    assert cal.dispatch_overhead_s == pytest.approx(0.002)
    assert cal.store_read_s == pytest.approx(0.8)
    assert cal.graph_degree == 24
    assert cal.vector_row_bytes == 512
    assert cal.block_size == 4096
    assert cal.source == {"store_read_spans": 400, "superstep_spans": 125}


def test_calibrate_counts_unfused_hops_as_supersteps():
    """On the unfused path each hop IS one host sync: `hop` spans stand
    in for `hop_superstep` in the dispatch fit."""
    snap = synthetic_snapshot()
    for h in snap["histograms"]:
        if h["labels"].get("stage") == "hop_superstep":
            h["labels"]["stage"] = "hop"
    cal = calibrate(snap)
    assert cal.dispatch_overhead_s == pytest.approx(0.002)


def test_calibrate_empty_snapshot_is_all_none():
    cal = calibrate({"counters": [], "gauges": [], "histograms": []})
    assert cal.queries is None
    assert cal.cache_hit_rate is None
    assert cal.effective_ssd_bw is None
    assert cal.dispatch_overhead_s is None
    d = cal.asdict()
    assert json.dumps(d)                       # JSON-safe for the dryrun


def test_compare_terms_known_answers():
    cal = calibrate(synthetic_snapshot())
    terms = compare_terms(cal)
    st = terms["storage"]
    # measured: 0.8s over 100 queries = 8ms/query; fitted reprices the
    # same misses through the fitted bandwidth -> exact by construction
    assert st["measured"] == pytest.approx(0.008)
    assert st["calibrated"] == pytest.approx(0.008)
    assert st["calibrated_rel_error"] == pytest.approx(0.0, abs=1e-6)
    assert st["unit"] == "s/query"
    fo = terms["fanout"]
    # 5 hops x degree 24 x 512B / 4096B block = 15 modeled blocks vs 10
    assert fo["modeled"] == pytest.approx(15.0)
    assert fo["measured"] == pytest.approx(10.0)
    assert fo["unit"] == "blocks/query"
    dp = terms["dispatch"]
    assert dp["modeled"] == 0.0                # the prior omits dispatch
    assert dp["measured"] == pytest.approx(0.002)
    # 1.25 supersteps/query x 2ms = 2.5ms/query of host overhead
    assert dp["dispatch_s_per_query"] == pytest.approx(0.0025)


def test_compare_terms_unavailable_without_csd_traffic():
    cal = calibrate({"counters": [], "gauges": [], "histograms": []})
    terms = compare_terms(cal)
    assert terms["storage"] == {"unavailable": True}
    assert terms["fanout"] == {"unavailable": True}
    assert terms["dispatch"] == {"unavailable": True}


def test_load_calibration_roundtrip(tmp_path):
    path = str(tmp_path / "metrics.json")
    with open(path, "w") as f:
        json.dump(synthetic_snapshot(), f)
    cal = load_calibration(path)
    assert cal.queries == 100 and cal.block_size == 4096


def test_dispatch_cost_model():
    dc = dispatch_cost(4.0, 0.002)
    assert isinstance(dc, DispatchCost)
    assert dc.dispatch_s == pytest.approx(0.008)
    assert dispatch_cost(0.0, 0.5).dispatch_s == 0.0
    with pytest.raises(ValueError):
        dispatch_cost(-1.0, 0.002)
    with pytest.raises(ValueError):
        dispatch_cost(1.0, -0.002)


# ---------------------------------------------------------------------------
# live end-to-end: csd traffic -> snapshot -> fit -> compare
# ---------------------------------------------------------------------------


def test_live_csd_calibration(backend_zoo):
    """Real csd traffic through the zoo service: the fitted storage term
    must land within 2x of measured (the slo_smoke / ISSUE acceptance
    bound), and the csd_* collector series must be present and
    consistent with the backend's own counters."""
    from repro.api import SearchRequest

    svc = backend_zoo.service("csd", "l2")
    q = backend_zoo.queries()
    PROFILER.configure(enabled=True)
    before = svc.backend._queries
    for _ in range(3):
        svc.search(SearchRequest(queries=q, k=10, ef=40))
    snap = REGISTRY.snapshot()

    uid = svc.backend.uid
    csd = {c["name"]: c["value"] for c in snap["counters"]
           if c["labels"].get("backend") == uid}
    assert csd["csd_queries_total"] == svc.backend._queries
    assert csd["csd_queries_total"] >= before + 3 * len(q)
    assert csd["csd_hops_total"] == svc.backend._hops > 0
    assert csd["search_dist_calcs_total"] == svc.backend._dist_calcs > 0
    assert csd["csd_supersteps_total"] == svc.backend._supersteps > 0
    gauges = {g["name"]: g["value"] for g in snap["gauges"]
              if g["labels"].get("backend") == uid}
    assert gauges["csd_graph_degree"] > 0
    assert gauges["csd_vector_row_bytes"] > 0
    assert gauges["csd_block_size"] > 0

    cal = calibrate(snap)
    assert cal.queries and cal.effective_ssd_bw and cal.blocks_per_query
    terms = compare_terms(cal)
    available = [k for k, t in terms.items() if not t.get("unavailable")]
    assert set(available) >= {"storage", "fanout", "dispatch"}
    st = terms["storage"]
    ratio = st["calibrated"] / st["measured"]
    assert 0.5 <= ratio <= 2.0, \
        f"calibrated storage off by {ratio:.2f}x: {st}"
