"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""

import numpy as np
import pytest

from repro.core import hnsw_graph as hg
from repro.data import clustered_vectors

ZOO_CFG = hg.HNSWConfig(M=12, ef_construction=80, seed=0)


class BackendZoo:
    """Session-cached SearchService per (backend, metric, normalized).

    One graph build is shared wherever bit-identical results are required:
    the csd store is written from the partitioned backend's own DB
    (`CSDBackend.from_partitioned`), and the distributed build is
    deterministic from the same seed — so partitioned / distributed / csd
    answer from the SAME graph. `normalized=True` builds over unit-norm
    vectors (the cosine <-> l2 parity golden); `ids()` then queries with
    unit-norm queries.
    """

    def __init__(self, dataset, tmp_path_factory):
        self.data = dataset
        self._tmp = tmp_path_factory
        self._svcs = {}
        vecs = dataset["vectors"]
        q = dataset["queries"]
        self._vectors = {False: vecs,
                         True: vecs / np.linalg.norm(vecs, axis=1,
                                                     keepdims=True)}
        self._queries = {False: q,
                         True: q / np.linalg.norm(q, axis=1, keepdims=True)}

    def service(self, backend: str, metric: str = "l2", *,
                normalized: bool = False):
        key = (backend, metric, normalized)
        if key not in self._svcs:
            self._svcs[key] = self._build(backend, metric, normalized)
        return self._svcs[key]

    def queries(self, *, normalized: bool = False) -> np.ndarray:
        return self._queries[normalized]

    def ids(self, backend: str, metric: str = "l2", *, k: int = 10,
            ef: int = 40, rerank: bool = False,
            normalized: bool = False) -> np.ndarray:
        from repro.api import SearchRequest
        svc = self.service(backend, metric, normalized=normalized)
        resp = svc.search(SearchRequest(queries=self.queries(
            normalized=normalized), k=k, ef=ef, rerank=rerank))
        return np.asarray(resp.ids)

    def _build(self, backend: str, metric: str, normalized: bool):
        import dataclasses

        from repro.api import IndexSpec, SearchService
        from repro.store.csd import CSDBackend

        vecs = self._vectors[normalized]
        if backend == "uint8":
            # the paper's SIFT1B precision: quantized partitioned engine
            spec = IndexSpec(metric=metric, backend="partitioned",
                             dtype="uint8", num_partitions=2, hnsw=ZOO_CFG,
                             keep_vectors=True)
            return SearchService.build(vecs, spec)
        if backend == "uint8_csd":
            # same quantized graph, served out-of-core (1-byte vector rows)
            part = self.service("uint8", metric, normalized=normalized)
            store = str(self._tmp.mktemp("zoo-csd-u8") / "store")
            spec = dataclasses.replace(part.spec, backend="csd",
                                       keep_vectors=False,
                                       storage_path=store, prefetch=False)
            return SearchService(
                spec, CSDBackend.from_partitioned(part.backend.pdb, spec))
        if backend == "pq":
            # product-quantized engine: M=8 byte codes per row, LUT ADC
            spec = IndexSpec(metric=metric, backend="partitioned",
                             dtype="pq", pq_m=8, num_partitions=2,
                             hnsw=ZOO_CFG, keep_vectors=True)
            return SearchService.build(vecs, spec)
        if backend == "pq_csd":
            # same PQ graph + codebooks, served out-of-core (M-byte rows);
            # `raw` supplies the true float32 rows for the rerank table
            part = self.service("pq", metric, normalized=normalized)
            store = str(self._tmp.mktemp("zoo-csd-pq") / "store")
            spec = dataclasses.replace(part.spec, backend="csd",
                                       keep_vectors=False,
                                       storage_path=store, prefetch=False)
            return SearchService(
                spec, CSDBackend.from_partitioned(part.backend.pdb, spec,
                                                  raw=part.backend.raw))
        if backend == "csd":
            # same graph as the partitioned service, restructured on "flash"
            part = self.service("partitioned", metric, normalized=normalized)
            store = str(self._tmp.mktemp("zoo-csd") / "store")
            spec = IndexSpec(metric=metric, backend="csd", num_partitions=2,
                             hnsw=ZOO_CFG, storage_path=store,
                             prefetch=False)
            return SearchService(
                spec, CSDBackend.from_partitioned(part.backend.pdb, spec))
        partitions = {"exact": 1, "hnsw": 1, "partitioned1": 1}.get(backend, 2)
        spec = IndexSpec(
            metric=metric,
            backend="partitioned" if backend == "partitioned1" else backend,
            num_partitions=partitions, hnsw=ZOO_CFG,
            keep_vectors=backend in ("hnsw", "partitioned", "partitioned1"))
        return SearchService.build(vecs, spec)


@pytest.fixture(scope="session")
def backend_zoo(small_dataset, tmp_path_factory):
    """Shared golden services for the parity matrix, recall-regression, and
    serve tests — built lazily, cached for the whole session."""
    return BackendZoo(small_dataset, tmp_path_factory)


@pytest.fixture(scope="session")
def small_dataset():
    """2k clustered vectors + queries + exact ground truth (session-cached)."""
    n, d, nq, k = 2000, 64, 16, 10
    vecs = clustered_vectors(n, d, k=24, seed=0)
    rng = np.random.default_rng(1)
    queries = vecs[rng.integers(0, n, nq)] + rng.normal(
        scale=2.0, size=(nq, d)).astype(np.float32)
    queries = queries.astype(np.float32)
    d2 = (
        np.einsum("nd,nd->n", vecs, vecs)[None]
        - 2 * queries @ vecs.T
        + np.einsum("qd,qd->q", queries, queries)[:, None]
    )
    gt = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return {"vectors": vecs, "queries": queries, "gt": gt, "k": k}


@pytest.fixture(scope="session")
def built_graph(small_dataset):
    cfg = hg.HNSWConfig(M=12, ef_construction=80, seed=0)
    g = hg.build_hnsw(small_dataset["vectors"], cfg)
    return g, cfg
