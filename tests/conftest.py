"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""

import numpy as np
import pytest

from repro.core import hnsw_graph as hg
from repro.data import clustered_vectors


@pytest.fixture(scope="session")
def small_dataset():
    """2k clustered vectors + queries + exact ground truth (session-cached)."""
    n, d, nq, k = 2000, 64, 16, 10
    vecs = clustered_vectors(n, d, k=24, seed=0)
    rng = np.random.default_rng(1)
    queries = vecs[rng.integers(0, n, nq)] + rng.normal(
        scale=2.0, size=(nq, d)).astype(np.float32)
    queries = queries.astype(np.float32)
    d2 = (
        np.einsum("nd,nd->n", vecs, vecs)[None]
        - 2 * queries @ vecs.T
        + np.einsum("qd,qd->q", queries, queries)[:, None]
    )
    gt = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return {"vectors": vecs, "queries": queries, "gt": gt, "k": k}


@pytest.fixture(scope="session")
def built_graph(small_dataset):
    cfg = hg.HNSWConfig(M=12, ef_construction=80, seed=0)
    g = hg.build_hnsw(small_dataset["vectors"], cfg)
    return g, cfg
