"""repro.obs.slo: declarative SLOs with multi-window burn-rate breaches.

Acceptance bars (ISSUE 10):

  * the SRE two-window rule, exactly: a breach needs BOTH windows over
    the burn threshold AND min_samples in the long window — brief spikes
    (short hot, long cool) and stale pain (long hot, short recovered)
    both stay quiet;
  * breach events are edge-triggered and bounded; `slo_breaches_total` /
    `slo_burn_rate` / `slo_breaching` land in the registry;
  * the serve and cluster integrations feed it from real traffic.

Time is injected (FakeClock) — no sleeps, no wall-clock flakes.
"""

import pytest

from repro.obs import SLO, SLOTracker, default_slos
from repro.obs.metrics import MetricsRegistry


class FakeClock:
    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_tracker(slos=None, **kw):
    clock = FakeClock()
    reg = MetricsRegistry()
    tr = SLOTracker(slos or default_slos(p99_ms=10.0, window_s=60.0),
                    clock=clock, registry=reg, **kw)
    return tr, clock, reg


# ---------------------------------------------------------------------------
# SLO declaration
# ---------------------------------------------------------------------------


def test_slo_kinds_validated():
    with pytest.raises(ValueError, match="unknown SLO kind"):
        SLO(name="x", kind="throughput", target=1.0)


def test_slo_without_budget_rejected():
    with pytest.raises(ValueError, match="no error budget"):
        SLO(name="x", kind="latency", target=10.0, objective=1.0)
    with pytest.raises(ValueError, match="no error budget"):
        SLO(name="x", kind="error_rate", target=0.0)


def test_budget_by_kind():
    assert SLO(name="l", kind="latency", target=10.0,
               objective=0.99).budget() == pytest.approx(0.01)
    assert SLO(name="e", kind="error_rate", target=0.05).budget() == 0.05


def test_default_slos_shape():
    slos = default_slos(p99_ms=25.0, error_rate=0.02, recall_floor=0.9)
    by_name = {s.name: s for s in slos}
    assert by_name["latency_p99"].target == 25.0
    assert by_name["error_rate"].budget() == 0.02
    assert by_name["recall_floor"].kind == "recall"
    assert "recall_floor" not in {s.name for s in default_slos()}


def test_tracker_requires_slos():
    with pytest.raises(ValueError, match="at least one"):
        SLOTracker([])


# ---------------------------------------------------------------------------
# burn-rate mechanics
# ---------------------------------------------------------------------------


def test_all_good_never_breaches():
    tr, clock, _ = make_tracker()
    for _ in range(100):
        tr.record_latency(1.0)          # all within the 10ms target
        clock.advance(0.1)
    rows = {r["slo"]: r for r in tr.evaluate()}
    assert rows["latency_p99"]["burn_long"] == 0.0
    assert not rows["latency_p99"]["breaching"]
    assert tr.breaches() == []


def test_sustained_badness_breaches_with_exact_accounting():
    tr, clock, _ = make_tracker()
    for _ in range(50):
        tr.record_latency(100.0)        # every sample misses 10ms
        clock.advance(0.1)
    rows = {r["slo"]: r for r in tr.evaluate()}
    lat = rows["latency_p99"]
    assert lat["samples"] == 50 and lat["bad"] == 50
    assert lat["bad_frac"] == 1.0
    assert lat["burn_long"] == 100.0    # 1.0 bad over a 0.01 budget
    assert lat["burn_short"] == 100.0
    assert lat["breaching"]
    # error_rate saw the same 50 requests, all successes
    err = rows["error_rate"]
    assert err["samples"] == 50 and err["bad"] == 0 and not err["breaching"]


def test_min_samples_gate():
    tr, clock, _ = make_tracker()
    for _ in range(19):                 # default min_samples = 20
        tr.record_latency(100.0)
        clock.advance(0.1)
    assert not any(r["breaching"] for r in tr.evaluate())
    tr.record_latency(100.0)
    assert any(r["breaching"] for r in tr.evaluate())


def test_short_window_vetoes_recovered_pain():
    """Long window still hot, short window fully recovered: no breach —
    the two-window rule's whole point (no alerting on stale pain)."""
    tr, clock, _ = make_tracker()
    for _ in range(40):
        tr.record_latency(100.0)        # bad burst
        clock.advance(0.1)
    # recover: short window (60/12 = 5s) fills with good samples
    for _ in range(80):
        tr.record_latency(1.0)
        clock.advance(0.1)
    rows = {r["slo"]: r for r in tr.evaluate()}
    lat = rows["latency_p99"]
    assert lat["burn_long"] > 2.0       # long window still over threshold
    assert lat["burn_short"] < 2.0      # but the pain stopped
    assert not lat["breaching"]


def test_window_pruning_forgets_old_badness():
    tr, clock, _ = make_tracker()
    for _ in range(50):
        tr.record_latency(100.0)
        clock.advance(0.1)
    clock.advance(120.0)                # everything ages out of 60s window
    for _ in range(30):
        tr.record_latency(1.0)
        clock.advance(0.1)
    lat = {r["slo"]: r for r in tr.evaluate()}["latency_p99"]
    assert lat["samples"] == 30 and lat["bad"] == 0
    assert not lat["breaching"]


def test_breach_events_edge_triggered_and_counted():
    tr, clock, reg = make_tracker()
    for _ in range(30):
        tr.record_latency(100.0)
        clock.advance(0.1)
    tr.evaluate()
    tr.evaluate()                       # still breaching: no second event
    assert len(tr.breaches()) == 1
    ev = tr.breaches()[0]
    assert ev["slo"] == "latency_p99" and ev["burn_long"] == 100.0
    # recover, then breach again -> second edge
    clock.advance(120.0)
    for _ in range(30):
        tr.record_latency(1.0)
        clock.advance(0.1)
    tr.evaluate()
    for _ in range(30):
        tr.record_latency(100.0)
        clock.advance(0.1)
    tr.evaluate()
    assert len(tr.breaches()) == 2
    counters = {s["labels"]["slo"]: s["value"]
                for s in reg.snapshot()["counters"]
                if s["name"] == "slo_breaches_total"}
    assert counters["latency_p99"] == 2


def test_record_error_burns_error_budget():
    tr, clock, _ = make_tracker(default_slos(p99_ms=10.0, error_rate=0.01,
                                             window_s=60.0))
    for _ in range(20):
        tr.record_latency(1.0)          # 20 successes
        clock.advance(0.1)
    tr.record_error(20)                 # then a failure burst
    rows = {r["slo"]: r for r in tr.evaluate()}
    err = rows["error_rate"]
    assert err["samples"] == 40 and err["bad"] == 20
    assert err["burn_long"] == pytest.approx(50.0)   # 0.5 over 0.01
    assert err["breaching"]
    assert not rows["latency_p99"]["breaching"]      # latencies were fine


def test_recall_probes_feed_recall_slo():
    slos = default_slos(p99_ms=10.0, recall_floor=0.9, window_s=60.0)
    tr, clock, _ = make_tracker(slos)
    for _ in range(25):
        tr.record_recall(0.5)           # below the 0.9 floor
        clock.advance(0.1)
    rec = {r["slo"]: r for r in tr.evaluate()}["recall_floor"]
    assert rec["bad"] == 25 and rec["breaching"]
    # good probes don't burn
    clock.advance(120.0)
    for _ in range(25):
        tr.record_recall(0.95)
        clock.advance(0.1)
    rec = {r["slo"]: r for r in tr.evaluate()}["recall_floor"]
    assert rec["bad"] == 0 and not rec["breaching"]


def test_gauges_and_sample_counters_in_registry():
    tr, clock, reg = make_tracker(labels={"router": "r1"})
    for _ in range(30):
        tr.record_latency(100.0)
        clock.advance(0.1)
    tr.evaluate()
    snap = reg.snapshot()
    gauges = {(g["name"], g["labels"].get("slo"), g["labels"].get("window")):
              g["value"] for g in snap["gauges"]}
    assert gauges[("slo_burn_rate", "latency_p99", "long")] == 100.0
    assert gauges[("slo_breaching", "latency_p99", None)] == 1.0
    counters = {(c["name"], c["labels"].get("slo")): c["value"]
                for c in snap["counters"]}
    assert counters[("slo_samples_total", "latency_p99")] == 30
    # custom labels ride along on every series
    assert all(g["labels"].get("router") == "r1" for g in snap["gauges"]
               if g["name"].startswith("slo_"))


def test_bounded_memory():
    tr, clock, _ = make_tracker(max_samples=100, max_events=4)
    for _ in range(1000):
        tr.record_latency(100.0)
    lat = {r["slo"]: r for r in tr.evaluate()}["latency_p99"]
    assert lat["samples"] <= 100        # window deque bounded
    assert len(tr.breaches()) <= 4


def test_summary_mentions_breach():
    tr, clock, _ = make_tracker()
    for _ in range(30):
        tr.record_latency(100.0)
        clock.advance(0.1)
    text = tr.summary()
    assert "BREACH" in text and "latency_p99" in text
    assert "breach events: 1" in text


# ---------------------------------------------------------------------------
# serve / cluster integration
# ---------------------------------------------------------------------------


def test_search_server_feeds_slo(backend_zoo):
    from repro.serve import SearchServer

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    slo = SLOTracker(default_slos(p99_ms=0.001),  # impossible target
                     registry=MetricsRegistry())
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=1.0,
                      slo=slo) as srv:
        futs = [srv.submit(x, k=5, ef=40) for x in q[:8]]
        [f.result(timeout=60) for f in futs]
        srv.drain()
        rows = {r["slo"]: r for r in srv.slo_status()}
    lat = rows["latency_p99"]
    assert lat["samples"] == 8 and lat["bad"] == 8
    assert rows["error_rate"]["samples"] == 8


def test_search_server_accepts_slo_list(backend_zoo):
    """Passing raw SLO objects (not a tracker) wraps them."""
    from repro.serve import SearchServer

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    with SearchServer(svc, replicas=1, max_batch=4, max_wait_ms=1.0,
                      slo=default_slos(p99_ms=1000.0)) as srv:
        [f.result(timeout=60) for f in
         [srv.submit(x, k=5, ef=40) for x in q[:4]]]
        srv.drain()
        assert isinstance(srv.slo, SLOTracker)
        rows = {r["slo"]: r for r in srv.slo_status()}
    assert rows["latency_p99"]["samples"] == 4


def test_cluster_router_per_shard_slo(backend_zoo):
    from repro.api import SearchRequest
    from repro.cluster import build_cluster

    svc = backend_zoo.service("partitioned", "l2")
    q = backend_zoo.queries()
    cluster = build_cluster(
        backend_zoo.data["vectors"], svc.spec, 2, replicas=1,
        slo=default_slos(p99_ms=0.001))     # impossible target
    try:
        for _ in range(3):
            cluster.search(SearchRequest(queries=q, k=5, ef=40))
        stats = cluster.stats()
        shards = {row["shard"]: {r["slo"]: r for r in row["slo"]}
                  for row in stats.slo}
        assert len(shards) == 2             # one tracker per shard
        for rows in shards.values():
            lat = rows["latency_p99"]
            assert lat["samples"] == 3 and lat["bad"] == 3
    finally:
        cluster.close()
