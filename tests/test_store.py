"""repro.store: block layout, crash safety, LRU accounting, out-of-core csd.

The headline acceptance test: a `csd` index over a dataset whose vector
table exceeds `cache_bytes` returns top-k *identical* to the in-memory
`partitioned` backend at the same ef/K/metric, while peak resident store
memory stays bounded by the cache capacity and the stats report real block
traffic.

`REPRO_STORE_TEST_CACHE_BYTES` (CI: 8192 — two blocks) shrinks the cache
so the eviction path is exercised on every hop.
"""

import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.api import IndexSpec, SearchRequest, SearchService
from repro.core.hnsw_graph import HNSWConfig, db_from_tables, db_to_tables
from repro.store import (
    BlockFile,
    BlockFileWriter,
    CSDBackend,
    PageCache,
    StoreFormatError,
    open_store,
    store_search,
    write_store,
)

CFG = HNSWConfig(M=12, ef_construction=80, seed=0)
BLOCK = 4096
CACHE_BYTES = max(
    int(os.environ.get("REPRO_STORE_TEST_CACHE_BYTES", 128 * 1024)), BLOCK)


# ---------------------------------------------------------------------------
# fixtures: one partitioned build, served resident and out-of-core
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def svc_partitioned(small_dataset):
    spec = IndexSpec(backend="partitioned", num_partitions=2, hnsw=CFG,
                     keep_vectors=True)
    return SearchService.build(small_dataset["vectors"], spec)


@pytest.fixture(scope="module")
def svc_csd(small_dataset, tmp_path_factory):
    store = str(tmp_path_factory.mktemp("csd") / "store")
    spec = IndexSpec(backend="csd", num_partitions=2, hnsw=CFG,
                     storage_path=store, block_size=BLOCK,
                     cache_bytes=CACHE_BYTES, prefetch=False)
    return SearchService.build(small_dataset["vectors"], spec)


# ---------------------------------------------------------------------------
# block file + manifest
# ---------------------------------------------------------------------------


def _tiny_store(path, blocks=8, block_size=BLOCK):
    """One int32 table, exactly one row per block."""
    rows = np.arange(blocks * block_size // 4,
                     dtype=np.int32).reshape(blocks, -1)
    w = BlockFileWriter(str(path), block_size)
    w.add_table("t", rows)
    w.finalize({"note": "tiny"})
    return rows


def test_blockfile_roundtrip(tmp_path):
    rows = _tiny_store(tmp_path / "s")
    bf = BlockFile(str(tmp_path / "s"))
    assert bf.num_blocks == 8
    got = np.frombuffer(bf.read_block(3), np.int32)
    np.testing.assert_array_equal(got, rows[3])
    assert list(bf.blocks_of_row("t", 3)) == [3]


def test_crash_safety_no_commit_marker(tmp_path):
    _tiny_store(tmp_path / "s")
    os.remove(tmp_path / "s" / "_COMMITTED")
    with pytest.raises(StoreFormatError, match="commit marker"):
        BlockFile(str(tmp_path / "s"))


def test_crash_safety_truncated_data(tmp_path):
    _tiny_store(tmp_path / "s")
    data = tmp_path / "s" / "blocks.bin"
    with open(data, "r+b") as f:
        f.truncate(BLOCK)            # partial write survived a "crash"
    with pytest.raises(StoreFormatError, match="data file"):
        BlockFile(str(tmp_path / "s"))


def test_rewrite_clears_stale_commit(tmp_path):
    _tiny_store(tmp_path / "s")
    # a writer that dies mid-rewrite must not leave the old marker behind
    BlockFileWriter(str(tmp_path / "s"), BLOCK)
    with pytest.raises(StoreFormatError, match="commit marker"):
        BlockFile(str(tmp_path / "s"))


# ---------------------------------------------------------------------------
# page cache: LRU eviction + counters
# ---------------------------------------------------------------------------


def test_page_cache_lru_and_counters(tmp_path):
    rows = _tiny_store(tmp_path / "s")
    cache = PageCache(BlockFile(str(tmp_path / "s")), 2 * BLOCK)
    cache.get(0)
    cache.get(1)
    cache.get(0)                       # hit, refreshes 0's recency
    cache.get(2)                       # evicts 1 (LRU), not 0
    cache.get(1)                       # miss again — 1 was evicted
    assert cache.hits == 1
    assert cache.misses == 4
    assert cache.evictions == 2
    assert cache.block_reads == 4
    assert cache.bytes_read == 4 * BLOCK
    assert cache.current_bytes == 2 * BLOCK
    assert cache.peak_bytes == 2 * BLOCK
    assert cache.hit_rate == pytest.approx(0.2)
    np.testing.assert_array_equal(np.frombuffer(cache.get(2), np.int32),
                                  rows[2])
    assert cache.hits == 2             # 2 is still resident after the last miss


def test_page_cache_rejects_capacity_below_one_block(tmp_path):
    _tiny_store(tmp_path / "s")
    with pytest.raises(ValueError, match="capacity"):
        PageCache(BlockFile(str(tmp_path / "s")), BLOCK - 1)


# ---------------------------------------------------------------------------
# Fig. 5 table serialization
# ---------------------------------------------------------------------------


def test_db_tables_roundtrip(svc_partitioned):
    db = jax.tree.map(np.asarray, svc_partitioned.backend.pdb.db)
    tables, meta = db_to_tables(db)
    back = db_from_tables(tables, meta)
    for f in db._fields:
        np.testing.assert_array_equal(getattr(db, f), getattr(back, f),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# csd backend: out-of-core parity + bounded memory (acceptance test)
# ---------------------------------------------------------------------------


def test_csd_matches_partitioned_with_bounded_memory(
        svc_partitioned, svc_csd, small_dataset):
    q = small_dataset["queries"]
    reader = svc_csd.backend.reader
    vec_table_bytes = reader.blockfile.tables["vectors"]["nbytes"]
    assert vec_table_bytes > CACHE_BYTES, (
        "scenario precondition: the vector table must not fit the cache")

    req = SearchRequest(queries=q, k=10, ef=40, with_stats=True)
    resp_p = svc_partitioned.search(req)
    resp_c = svc_csd.search(req)

    # identical top-k (ids AND distances), identical traversal counters
    np.testing.assert_array_equal(np.asarray(resp_c.ids),
                                  np.asarray(resp_p.ids))
    np.testing.assert_array_equal(np.asarray(resp_c.dists),
                                  np.asarray(resp_p.dists))
    np.testing.assert_array_equal(np.asarray(resp_c.stats.hops),
                                  np.asarray(resp_p.stats.hops))
    np.testing.assert_array_equal(np.asarray(resp_c.stats.dist_calcs),
                                  np.asarray(resp_p.stats.dist_calcs))

    # storage stats: real block traffic, plausible hit rate
    assert resp_c.stats.block_reads > 0
    assert resp_c.stats.bytes_read == resp_c.stats.block_reads * BLOCK
    assert 0.0 <= resp_c.stats.cache_hit_rate <= 1.0
    if CACHE_BYTES >= 16 * BLOCK:
        # a cache that holds a working set must actually hit; the CI
        # tiny-cache job (2 blocks) legitimately thrashes to ~0
        assert resp_c.stats.cache_hit_rate > 0.0

    # the out-of-core guarantee: resident store memory bounded by the cache
    assert reader.cache.peak_bytes <= CACHE_BYTES
    # and with a cache smaller than the data, eviction actually ran
    assert reader.cache.evictions > 0


def test_csd_rerank_matches_partitioned(svc_partitioned, svc_csd,
                                        small_dataset):
    """Stage-2 rerank from store reads == rerank from kept vectors."""
    req = SearchRequest(queries=small_dataset["queries"], k=10, ef=40,
                        rerank=True)
    resp_p = svc_partitioned.search(req)
    resp_c = svc_csd.search(req)
    np.testing.assert_array_equal(np.asarray(resp_c.ids),
                                  np.asarray(resp_p.ids))
    np.testing.assert_array_equal(np.asarray(resp_c.dists),
                                  np.asarray(resp_p.dists))


def test_csd_requires_storage_path(small_dataset):
    with pytest.raises(ValueError, match="storage_path"):
        SearchService.build(small_dataset["vectors"],
                            IndexSpec(backend="csd", hnsw=CFG))


def test_csd_save_load_points_at_block_store(svc_csd, small_dataset,
                                             tmp_path):
    idx = str(tmp_path / "idx")
    svc_csd.save(idx)
    svc2 = SearchService.load(idx)
    assert svc2.spec == svc_csd.spec
    req = SearchRequest(queries=small_dataset["queries"], k=10, ef=40)
    np.testing.assert_array_equal(np.asarray(svc2.search(req).ids),
                                  np.asarray(svc_csd.search(req).ids))
    # the versioned step holds a tag, not the data: the manifest points at
    # the block files via spec.storage_path
    step = os.path.join(idx, "step_00000000")
    step_bytes = sum(os.path.getsize(os.path.join(step, f))
                     for f in os.listdir(step))
    store_bytes = os.path.getsize(
        os.path.join(svc_csd.spec.storage_path, "blocks.bin"))
    assert step_bytes < store_bytes / 10


def test_prefetcher_overlaps_and_preserves_results(svc_csd, small_dataset):
    q = small_dataset["queries"][:8]
    base = svc_csd.search(SearchRequest(queries=q, k=10, ef=40))
    reader = open_store(svc_csd.spec.storage_path, CACHE_BYTES,
                        prefetch=True)
    try:
        p = svc_csd.backend.params(10, 40)
        ids, _, _, _, _ = store_search(reader, q, p)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(base.ids))
        reader.prefetcher.drain()
        assert reader.cache.prefetch_reads > 0
        assert reader.cache.peak_bytes <= CACHE_BYTES
    finally:
        reader.close()


# csd cosine/l2 parity vs the shared partitioned graph now lives in the
# cross-backend matrix (tests/test_parity_matrix.py); this file keeps the
# storage-specific guarantees (bounded memory, block traffic, crash safety).
