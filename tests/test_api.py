"""The unified repro.api surface: spec/backends/metrics/rerank/persistence."""

import os

import numpy as np
import pytest

from repro.api import (
    FORMAT_VERSION,
    IndexSpec,
    SearchRequest,
    SearchService,
    available_backends,
    available_metrics,
    batched_rerank,
    exact_topk_np,
)
from repro.core.hnsw_graph import HNSWConfig

CFG = HNSWConfig(M=12, ef_construction=80, seed=0)


def _recall(ids, gt, k):
    return np.mean([len(set(ids[b]) & set(gt[b])) / k for b in range(len(gt))])


@pytest.fixture(scope="module")
def svc4(small_dataset):
    spec = IndexSpec(backend="partitioned", num_partitions=4, hnsw=CFG,
                     keep_vectors=True)
    return SearchService.build(small_dataset["vectors"], spec)


def test_registries_advertise_the_contract():
    assert {"exact", "hnsw", "partitioned", "distributed"} <= set(
        available_backends())
    assert {"l2", "ip", "cosine"} <= set(available_metrics())
    with pytest.raises(ValueError, match="unknown backend"):
        SearchService.build(np.zeros((8, 4), np.float32),
                            IndexSpec(backend="nope"))
    with pytest.raises(ValueError, match="unknown metric"):
        SearchService.build(np.zeros((8, 4), np.float32),
                            IndexSpec(metric="nope"))


def test_partitioned_backend_recall(svc4, small_dataset):
    resp = svc4.search(SearchRequest(queries=small_dataset["queries"],
                                     k=10, ef=40))
    r = _recall(np.asarray(resp.ids), small_dataset["gt"], 10)
    assert r >= 0.9, f"recall {r:.3f}"


def test_exact_backend_is_exact(small_dataset):
    svc = SearchService.build(small_dataset["vectors"],
                              IndexSpec(backend="exact"))
    resp = svc.search(SearchRequest(queries=small_dataset["queries"], k=10))
    np.testing.assert_array_equal(np.asarray(resp.ids), small_dataset["gt"])


def test_with_stats_returns_per_query_counters(svc4, small_dataset):
    resp = svc4.search(SearchRequest(queries=small_dataset["queries"],
                                     k=10, ef=40, with_stats=True))
    b = len(small_dataset["queries"])
    assert np.asarray(resp.stats.dist_calcs).shape == (b,)
    assert np.asarray(resp.stats.hops).shape == (b,)
    assert (np.asarray(resp.stats.dist_calcs) > 0).all()


# -- persistence -------------------------------------------------------------


def test_save_load_roundtrip_through_spec(svc4, small_dataset, tmp_path):
    path = str(tmp_path / "idx")
    svc4.save(path)
    svc2 = SearchService.load(path)
    assert svc2.spec == svc4.spec
    req = SearchRequest(queries=small_dataset["queries"], k=10, ef=40)
    np.testing.assert_array_equal(np.asarray(svc4.search(req).ids),
                                  np.asarray(svc2.search(req).ids))
    # rerank still works after reload (vectors persisted via keep_vectors)
    req_r = SearchRequest(queries=small_dataset["queries"], k=10, ef=40,
                          rerank=True)
    np.testing.assert_array_equal(np.asarray(svc4.search(req_r).ids),
                                  np.asarray(svc2.search(req_r).ids))


def test_save_is_versioned_and_load_opens_latest(svc4, tmp_path):
    path = str(tmp_path / "idx")
    svc4.save(path)
    svc4.save(path)
    assert os.path.isdir(os.path.join(path, "step_00000000"))
    assert os.path.isdir(os.path.join(path, "step_00000001"))
    SearchService.load(path)                      # opens step 1, no error


def test_load_rejects_future_format(svc4, tmp_path):
    import json
    path = str(tmp_path / "idx")
    svc4.save(path)
    mpath = os.path.join(path, "index_manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    m["format_version"] = FORMAT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(ValueError, match="format_version"):
        SearchService.load(path)


def test_spec_json_roundtrip():
    spec = IndexSpec(metric="cosine", backend="hnsw", num_partitions=3,
                     hnsw=HNSWConfig(M=24, ef_construction=64, seed=9),
                     keep_vectors=False)
    assert IndexSpec.from_json(spec.to_json()) == spec


# -- metric registry ---------------------------------------------------------
# (cross-backend metric parity — cosine == l2-over-normalized, per backend —
# lives in the shared matrix: tests/test_parity_matrix.py)


def test_ip_rejected_on_graph_backends(small_dataset):
    """An L2-built graph does not answer MIPS correctly — the service must
    refuse rather than silently degrade."""
    for backend in ("hnsw", "partitioned", "distributed"):
        with pytest.raises(ValueError, match="not graph-safe"):
            SearchService.build(small_dataset["vectors"],
                                IndexSpec(metric="ip", backend=backend))


def test_legacy_index_without_manifest_still_loads(svc4, small_dataset,
                                                   tmp_path):
    """Pre-manifest indexes (bare step dirs) load through the fallback
    that moved from the retired ANNEngine shim into SearchService.load."""
    path = str(tmp_path / "idx")
    svc4.save(path)
    os.remove(os.path.join(path, "index_manifest.json"))
    svc = SearchService.load(path)
    req = SearchRequest(queries=small_dataset["queries"], k=10, ef=40)
    np.testing.assert_array_equal(np.asarray(svc.search(req).ids),
                                  np.asarray(svc4.search(req).ids))


def test_ip_exact_matches_ground_truth(small_dataset):
    vecs = small_dataset["vectors"]
    q = small_dataset["queries"]
    svc = SearchService.build(vecs, IndexSpec(metric="ip", backend="exact"))
    ids = np.asarray(svc.search(SearchRequest(queries=q, k=10)).ids)
    np.testing.assert_array_equal(ids, exact_topk_np("ip", vecs, q, 10))


def test_cosine_exact_matches_ground_truth(small_dataset):
    vecs = small_dataset["vectors"]
    q = small_dataset["queries"]
    svc = SearchService.build(vecs, IndexSpec(metric="cosine",
                                              backend="exact"))
    ids = np.asarray(svc.search(SearchRequest(queries=q, k=10)).ids)
    np.testing.assert_array_equal(ids, exact_topk_np("cosine", vecs, q, 10))


# -- rerank ------------------------------------------------------------------


def test_rerank_flag_matches_old_numpy_rerank(svc4, small_dataset):
    """The batched device rerank must reproduce the retired per-query
    NumPy loop (unique candidates, exact distances, smallest-id ties)."""
    q = small_dataset["queries"]
    resp = svc4.search(SearchRequest(queries=q, k=10, ef=40, rerank=True))
    ids_new = np.asarray(resp.ids)
    ds_new = np.asarray(resp.dists)

    # the retired implementation, verbatim (over the same candidate pool)
    from repro.core.partitioned import search_partitioned_candidates
    import jax.numpy as jnp
    p = svc4.backend.params(10, 40)
    cand, _, _ = search_partitioned_candidates(
        svc4.backend.pdb, jnp.asarray(q), p)
    cand = np.asarray(cand)
    vectors = svc4.backend.raw
    out_i = np.full((cand.shape[0], 10), -1, np.int32)
    out_d = np.full((cand.shape[0], 10), np.inf, np.float32)
    for b, (qq, row) in enumerate(zip(q, cand)):
        cu = np.unique(row[row >= 0])
        d = np.einsum("nd,nd->n", vectors[cu] - qq, vectors[cu] - qq)
        order = np.argsort(d, kind="stable")[:10]
        out_i[b, : len(order)] = cu[order]
        out_d[b, : len(order)] = d[order]
    np.testing.assert_array_equal(ids_new, out_i)
    # ||x||^2 - 2 x.q + ||q||^2 vs (x-q)^2: cancellation costs ~1 ulp*|x|^2
    # at SIFT magnitudes (same tolerance as test_search.py)
    np.testing.assert_allclose(ds_new, out_d, rtol=1e-3, atol=2.0)


def test_rerank_requires_kept_vectors(small_dataset):
    svc = SearchService.build(
        small_dataset["vectors"],
        IndexSpec(backend="partitioned", num_partitions=2, hnsw=CFG,
                  keep_vectors=False))
    with pytest.raises(ValueError, match="keep_vectors"):
        svc.search(SearchRequest(queries=small_dataset["queries"], k=10,
                                 ef=40, rerank=True))


def test_batched_rerank_dedups_and_pads():
    import jax.numpy as jnp
    vecs = np.eye(4, dtype=np.float32)
    sq = np.ones(4, np.float32)
    q = np.zeros((1, 4), np.float32)
    cand = np.array([[2, 2, 0, -1, -1, 1]], np.int32)
    ids, ds = batched_rerank(jnp.asarray(vecs), jnp.asarray(sq),
                             jnp.asarray(q), jnp.asarray(cand), k=5)
    ids, ds = np.asarray(ids), np.asarray(ds)
    # unique survivors 0,1,2 (equidistant -> smallest id first), then pads
    np.testing.assert_array_equal(ids[0], [0, 1, 2, -1, -1])
    assert np.isinf(ds[0, 3:]).all()
